//! `mpqd` — the quantization daemon.
//!
//! One process owns one [`EvalFleet`] and multiplexes many quantization
//! jobs onto it.  Connections arrive over a Unix domain socket speaking
//! the [`super::proto`] frame protocol; a per-connection handler thread
//! translates frames into [`Ctl`] messages over an mpsc channel, and a
//! single-threaded scheduler (the thread that called [`run`]) owns every
//! `!Send` piece — the runtime, the fleet, the pipelines — and
//! interleaves jobs one **phase step** at a time.
//!
//! Scheduling: runnable jobs are ordered by `(priority desc, least
//! recently stepped, id)`, which degenerates to FIFO round-robin between
//! equal-priority jobs — two concurrent jobs alternate phases on the
//! shared fleet.  Admission control refuses submits beyond
//! [`ServeCfg::max_jobs`] resident (queued + running) jobs.
//!
//! Durability: every job persists a state record
//! (`state_dir/job_<id>.json`, written with fsync + rename) and journals
//! its evaluation barriers to `state_dir/job_<id>.mpqj`.  A killed
//! daemon restarts, reloads the records, and re-queues anything that was
//! queued or running — the journal replays completed units bit-exactly,
//! so no finished work is re-executed.  Finished jobs keep their result
//! payload on disk (`job_<id>.result.json`); the journal is deleted only
//! after the `done` record is durable.

use crate::cli::Args;
use crate::coordinator::Pipeline;
use crate::jsonio::{self, Json};
use crate::manifest::Manifest;
use crate::pool::{EvalFleet, FaultPlan, WireConn, WireFaults, WireStats};
use crate::runtime::Runtime;
use crate::store::{self, RunJournal, StoreStats};
use crate::telemetry::{FleetTelemetry, Snapshot, StoreCounters};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use super::job::{JobPolicy, JobRun};
use super::proto::{self, msg};

/// Daemon configuration (CLI: `mpq serve`).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    /// artifacts directory (manifest + model binaries + datasets)
    pub dir: PathBuf,
    /// Unix socket path; a stale file is replaced on startup, but a
    /// socket with a live listener behind it refuses the start
    pub socket: PathBuf,
    /// job records, journals and result payloads live here
    pub state_dir: PathBuf,
    /// evaluation-fleet width (min 1)
    pub workers: usize,
    /// idle models kept warm on the fleet after their last job detaches
    pub max_idle: usize,
    /// admission cap: max queued + running jobs
    pub max_jobs: usize,
    /// deterministic fault injection for job journals (`crash@PHASE:N`)
    /// and, via the wire clauses (`wdrop@…`, `wseed:…`), the daemon's
    /// reply control plane
    pub fault_plan: Option<String>,
    /// start with the scheduler held (jobs queue until `Release`) — lets
    /// tests stage several submissions before any work begins
    pub hold: bool,
    /// per-connection socket I/O timeout in ms, applied symmetrically to
    /// daemon reads/writes and (through [`super::client::Client`]) the
    /// client side.  Bounds a *mid-frame* stall, never client think-time:
    /// the connection loop idles on a peek, so a quiet-but-healthy client
    /// is never dropped.  `0` disables (blocking I/O).
    pub io_timeout_ms: u64,
}

impl ServeCfg {
    /// `mpq serve --socket PATH [--artifacts DIR] [--state-dir DIR]
    /// [--workers N] [--max-idle N] [--max-jobs N] [--fault-plan SPEC]
    /// [--hold] [--io-timeout-ms MS]`
    pub fn from_args(args: &Args) -> Result<Self> {
        let dir: PathBuf = args.opt_str("artifacts", "artifacts").into();
        let state_dir = match args.opt("state-dir") {
            Some(s) => s.into(),
            None => dir.join("mpqd"),
        };
        let socket = match args.opt("socket") {
            Some(s) => s.into(),
            None => dir.join("mpqd.sock"),
        };
        Ok(Self {
            dir,
            socket,
            state_dir,
            workers: args.opt_workers()?,
            max_idle: args.opt_usize("max-idle", 2)?,
            max_jobs: args.opt_usize("max-jobs", 4)?,
            fault_plan: args.opt("fault-plan").map(String::from),
            hold: args.flag("hold"),
            io_timeout_ms: args.opt_usize("io-timeout-ms", DEFAULT_IO_TIMEOUT_MS as usize)? as u64,
        })
    }

    /// The connection I/O timeout as a `set_read_timeout`-shaped option.
    pub fn io_timeout(&self) -> Option<std::time::Duration> {
        io_timeout_opt(self.io_timeout_ms)
    }
}

/// Default per-connection I/O timeout (ms) — both planes, both sides.
pub const DEFAULT_IO_TIMEOUT_MS: u64 = 2000;

/// Wire-fault lanes for daemon connections: connection `n` maps to fault
/// lane `n % CONN_LANES`, so a `wseed` schedule covers early connections
/// distinctly and then cycles.
const CONN_LANES: usize = 8;

/// `0` means "no timeout" on both `set_read_timeout` and
/// `set_write_timeout`, which take `None` for that.
pub fn io_timeout_opt(ms: u64) -> Option<std::time::Duration> {
    (ms > 0).then(|| std::time::Duration::from_millis(ms))
}

/// Control messages from connection handlers to the scheduler.  Replies
/// travel back over per-request channels so handlers never touch `!Send`
/// daemon state.
enum Ctl {
    Submit {
        model: String,
        policy: JobPolicy,
        /// client-chosen idempotency key: a resubmit bearing the key of an
        /// already-admitted job returns that job's id instead of admitting
        /// a duplicate, so retry-after-timeout can never double-execute
        idem: Option<String>,
        reply: Sender<Result<u64, String>>,
    },
    Status { reply: Sender<Json> },
    Cancel { job: u64, reply: Sender<Result<(), String>> },
    Subscribe { job: u64, tx: Sender<Vec<u8>>, reply: Sender<Result<(), String>> },
    Release,
    Shutdown,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    fn label(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

struct Job {
    id: u64,
    model: String,
    policy: JobPolicy,
    /// client idempotency key (persisted; survives restart)
    idem: Option<String>,
    state: JobState,
    /// wall clock of the job's first start — the `deadline_ms` anchor.
    /// Not persisted: a restarted daemon restarts the clock, which only
    /// ever grants a resumed job *more* time.
    started: Option<Instant>,
    run: Option<JobRun>,
    journal: Option<Rc<RunJournal>>,
    /// per-job durability counters (shared with the journal + pipeline)
    stats: Rc<StoreStats>,
    result: Option<Json>,
    error: Option<String>,
    /// progress subscribers; encoded frames fan out over these
    subs: Rc<RefCell<Vec<Sender<Vec<u8>>>>>,
    /// scheduler clock of this job's most recent step (round-robin key)
    last_step: u64,
}

impl Job {
    fn new(id: u64, model: String, policy: JobPolicy) -> Self {
        Self {
            id,
            model,
            policy,
            idem: None,
            state: JobState::Queued,
            started: None,
            run: None,
            journal: None,
            stats: Rc::new(StoreStats::default()),
            result: None,
            error: None,
            subs: Rc::new(RefCell::new(Vec::new())),
            last_step: 0,
        }
    }
}

struct Daemon {
    cfg: ServeCfg,
    manifest: Manifest,
    rt: Rc<Runtime>,
    fleet: Rc<EvalFleet>,
    jobs: BTreeMap<u64, Job>,
    /// idempotency key → job id (rebuilt from persisted records on start)
    idem: HashMap<String, u64>,
    /// serve-plane wire telemetry (sheds, deadline cancels, injected
    /// reply-path faults); connection handlers share it
    wire_stats: Arc<WireStats>,
    next_id: u64,
    held: bool,
    /// `"<id>:<phase>"` per executed step, served by `Status` — the
    /// interleaving tests read the schedule from here
    sched_log: Vec<String>,
    step_counter: u64,
}

/// Run the daemon on the calling thread until a `Shutdown` message
/// arrives.  Binds `cfg.socket`, restores persisted jobs from
/// `cfg.state_dir` (queued/running records resume automatically), and
/// on shutdown parks running jobs back to `queued` (fsynced) so the next
/// start continues them.
pub fn run(cfg: ServeCfg) -> Result<()> {
    std::fs::create_dir_all(&cfg.state_dir)
        .with_context(|| format!("creating {}", cfg.state_dir.display()))?;
    let manifest = Manifest::load(&cfg.dir)?;
    let rt = Rc::new(Runtime::for_manifest(&manifest)?);
    let fleet = EvalFleet::new(&cfg.dir, cfg.workers.max(1))?;
    fleet.set_max_idle(cfg.max_idle);
    let (jobs, next_id) = load_jobs(&cfg.state_dir)?;
    let idem: HashMap<String, u64> = jobs
        .values()
        .filter_map(|j| j.idem.clone().map(|k| (k, j.id)))
        .collect();

    // The daemon's own wire-fault seam comes ONLY from the explicit
    // `--fault-plan` (never `MPQ_FAULT_PLAN`): the env var targets the
    // fleet, and a chaos CI run must not silently corrupt the daemon's
    // replies unless a test asked for exactly that.
    let wire_stats = Arc::new(WireStats::default());
    let wire_faults = match &cfg.fault_plan {
        Some(spec) => WireFaults::new(&FaultPlan::parse(spec)?, CONN_LANES, wire_stats.clone()),
        None => None,
    };

    claim_socket(&cfg.socket, cfg.io_timeout())?;
    let listener = UnixListener::bind(&cfg.socket)
        .with_context(|| format!("binding {}", cfg.socket.display()))?;
    let (ctl_tx, ctl_rx): (Sender<Ctl>, Receiver<Ctl>) = channel();
    let stop = Arc::new(AtomicBool::new(false));
    let accept = {
        let stop = stop.clone();
        let ctl = ctl_tx;
        let io = cfg.io_timeout();
        let wire_faults = wire_faults.clone();
        let wire_stats = wire_stats.clone();
        thread::spawn(move || {
            let mut conn_seq = 0usize;
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { break };
                // symmetric I/O deadlines: a peer stalling mid-frame (or
                // never draining its socket) times the connection out
                // instead of wedging its handler thread forever
                let _ = stream.set_read_timeout(io);
                let _ = stream.set_write_timeout(io);
                let ctl = ctl.clone();
                let conn = WireConn::new(wire_faults.clone(), conn_seq % CONN_LANES);
                let stats = wire_stats.clone();
                conn_seq += 1;
                thread::spawn(move || serve_conn(stream, ctl, conn, stats));
            }
        })
    };

    let socket = cfg.socket.clone();
    let held = cfg.hold;
    let mut d = Daemon {
        cfg,
        manifest,
        rt,
        fleet,
        jobs,
        idem,
        wire_stats,
        next_id,
        held,
        sched_log: Vec::new(),
        step_counter: 0,
    };

    let mut shutdown = false;
    while !shutdown {
        // absorb every pending control message first (cheap), then either
        // run one phase step or block for the next message
        while let Ok(m) = ctl_rx.try_recv() {
            if d.handle(m) {
                shutdown = true;
                break;
            }
        }
        if shutdown {
            break;
        }
        let next = if d.held { None } else { d.pick() };
        match next {
            Some(id) => d.step_one(id),
            None => match ctl_rx.recv() {
                Ok(m) => shutdown = d.handle(m),
                Err(_) => shutdown = true,
            },
        }
    }

    d.park_running();
    stop.store(true, Ordering::SeqCst);
    let _ = UnixStream::connect(&socket); // unblock the accept loop
    let _ = accept.join();
    let _ = std::fs::remove_file(&socket);
    Ok(())
}

impl Daemon {
    /// Process one control message; `true` means shut down.
    fn handle(&mut self, m: Ctl) -> bool {
        match m {
            Ctl::Submit { model, policy, idem, reply } => {
                let r = self.admit(model, policy, idem).map_err(|e| format!("{e:#}"));
                let _ = reply.send(r);
            }
            Ctl::Status { reply } => {
                self.prune_subs();
                let _ = reply.send(self.status_json());
            }
            Ctl::Cancel { job, reply } => {
                let r = self.cancel(job).map_err(|e| format!("{e:#}"));
                let _ = reply.send(r);
            }
            Ctl::Subscribe { job, tx, reply } => self.subscribe(job, tx, reply),
            Ctl::Release => self.held = false,
            Ctl::Shutdown => return true,
        }
        false
    }

    fn admit(&mut self, model: String, policy: JobPolicy, idem: Option<String>) -> Result<u64> {
        let resident = self
            .jobs
            .values()
            .filter(|j| matches!(j.state, JobState::Queued | JobState::Running))
            .count();
        // Idempotency first — before the admission cap: a retried submit
        // of a job that is already resident (or already finished) must
        // return its id, never a duplicate and never a shed.  The durable
        // result, if any, is then fetched by id; the job is NOT re-run.
        // One exception re-queues: a **failed** job resubmitted under its
        // key is revived in place — same id, same kept journal (completed
        // barriers replay), the *new* policy applies (e.g. a longer
        // `deadline_ms` after a deadline cancel) and the deadline clock
        // restarts.  Revival takes a residency slot, so it respects the cap.
        if let Some(key) = &idem {
            if let Some(&id) = self.idem.get(key) {
                // a known key means the client resent after losing a reply
                self.wire_stats.retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let failed = self.jobs.get(&id).is_some_and(|j| j.state == JobState::Failed);
                if failed {
                    if resident >= self.cfg.max_jobs {
                        bail!(
                            "admission refused: {resident} resident jobs at the max_jobs={} cap",
                            self.cfg.max_jobs
                        );
                    }
                    let j = self.jobs.get_mut(&id).unwrap();
                    j.state = JobState::Queued;
                    j.error = None;
                    j.started = None;
                    j.policy = policy;
                    self.persist(id)?;
                }
                return Ok(id);
            }
        }
        if resident >= self.cfg.max_jobs {
            bail!(
                "admission refused: {resident} resident jobs at the max_jobs={} cap",
                self.cfg.max_jobs
            );
        }
        if !self.manifest.models.iter().any(|m| m.name == model) {
            bail!("unknown model '{model}'");
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut job = Job::new(id, model, policy);
        job.idem = idem.clone();
        self.jobs.insert(id, job);
        if let Some(key) = idem {
            self.idem.insert(key, id);
        }
        self.persist(id)?;
        Ok(id)
    }

    fn cancel(&mut self, id: u64) -> Result<()> {
        let journal_path = {
            let Some(j) = self.jobs.get_mut(&id) else {
                bail!("no such job {id}")
            };
            if !matches!(j.state, JobState::Queued | JobState::Running) {
                bail!("job {id} is already {}", j.state.label());
            }
            j.state = JobState::Cancelled;
            j.run = None; // drops the pipeline → detaches the model
            let p = j.journal.as_ref().map(|r| r.path().to_path_buf());
            j.journal = None;
            p
        };
        self.persist(id)?;
        if let Some(p) = journal_path {
            let _ = std::fs::remove_file(p);
        }
        self.broadcast(
            id,
            encode_or_err(
                msg::EVENT,
                id,
                &Json::Obj(vec![("cancelled".into(), Json::Bool(true))]),
            ),
        );
        self.jobs.get_mut(&id).unwrap().subs.borrow_mut().clear();
        Ok(())
    }

    fn subscribe(&mut self, id: u64, tx: Sender<Vec<u8>>, reply: Sender<Result<(), String>>) {
        let Some(state) = self.jobs.get(&id).map(|j| j.state) else {
            let _ = reply.send(Err(format!("no such job {id}")));
            return;
        };
        let _ = reply.send(Ok(()));
        match state {
            JobState::Done => {
                if let Some(payload) = self.result_payload(id) {
                    let _ = tx.send(encode_or_err(msg::RESULT, id, &payload));
                }
            }
            JobState::Failed => {
                let err = self.jobs[&id].error.clone().unwrap_or_default();
                let _ = tx.send(encode_or_err(
                    msg::ERR,
                    id,
                    &Json::Obj(vec![("error".into(), Json::Str(err))]),
                ));
            }
            JobState::Cancelled => {
                let _ = tx.send(encode_or_err(
                    msg::EVENT,
                    id,
                    &Json::Obj(vec![("cancelled".into(), Json::Bool(true))]),
                ));
            }
            JobState::Queued | JobState::Running => {
                self.jobs[&id].subs.borrow_mut().push(tx);
            }
        }
    }

    /// Next runnable job: highest priority first, then least recently
    /// stepped (round-robin), then id (FIFO).
    fn pick(&self) -> Option<u64> {
        self.jobs
            .values()
            .filter(|j| matches!(j.state, JobState::Queued | JobState::Running))
            .min_by_key(|j| (std::cmp::Reverse(j.policy.priority), j.last_step, j.id))
            .map(|j| j.id)
    }

    /// Run one phase of one job (starting it first if queued).  The
    /// per-job `deadline_ms` is enforced here, at phase granularity: an
    /// expired job is failed *before* paying for another phase.  `fail`
    /// keeps the journal, so the cancel is graceful — completed barriers
    /// replay on a resubmit with a longer deadline.
    fn step_one(&mut self, id: u64) {
        if self.jobs[&id].run.is_none() {
            if let Err(e) = self.start(id) {
                self.fail(id, &format!("{e:#}"));
                return;
            }
        }
        if let (Some(deadline), Some(started)) =
            (self.jobs[&id].policy.deadline_ms, self.jobs[&id].started)
        {
            let elapsed = started.elapsed().as_millis() as u64;
            if elapsed > deadline {
                self.wire_stats.deadline_cancels.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.fail(
                    id,
                    &format!("deadline exceeded: job ran {elapsed}ms, deadline_ms={deadline}"),
                );
                return;
            }
        }
        let phase = self.jobs[&id].run.as_ref().unwrap().phase();
        self.step_counter += 1;
        let clock = self.step_counter;
        self.sched_log.push(format!("{id}:{}", phase.label()));
        self.broadcast(
            id,
            encode_or_err(
                msg::EVENT,
                id,
                &Json::Obj(vec![("phase".into(), Json::Str(phase.label().into()))]),
            ),
        );
        let res = {
            let j = self.jobs.get_mut(&id).unwrap();
            j.last_step = clock;
            j.run.as_mut().unwrap().step()
        };
        match res {
            Ok(_) => {
                if self.jobs[&id].run.as_ref().unwrap().done() {
                    self.finish(id);
                }
            }
            Err(e) => self.fail(id, &format!("{e:#}")),
        }
    }

    /// Open the job's journal + pipeline and attach it to the fleet.
    fn start(&mut self, id: u64) -> Result<()> {
        let (model, policy, subs) = {
            let j = &self.jobs[&id];
            (j.model.clone(), j.policy.clone(), j.subs.clone())
        };
        let stats = Rc::new(StoreStats::default());
        let jpath = self.cfg.state_dir.join(format!("job_{id}.mpqj"));
        let mut journal = RunJournal::open(&jpath, true, stats.clone())?;
        if let Some(spec) = &self.cfg.fault_plan {
            journal = journal.with_crash_barriers(FaultPlan::parse(spec)?.crash_barriers());
        }
        let journal = Rc::new(journal);
        journal.set_notifier(move |n, kind| {
            let bytes = encode_or_err(
                msg::EVENT,
                id,
                &Json::Obj(vec![
                    ("barrier".into(), Json::Num(n as f64)),
                    ("kind".into(), Json::Num(kind as f64)),
                ]),
            );
            subs.borrow_mut().retain(|tx| tx.send(bytes.clone()).is_ok());
        });
        let mut pipe = Pipeline::open_with(self.rt.clone(), &self.manifest, &model)?;
        pipe.set_journal(Some(journal.clone()));
        pipe.attach_fleet(&self.fleet)?;
        {
            let j = self.jobs.get_mut(&id).unwrap();
            j.stats = stats;
            j.journal = Some(journal.clone());
            j.run = Some(JobRun::new(model, pipe, Some(journal), policy));
            j.state = JobState::Running;
            if j.started.is_none() {
                j.started = Some(Instant::now());
            }
        }
        self.persist(id)
    }

    fn finish(&mut self, id: u64) {
        let result = {
            let j = &self.jobs[&id];
            match j.run.as_ref().expect("finish on a running job").result() {
                Ok(r) => r,
                Err(e) => return self.fail(id, &format!("{e:#}")),
            }
        };
        let rpath = self.cfg.state_dir.join(format!("job_{id}.result.json"));
        if let Err(e) = store::atomic_write(&rpath, result.to_string().as_bytes()) {
            return self.fail(id, &format!("persisting result: {e:#}"));
        }
        let journal_path = {
            let j = self.jobs.get_mut(&id).unwrap();
            j.state = JobState::Done;
            j.result = Some(result);
            j.run = None; // detach the model (fleet may keep it warm)
            let p = j.journal.as_ref().map(|r| r.path().to_path_buf());
            j.journal = None;
            p
        };
        if let Err(e) = self.persist(id) {
            eprintln!("[mpqd] warning: persisting job {id} state: {e:#}");
        }
        // only after the `done` record is durable may the journal go
        if let Some(p) = journal_path {
            let _ = std::fs::remove_file(p);
        }
        if let Some(payload) = self.result_payload(id) {
            self.broadcast(id, encode_or_err(msg::RESULT, id, &payload));
        }
        self.jobs.get_mut(&id).unwrap().subs.borrow_mut().clear();
    }

    /// Fail a job.  Its journal file is deliberately kept: completed
    /// barriers replay on a future resubmission.
    fn fail(&mut self, id: u64, err: &str) {
        {
            let j = self.jobs.get_mut(&id).unwrap();
            j.state = JobState::Failed;
            j.error = Some(err.to_string());
            j.run = None;
            j.journal = None;
        }
        if let Err(e) = self.persist(id) {
            eprintln!("[mpqd] warning: persisting job {id} state: {e:#}");
        }
        self.broadcast(
            id,
            encode_or_err(
                msg::ERR,
                id,
                &Json::Obj(vec![("error".into(), Json::Str(err.to_string()))]),
            ),
        );
        self.jobs.get_mut(&id).unwrap().subs.borrow_mut().clear();
    }

    /// Shutdown path: running jobs go back to `queued` (fsynced record,
    /// journal kept) so the next daemon start resumes them.
    fn park_running(&mut self) {
        let ids: Vec<u64> = self.jobs.keys().copied().collect();
        for id in ids {
            let parked = {
                let j = self.jobs.get_mut(&id).unwrap();
                if j.state == JobState::Running {
                    j.state = JobState::Queued;
                    j.run = None;
                    j.journal = None;
                    true
                } else {
                    false
                }
            };
            if parked {
                if let Err(e) = self.persist(id) {
                    eprintln!("[mpqd] warning: parking job {id}: {e:#}");
                }
            }
        }
    }

    /// Fan one encoded frame out to a job's subscribers, pruning every
    /// channel whose receiving connection is gone.
    fn broadcast(&self, id: u64, bytes: Vec<u8>) {
        if let Some(j) = self.jobs.get(&id) {
            j.subs.borrow_mut().retain(|tx| tx.send(bytes.clone()).is_ok());
        }
    }

    /// Reap subscribers whose connection is gone without waiting for the
    /// next event: a zero-length probe goes down each channel.  A live
    /// forwarding loop peeks its socket and keeps going; one whose peer
    /// hung up exits, dropping its receiver, so the *next* probe's send
    /// errors and the channel is pruned.  Detection is two-phase, but a
    /// disconnected `watch` client can no longer park its channel and
    /// queued frames on a job for the job's lifetime.
    fn prune_subs(&self) {
        for j in self.jobs.values() {
            j.subs.borrow_mut().retain(|tx| tx.send(Vec::new()).is_ok());
        }
    }

    fn result_payload(&self, id: u64) -> Option<Json> {
        let j = self.jobs.get(&id)?;
        let result = j.result.clone()?;
        Some(Json::Obj(vec![
            ("job".into(), Json::Num(id as f64)),
            ("result".into(), result),
            (
                "durability".into(),
                Json::Obj(vec![
                    ("appended".into(), Json::Num(j.stats.journal_appended.get() as f64)),
                    ("replayed".into(), Json::Num(j.stats.journal_replayed.get() as f64)),
                    ("skips".into(), Json::Num(j.stats.journal_skips.get() as f64)),
                ]),
            ),
        ]))
    }

    /// The `Status` reply: job table, schedule log, and one consolidated
    /// telemetry snapshot (fleet counters + summed per-job durability).
    fn status_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .jobs
            .values()
            .map(|j| {
                let phase = match &j.run {
                    Some(r) => r.phase().label(),
                    None => j.state.label(),
                };
                Json::Obj(vec![
                    ("id".into(), Json::Num(j.id as f64)),
                    ("model".into(), Json::Str(j.model.clone())),
                    ("state".into(), Json::Str(j.state.label().into())),
                    ("phase".into(), Json::Str(phase.into())),
                    ("priority".into(), Json::Num(j.policy.priority as f64)),
                    ("subscribers".into(), Json::Num(j.subs.borrow().len() as f64)),
                    (
                        "journal".into(),
                        Json::Obj(vec![
                            ("appended".into(), Json::Num(j.stats.journal_appended.get() as f64)),
                            ("replayed".into(), Json::Num(j.stats.journal_replayed.get() as f64)),
                            ("skips".into(), Json::Num(j.stats.journal_skips.get() as f64)),
                        ]),
                    ),
                ])
            })
            .collect();
        let mut store_total = StoreCounters::default();
        for j in self.jobs.values() {
            let c = StoreCounters::from_stats(&j.stats);
            store_total.journal_appended += c.journal_appended;
            store_total.journal_replayed += c.journal_replayed;
            store_total.journal_skips += c.journal_skips;
            store_total.journal_truncations += c.journal_truncations;
            store_total.cache_corrupt_misses += c.cache_corrupt_misses;
            store_total.files_quarantined += c.files_quarantined;
        }
        // one consolidated wire view: the fleet's socket plane plus the
        // daemon's own (sheds, deadline cancels, reply-path injections)
        let mut wire = self.fleet.wire_counters();
        wire.add(&self.wire_stats.counters());
        let snap = Snapshot {
            sens_cache: (0, 0),
            ref_cache: (0, 0),
            store: store_total,
            fleet: Some(FleetTelemetry::collect(&self.fleet)),
            wire,
        };
        Json::Obj(vec![
            ("jobs".into(), Json::Arr(jobs)),
            ("held".into(), Json::Bool(self.held)),
            (
                "warm_models".into(),
                Json::Arr(self.fleet.warm_models().into_iter().map(Json::Str).collect()),
            ),
            (
                "sched_log".into(),
                Json::Arr(self.sched_log.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            ("telemetry".into(), snap.to_json()),
        ])
    }

    /// Durably record one job's state (`job_<id>.json`, fsync + rename).
    fn persist(&self, id: u64) -> Result<()> {
        let j = &self.jobs[&id];
        let obj = Json::Obj(vec![
            ("id".into(), Json::Num(j.id as f64)),
            ("model".into(), Json::Str(j.model.clone())),
            ("state".into(), Json::Str(j.state.label().into())),
            ("policy".into(), j.policy.to_json()),
            (
                "idem".into(),
                match &j.idem {
                    Some(k) => Json::Str(k.clone()),
                    None => Json::Null,
                },
            ),
            (
                "error".into(),
                match &j.error {
                    Some(e) => Json::Str(e.clone()),
                    None => Json::Null,
                },
            ),
        ]);
        store::atomic_write(
            self.cfg.state_dir.join(format!("job_{id}.json")),
            obj.to_string().as_bytes(),
        )
    }
}

/// Claim the socket path before binding.  A leftover file is probed, not
/// blindly unlinked: if anything accepts a connection there — a live
/// `mpqd` (answers the handshake) or any other listener — starting a
/// second daemon would silently strand the first one's clients, so we
/// refuse.  Only a definitively dead socket — connect fails with
/// `ECONNREFUSED` — is stale and safe to remove; ambiguous probe errors
/// also refuse, since a saturated healthy daemon must not lose its socket.
/// The probe's read deadline is the configured `--io-timeout-ms`, so a
/// chaos-tier daemon with a tight timeout also probes tightly.
fn claim_socket(path: &Path, io: Option<std::time::Duration>) -> Result<()> {
    if !path.exists() {
        return Ok(());
    }
    match UnixStream::connect(path) {
        Ok(mut peer) => {
            let _ = peer.set_read_timeout(io);
            if proto::handshake(&mut peer).is_ok() {
                bail!(
                    "a live mpqd already serves {} — refusing to start a second \
                     daemon on the same socket (shut it down first, or pick \
                     another --socket)",
                    path.display()
                );
            }
            bail!(
                "{} has a live listener that does not speak the mpqd protocol — \
                 refusing to unlink it",
                path.display()
            );
        }
        // ECONNREFUSED is the one definitive dead-listener signal: the
        // file exists but no process holds it.  Anything else (EAGAIN from
        // a saturated but healthy daemon's full backlog, EACCES, …) is not
        // proof of staleness, so refuse rather than steal the socket.
        Err(e) if e.kind() == std::io::ErrorKind::ConnectionRefused => {
            std::fs::remove_file(path)
                .with_context(|| format!("removing stale socket {}", path.display()))
        }
        // the file vanished between exists() and connect(): nothing to claim
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => bail!(
            "probing {} failed with '{e}' — cannot tell whether a live mpqd \
             holds it, refusing to unlink (remove the socket manually if the \
             daemon is known dead)",
            path.display()
        ),
    }
}

/// Encode a fan-out frame.  An oversize payload degrades to a tiny `ERR`
/// frame naming the kind, so subscribers receive a decodable error
/// instead of a frame their `recv` would reject at the cap.
fn encode_or_err(kind: u16, id: u64, payload: &Json) -> Vec<u8> {
    proto::encode(kind, id, payload).unwrap_or_else(|e| {
        proto::encode(
            msg::ERR,
            id,
            &Json::Obj(vec![("error".into(), Json::Str(format!("{e:#}")))]),
        )
        .expect("an ERR frame is far below MAX_FRAME")
    })
}

/// Restore persisted job records.  `queued`/`running` records come back
/// as `Queued` (auto-resume — their journals replay completed work);
/// terminal records keep their state, and `done` jobs reload their
/// result payload.
fn load_jobs(state_dir: &Path) -> Result<(BTreeMap<u64, Job>, u64)> {
    let mut jobs = BTreeMap::new();
    let mut next_id = 1;
    let Ok(rd) = std::fs::read_dir(state_dir) else {
        return Ok((jobs, next_id));
    };
    let mut paths: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        let Some(stem) = name.strip_prefix("job_").and_then(|s| s.strip_suffix(".json")) else {
            continue;
        };
        let Ok(id) = stem.parse::<u64>() else {
            continue; // job_<id>.result.json and foreign files land here
        };
        let rec = jsonio::parse_file(&p).with_context(|| format!("job record {}", p.display()))?;
        let model = rec.req("model")?.as_str()?.to_string();
        let policy = JobPolicy::from_json(rec.get("policy"))?;
        let state = match rec.req("state")?.as_str()? {
            "queued" | "running" => JobState::Queued,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            other => bail!("job {id}: unknown persisted state '{other}'"),
        };
        let mut job = Job::new(id, model, policy);
        job.state = state;
        job.idem = match rec.get("idem") {
            Some(v) if !v.is_null() => Some(v.as_str()?.to_string()),
            _ => None,
        };
        job.error = match rec.get("error") {
            Some(v) if !v.is_null() => Some(v.as_str()?.to_string()),
            _ => None,
        };
        if state == JobState::Done {
            let rp = state_dir.join(format!("job_{id}.result.json"));
            if let Ok(r) = jsonio::parse_file(&rp) {
                job.result = Some(r);
            }
        }
        next_id = next_id.max(id + 1);
        jobs.insert(id, job);
    }
    Ok((jobs, next_id))
}

/// Per-connection handler: frames in, [`Ctl`] across, frames out.
fn serve_conn(mut stream: UnixStream, ctl: Sender<Ctl>, conn: WireConn, stats: Arc<WireStats>) {
    let _ = conn_loop(&mut stream, ctl, &conn, &stats);
}

/// Has the peer hung up?  A non-blocking `peek` distinguishes a closed
/// connection (`Ok(0)` / a hard error) from an idle one (`WouldBlock`,
/// or buffered bytes we leave in place).
fn conn_closed(stream: &UnixStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let closed = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    if stream.set_nonblocking(false).is_err() {
        return true;
    }
    closed
}

/// Shed backoff hint (ms) carried in `RETRY_AFTER` replies.  Small: the
/// cap usually clears within a phase step, and clients add exponential
/// backoff on top.
const SHED_RETRY_MS: u64 = 50;

fn err_json(e: &str) -> Json {
    Json::Obj(vec![("error".into(), Json::Str(e.into()))])
}

fn conn_loop(
    stream: &mut UnixStream,
    ctl: Sender<Ctl>,
    conn: &WireConn,
    stats: &WireStats,
) -> Result<()> {
    proto::handshake(stream)?;
    loop {
        // Idle-tolerant read: the connection's read timeout bounds a
        // *mid-frame* stall, never client think-time.  Peek until the
        // next frame's first byte shows up; each timeout tick just loops.
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => return Ok(()), // clean EOF between frames
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => return Err(e).context("polling connection for the next frame"),
        }
        let Some((kind, job, payload)) = proto::recv(stream)? else {
            return Ok(());
        };
        match kind {
            msg::SUBMIT => {
                let model = payload.req("model")?.as_str()?.to_string();
                let policy = JobPolicy::from_json(payload.get("policy"))?;
                let idem = match payload.get("idem") {
                    Some(v) if !v.is_null() => Some(v.as_str()?.to_string()),
                    _ => None,
                };
                let (rtx, rrx) = channel();
                if ctl.send(Ctl::Submit { model, policy, idem, reply: rtx }).is_err() {
                    return Ok(());
                }
                match rrx.recv() {
                    Ok(Ok(id)) => proto::send_via(
                        stream,
                        conn,
                        msg::ACK,
                        id,
                        &Json::Obj(vec![("job".into(), Json::Num(id as f64))]),
                    )?,
                    Ok(Err(e)) if e.contains("admission refused") => {
                        // overload is a *typed, retryable* condition, not
                        // a submit failure: shed with a backoff hint
                        stats.sheds.fetch_add(1, Ordering::Relaxed);
                        proto::send_retry_after(stream, conn, SHED_RETRY_MS, &e)?;
                    }
                    Ok(Err(e)) => proto::send_via(stream, conn, msg::ERR, 0, &err_json(&e))?,
                    Err(_) => return Ok(()),
                }
            }
            msg::STATUS => {
                let (rtx, rrx) = channel();
                if ctl.send(Ctl::Status { reply: rtx }).is_err() {
                    return Ok(());
                }
                match rrx.recv() {
                    Ok(state) => proto::send_via(stream, conn, msg::STATE, 0, &state)?,
                    Err(_) => return Ok(()),
                }
            }
            msg::CANCEL => {
                let (rtx, rrx) = channel();
                if ctl.send(Ctl::Cancel { job, reply: rtx }).is_err() {
                    return Ok(());
                }
                match rrx.recv() {
                    Ok(Ok(())) => proto::send_via(stream, conn, msg::ACK, job, &Json::Null)?,
                    Ok(Err(e)) => proto::send_via(stream, conn, msg::ERR, job, &err_json(&e))?,
                    Err(_) => return Ok(()),
                }
            }
            msg::SUBSCRIBE => {
                let (etx, erx) = channel::<Vec<u8>>();
                let (rtx, rrx) = channel();
                if ctl.send(Ctl::Subscribe { job, tx: etx, reply: rtx }).is_err() {
                    return Ok(());
                }
                match rrx.recv() {
                    Ok(Ok(())) => proto::send_via(stream, conn, msg::ACK, job, &Json::Null)?,
                    Ok(Err(e)) => {
                        proto::send_via(stream, conn, msg::ERR, job, &err_json(&e))?;
                        continue;
                    }
                    Err(_) => return Ok(()),
                }
                // the connection is a one-way event stream from here on;
                // it closes when the job reaches a terminal state (the
                // scheduler drops our sender)
                while let Ok(bytes) = erx.recv() {
                    // an empty message is the scheduler's liveness probe
                    // (`prune_subs`); `write_all(&[])` makes no syscall, so
                    // probe the socket itself and exit if the watcher is
                    // gone — the next prune then errors on our dropped
                    // receiver and removes the channel
                    if bytes.is_empty() {
                        if conn_closed(stream) {
                            return Ok(());
                        }
                        continue;
                    }
                    stream.write_all(&bytes).context("forwarding event")?;
                    stream.flush().context("flushing event")?;
                }
                return Ok(());
            }
            msg::RELEASE => {
                if ctl.send(Ctl::Release).is_err() {
                    return Ok(());
                }
                proto::send_via(stream, conn, msg::ACK, 0, &Json::Null)?;
            }
            msg::SHUTDOWN => {
                let _ = ctl.send(Ctl::Shutdown);
                proto::send_via(stream, conn, msg::ACK, 0, &Json::Null)?;
                return Ok(());
            }
            other => proto::send_via(
                stream,
                conn,
                msg::ERR,
                job,
                &err_json(&format!("unknown message kind {other}")),
            )?,
        }
    }
}
