//! The `mpqd` wire protocol: MPQJ checksummed frames over a Unix domain
//! socket.
//!
//! Every message is one [`crate::store`] frame — `u32 len · u16 kind ·
//! u16 reserved · u64 digest · u64 checksum · payload` — written with
//! [`crate::store::write_frame`] and read with
//! [`crate::store::read_frame`].  The `kind` field carries the message
//! kind ([`msg`]), the `digest` field carries the **job id** for
//! job-scoped messages (0 otherwise), and the payload is a small JSON
//! object ([`crate::jsonio`]).  Payloads are bounded by [`MAX_FRAME`]:
//! this is a control plane — tensors and datasets never ride it; jobs
//! reference artifact paths and the daemon reads them from disk.
//!
//! Connections open with a mutual 8-byte MPQJ container-header handshake
//! ([`handshake`]), so either side rejects a non-mpqd peer before
//! parsing anything.

use crate::jsonio::{self, Json};
use crate::pool::WireConn;
use crate::store;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Control-plane payload cap (1 MiB).  Control messages are small and
/// bounded; anything bigger is corruption or abuse.
pub const MAX_FRAME: usize = 1 << 20;

/// Message kinds.  Requests are 16..32, replies/events 32..48 — disjoint
/// from the journal's record kinds (1..=4) so a frame can never be
/// mistaken for the wrong plane.
pub mod msg {
    /// client → daemon: `{model, policy?}`; digest 0
    pub const SUBMIT: u16 = 16;
    /// client → daemon: empty payload; digest 0
    pub const STATUS: u16 = 17;
    /// client → daemon: empty payload; digest = job id
    pub const CANCEL: u16 = 18;
    /// client → daemon: empty payload; digest = job id — converts the
    /// connection into a one-way event stream for that job
    pub const SUBSCRIBE: u16 = 19;
    /// client → daemon: start held jobs (`--hold` admission staging)
    pub const RELEASE: u16 = 20;
    /// client → daemon: drain, persist and exit
    pub const SHUTDOWN: u16 = 21;

    /// daemon → client: request accepted (`{job}` for submits)
    pub const ACK: u16 = 32;
    /// daemon → client: request rejected / job failed (`{error}`)
    pub const ERR: u16 = 33;
    /// daemon → client: streamed progress (`{phase}` at phase barriers,
    /// `{barrier, kind}` at journal append points); digest = job id
    pub const EVENT: u16 = 34;
    /// daemon → client: final report `{job, result, durability}`
    pub const RESULT: u16 = 35;
    /// daemon → client: the `Status` reply (jobs + telemetry snapshot)
    pub const STATE: u16 = 36;
    /// daemon → client: overload shed — admission refused *for now*;
    /// `{retry_after_ms, error}`.  A typed signal (distinct from `ERR`)
    /// so clients back off and retry instead of failing the submit.
    pub const RETRY_AFTER: u16 = 37;
}

/// Mutual protocol handshake: write our MPQJ container header, read and
/// validate the peer's.  Both sides write first (8 bytes fit any socket
/// buffer, so this cannot deadlock).
pub fn handshake(stream: &mut (impl Read + Write)) -> Result<()> {
    stream
        .write_all(&store::file_header())
        .context("writing protocol handshake")?;
    stream.flush().context("flushing protocol handshake")?;
    let mut hdr = [0u8; 8];
    stream
        .read_exact(&mut hdr)
        .context("reading protocol handshake")?;
    if !store::header_ok(&hdr) {
        bail!("peer is not an mpqd endpoint (bad MPQJ handshake)");
    }
    Ok(())
}

/// Human-readable name of a message kind, used in cap-violation errors.
pub fn kind_name(kind: u16) -> &'static str {
    match kind {
        msg::SUBMIT => "SUBMIT",
        msg::STATUS => "STATUS",
        msg::CANCEL => "CANCEL",
        msg::SUBSCRIBE => "SUBSCRIBE",
        msg::RELEASE => "RELEASE",
        msg::SHUTDOWN => "SHUTDOWN",
        msg::ACK => "ACK",
        msg::ERR => "ERR",
        msg::EVENT => "EVENT",
        msg::RESULT => "RESULT",
        msg::STATE => "STATE",
        msg::RETRY_AFTER => "RETRY_AFTER",
        _ => "UNKNOWN",
    }
}

/// Serialize a payload, enforcing [`MAX_FRAME`] on the send side: a peer
/// whose `recv` rejects an oversize frame can only report an opaque cap
/// error, so the writer must refuse first, naming the message kind.
fn encode_payload(kind: u16, payload: &Json) -> Result<String> {
    let text = payload.to_string();
    if text.len() > MAX_FRAME {
        bail!(
            "{} payload is {} bytes, over the {MAX_FRAME}-byte control-plane cap; \
             control messages must stay small — ship bulk data out of band",
            kind_name(kind),
            text.len(),
        );
    }
    Ok(text)
}

/// Send one message: JSON payload under `kind` with `job` in the digest
/// field (0 for daemon-scoped messages).  Fails (writing nothing) when
/// the payload exceeds [`MAX_FRAME`].
pub fn send(w: &mut impl Write, kind: u16, job: u64, payload: &Json) -> Result<()> {
    send_via(w, &WireConn::off(), kind, job, payload)
}

/// [`send`] through a wire-fault seam: the daemon routes every reply
/// through its connection's [`WireConn`], so `wdrop`/`wcorrupt`/… clauses
/// in a serve fault plan hit this control plane exactly as they hit the
/// fleet's.  With [`WireConn::off`] this **is** `send` — zero overhead,
/// identical bytes.
pub fn send_via(w: &mut impl Write, conn: &WireConn, kind: u16, job: u64, payload: &Json) -> Result<()> {
    let text = encode_payload(kind, payload)?;
    conn.write_frame(w, kind, job, text.as_bytes())
}

/// Encode one message to bytes (the daemon fans these out to
/// subscribers through plain byte channels).  Enforces [`MAX_FRAME`]
/// like [`send`].
pub fn encode(kind: u16, job: u64, payload: &Json) -> Result<Vec<u8>> {
    let text = encode_payload(kind, payload)?;
    Ok(store::encode_record(kind, job, text.as_bytes()))
}

/// An `ERR` reply.
pub fn send_err(w: &mut impl Write, job: u64, error: &str) -> Result<()> {
    send(
        w,
        msg::ERR,
        job,
        &Json::Obj(vec![("error".into(), Json::Str(error.into()))]),
    )
}

/// A `RETRY_AFTER` shed reply: the request was refused *for now*; a
/// well-behaved client waits `retry_after_ms` (plus jitter/backoff) and
/// resubmits.  The error text still names the admission rule so a
/// non-retrying caller sees a useful message.
pub fn send_retry_after(
    w: &mut impl Write,
    conn: &WireConn,
    retry_after_ms: u64,
    error: &str,
) -> Result<()> {
    send_via(
        w,
        conn,
        msg::RETRY_AFTER,
        0,
        &Json::Obj(vec![
            ("retry_after_ms".into(), Json::Num(retry_after_ms as f64)),
            ("error".into(), Json::Str(error.into())),
        ]),
    )
}

/// One decoded message: `(kind, job, payload)`.
pub type Msg = (u16, u64, Json);

/// Read one message; `Ok(None)` on clean EOF.  An empty payload decodes
/// as `Json::Null`.
pub fn recv(r: &mut impl Read) -> Result<Option<Msg>> {
    let Some(frame) = store::read_frame(r, MAX_FRAME)? else {
        return Ok(None);
    };
    let payload = if frame.payload.is_empty() {
        Json::Null
    } else {
        let text = std::str::from_utf8(&frame.payload).context("frame payload is not UTF-8")?;
        jsonio::parse(text).context("frame payload is not JSON")?
    };
    Ok(Some((frame.kind, frame.digest, payload)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_roundtrip_over_a_byte_stream() {
        let mut buf = Vec::new();
        let payload = Json::Obj(vec![("model".into(), Json::Str("m".into()))]);
        send(&mut buf, msg::SUBMIT, 0, &payload).unwrap();
        buf.extend_from_slice(&encode(msg::EVENT, 3, &Json::Null).unwrap());
        send_err(&mut buf, 9, "nope").unwrap();
        let mut r: &[u8] = &buf;
        let (k, j, p) = recv(&mut r).unwrap().unwrap();
        assert_eq!((k, j), (msg::SUBMIT, 0));
        assert_eq!(p.req("model").unwrap().as_str().unwrap(), "m");
        let (k, j, p) = recv(&mut r).unwrap().unwrap();
        assert_eq!((k, j), (msg::EVENT, 3));
        assert!(p.is_null());
        let (k, j, p) = recv(&mut r).unwrap().unwrap();
        assert_eq!((k, j), (msg::ERR, 9));
        assert_eq!(p.req("error").unwrap().as_str().unwrap(), "nope");
        assert!(recv(&mut r).unwrap().is_none());
    }

    #[test]
    fn send_enforces_the_frame_cap_at_the_exact_boundary() {
        // a plain ASCII string payload serializes as itself plus the two
        // surrounding quote bytes, so the cap is hit exactly
        let fits = Json::Str("x".repeat(MAX_FRAME - 2));
        let mut buf = Vec::new();
        send(&mut buf, msg::RESULT, 7, &fits).unwrap();
        let mut r: &[u8] = &buf;
        let (k, j, p) = recv(&mut r).unwrap().unwrap();
        assert_eq!((k, j), (msg::RESULT, 7));
        assert_eq!(p.as_str().unwrap().len(), MAX_FRAME - 2);

        let over = Json::Str("x".repeat(MAX_FRAME - 1));
        let mut buf = Vec::new();
        let err = send(&mut buf, msg::RESULT, 7, &over).unwrap_err().to_string();
        assert!(err.contains("RESULT"), "cap error must name the message kind: {err}");
        assert!(buf.is_empty(), "nothing may reach the wire on a cap violation");
        assert!(encode(msg::STATE, 0, &over).is_err());
    }

    #[test]
    fn retry_after_is_a_typed_shed_reply() {
        let mut buf = Vec::new();
        send_retry_after(&mut buf, &WireConn::off(), 40, "admission refused: at capacity").unwrap();
        let mut r: &[u8] = &buf;
        let (k, j, p) = recv(&mut r).unwrap().unwrap();
        assert_eq!((k, j), (msg::RETRY_AFTER, 0));
        assert_eq!(p.req("retry_after_ms").unwrap().as_f64().unwrap() as u64, 40);
        assert!(p.req("error").unwrap().as_str().unwrap().contains("admission refused"));
        assert_eq!(kind_name(msg::RETRY_AFTER), "RETRY_AFTER");
    }

    #[test]
    fn send_via_routes_through_the_wire_fault_seam() {
        use crate::pool::{FaultPlan, WireFaults, WireStats};
        use std::sync::Arc;

        // a corrupt clause on frame 1 of lane 0: the bytes reach the
        // stream but recv must reject them with a checksum error
        let plan = FaultPlan::parse("wcorrupt@0:1").unwrap();
        let wf = WireFaults::new(&plan, 1, Arc::new(WireStats::default())).unwrap();
        let conn = WireConn::new(Some(wf), 0);
        let mut buf = Vec::new();
        send_via(&mut buf, &conn, msg::ACK, 5, &Json::Null).unwrap();
        let mut r: &[u8] = &buf;
        let err = recv(&mut r).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        // the second frame through the same conn is clean (one-shot)
        let mut buf = Vec::new();
        send_via(&mut buf, &conn, msg::ACK, 6, &Json::Null).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(recv(&mut r).unwrap().unwrap().1, 6);
    }

    #[test]
    fn handshake_rejects_a_non_mpqd_peer() {
        // a duplex fake: read side serves garbage, write side discards
        struct Fake {
            input: std::io::Cursor<Vec<u8>>,
        }
        impl Read for Fake {
            fn read(&mut self, b: &mut [u8]) -> std::io::Result<usize> {
                self.input.read(b)
            }
        }
        impl Write for Fake {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut good = Fake { input: std::io::Cursor::new(store::file_header().to_vec()) };
        assert!(handshake(&mut good).is_ok());
        let mut bad = Fake { input: std::io::Cursor::new(b"HTTP/1.1".to_vec()) };
        assert!(handshake(&mut bad).is_err());
    }
}
