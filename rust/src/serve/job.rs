//! One quantization job: policy, phase state machine, and the final
//! report payload.
//!
//! A job runs the paper's full pipeline — calibrate → Phase-1 SQNR
//! sensitivity → Phase-2 pareto search → AdaRound — as a sequence of
//! [`JobRun::step`] calls, one **phase** per call.  The daemon scheduler
//! interleaves many jobs by round-robining steps across them; the serial
//! reference path ([`run_local`]) drives the identical state machine to
//! completion in one loop, so daemon results are byte-equal to the
//! serial CLI path *by construction* (pooled evaluation is bit-identical
//! to serial at any worker count, and the report encodes every float as
//! its exact bit pattern).
//!
//! Durability: each phase journals its own barriers (probe scores,
//! prefix evaluations, rounded tensors) through the pipeline's attached
//! [`RunJournal`], so a killed daemon re-steps a resumed job through the
//! same phases and every completed unit is served from the journal.

use crate::adaround::AdaRoundCfg;
use crate::coordinator::Pipeline;
use crate::groups::Lattice;
use crate::jsonio::Json;
use crate::manifest::Manifest;
use crate::runtime::Runtime;
use crate::search::SearchRun;
use crate::sensitivity::{RoundedWeights, SensEntry};
use crate::store::{RunJournal, StoreStats};
use crate::util::Fnv;
use anyhow::{bail, Result};
use std::path::Path;
use std::rc::Rc;

/// Per-job execution policy, carried in the `Submit` payload.
#[derive(Clone, Debug)]
pub struct JobPolicy {
    /// calibration subset size
    pub calib_n: usize,
    /// calibration subset seed
    pub seed: u64,
    /// higher runs first; FIFO (by id) within a priority
    pub priority: i64,
    /// per-job eval budget: max journal barriers (probe scores + prefix
    /// evals + rounded layers) this job may append before it is failed
    pub eval_budget: Option<u64>,
    /// per-job wall-clock deadline: a job still unfinished this many ms
    /// after it first started running is failed ("deadline exceeded") at
    /// the next phase boundary.  Completed journal barriers stay durable,
    /// so a resubmit with a longer deadline *resumes* rather than
    /// restarts — the same contract as `eval_budget`.
    pub deadline_ms: Option<u64>,
    /// run the AdaRound phase
    pub adaround: bool,
    pub adaround_steps: usize,
}

impl Default for JobPolicy {
    fn default() -> Self {
        Self {
            calib_n: 64,
            seed: 0,
            priority: 0,
            eval_budget: None,
            deadline_ms: None,
            adaround: true,
            adaround_steps: 8,
        }
    }
}

impl JobPolicy {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("calib_n".into(), Json::Num(self.calib_n as f64)),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("priority".into(), Json::Num(self.priority as f64)),
            (
                "eval_budget".into(),
                match self.eval_budget {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            (
                "deadline_ms".into(),
                match self.deadline_ms {
                    Some(d) => Json::Num(d as f64),
                    None => Json::Null,
                },
            ),
            ("adaround".into(), Json::Bool(self.adaround)),
            ("adaround_steps".into(), Json::Num(self.adaround_steps as f64)),
        ])
    }

    /// Decode a policy; absent keys (or an absent/null object) keep their
    /// defaults, so clients only send what they override.
    pub fn from_json(j: Option<&Json>) -> Result<Self> {
        let mut p = Self::default();
        let Some(j) = j else { return Ok(p) };
        if j.is_null() {
            return Ok(p);
        }
        if let Some(v) = j.get("calib_n") {
            p.calib_n = v.as_usize()?;
        }
        if let Some(v) = j.get("seed") {
            p.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.get("priority") {
            p.priority = v.as_f64()? as i64;
        }
        if let Some(v) = j.get("eval_budget") {
            p.eval_budget = if v.is_null() { None } else { Some(v.as_f64()? as u64) };
        }
        if let Some(v) = j.get("deadline_ms") {
            p.deadline_ms = if v.is_null() { None } else { Some(v.as_f64()? as u64) };
        }
        if let Some(v) = j.get("adaround") {
            p.adaround = matches!(v, Json::Bool(true));
        }
        if let Some(v) = j.get("adaround_steps") {
            p.adaround_steps = v.as_usize()?;
        }
        Ok(p)
    }
}

/// Pipeline phases, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Calibrate,
    Sensitivity,
    Search,
    AdaRound,
    Done,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::Calibrate => "calibrate",
            Phase::Sensitivity => "sensitivity",
            Phase::Search => "search",
            Phase::AdaRound => "adaround",
            Phase::Done => "done",
        }
    }
}

/// The resumable per-job state machine.  Holds the job's [`Pipeline`]
/// (and through it the per-model `EvalPool` attachment — dropping a
/// `JobRun` detaches the model from the fleet) plus every intermediate
/// the later phases need.
pub struct JobRun {
    model: String,
    pipe: Pipeline,
    journal: Option<Rc<RunJournal>>,
    policy: JobPolicy,
    lattice: Lattice,
    phase: Phase,
    sens: Option<Vec<SensEntry>>,
    curve: Option<SearchRun>,
    rounded: Option<RoundedWeights>,
}

impl JobRun {
    pub fn new(
        model: String,
        pipe: Pipeline,
        journal: Option<Rc<RunJournal>>,
        policy: JobPolicy,
    ) -> Self {
        Self {
            model,
            pipe,
            journal,
            policy,
            lattice: Lattice::practical(),
            phase: Phase::Calibrate,
            sens: None,
            curve: None,
            rounded: None,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn done(&self) -> bool {
        self.phase == Phase::Done
    }

    /// Run the current phase to its end and advance.  Returns the phase
    /// that was executed.  The per-job eval budget is enforced at the
    /// phase boundary: a job that appended more journal barriers than its
    /// budget fails here (completed barriers stay durable, so a resubmit
    /// with a bigger budget resumes instead of restarting).
    pub fn step(&mut self) -> Result<Phase> {
        let cur = self.phase;
        match cur {
            Phase::Calibrate => {
                self.pipe.calibrate(self.policy.calib_n, self.policy.seed)?;
                self.phase = Phase::Sensitivity;
            }
            Phase::Sensitivity => {
                self.sens = Some(self.pipe.sensitivity_sqnr(&self.lattice)?);
                self.phase = Phase::Search;
            }
            Phase::Search => {
                let sens = self.sens.as_ref().expect("sensitivity ran");
                let flips = self.pipe.flips(&self.lattice, sens);
                self.curve = Some(self.pipe.pareto_curve(&self.lattice, &flips, None)?);
                self.phase = if self.policy.adaround { Phase::AdaRound } else { Phase::Done };
            }
            Phase::AdaRound => {
                let cfg = AdaRoundCfg {
                    steps: self.policy.adaround_steps,
                    ..Default::default()
                };
                self.rounded = Some(self.pipe.adaround(&self.lattice, &cfg)?);
                self.phase = Phase::Done;
            }
            Phase::Done => {}
        }
        if let (Some(j), Some(budget)) = (&self.journal, self.policy.eval_budget) {
            if j.barriers() > budget {
                bail!(
                    "eval budget exceeded: {} journal barriers > budget {budget}",
                    j.barriers()
                );
            }
        }
        Ok(cur)
    }

    /// The final report payload.  Floats are encoded as 16-hex-digit bit
    /// patterns (JSON numbers do not round-trip `f64` bits), so two runs
    /// produced equal payloads iff their results are **bit-identical**.
    pub fn result(&self) -> Result<Json> {
        if self.phase != Phase::Done {
            bail!("job still in phase {}", self.phase.label());
        }
        let sens = self.sens.as_ref().expect("done implies sensitivity");
        let curve = self.curve.as_ref().expect("done implies search");
        Ok(Json::Obj(vec![
            ("model".into(), Json::Str(self.model.clone())),
            (
                "sens".into(),
                Json::Arr(
                    sens.iter()
                        .map(|e| {
                            Json::Arr(vec![
                                Json::Num(e.group as f64),
                                Json::Num(e.cand.wbits as f64),
                                Json::Num(e.cand.abits as f64),
                                Json::Str(hex64(e.score.to_bits())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "curve".into(),
                Json::Arr(
                    curve
                        .curve
                        .iter()
                        .map(|&(b, m)| {
                            Json::Arr(vec![
                                Json::Str(hex64(b.to_bits())),
                                Json::Str(hex64(m.to_bits())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "adaround".into(),
                match &self.rounded {
                    Some(r) => Json::Str(hex64(rounded_digest(r))),
                    None => Json::Null,
                },
            ),
        ]))
    }
}

fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

/// Content digest of the AdaRounded tensors: sorted `(param_idx, wbits)`
/// keys, each folded with its full tensor content — deterministic
/// regardless of `HashMap` iteration order.
fn rounded_digest(r: &RoundedWeights) -> u64 {
    let mut keys: Vec<_> = r.keys().copied().collect();
    keys.sort_unstable();
    let mut h = Fnv::new();
    for (p, b) in keys {
        h.write_usize(p);
        h.write_u8(b);
        h.write_tensor(&r[&(p, b)]);
    }
    h.finish()
}

/// The serial single-process reference path: the exact state machine the
/// daemon steps, run to completion in one loop.  `workers == 0` stays
/// serial; `workers > 1` uses a private pool (bit-identical either way).
/// `journal_path` arms crash/resume; `None` runs unjournaled.
pub fn run_local(
    dir: &Path,
    model: &str,
    policy: &JobPolicy,
    workers: usize,
    journal_path: Option<&Path>,
) -> Result<Json> {
    let manifest = Manifest::load(dir)?;
    let rt = Rc::new(Runtime::for_manifest(&manifest)?);
    let mut pipe = Pipeline::open_with(rt, &manifest, model)?;
    let journal = match journal_path {
        Some(p) => Some(Rc::new(RunJournal::open(p, true, Rc::new(StoreStats::default()))?)),
        None => None,
    };
    pipe.set_journal(journal.clone());
    if workers > 1 {
        pipe.enable_pool(workers)?;
    }
    let mut run = JobRun::new(model.to_string(), pipe, journal, policy.clone());
    while !run.done() {
        run.step()?;
    }
    run.result()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_roundtrips_and_defaults_apply() {
        let p = JobPolicy {
            calib_n: 32,
            seed: 9,
            priority: -2,
            eval_budget: Some(500),
            deadline_ms: Some(1500),
            adaround: false,
            adaround_steps: 4,
        };
        let back = JobPolicy::from_json(Some(&p.to_json())).unwrap();
        assert_eq!(back.calib_n, 32);
        assert_eq!(back.seed, 9);
        assert_eq!(back.priority, -2);
        assert_eq!(back.eval_budget, Some(500));
        assert_eq!(back.deadline_ms, Some(1500));
        assert!(!back.adaround);
        assert_eq!(back.adaround_steps, 4);

        let d = JobPolicy::from_json(None).unwrap();
        assert_eq!(d.calib_n, JobPolicy::default().calib_n);
        let partial = crate::jsonio::parse(r#"{"calib_n": 16}"#).unwrap();
        let d = JobPolicy::from_json(Some(&partial)).unwrap();
        assert_eq!(d.calib_n, 16);
        assert_eq!(d.adaround_steps, JobPolicy::default().adaround_steps);
        assert_eq!(d.eval_budget, None);
        assert_eq!(d.deadline_ms, None);
    }

    #[test]
    fn phases_run_in_order() {
        assert_eq!(Phase::Calibrate.label(), "calibrate");
        assert_eq!(Phase::Done.label(), "done");
    }
}
