//! Thin `mpqd` client: one Unix-socket connection speaking
//! [`super::proto`], plus the `mpq client <sub>` CLI entry.
//!
//! Request methods (`submit`/`status`/`cancel`/`release`/`shutdown`) are
//! strict request→reply pairs on one connection.  [`Client::watch`]
//! converts the connection into a one-way event stream for a job and
//! blocks until the job's final report (or failure) arrives.
//!
//! ## Chaos posture
//!
//! The client is built to survive a hostile wire:
//!
//! * **Connect deadline** — [`Client::connect`] retries a refused or
//!   absent socket briefly, then fails with a typed *"daemon unreachable
//!   at `<path>`"* error naming the socket, never hangs.
//! * **Symmetric I/O timeouts** — reads and writes carry the same
//!   `io_timeout_ms` the daemon applies (default
//!   [`DEFAULT_IO_TIMEOUT_MS`](super::daemon::DEFAULT_IO_TIMEOUT_MS)),
//!   so a mid-frame stall on either side is bounded.
//! * **Idempotent submit retry** — every submit carries an idempotency
//!   key.  On a transport error (timeout, torn frame, reset) the client
//!   reconnects and resubmits with bounded exponential backoff; the
//!   daemon maps the key back to the already-admitted job, so a retried
//!   submit of a completed job returns the durable result and **never
//!   re-executes**.  A typed `ERR` reply fails fast — only transport
//!   trouble and sheds retry.
//! * **Shed handling** — a `RETRY_AFTER` reply (admission shed) sleeps
//!   the hinted delay plus backoff and resubmits, up to the retry
//!   budget.

use crate::cli::Args;
use crate::jsonio::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::daemon::{io_timeout_opt, DEFAULT_IO_TIMEOUT_MS};
use super::job::JobPolicy;
use super::proto::{self, msg};

/// Transport-retry budget: a submit survives this many reconnect/shed
/// rounds before the underlying error surfaces.
const DEFAULT_RETRIES: u32 = 3;

/// First backoff step (ms); doubles per attempt, capped at [`BACKOFF_CAP_MS`].
const BACKOFF_BASE_MS: u64 = 25;
const BACKOFF_CAP_MS: u64 = 500;

/// Bounded exponential backoff for attempt `n` (1-based).
fn backoff(base_ms: u64, attempt: u32) -> Duration {
    let exp = base_ms.saturating_mul(1u64 << attempt.saturating_sub(1).min(10));
    Duration::from_millis(exp.min(BACKOFF_CAP_MS))
}

pub struct Client {
    stream: UnixStream,
    socket: PathBuf,
    io: Option<Duration>,
    retries: u32,
}

impl Client {
    /// Connect with the default I/O timeout.
    pub fn connect(socket: impl AsRef<Path>) -> Result<Self> {
        Self::connect_with(socket, DEFAULT_IO_TIMEOUT_MS)
    }

    /// Connect with an explicit I/O timeout in ms (`0` = blocking I/O).
    /// The same value bounds the connect attempt: a socket nobody serves
    /// fails with a typed "daemon unreachable" error after at most this
    /// long (refused connects are retried inside the window, so a daemon
    /// mid-startup is not a spurious failure).
    pub fn connect_with(socket: impl AsRef<Path>, io_timeout_ms: u64) -> Result<Self> {
        let socket = socket.as_ref().to_path_buf();
        let io = io_timeout_opt(io_timeout_ms);
        let stream = dial(&socket, io)?;
        Ok(Self { stream, socket, io, retries: DEFAULT_RETRIES })
    }

    /// Override the transport-retry budget (tests pin this).
    pub fn set_retries(&mut self, n: u32) {
        self.retries = n;
    }

    /// Drop the (possibly broken) connection and dial a fresh one.
    fn reconnect(&mut self) -> Result<()> {
        self.stream = dial(&self.socket, self.io)?;
        Ok(())
    }

    /// Submit a job; returns its id.  Carries an auto-generated
    /// idempotency key, so the internal transport retry can never admit
    /// the job twice.
    pub fn submit(&mut self, model: &str, policy: &JobPolicy) -> Result<u64> {
        let key = fresh_idem_key(model);
        self.submit_idem(model, policy, &key)
    }

    /// Submit with a caller-chosen idempotency key.  Submitting the same
    /// key again — even from a new client, even after the daemon
    /// restarted — returns the existing job's id instead of admitting a
    /// duplicate; fetch its durable result with [`Client::watch`].
    pub fn submit_idem(&mut self, model: &str, policy: &JobPolicy, idem: &str) -> Result<u64> {
        let payload = Json::Obj(vec![
            ("model".into(), Json::Str(model.to_string())),
            ("policy".into(), policy.to_json()),
            ("idem".into(), Json::Str(idem.to_string())),
        ]);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let r = (|| -> Result<proto::Msg> {
                proto::send(&mut self.stream, msg::SUBMIT, 0, &payload)?;
                self.expect_reply()
            })();
            match r {
                Ok((msg::ACK, job, _)) => return Ok(job),
                // a typed refusal is final: retrying cannot change it
                Ok((msg::ERR, _, p)) => bail!("submit refused: {}", err_text(&p)),
                Ok((msg::RETRY_AFTER, _, p)) => {
                    let hint = p
                        .get("retry_after_ms")
                        .and_then(|v| v.as_f64().ok())
                        .unwrap_or(BACKOFF_BASE_MS as f64) as u64;
                    if attempt > self.retries {
                        bail!("submit shed {attempt} times: {}", err_text(&p));
                    }
                    std::thread::sleep(backoff(hint.max(1), attempt));
                }
                Ok((other, _, _)) => bail!("unexpected reply kind {other} to submit"),
                Err(e) => {
                    // transport trouble (timeout, torn/corrupt frame,
                    // reset): reconnect and resubmit — the idem key makes
                    // the retry safe
                    if attempt > self.retries {
                        return Err(e.context(format!(
                            "submit failed after {attempt} attempts (socket {})",
                            self.socket.display()
                        )));
                    }
                    std::thread::sleep(backoff(BACKOFF_BASE_MS, attempt));
                    if let Err(de) = self.reconnect() {
                        if attempt >= self.retries {
                            return Err(de);
                        }
                    }
                }
            }
        }
    }

    /// The daemon's full state: job table, schedule log, telemetry.
    pub fn status(&mut self) -> Result<Json> {
        proto::send(&mut self.stream, msg::STATUS, 0, &Json::Null)?;
        let (kind, _, p) = self.expect_reply()?;
        match kind {
            msg::STATE => Ok(p),
            msg::ERR => bail!("status failed: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to status"),
        }
    }

    pub fn cancel(&mut self, job: u64) -> Result<()> {
        proto::send(&mut self.stream, msg::CANCEL, job, &Json::Null)?;
        self.expect_ack("cancel")
    }

    /// Start held jobs (`mpq serve --hold` staging).
    pub fn release(&mut self) -> Result<()> {
        proto::send(&mut self.stream, msg::RELEASE, 0, &Json::Null)?;
        self.expect_ack("release")
    }

    pub fn shutdown(&mut self) -> Result<()> {
        proto::send(&mut self.stream, msg::SHUTDOWN, 0, &Json::Null)?;
        self.expect_ack("shutdown")
    }

    /// Subscribe to `job` and block until its final report.  Progress
    /// messages (`{phase}` at phase starts, `{barrier, kind}` at journal
    /// appends) are handed to `on_event` as they stream in; the returned
    /// payload is the daemon's `{job, result, durability}` object.
    /// Consumes the client: the connection is an event stream afterwards.
    pub fn watch(mut self, job: u64, mut on_event: impl FnMut(&Json)) -> Result<Json> {
        proto::send(&mut self.stream, msg::SUBSCRIBE, job, &Json::Null)?;
        let (kind, _, p) = self.expect_reply()?;
        match kind {
            msg::ACK => {}
            msg::ERR => bail!("subscribe refused: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to subscribe"),
        }
        // A long phase may legitimately stream nothing for far longer
        // than the I/O timeout; once subscribed, event arrival has no
        // deadline (the terminal RESULT/ERR frame is what ends the wait).
        let _ = self.stream.set_read_timeout(None);
        loop {
            let Some((kind, _, p)) = proto::recv(&mut self.stream)? else {
                bail!("daemon closed the stream before a result (job cancelled or daemon exited)");
            };
            match kind {
                msg::EVENT => on_event(&p),
                msg::RESULT => return Ok(p),
                msg::ERR => bail!("job {job} failed: {}", err_text(&p)),
                other => bail!("unexpected stream kind {other}"),
            }
        }
    }

    fn expect_reply(&mut self) -> Result<proto::Msg> {
        match proto::recv(&mut self.stream)? {
            Some(m) => Ok(m),
            None => bail!("daemon closed the connection"),
        }
    }

    fn expect_ack(&mut self, what: &str) -> Result<()> {
        let (kind, _, p) = self.expect_reply()?;
        match kind {
            msg::ACK => Ok(()),
            msg::ERR => bail!("{what} refused: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to {what}"),
        }
    }
}

/// Dial the daemon within a deadline.  Connect errors (refused, absent)
/// retry on a short cadence inside the window — a daemon mid-startup is
/// reachable a few ms later — then surface as one typed error naming the
/// socket.  Handshake failures are not retried: a peer that answers but
/// speaks the wrong protocol will not improve.
fn dial(socket: &Path, io: Option<Duration>) -> Result<UnixStream> {
    let window = io.unwrap_or(Duration::from_millis(DEFAULT_IO_TIMEOUT_MS));
    let deadline = Instant::now() + window;
    loop {
        match UnixStream::connect(socket) {
            Ok(mut s) => {
                let _ = s.set_read_timeout(io);
                let _ = s.set_write_timeout(io);
                proto::handshake(&mut s)
                    .with_context(|| format!("handshaking {}", socket.display()))?;
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!(
                        "daemon unreachable at {}: {e} (no listener within {}ms)",
                        socket.display(),
                        window.as_millis()
                    );
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// A process-unique idempotency key: pid + a process-wide sequence + the
/// model name + a wall-clock component (so two *processes* with the same
/// pid across reboots still diverge).  Stable for the lifetime of one
/// submit call, including its internal retries.
fn fresh_idem_key(model: &str) -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!("c{}-{model}-{n}-{t:x}", std::process::id())
}

fn err_text(p: &Json) -> String {
    match p.get("error") {
        Some(v) => v.as_str().map(String::from).unwrap_or_else(|_| p.to_string()),
        None => "unknown error".to_string(),
    }
}

/// `mpq client <submit|status|watch|cancel|release|shutdown> --socket P
/// [--io-timeout-ms MS]`
pub fn cli(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("status");
    let socket = args.opt_str("socket", "mpqd.sock");
    let io_ms = args.opt_usize("io-timeout-ms", DEFAULT_IO_TIMEOUT_MS as usize)? as u64;
    let mut client = Client::connect_with(socket, io_ms)?;
    match sub {
        "submit" => {
            let model = args.opt("model").context("submit needs --model")?;
            let mut policy = JobPolicy::default();
            policy.calib_n = args.opt_usize("calib", policy.calib_n)?;
            policy.seed = args.opt_u64("seed", policy.seed)?;
            if let Some(v) = args.opt("priority") {
                policy.priority = v.parse().map_err(|e| anyhow!("--priority {v}: {e}"))?;
            }
            if let Some(v) = args.opt("eval-budget") {
                policy.eval_budget =
                    Some(v.parse().map_err(|e| anyhow!("--eval-budget {v}: {e}"))?);
            }
            if let Some(v) = args.opt("deadline-ms") {
                policy.deadline_ms =
                    Some(v.parse().map_err(|e| anyhow!("--deadline-ms {v}: {e}"))?);
            }
            policy.adaround = !args.flag("no-adaround");
            policy.adaround_steps = args.opt_usize("adaround-steps", policy.adaround_steps)?;
            let id = match args.opt("idem") {
                Some(key) => client.submit_idem(model, &policy, key)?,
                None => client.submit(model, &policy)?,
            };
            println!("job {id}");
        }
        "status" => println!("{}", client.status()?.to_string()),
        "watch" => {
            let job = args.opt_u64("job", 0)?;
            let result = client.watch(job, |e| println!("event {}", e.to_string()))?;
            println!("{}", result.to_string());
        }
        "cancel" => {
            let job = args.opt_u64("job", 0)?;
            client.cancel(job)?;
            println!("cancelled job {job}");
        }
        "release" => {
            client.release()?;
            println!("released");
        }
        "shutdown" => {
            client.shutdown()?;
            println!("daemon shutting down");
        }
        other => bail!("unknown client subcommand '{other}'"),
    }
    Ok(())
}
