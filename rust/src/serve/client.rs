//! Thin `mpqd` client: one Unix-socket connection speaking
//! [`super::proto`], plus the `mpq client <sub>` CLI entry.
//!
//! Request methods (`submit`/`status`/`cancel`/`release`/`shutdown`) are
//! strict request→reply pairs on one connection.  [`Client::watch`]
//! converts the connection into a one-way event stream for a job and
//! blocks until the job's final report (or failure) arrives.

use crate::cli::Args;
use crate::jsonio::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::os::unix::net::UnixStream;
use std::path::Path;

use super::job::JobPolicy;
use super::proto::{self, msg};

pub struct Client {
    stream: UnixStream,
}

impl Client {
    pub fn connect(socket: impl AsRef<Path>) -> Result<Self> {
        let mut stream = UnixStream::connect(socket.as_ref())
            .with_context(|| format!("connecting {}", socket.as_ref().display()))?;
        proto::handshake(&mut stream)?;
        Ok(Self { stream })
    }

    /// Submit a job; returns its id.
    pub fn submit(&mut self, model: &str, policy: &JobPolicy) -> Result<u64> {
        let payload = Json::Obj(vec![
            ("model".into(), Json::Str(model.to_string())),
            ("policy".into(), policy.to_json()),
        ]);
        proto::send(&mut self.stream, msg::SUBMIT, 0, &payload)?;
        let (kind, job, p) = self.expect_reply()?;
        match kind {
            msg::ACK => Ok(job),
            msg::ERR => bail!("submit refused: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to submit"),
        }
    }

    /// The daemon's full state: job table, schedule log, telemetry.
    pub fn status(&mut self) -> Result<Json> {
        proto::send(&mut self.stream, msg::STATUS, 0, &Json::Null)?;
        let (kind, _, p) = self.expect_reply()?;
        match kind {
            msg::STATE => Ok(p),
            msg::ERR => bail!("status failed: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to status"),
        }
    }

    pub fn cancel(&mut self, job: u64) -> Result<()> {
        proto::send(&mut self.stream, msg::CANCEL, job, &Json::Null)?;
        self.expect_ack("cancel")
    }

    /// Start held jobs (`mpq serve --hold` staging).
    pub fn release(&mut self) -> Result<()> {
        proto::send(&mut self.stream, msg::RELEASE, 0, &Json::Null)?;
        self.expect_ack("release")
    }

    pub fn shutdown(&mut self) -> Result<()> {
        proto::send(&mut self.stream, msg::SHUTDOWN, 0, &Json::Null)?;
        self.expect_ack("shutdown")
    }

    /// Subscribe to `job` and block until its final report.  Progress
    /// messages (`{phase}` at phase starts, `{barrier, kind}` at journal
    /// appends) are handed to `on_event` as they stream in; the returned
    /// payload is the daemon's `{job, result, durability}` object.
    /// Consumes the client: the connection is an event stream afterwards.
    pub fn watch(mut self, job: u64, mut on_event: impl FnMut(&Json)) -> Result<Json> {
        proto::send(&mut self.stream, msg::SUBSCRIBE, job, &Json::Null)?;
        let (kind, _, p) = self.expect_reply()?;
        match kind {
            msg::ACK => {}
            msg::ERR => bail!("subscribe refused: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to subscribe"),
        }
        loop {
            let Some((kind, _, p)) = proto::recv(&mut self.stream)? else {
                bail!("daemon closed the stream before a result (job cancelled or daemon exited)");
            };
            match kind {
                msg::EVENT => on_event(&p),
                msg::RESULT => return Ok(p),
                msg::ERR => bail!("job {job} failed: {}", err_text(&p)),
                other => bail!("unexpected stream kind {other}"),
            }
        }
    }

    fn expect_reply(&mut self) -> Result<proto::Msg> {
        match proto::recv(&mut self.stream)? {
            Some(m) => Ok(m),
            None => bail!("daemon closed the connection"),
        }
    }

    fn expect_ack(&mut self, what: &str) -> Result<()> {
        let (kind, _, p) = self.expect_reply()?;
        match kind {
            msg::ACK => Ok(()),
            msg::ERR => bail!("{what} refused: {}", err_text(&p)),
            other => bail!("unexpected reply kind {other} to {what}"),
        }
    }
}

fn err_text(p: &Json) -> String {
    match p.get("error") {
        Some(v) => v.as_str().map(String::from).unwrap_or_else(|_| p.to_string()),
        None => "unknown error".to_string(),
    }
}

/// `mpq client <submit|status|watch|cancel|release|shutdown> --socket P`
pub fn cli(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("status");
    let socket = args.opt_str("socket", "mpqd.sock");
    let mut client = Client::connect(socket)?;
    match sub {
        "submit" => {
            let model = args.opt("model").context("submit needs --model")?;
            let mut policy = JobPolicy::default();
            policy.calib_n = args.opt_usize("calib", policy.calib_n)?;
            policy.seed = args.opt_u64("seed", policy.seed)?;
            if let Some(v) = args.opt("priority") {
                policy.priority = v.parse().map_err(|e| anyhow!("--priority {v}: {e}"))?;
            }
            if let Some(v) = args.opt("eval-budget") {
                policy.eval_budget =
                    Some(v.parse().map_err(|e| anyhow!("--eval-budget {v}: {e}"))?);
            }
            policy.adaround = !args.flag("no-adaround");
            policy.adaround_steps = args.opt_usize("adaround-steps", policy.adaround_steps)?;
            let id = client.submit(model, &policy)?;
            println!("job {id}");
        }
        "status" => println!("{}", client.status()?.to_string()),
        "watch" => {
            let job = args.opt_u64("job", 0)?;
            let result = client.watch(job, |e| println!("event {}", e.to_string()))?;
            println!("{}", result.to_string());
        }
        "cancel" => {
            let job = args.opt_u64("job", 0)?;
            client.cancel(job)?;
            println!("cancelled job {job}");
        }
        "release" => {
            client.release()?;
            println!("released");
        }
        "shutdown" => {
            client.shutdown()?;
            println!("daemon shutting down");
        }
        other => bail!("unknown client subcommand '{other}'"),
    }
    Ok(())
}
