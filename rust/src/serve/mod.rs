//! `mpqd` — quantization as a service.
//!
//! A long-running daemon that owns one process-wide evaluation fleet
//! ([`crate::pool::EvalFleet`]) and multiplexes many quantization jobs
//! onto it: each job runs the paper's full pipeline (calibrate → Phase-1
//! SQNR sensitivity → Phase-2 pareto search → AdaRound) and jobs whose
//! model is already resident on the fleet start at zero recompiles.
//!
//! ```text
//! mpq serve  --socket PATH [--artifacts DIR] [--state-dir DIR]
//!            [--workers N] [--max-idle N] [--max-jobs N] [--hold]
//! mpq client submit  --socket PATH --model M [--calib N] [--priority P]
//! mpq client status|watch|cancel|release|shutdown --socket PATH [--job J]
//! ```
//!
//! # Wire protocol
//!
//! Everything on the socket is an MPQJ checksummed frame (the same
//! `u32 len · u16 kind · u16 reserved · u64 digest · u64 checksum ·
//! payload` layout the run journal uses on disk — [`crate::store`]),
//! preceded by a mutual 8-byte MPQJ container-header handshake.  The
//! frame's `kind` is the message kind, the `digest` field carries the
//! job id, and payloads are small JSON objects capped at
//! [`proto::MAX_FRAME`]:
//!
//! | kind        | dir | payload                                        |
//! |-------------|-----|------------------------------------------------|
//! | `SUBMIT`    | c→d | `{model, policy?}`                             |
//! | `STATUS`    | c→d | —                                              |
//! | `CANCEL`    | c→d | — (job in digest)                              |
//! | `SUBSCRIBE` | c→d | — (job in digest; connection becomes a stream) |
//! | `RELEASE`   | c→d | — (start jobs staged under `--hold`)           |
//! | `SHUTDOWN`  | c→d | —                                              |
//! | `ACK`/`ERR` | d→c | `{job}` / `{error}`                            |
//! | `EVENT`     | d→c | `{phase}` or `{barrier, kind}` or `{cancelled}`|
//! | `RESULT`    | d→c | `{job, result, durability}`                    |
//! | `STATE`     | d→c | `{jobs, held, warm_models, sched_log, telemetry}` |
//!
//! This is a **control plane**: tensors, datasets and executables never
//! ride the socket — jobs name a model from the daemon's artifacts
//! manifest and all bulk data moves through the filesystem and the
//! fleet's own channels.
//!
//! # Admission and scheduling
//!
//! `Submit` is refused once `max_jobs` jobs are resident (queued +
//! running) — clients see a bounded, immediate `ERR` instead of an
//! unbounded queue.  Runnable jobs are ordered by `(priority desc,
//! least-recently-stepped, id)`: strict priority first, FIFO among
//! equals, and because the scheduler runs one *phase* per pick, equal
//! jobs round-robin phase-by-phase across the shared fleet.  A job whose
//! model another job just left warm ([`EvalFleet::set_max_idle`],
//! `--max-idle`) reattaches with zero recompiles.
//!
//! # Crash / restart semantics
//!
//! Every state transition is fsynced to `state_dir/job_<id>.json`
//! (atomic temp + rename) *before* it is acted on, and each running job
//! appends its evaluation barriers to a per-job journal
//! `state_dir/job_<id>.mpqj`.  A killed daemon restarts, reloads the
//! records, re-queues anything `queued`/`running`, and the journal
//! replays completed probes/prefix-evals/AdaRound layers bit-exactly —
//! zero completed units re-execute.  Job results are durable
//! (`job_<id>.result.json` before the `done` record; the journal is
//! removed only after), `Cancel` removes the journal and record
//! atomically, and a clean `Shutdown` parks running jobs back to
//! `queued` so nothing is stranded.

pub mod client;
pub mod daemon;
pub mod job;
pub mod proto;

pub use client::Client;
pub use daemon::{run, ServeCfg};
pub use job::{run_local, JobPolicy, JobRun, Phase};
