//! `mpqd` — quantization as a service.
//!
//! A long-running daemon that owns one process-wide evaluation fleet
//! ([`crate::pool::EvalFleet`]) and multiplexes many quantization jobs
//! onto it: each job runs the paper's full pipeline (calibrate → Phase-1
//! SQNR sensitivity → Phase-2 pareto search → AdaRound) and jobs whose
//! model is already resident on the fleet start at zero recompiles.
//!
//! ```text
//! mpq serve  --socket PATH [--artifacts DIR] [--state-dir DIR]
//!            [--workers N] [--max-idle N] [--max-jobs N] [--hold]
//!            [--io-timeout-ms MS]
//! mpq client submit  --socket PATH --model M [--calib N] [--priority P]
//!            [--deadline-ms MS] [--idem KEY] [--io-timeout-ms MS]
//! mpq client status|watch|cancel|release|shutdown --socket PATH [--job J]
//! ```
//!
//! # Wire protocol
//!
//! Everything on the socket is an MPQJ checksummed frame (the same
//! `u32 len · u16 kind · u16 reserved · u64 digest · u64 checksum ·
//! payload` layout the run journal uses on disk — [`crate::store`]),
//! preceded by a mutual 8-byte MPQJ container-header handshake.  The
//! frame's `kind` is the message kind, the `digest` field carries the
//! job id, and payloads are small JSON objects capped at
//! [`proto::MAX_FRAME`]:
//!
//! | kind        | dir | payload                                        |
//! |-------------|-----|------------------------------------------------|
//! | `SUBMIT`    | c→d | `{model, policy?}`                             |
//! | `STATUS`    | c→d | —                                              |
//! | `CANCEL`    | c→d | — (job in digest)                              |
//! | `SUBSCRIBE` | c→d | — (job in digest; connection becomes a stream) |
//! | `RELEASE`   | c→d | — (start jobs staged under `--hold`)           |
//! | `SHUTDOWN`  | c→d | —                                              |
//! | `ACK`/`ERR` | d→c | `{job}` / `{error}`                            |
//! | `EVENT`     | d→c | `{phase}` or `{barrier, kind}` or `{cancelled}`|
//! | `RESULT`    | d→c | `{job, result, durability}`                    |
//! | `STATE`     | d→c | `{jobs, held, warm_models, sched_log, telemetry}` |
//! | `RETRY_AFTER` | d→c | `{retry_after_ms, error}` (admission shed)   |
//!
//! This is a **control plane**: tensors, datasets and executables never
//! ride the socket — jobs name a model from the daemon's artifacts
//! manifest and all bulk data moves through the filesystem and the
//! fleet's own channels.
//!
//! # Timeouts, retries and chaos hardening
//!
//! Both sides of the socket run under one symmetric I/O deadline
//! (`--io-timeout-ms`, default 2000; `0` disables): a peer that stalls
//! **mid-frame** — or never drains its receive buffer — times out and
//! loses the connection, while an *idle* peer is never dropped (the
//! daemon's connection loop peeks between frames, and `watch` lifts the
//! read deadline once subscribed, since a long phase may stream nothing
//! for minutes).  Client submits carry an **idempotency key** (`{model,
//! policy?, idem?}`): on a transport error the client reconnects and
//! resubmits with bounded exponential backoff, and the daemon maps the
//! key to the already-admitted job — a retried submit of a finished job
//! returns the durable result without re-executing anything, across
//! daemon restarts (the key is persisted in the job record).  Overload
//! is a typed `RETRY_AFTER` shed, not an error; per-job `deadline_ms`
//! cancels an overrunning job at the next phase boundary while keeping
//! its journal, so a resubmit resumes.  The whole plane is exercised by
//! the chaos tier: the fault grammar's wire clauses (`wdrop@…`,
//! `wcorrupt@…`, `wseed:…` — see `pool/fault.rs`) inject into the
//! daemon's replies via `--fault-plan`, and every injected fault either
//! heals through retry or surfaces naming itself.
//!
//! # Admission and scheduling
//!
//! `Submit` is refused once `max_jobs` jobs are resident (queued +
//! running) — clients see a bounded, immediate `RETRY_AFTER` shed
//! instead of an unbounded queue.  Runnable jobs are ordered by
//! `(priority desc, least-recently-stepped, id)`: strict priority first,
//! FIFO among equals, and because the scheduler runs one *phase* per
//! pick, equal jobs round-robin phase-by-phase across the shared fleet.
//! A job whose model another job just left warm
//! ([`EvalFleet::set_max_idle`], `--max-idle`) reattaches with zero
//! recompiles.
//!
//! # Crash / restart semantics
//!
//! Every state transition is fsynced to `state_dir/job_<id>.json`
//! (atomic temp + rename) *before* it is acted on, and each running job
//! appends its evaluation barriers to a per-job journal
//! `state_dir/job_<id>.mpqj`.  A killed daemon restarts, reloads the
//! records, re-queues anything `queued`/`running`, and the journal
//! replays completed probes/prefix-evals/AdaRound layers bit-exactly —
//! zero completed units re-execute.  Job results are durable
//! (`job_<id>.result.json` before the `done` record; the journal is
//! removed only after), `Cancel` removes the journal and record
//! atomically, and a clean `Shutdown` parks running jobs back to
//! `queued` so nothing is stranded.

pub mod client;
pub mod daemon;
pub mod job;
pub mod proto;

pub use client::Client;
pub use daemon::{run, ServeCfg};
pub use job::{run_local, JobPolicy, JobRun, Phase};
