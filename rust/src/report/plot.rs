//! ASCII curve rendering for the figure reproductions (Figs. 2, 4, 5):
//! turns `(x, y)` series into a terminal scatter/step plot so the pareto
//! curves are inspectable without any plotting stack.

/// One named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Self { name: name.into(), points }
    }
}

/// Render series into a `width`×`height` character grid with axis labels.
/// Each series gets a distinct marker; overlapping cells show the later
/// series' marker.
pub fn render(title: &str, xlabel: &str, ylabel: &str, series: &[Series],
              width: usize, height: usize) -> String {
    const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@'];
    let pts: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if pts.is_empty() {
        return format!("== {title} == (no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out.push_str(&format!("{ylabel} {y1:>8.4}\n"));
    for row in &grid {
        out.push_str("  |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("  +{}\n", "-".repeat(width)));
    out.push_str(&format!(
        "   {x0:<10.3}{:>pad$.3}   ({xlabel})\n",
        x1,
        pad = width.saturating_sub(10)
    ));
    out.push_str(&format!("  y-min {y0:.4}\n"));
    out
}

/// Parse the `"r:metric r:metric …"` strings the experiment tables store.
pub fn parse_curve(s: &str) -> Vec<(f64, f64)> {
    s.split_whitespace()
        .filter_map(|p| {
            let (a, b) = p.split_once(':')?;
            Some((a.parse().ok()?, b.parse().ok()?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_curve_roundtrip() {
        let pts = parse_curve("1.000:0.95 0.500:0.93 0.250:0.80");
        assert_eq!(pts, vec![(1.0, 0.95), (0.5, 0.93), (0.25, 0.8)]);
        assert!(parse_curve("garbage").is_empty());
    }

    #[test]
    fn render_contains_marks_and_bounds() {
        let s = vec![
            Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)]),
            Series::new("b", vec![(0.5, 0.2)]),
        ];
        let out = render("T", "r", "acc", &s, 40, 10);
        assert!(out.contains("== T =="));
        assert!(out.contains('*') && out.contains('o'));
        assert!(out.contains("0.000"));
        assert!(out.contains("1.000"));
    }

    #[test]
    fn render_degenerate_ranges() {
        let s = vec![Series::new("a", vec![(0.5, 0.5), (0.5, 0.5)])];
        let out = render("T", "x", "y", &s, 20, 5);
        assert!(out.contains('*'));
        assert!(render("E", "x", "y", &[], 20, 5).contains("no data"));
    }
}
