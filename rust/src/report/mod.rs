//! Table/figure rendering: aligned text for the terminal, CSV for files.
//!
//! Every experiment in `crate::experiments` emits one or more [`Table`]s;
//! `save` drops them under `results/` so EXPERIMENTS.md can reference them.

pub mod plot;

use anyhow::Result;
use std::fmt::Write as _;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                let pad = width[i] - c.chars().count();
                s.push_str(c);
                s.push_str(&" ".repeat(pad));
            }
            let _ = writeln!(out, "{}", s.trim_end());
        };
        line(&mut out, &self.headers);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Save both renderings under `results/<stem>.{txt,csv}` (atomic
    /// temp+rename — a crash mid-save never leaves a half-written report).
    pub fn save(&self, results_dir: impl AsRef<Path>, stem: &str) -> Result<()> {
        let dir = results_dir.as_ref();
        std::fs::create_dir_all(dir)?;
        crate::store::atomic_write(dir.join(format!("{stem}.txt")), self.render().as_bytes())?;
        crate::store::atomic_write(dir.join(format!("{stem}.csv")), self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Format helpers shared by the experiment drivers.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

/// Render the evaluation fleet's failure telemetry
/// ([`crate::pool::FailureStats`]) as a [`Table`] — counters first, then
/// one row per degradation event and stored death reason, so driver
/// reports carry the self-healing story alongside the paper numbers.
pub fn fleet_failure_table(stats: &crate::pool::FailureStats) -> Table {
    let mut t = Table::new("Fleet failures — supervision telemetry", &["event", "detail"]);
    t.row(vec!["worker_restarts".into(), stats.worker_restarts.to_string()]);
    t.row(vec!["jobs_requeued".into(), stats.jobs_requeued.to_string()]);
    t.row(vec!["faults_injected".into(), stats.faults_injected.to_string()]);
    for d in &stats.degraded_events {
        t.row(vec!["degraded".into(), d.clone()]);
    }
    for d in &stats.last_deaths {
        t.row(vec!["death".into(), d.clone()]);
    }
    t
}

/// Render the durability telemetry ([`crate::store::StoreStats`]) as a
/// [`Table`] — journal traffic first, then the degradation counters, so
/// resumed / corruption-degraded runs surface their story next to the
/// fleet failure table.
pub fn store_stats_table(stats: &crate::store::StoreStats) -> Table {
    let mut t = Table::new("Store — durability telemetry", &["event", "count"]);
    t.row(vec!["journal_appended".into(), stats.journal_appended.get().to_string()]);
    t.row(vec!["journal_replayed".into(), stats.journal_replayed.get().to_string()]);
    t.row(vec!["journal_skips".into(), stats.journal_skips.get().to_string()]);
    t.row(vec!["journal_truncations".into(), stats.journal_truncations.get().to_string()]);
    t.row(vec!["cache_corrupt_misses".into(), stats.cache_corrupt_misses.get().to_string()]);
    t.row(vec!["files_quarantined".into(), stats.files_quarantined.get().to_string()]);
    t
}

/// Default results directory, overridable with `MPQ_RESULTS`.
pub fn results_dir() -> std::path::PathBuf {
    std::env::var_os("MPQ_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment() {
        let mut t = Table::new("T", &["model", "acc"]);
        t.row(vec!["resnet".into(), "0.91".into()]);
        t.row(vec!["m".into(), "0.123456".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        // header & rows aligned on the second column
        let col = lines[1].find("acc").unwrap();
        assert_eq!(lines[3].find("0.91").unwrap(), col);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
