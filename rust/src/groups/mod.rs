//! Bit-width candidates and quantizer-group assignment (paper §3.4).
//!
//! On-device kernels come in fixed (weight-bits, activation-bits) pairs —
//! e.g. a device may only ship W4A8 / W8A8 / W8A16 kernels.  A
//! [`Lattice`] is that kernel menu; Phase 2 flips whole groups between
//! lattice [`Candidate`]s, never individual tensors.

use crate::manifest::ModelEntry;
use anyhow::{bail, Result};

/// One hardware kernel option: weight bits × activation bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Candidate {
    pub wbits: u8,
    pub abits: u8,
}

impl Candidate {
    pub const fn new(wbits: u8, abits: u8) -> Self {
        Self { wbits, abits }
    }

    /// BOPs weight of this candidate (Eq. 5 factor `b_w · b_a`).
    pub fn bops_factor(&self) -> u64 {
        self.wbits as u64 * self.abits as u64
    }

    pub fn label(&self) -> String {
        format!("W{}A{}", self.wbits, self.abits)
    }
}

/// The search space of kernel candidates, with the highest-precision
/// baseline Phase 2 starts from.
#[derive(Clone, Debug)]
pub struct Lattice {
    pub candidates: Vec<Candidate>,
    pub baseline: Candidate,
}

impl Lattice {
    /// The paper's practical deployment menu: W4A8, W8A8, W8A16
    /// (Tables 1 & 3-5).
    pub fn practical() -> Self {
        Self {
            candidates: vec![
                Candidate::new(4, 8),
                Candidate::new(8, 8),
                Candidate::new(8, 16),
            ],
            baseline: Candidate::new(8, 16),
        }
    }

    /// Fig. 2/4's two-candidate menu: W4A8 + W8A8, starting from W8A8
    /// (curve compression is reported relative to the W8A8 model).
    pub fn practical_no16() -> Self {
        Self {
            candidates: vec![Candidate::new(4, 8), Candidate::new(8, 8)],
            baseline: Candidate::new(8, 8),
        }
    }

    /// The expanded low-bit space of Table 2 / Fig. 5:
    /// W4A4, W4A6, W6A4, W6A6, W8A6, W6A8, W8A8, W8A16.
    pub fn expanded() -> Self {
        Self {
            candidates: vec![
                Candidate::new(4, 4),
                Candidate::new(4, 6),
                Candidate::new(6, 4),
                Candidate::new(6, 6),
                Candidate::new(8, 6),
                Candidate::new(6, 8),
                Candidate::new(8, 8),
                Candidate::new(8, 16),
            ],
            baseline: Candidate::new(8, 16),
        }
    }

    /// Candidates strictly cheaper (in BOPs factor) than `cur` — the legal
    /// downward flips for a group currently at `cur`.
    pub fn cheaper_than(&self, cur: Candidate) -> Vec<Candidate> {
        self.candidates
            .iter()
            .copied()
            .filter(|c| c.bops_factor() < cur.bops_factor())
            .collect()
    }

    /// Distinct weight-bit options (for AdaRound precomputation).
    pub fn wbits_options(&self) -> Vec<u8> {
        let mut v: Vec<u8> = self.candidates.iter().map(|c| c.wbits).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Distinct activation-bit options.
    pub fn abits_options(&self) -> Vec<u8> {
        let mut v: Vec<u8> = self.candidates.iter().map(|c| c.abits).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Per-group candidate assignment: the mixed-precision configuration Phase 2
/// manipulates.  Weightless groups (no MACs) are pinned to the baseline —
/// flipping them cannot reduce BOPs (Eq. 5 only counts MAC ops).
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub per_group: Vec<Candidate>,
}

impl Assignment {
    pub fn baseline(entry: &ModelEntry, lattice: &Lattice) -> Self {
        Self { per_group: vec![lattice.baseline; entry.groups.len()] }
    }

    /// Is group `g` flippable (owns at least one weighted op)?
    pub fn flippable(entry: &ModelEntry, g: usize) -> bool {
        entry.groups[g].macs > 0 && !entry.groups[g].w_q.is_empty()
    }

    pub fn set(&mut self, g: usize, c: Candidate) {
        self.per_group[g] = c;
    }

    /// Expand to per-quantizer bit levels: `(act_bits[A], w_bits[W])`,
    /// `None` = leave FP (never used by full configs, but probes use it).
    pub fn per_quantizer(&self, entry: &ModelEntry) -> (Vec<Option<u8>>, Vec<Option<u8>>) {
        let mut act = vec![None; entry.n_act()];
        let mut w = vec![None; entry.n_w()];
        for (g, cand) in self.per_group.iter().enumerate() {
            for &a in &entry.groups[g].act_q {
                act[a] = Some(cand.abits);
            }
            for &wq in &entry.groups[g].w_q {
                w[wq] = Some(cand.wbits);
            }
        }
        (act, w)
    }

    /// Sanity check: every quantizer belongs to exactly one group.
    pub fn validate_partition(entry: &ModelEntry) -> Result<()> {
        let mut act_seen = vec![0usize; entry.n_act()];
        let mut w_seen = vec![0usize; entry.n_w()];
        for g in &entry.groups {
            for &a in &g.act_q {
                act_seen[a] += 1;
            }
            for &w in &g.w_q {
                w_seen[w] += 1;
            }
        }
        if act_seen.iter().any(|&c| c != 1) || w_seen.iter().any(|&c| c != 1) {
            bail!("quantizer groups do not partition the quantizers: act={act_seen:?} w={w_seen:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_menus_match_paper() {
        let p = Lattice::practical();
        assert_eq!(p.candidates.len(), 3);
        assert_eq!(p.baseline, Candidate::new(8, 16));
        let e = Lattice::expanded();
        assert_eq!(e.candidates.len(), 8);
        assert!(e.candidates.contains(&Candidate::new(6, 4)));
    }

    #[test]
    fn bops_factors() {
        // relative r of fixed configs vs W8A16 — Table 1/2 headers
        let base = Candidate::new(8, 16).bops_factor() as f64;
        assert_eq!(Candidate::new(8, 8).bops_factor() as f64 / base, 0.5);
        assert_eq!(Candidate::new(6, 8).bops_factor() as f64 / base, 0.375);
        assert!((Candidate::new(6, 6).bops_factor() as f64 / base - 0.28125).abs() < 1e-9);
        assert_eq!(Candidate::new(4, 8).bops_factor() as f64 / base, 0.25);
    }

    #[test]
    fn cheaper_than_is_strict() {
        let l = Lattice::practical();
        let c = l.cheaper_than(Candidate::new(8, 8));
        assert_eq!(c, vec![Candidate::new(4, 8)]);
        assert!(l.cheaper_than(Candidate::new(4, 8)).is_empty());
    }

    #[test]
    fn bit_options() {
        let e = Lattice::expanded();
        assert_eq!(e.wbits_options(), vec![4, 6, 8]);
        assert_eq!(e.abits_options(), vec![4, 6, 8, 16]);
    }
}
