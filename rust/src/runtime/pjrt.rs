//! PJRT implementation of the execution backend (the `pjrt` cargo
//! feature): HLO-text artifacts compiled and executed through the `xla`
//! crate on a CPU `PjRtClient`.  See the module docs in
//! [`crate::runtime`] for the interchange-format and threading contracts.

use super::{Backend, Buffer, Executable};
use crate::tensor::{Data, Tensor};
use anyhow::{anyhow, bail, Result};
use std::path::Path;

pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client })
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, path: &Path) -> Result<Box<dyn Executable>> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Box::new(PjrtExe { exe }))
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        let dims = &t.shape;
        let buf = match &t.data {
            Data::F32(v) => self
                .client
                .buffer_from_host_buffer(v, dims, None)
                .map_err(|e| anyhow!("upload f32 {:?}: {e:?}", dims))?,
            Data::I32(v) => self
                .client
                .buffer_from_host_buffer(v, dims, None)
                .map_err(|e| anyhow!("upload i32 {:?}: {e:?}", dims))?,
        };
        Ok(Buffer::Pjrt(buf))
    }
}

struct PjrtExe {
    exe: xla::PjRtLoadedExecutable,
}

// error messages carry no executable name — `Exe::run_b` wraps every
// execution error with `executing <artifact file>` generically
impl Executable for PjrtExe {
    fn run(&self, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        let bufs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .map(|b| match b {
                Buffer::Pjrt(p) => Ok(p),
                Buffer::Host(_) => Err(anyhow!("host (sim) buffer passed to PJRT")),
            })
            .collect::<Result<_>>()?;
        let outs = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let buf = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output buffer"))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts.into_iter().map(literal_to_tensor).collect()
    }
}

pub fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Tensor::from_f32(&dims, v)
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = lit.to_vec().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Tensor::from_i32(&dims, v)
        }
        t => bail!("unsupported output element type {t:?}"),
    }
}
