//! Execution runtime: a pluggable backend behind one [`Runtime`] facade.
//!
//! Everything above this layer (model handles, the evaluation engine, the
//! pool, Phase 1/2) speaks three verbs: *compile an artifact*, *upload a
//! host tensor*, *execute with resident buffers*.  Those verbs are the
//! [`Backend`] / [`Executable`] traits; two implementations exist:
//!
//! * **PJRT** ([`pjrt`], behind the default `pjrt` cargo feature) — loads
//!   AOT-compiled HLO-text artifacts and executes them through the `xla`
//!   crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `compile` → `execute_b`).  Follows /opt/xla-example/load_hlo: HLO
//!   *text* is the interchange format (64-bit-id protos from jax ≥ 0.5 are
//!   rejected by xla_extension 0.5.1), and every executable returns a 1+
//!   element tuple (`return_tuple=True` at lowering).
//! * **Sim** ([`crate::sim`]) — a pure-Rust interpreter for a synthetic
//!   linear+fake-quant model family, selected by `"backend": "sim"` in the
//!   manifest.  It consumes the *same* packed quant-param tensors and the
//!   same argument layout as the lowered HLO executables, so the whole
//!   Phase-1/Phase-2/pool stack runs end-to-end on it with no PJRT
//!   artifacts, no `xla` shared library and no skips — the hermetic test
//!   tier (see `rust/tests/README.md`).
//!
//! Performance notes (§Perf): all executions go through [`Exe::run_b`] with
//! backend-resident [`Buffer`] arguments — model weights and calibration
//! batches are uploaded **once** per run (see `ModelHandle::param_buffers`),
//! and every consumer (forward, stats, taps, FIT) shares those buffers
//! instead of re-uploading per batch.  Above this layer, [`crate::engine`]
//! removes the remaining per-probe redundancy:
//!
//! * the FP32 reference (logits + per-sample signal power) is **one cached
//!   forward sweep** per `(model, eval-set)`, so a Phase-1 sweep costs
//!   exactly `1 + probes` forward-sweep-equivalents;
//! * SQNR and task metrics **stream batch-by-batch** — no `O(N×C)` host
//!   concatenation per probe;
//! * Phase-2 prefix metrics are **memoized** by canonical configuration, so
//!   re-visited prefixes (binary/interpolation revisits, the final report)
//!   cost zero forward calls;
//! * packed quant-param tensors are **row-patched** from a cached FP32
//!   baseline rather than recomputed per probe;
//! * pure host math (weight-scale grid search, quantization MSE, FIT
//!   accumulation) fans out across threads via `util::par_map` — the PJRT
//!   client itself is single-threaded and is **never shared across
//!   threads**.
//!
//! The PJRT client's `!Send` boundary is scaled past by *replication*, not
//! sharing: [`crate::pool::EvalPool`] spawns N worker threads, each
//! constructing its own `Runtime` (own client, own compiled executables,
//! own resident parameters) entirely inside the thread, with its own
//! contiguous shard of each eval set.  Only host tensors and configurations
//! cross the channels; probe results come back as per-shard streaming
//! accumulators merged in global batch order, which is what makes pooled
//! results bit-identical to this single-client path.  The sim backend keeps
//! the identical architecture (its "buffers" are host tensors), so the pool
//! paths are exercised for real in the hermetic tier.
//!
//! Run-time accounting: `Exe::calls`, `ModelHandle::fwd_calls` and the
//! engine's eval/memo/reference counters feed the Table-5 numbers
//! (per-worker in a pool; the pool adds its own probe/memo counters).

#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A backend-resident buffer.  Uploaded once, referenced by every execution
/// that needs it; which variant a `Runtime` produces is an implementation
/// detail callers never match on.
pub enum Buffer {
    /// Device-resident PJRT buffer (the `pjrt` backend).
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
    /// Host-resident tensor (the sim backend's "device" is host memory).
    Host(Tensor),
}

/// One compiled artifact: takes resident [`Buffer`]s, returns host tensors.
pub trait Executable {
    fn run(&self, args: &[&Buffer]) -> Result<Vec<Tensor>>;
}

/// An execution backend: compile artifacts, upload tensors.
pub trait Backend {
    /// Human-readable platform tag (diagnostics only).
    fn platform(&self) -> String;
    /// Parse + compile the artifact at `path`.
    fn compile(&self, path: &Path) -> Result<Box<dyn Executable>>;
    /// Upload a host tensor to a backend-resident buffer.
    fn upload(&self, t: &Tensor) -> Result<Buffer>;
}

/// A compiled executable plus bookkeeping.
pub struct Exe {
    pub name: String,
    imp: Box<dyn Executable>,
    /// number of `run*` invocations (run-time accounting for Table 5)
    pub calls: RefCell<u64>,
}

/// Backend facade with an executable cache keyed by artifact path.
pub struct Runtime {
    backend: Box<dyn Backend>,
    cache: RefCell<HashMap<PathBuf, Rc<Exe>>>,
    /// armed compile fault (deterministic fault-injection harness, see
    /// `pool::fault`): `(cache-miss compiles left before failing, counter
    /// bumped when the fault actually fires)`
    compile_fault: RefCell<Option<(usize, std::sync::Arc<std::sync::atomic::AtomicUsize>)>>,
}

impl Runtime {
    fn with_backend(backend: Box<dyn Backend>) -> Self {
        Self {
            backend,
            cache: RefCell::new(HashMap::new()),
            compile_fault: RefCell::new(None),
        }
    }

    /// PJRT CPU backend (requires the `pjrt` feature and the
    /// `xla_extension` shared library baked into the toolchain image).
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Self> {
        Ok(Self::with_backend(Box::new(pjrt::PjrtBackend::cpu()?)))
    }

    /// Pure-Rust sim backend ([`crate::sim`]).
    pub fn sim() -> Self {
        Self::with_backend(Box::new(crate::sim::SimBackend))
    }

    /// The backend a manifest's artifacts were built for
    /// (`manifest.json`'s `"backend"` key; `"pjrt"` when absent).
    pub fn for_manifest(manifest: &crate::manifest::Manifest) -> Result<Self> {
        Self::for_backend(&manifest.backend)
    }

    /// Construct by backend tag: `"pjrt"` or `"sim"`.
    pub fn for_backend(kind: &str) -> Result<Self> {
        match kind {
            "sim" => Ok(Self::sim()),
            #[cfg(feature = "pjrt")]
            "pjrt" => Self::cpu(),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => bail!(
                "these artifacts want the PJRT backend, but this build has \
                 no `pjrt` feature (rebuild with default features)"
            ),
            k => bail!("unknown execution backend '{k}' (want 'pjrt' or 'sim')"),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Arm an injected compile failure: the `nth` (1-based) cache-miss
    /// compile after this call fails with an `injected fault:` error, then
    /// the hook disarms.  Cache hits don't count — only real compiles.
    /// Part of the deterministic fault-injection harness (`pool::fault`);
    /// `fired` is bumped when the failure actually triggers.
    pub fn inject_compile_fault(
        &self,
        nth: usize,
        fired: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    ) {
        *self.compile_fault.borrow_mut() = Some((nth.max(1), fired));
    }

    /// Load + compile an artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Rc<Exe>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.borrow().get(&path) {
            return Ok(e.clone());
        }
        let fire = {
            let mut armed = self.compile_fault.borrow_mut();
            match armed.as_mut() {
                Some((left, fired)) if *left <= 1 => {
                    let fired = fired.clone();
                    *armed = None; // disarm — the fault fires exactly once
                    Some(fired)
                }
                Some((left, _)) => {
                    *left -= 1;
                    None
                }
                None => None,
            }
        };
        if let Some(fired) = fire {
            fired.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            bail!("injected fault: compile failure for {}", path.display());
        }
        let imp = self.backend.compile(&path)?;
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let rc = Rc::new(Exe { name, imp, calls: RefCell::new(0) });
        self.cache.borrow_mut().insert(path, rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor to a backend-resident buffer.
    pub fn buffer(&self, t: &Tensor) -> Result<Buffer> {
        self.backend.upload(t)
    }

    /// Number of distinct compiled executables (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

impl Exe {
    /// Execute with resident buffers; returns the decomposed output tuple
    /// as host tensors.
    pub fn run_b(&self, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        *self.calls.borrow_mut() += 1;
        self.imp
            .run(args)
            .with_context(|| format!("executing {}", self.name))
    }

    /// Convenience: upload host tensors, then `run_b`.
    pub fn run(&self, rt: &Runtime, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let bufs: Vec<Buffer> = args.iter().map(|t| rt.buffer(t)).collect::<Result<_>>()?;
        let refs: Vec<&Buffer> = bufs.iter().collect();
        self.run_b(&refs)
    }
}

impl Buffer {
    /// The host tensor behind a [`Buffer::Host`]; errors on a buffer that
    /// belongs to a different backend (a PJRT buffer handed to the sim
    /// interpreter is a wiring bug, not a downloadable value).
    pub fn host(&self) -> Result<&Tensor> {
        match self {
            Buffer::Host(t) => Ok(t),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => bail!("expected a host (sim) buffer, got a PJRT buffer"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_backend_rejects_unknown() {
        assert!(Runtime::for_backend("tpu-v9").is_err());
    }

    #[test]
    fn injected_compile_fault_fires_once_then_disarms() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rt = Runtime::sim();
        let fired = std::sync::Arc::new(AtomicUsize::new(0));
        rt.inject_compile_fault(1, fired.clone());
        let err = format!("{:#}", rt.load("/nonexistent/prog.json").unwrap_err());
        assert!(err.contains("injected fault"), "unexpected error: {err}");
        assert_eq!(fired.load(Ordering::Relaxed), 1);
        // disarmed: the next miss reaches the real backend (file error)
        let err2 = format!("{:#}", rt.load("/nonexistent/prog.json").unwrap_err());
        assert!(!err2.contains("injected fault"), "hook must disarm: {err2}");
        assert_eq!(fired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn sim_backend_constructs_and_uploads() {
        let rt = Runtime::sim();
        assert_eq!(rt.platform(), "sim-host");
        assert_eq!(rt.compiled_count(), 0);
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = rt.buffer(&t).unwrap();
        assert_eq!(b.host().unwrap(), &t);
    }
}
