//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute_b`).  Follows /opt/xla-example/load_hlo: HLO *text*
//! is the interchange format (64-bit-id protos from jax ≥ 0.5 are rejected
//! by xla_extension 0.5.1), and every executable returns a 1+ element tuple
//! (`return_tuple=True` at lowering).
//!
//! Performance notes (§Perf): all executions go through [`Exe::run_b`] with
//! device-resident [`xla::PjRtBuffer`] arguments — model weights and
//! calibration batches are uploaded **once** per run (see
//! `ModelHandle::param_buffers`), and every consumer (forward, stats, taps,
//! FIT) shares those buffers instead of re-uploading per batch.  Above this
//! layer, [`crate::engine`] removes the remaining per-probe redundancy:
//!
//! * the FP32 reference (logits + per-sample signal power) is **one cached
//!   forward sweep** per `(model, eval-set)`, so a Phase-1 sweep costs
//!   exactly `1 + probes` forward-sweep-equivalents;
//! * SQNR and task metrics **stream batch-by-batch** — no `O(N×C)` host
//!   concatenation per probe;
//! * Phase-2 prefix metrics are **memoized** by canonical configuration, so
//!   re-visited prefixes (binary/interpolation revisits, the final report)
//!   cost zero forward calls;
//! * packed quant-param tensors are **row-patched** from a cached FP32
//!   baseline rather than recomputed per probe;
//! * pure host math (weight-scale grid search, quantization MSE, FIT
//!   accumulation) fans out across threads via `util::par_map` — the PJRT
//!   client itself is single-threaded and is **never shared across
//!   threads**.
//!
//! The client's `!Send` boundary is scaled past by *replication*, not
//! sharing: [`crate::pool::EvalPool`] spawns N worker threads, each
//! constructing its own `Runtime` (own `PjRtClient`, own compiled
//! executables, own device-resident parameters) entirely inside the
//! thread, with its own contiguous shard of each eval set.  Only host
//! tensors and configurations cross the channels; probe results come back
//! as per-shard streaming accumulators merged in global batch order, which
//! is what makes pooled results bit-identical to this single-client path.
//!
//! Run-time accounting: `Exe::calls`, `ModelHandle::fwd_calls` and the
//! engine's eval/memo/reference counters feed the Table-5 numbers
//! (per-worker in a pool; the pool adds its own probe/memo counters).

use crate::tensor::{Data, Tensor};
use anyhow::{anyhow, bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A compiled executable plus bookkeeping.
pub struct Exe {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// number of `run*` invocations (run-time accounting for Table 5)
    pub calls: RefCell<u64>,
}

/// PJRT client wrapper with an executable cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<Exe>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Rc<Exe>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.borrow().get(&path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let rc = Rc::new(Exe { name, exe, calls: RefCell::new(0) });
        self.cache.borrow_mut().insert(path, rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor to a device buffer.
    pub fn buffer(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims = &t.shape;
        match &t.data {
            Data::F32(v) => self
                .client
                .buffer_from_host_buffer(v, dims, None)
                .map_err(|e| anyhow!("upload f32 {:?}: {e:?}", dims)),
            Data::I32(v) => self
                .client
                .buffer_from_host_buffer(v, dims, None)
                .map_err(|e| anyhow!("upload i32 {:?}: {e:?}", dims)),
        }
    }

    /// Number of distinct compiled executables (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

impl Exe {
    /// Execute with device buffers; returns the decomposed output tuple as
    /// host tensors.
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        *self.calls.borrow_mut() += 1;
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let buf = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: untuple: {e:?}", self.name))?;
        parts.into_iter().map(literal_to_tensor).collect()
    }

    /// Convenience: upload host tensors, then `run_b`.
    pub fn run(&self, rt: &Runtime, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let bufs: Vec<xla::PjRtBuffer> =
            args.iter().map(|t| rt.buffer(t)).collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_b(&refs)
    }
}

pub fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Tensor::from_f32(&dims, v)
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = lit.to_vec().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Tensor::from_i32(&dims, v)
        }
        t => bail!("unsupported output element type {t:?}"),
    }
}
