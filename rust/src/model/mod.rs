//! Quantized-model evaluation service.
//!
//! [`ModelHandle`] owns everything needed to evaluate one zoo model under
//! arbitrary bit-width configurations: the compiled forward executable, the
//! device-resident trained parameters, the calibration/validation data, and
//! the calibrated quantizer ranges.
//!
//! A configuration is a [`QuantConfig`] — per-quantizer `Option<bits>` —
//! materialized into the three packed runtime tensors the forward
//! executable consumes (`act_qp[A,5]`, `w_scales[W,Cmax]`, `w_qmeta[W,3]`,
//! see `python/compile/quantize.py`).  `None` rows have `enable = 0` and
//! bypass the quantizer exactly, so FP32 evaluation is the all-`None`
//! config on the *same* executable.

use crate::data::{self, DataSet, ModelData};
use crate::engine;
use crate::manifest::{Manifest, ModelEntry};
use crate::quant::{self, ActRanges};
use crate::runtime::{Buffer, Exe, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-quantizer bit assignment; `None` = leave in FP32.
///
/// `Eq + Hash` make the canonical configuration itself the key of the
/// engine's evaluation memo.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub act: Vec<Option<u8>>,
    pub w: Vec<Option<u8>>,
}

impl QuantConfig {
    pub fn fp32(entry: &ModelEntry) -> Self {
        Self { act: vec![None; entry.n_act()], w: vec![None; entry.n_w()] }
    }

    /// Homogeneous WxAy configuration.
    pub fn fixed(entry: &ModelEntry, wbits: u8, abits: u8) -> Self {
        Self {
            act: vec![Some(abits); entry.n_act()],
            w: vec![Some(wbits); entry.n_w()],
        }
    }
}

/// Weight overrides for AdaRound-stitched configurations: parameter index →
/// replacement tensor (already fake-quantized; the weight quantizer is
/// disabled for overridden params).
pub type WeightOverrides = HashMap<usize, Tensor>;

/// A batched, device-resident evaluation set (inputs only; labels stay on
/// the host for metric computation).
///
/// **Truncation contract:** the lowered executables have a *static* batch
/// dimension, so a dataset whose length is not a multiple of
/// [`ModelEntry::batch`] is truncated to `⌊len/batch⌋·batch` samples — the
/// ragged tail is dropped, never padded (padding would perturb batch-norm
/// statistics and metric counts).  `n` always reports the truncated count
/// and `labels` holds exactly `n` rows, so metrics stay consistent with
/// what actually ran; callers that must score every sample size their
/// subsets as batch multiples (see `DataSet::batches`).
pub struct EvalSet {
    /// process-unique identity — the engine's FP-reference cache key
    pub id: u64,
    pub batches: Vec<Buffer>,
    pub labels: Tensor,
    pub n: usize,
    pub batch: usize,
}

static NEXT_EVAL_SET_ID: AtomicU64 = AtomicU64::new(0);

fn next_eval_set_id() -> u64 {
    NEXT_EVAL_SET_ID.fetch_add(1, Ordering::Relaxed)
}

pub struct ModelHandle {
    pub rt: Rc<Runtime>,
    pub entry: ModelEntry,
    pub fwd: Rc<Exe>,
    /// host copies of the trained parameters (AdaRound math needs them)
    pub weights: Vec<Tensor>,
    /// backend-resident parameters (uploaded once)
    param_bufs: Vec<Buffer>,
    pub data: ModelData,
    /// calibrated activation ranges (None until [`Self::calibrate_ranges`])
    pub act_ranges: Option<ActRanges>,
    /// per-bits per-weight-quantizer MSE-optimal scales
    pub w_scales: HashMap<u8, Vec<Vec<f32>>>,
    /// forward executions performed (run-time accounting, Table 5)
    pub fwd_calls: RefCell<u64>,
    /// evaluation-engine state: FP reference cache + config materializer
    pub engine: engine::HandleEngine,
}

impl ModelHandle {
    pub fn open(rt: Rc<Runtime>, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.model(name)?.clone();
        let fwd = rt.load(manifest.path(&entry.forward))?;
        let weights = data::load_weights(&manifest.dir, &entry)?;
        let param_bufs = weights
            .iter()
            .map(|t| rt.buffer(t))
            .collect::<Result<Vec<_>>>()
            .context("uploading parameters")?;
        let md = ModelData::load(&manifest.dir, &entry.data)?;
        let eng = engine::HandleEngine::new(&entry);
        Ok(Self {
            rt,
            entry,
            fwd,
            weights,
            param_bufs,
            data: md,
            act_ranges: None,
            w_scales: HashMap::new(),
            fwd_calls: RefCell::new(0),
            engine: eng,
        })
    }

    /// Backend-resident trained parameters (uploaded once at open) — shared
    /// by the forward, stats, taps and FIT executables so no caller
    /// re-uploads them per batch.
    pub fn param_buffers(&self) -> &[Buffer] {
        &self.param_bufs
    }

    /// Cached FP32 reference for `set` (one forward sweep on first use).
    pub fn fp_reference(&self, set: &EvalSet) -> Result<Rc<engine::FpReference>> {
        self.engine.reference(self, set)
    }

    // -- calibration ---------------------------------------------------------

    /// Run the stats executable over `set` and distill MSE-optimal
    /// activation ranges; also precompute per-bits weight scales.
    pub fn calibrate_ranges(&mut self, manifest: &Manifest, set: &EvalSet) -> Result<()> {
        let stats = self.rt.load(manifest.path(&self.entry.stats))?;
        let mut ranges = ActRanges::new(
            self.entry.n_act(),
            self.entry.stats_bits.clone(),
            self.entry.stats_ratios.clone(),
        );
        for xb in &set.batches {
            let mut args: Vec<&Buffer> = vec![xb];
            args.extend(self.param_bufs.iter());
            // output tuple: one captured activation tensor per quantizer
            let outs = stats.run_b(&args)?;
            if outs.len() != self.entry.n_act() {
                bail!(
                    "stats exe returned {} outputs, want {}",
                    outs.len(),
                    self.entry.n_act()
                );
            }
            ranges.accumulate(&outs, set.batches.len())?;
        }
        self.act_ranges = Some(ranges);
        // new ranges invalidate the engine's cached activation qparam rows
        self.engine.mat.invalidate();

        let ratios = quant::default_ratios();
        let bits_list = self.entry.stats_bits.clone();
        for bits in bits_list {
            self.ensure_weight_scales(bits, &ratios)?;
        }
        Ok(())
    }

    pub fn ensure_weight_scales(&mut self, bits: u8, ratios: &[f64]) -> Result<()> {
        if self.w_scales.contains_key(&bits) {
            return Ok(());
        }
        // The MSE ratio grid search is independent per quantizer and pure
        // host math — fan it across threads (no PJRT involvement).
        let weights = &self.weights;
        let per_q = crate::util::par_map(&self.entry.w_quantizers, |_, wq| {
            quant::weight_scales_mse(
                &weights[wq.param_idx],
                wq.channels,
                wq.channel_axis,
                bits,
                ratios,
            )
        })
        .into_iter()
        .collect::<Result<Vec<_>>>()?;
        self.w_scales.insert(bits, per_q);
        Ok(())
    }

    // -- eval sets -----------------------------------------------------------

    /// Upload a dataset subset as device batches.
    ///
    /// A trailing partial batch is dropped per the [`EvalSet`] truncation
    /// contract; `n` and `labels` reflect the truncated sample count.
    pub fn eval_set(&self, ds: &DataSet) -> Result<EvalSet> {
        let batch = self.entry.batch;
        let xs = ds.batches(batch)?;
        if xs.is_empty() {
            bail!("dataset smaller than one batch ({batch})");
        }
        let batches = xs
            .iter()
            .map(|t| self.rt.buffer(t))
            .collect::<Result<Vec<_>>>()?;
        let n = batches.len() * batch;
        Ok(EvalSet {
            id: next_eval_set_id(),
            batches,
            labels: ds.labels_prefix(batch)?,
            n,
            batch,
        })
    }

    /// Upload an explicit list of pre-batched inputs plus their aligned
    /// labels — an [`crate::pool::EvalPool`] worker's shard of a larger
    /// set.  Unlike [`Self::eval_set`] an *empty* shard is legal (a pool
    /// with more workers than batches); probe code skips it.
    pub fn eval_set_shard(&self, batches: &[Tensor], labels: Tensor) -> Result<EvalSet> {
        let batch = self.entry.batch;
        for t in batches {
            if t.shape.first().copied() != Some(batch) {
                bail!(
                    "shard batch has leading dim {:?}, want {batch}",
                    t.shape.first()
                );
            }
        }
        let n = batches.len() * batch;
        if labels.shape.first().copied().unwrap_or(0) != n {
            bail!(
                "shard labels have {} rows, want {n}",
                labels.shape.first().copied().unwrap_or(0)
            );
        }
        let bufs = batches
            .iter()
            .map(|t| self.rt.buffer(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(EvalSet { id: next_eval_set_id(), batches: bufs, labels, n, batch })
    }

    /// Device batches for raw inputs with no labels (OOD calibration).
    pub fn eval_set_unlabeled(&self, x: &Tensor) -> Result<EvalSet> {
        let batch = self.entry.batch;
        let nb = x.shape[0] / batch;
        if nb == 0 {
            bail!("need at least one batch");
        }
        let mut batches = Vec::with_capacity(nb);
        for i in 0..nb {
            batches.push(self.rt.buffer(&x.slice_rows(i * batch, batch)?)?);
        }
        let n = nb * batch;
        Ok(EvalSet {
            id: next_eval_set_id(),
            batches,
            labels: Tensor::zeros(&[n]),
            n,
            batch,
        })
    }

    // -- configuration materialization ---------------------------------------

    /// Build the three packed quant-param tensors for a configuration —
    /// patched incrementally from the engine's cached FP32 baseline rows
    /// (see [`crate::engine::Materializer`]).
    pub fn qparam_tensors(&self, cfg: &QuantConfig) -> Result<(Tensor, Tensor, Tensor)> {
        self.engine.mat.tensors(self, cfg)
    }

    /// Upload a configuration once for repeated forward calls.
    pub fn config_buffers(
        &self,
        cfg: &QuantConfig,
        overrides: &WeightOverrides,
    ) -> Result<ConfigBuffers> {
        // Overridden params carry pre-quantized weights → disable their
        // weight quantizer so the L1 kernel passes them through.
        let mut cfg = cfg.clone();
        if !overrides.is_empty() {
            for (i, wq) in self.entry.w_quantizers.iter().enumerate() {
                if overrides.contains_key(&wq.param_idx) {
                    cfg.w[i] = None;
                }
            }
        }
        let (a, s, m) = self.qparam_tensors(&cfg)?;
        let mut override_bufs = HashMap::new();
        for (&pidx, t) in overrides {
            if t.shape != self.entry.params[pidx].shape {
                bail!(
                    "override for param {} has shape {:?}, want {:?}",
                    pidx,
                    t.shape,
                    self.entry.params[pidx].shape
                );
            }
            override_bufs.insert(pidx, self.rt.buffer(t)?);
        }
        Ok(ConfigBuffers {
            act_qp: self.rt.buffer(&a)?,
            w_scales: self.rt.buffer(&s)?,
            w_qmeta: self.rt.buffer(&m)?,
            overrides: override_bufs,
        })
    }

    // -- forward / metric ------------------------------------------------------

    /// One forward pass; returns the logits tensor for the batch.
    pub fn forward(&self, x: &Buffer, cb: &ConfigBuffers) -> Result<Tensor> {
        *self.fwd_calls.borrow_mut() += 1;
        let mut args: Vec<&Buffer> = Vec::with_capacity(self.param_bufs.len() + 4);
        args.push(x);
        for (i, p) in self.param_bufs.iter().enumerate() {
            args.push(cb.overrides.get(&i).unwrap_or(p));
        }
        args.push(&cb.act_qp);
        args.push(&cb.w_scales);
        args.push(&cb.w_qmeta);
        let mut outs = self.fwd.run_b(&args)?;
        if outs.len() != 1 {
            bail!("forward returned {} outputs", outs.len());
        }
        Ok(outs.remove(0))
    }

    /// Concatenated logits over an eval set.
    ///
    /// Compat path for consumers that genuinely need the full `O(N×C)`
    /// array (tests, Fig-2 ground-truth lists).  The hot Phase-1/Phase-2
    /// paths stream batch-by-batch through [`crate::engine::Evaluator`]
    /// instead and never materialize this concatenation.
    pub fn logits_on(&self, set: &EvalSet, cb: &ConfigBuffers) -> Result<Tensor> {
        let mut all: Option<(Vec<usize>, Vec<f32>)> = None;
        for xb in &set.batches {
            let out = self.forward(xb, cb)?;
            let v = out.f32s()?;
            match &mut all {
                None => {
                    let mut shape = out.shape.clone();
                    shape[0] = set.n;
                    all = Some((shape, v.to_vec()));
                }
                Some((_, acc)) => acc.extend_from_slice(v),
            }
        }
        let (shape, data) = all.unwrap();
        Tensor::from_f32(&shape, data)
    }

    /// Task metric of a configuration over an eval set, accumulated
    /// batch-by-batch (no host concatenation of the logits).
    pub fn eval_metric(&self, set: &EvalSet, cb: &ConfigBuffers) -> Result<f64> {
        let mut acc = crate::metrics::StreamingTaskMetric::new(&self.entry.task)?;
        for (bi, xb) in set.batches.iter().enumerate() {
            let logits = self.forward(xb, cb)?;
            acc.push(&logits, &set.labels.slice_rows(bi * set.batch, set.batch)?)?;
        }
        Ok(acc.finalize())
    }

    /// Convenience: metric of `cfg` with no overrides.
    pub fn eval_config(&self, set: &EvalSet, cfg: &QuantConfig) -> Result<f64> {
        let cb = self.config_buffers(cfg, &HashMap::new())?;
        self.eval_metric(set, &cb)
    }
}

/// Backend-resident packed configuration.
pub struct ConfigBuffers {
    pub act_qp: Buffer,
    pub w_scales: Buffer,
    pub w_qmeta: Buffer,
    pub overrides: HashMap<usize, Buffer>,
}
