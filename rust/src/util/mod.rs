//! Small shared utilities: a deterministic PRNG (no `rand` in the offline
//! crate set), a wall-clock timer, and numeric helpers.

/// xoshiro256** seeded via splitmix64 — deterministic across platforms.
///
/// Used everywhere randomness is needed (calibration subset sampling,
/// AdaRound batch order) so that experiment runs are reproducible from a
/// single `u64` seed, mirroring the paper's fixed-seed subset studies
/// (Fig. 2).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Wall-clock stopwatch for the run-time tables (Table 5) and §Perf.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// `10·log10(x)` with a floor to keep degenerate ratios finite.
pub fn db10(x: f64) -> f64 {
    10.0 * x.max(1e-30).log10()
}

/// Mean of an f64 iterator (0.0 on empty).
pub fn mean(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut s = 0.0;
    let mut n = 0usize;
    for x in xs {
        s += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seed_sensitivity() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(7);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_k_gt_n() {
        let mut r = Rng::new(7);
        assert_eq!(r.sample_indices(5, 10).len(), 5);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn db10_floor() {
        assert!(db10(0.0).is_finite());
        assert!((db10(10.0) - 10.0).abs() < 1e-12);
    }
}
