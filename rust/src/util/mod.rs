//! Small shared utilities: a deterministic PRNG (no `rand` in the offline
//! crate set), a wall-clock timer, a scoped-thread parallel map (no `rayon`
//! either), and numeric helpers.

/// xoshiro256** seeded via splitmix64 — deterministic across platforms.
///
/// Used everywhere randomness is needed (calibration subset sampling,
/// AdaRound batch order) so that experiment runs are reproducible from a
/// single `u64` seed, mirroring the paper's fixed-seed subset studies
/// (Fig. 2).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Wall-clock stopwatch for the run-time tables (Table 5) and §Perf.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Host parallelism available to worker fan-outs ([`par_map`], the
/// [`crate::pool::EvalPool`] default and the CLI `--workers` default);
/// 1 when the platform can't tell.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Order-preserving parallel map over a slice using scoped std threads —
/// the offline crate set has no `rayon`.  Work is pulled from a shared
/// atomic index (cheap work stealing for uneven item costs).
///
/// Intended for pure host math (weight-scale grid search, quantization MSE,
/// FIT accumulation); never hand it anything touching the PJRT client,
/// which is not thread-safe — the `T: Sync` bound enforces that for the
/// items, and the closure must only capture `Sync` data.  Evaluation work
/// that *does* need PJRT fans out through [`crate::pool::EvalPool`]
/// instead, whose workers each own a private client.
pub fn par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(usize, &T) -> R + Sync) -> Vec<R> {
    let threads = default_workers().min(items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut done = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        done.push((i, f(i, &items[i])));
                    }
                    done
                })
            })
            .collect();
        for w in workers {
            for (i, r) in w.join().expect("par_map worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("par_map slot unfilled"))
        .collect()
}

/// `10·log10(x)` with a floor to keep degenerate ratios finite.
pub fn db10(x: f64) -> f64 {
    10.0 * x.max(1e-30).log10()
}

/// FNV-1a 64-bit streaming hasher — content digests for the evaluation
/// pool's override fingerprints and the on-disk sensitivity-list cache keys
/// (the offline crate set has no hashing crates; collision resistance
/// needs are "don't confuse two experiment configurations", not
/// cryptographic).
#[derive(Clone, Debug)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_u8(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100_0000_01b3);
    }

    pub fn write_bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.write_u8(b);
        }
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Fold in a tensor's shape and contents (f32 bit patterns / i32
    /// values) — the one canonical tensor digest, shared by the pool's
    /// override fingerprints and the sensitivity-cache keys so the two can
    /// never drift apart.
    pub fn write_tensor(&mut self, t: &crate::tensor::Tensor) {
        for &d in &t.shape {
            self.write_usize(d);
        }
        match &t.data {
            crate::tensor::Data::F32(v) => {
                for x in v {
                    self.write_u32(x.to_bits());
                }
            }
            crate::tensor::Data::I32(v) => {
                for x in v {
                    self.write_u32(*x as u32);
                }
            }
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Mean of an f64 iterator (0.0 on empty).
pub fn mean(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut s = 0.0;
    let mut n = 0usize;
    for x in xs {
        s += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seed_sensitivity() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(7);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_k_gt_n() {
        let mut r = Rng::new(7);
        assert_eq!(r.sample_indices(5, 10).len(), 5);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn par_map_matches_sequential_in_order() {
        let items: Vec<u64> = (0..257).collect();
        let got = par_map(&items, |i, &x| x * x + i as u64);
        let want: Vec<u64> = items.iter().enumerate().map(|(i, &x)| x * x + i as u64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(par_map(&none, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn db10_floor() {
        assert!(db10(0.0).is_finite());
        assert!((db10(10.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn fnv_known_vector_and_sensitivity() {
        // FNV-1a 64 of "a" is a published test vector
        let mut h = Fnv::new();
        h.write_u8(b'a');
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        let mut h1 = Fnv::new();
        h1.write_bytes(b"abc");
        let mut h2 = Fnv::new();
        h2.write_bytes(b"acb");
        assert_ne!(h1.finish(), h2.finish());
        assert_eq!(Fnv::new().finish(), Fnv::default().finish());
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }
}
