//! Calibration / validation data management.
//!
//! Loads the MPQT dataset binaries referenced by the manifest and slices
//! them into fixed-size batches (the lowered executables have a static
//! batch dimension).  Subset sampling is seeded — Fig. 2's five random
//! 256-image subsets are `subset(256, seed)` for seed 0..5.

use crate::manifest::{DataFiles, ModelEntry};
use crate::tensor::{io, Tensor};
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// An (inputs, labels) dataset, first axis = sample.
#[derive(Clone, Debug)]
pub struct DataSet {
    pub x: Tensor,
    pub y: Tensor,
}

impl DataSet {
    pub fn load(dir: &Path, x_file: &str, y_file: &str) -> Result<Self> {
        let x = single(dir, x_file)?;
        let y = single(dir, y_file)?;
        if x.shape[0] != y.shape[0] {
            bail!(
                "{x_file} has {} samples but {y_file} has {}",
                x.shape[0],
                y.shape[0]
            );
        }
        Ok(Self { x, y })
    }

    pub fn len(&self) -> usize {
        self.x.shape[0]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Seeded random subset of `n` samples.
    pub fn subset(&self, n: usize, seed: u64) -> Result<DataSet> {
        let idx = Rng::new(seed).sample_indices(self.len(), n);
        Ok(DataSet { x: self.x.gather_rows(&idx)?, y: self.y.gather_rows(&idx)? })
    }

    /// First `n` samples (deterministic prefix).
    pub fn take(&self, n: usize) -> Result<DataSet> {
        let n = n.min(self.len());
        Ok(DataSet { x: self.x.slice_rows(0, n)?, y: self.y.slice_rows(0, n)? })
    }

    /// Split inputs into `batch`-sized chunks, dropping a ragged tail (the
    /// executables have a static batch dimension; callers size their subsets
    /// as multiples of `batch`).  See the `EvalSet` truncation contract in
    /// `crate::model` — [`Self::labels_prefix`] truncates identically so
    /// inputs and labels stay aligned.
    pub fn batches(&self, batch: usize) -> Result<Vec<Tensor>> {
        let n = (self.len() / batch) * batch;
        (0..n / batch)
            .map(|i| self.x.slice_rows(i * batch, batch))
            .collect()
    }

    /// Labels aligned with [`Self::batches`] (first `n_batches·batch`).
    pub fn labels_prefix(&self, batch: usize) -> Result<Tensor> {
        let n = (self.len() / batch) * batch;
        self.y.slice_rows(0, n)
    }
}

fn single(dir: &Path, file: &str) -> Result<Tensor> {
    let mut ts = io::read_tensors(dir.join(file))
        .with_context(|| format!("loading {file}"))?;
    if ts.len() != 1 {
        bail!("{file}: expected 1 tensor, found {}", ts.len());
    }
    Ok(ts.remove(0))
}

/// All data referenced by a model: calibration pool, validation set, and the
/// optional out-of-domain calibration pool (Fig. 4).
#[derive(Clone, Debug)]
pub struct ModelData {
    pub calib: DataSet,
    pub val: DataSet,
    pub ood_calib: Option<Tensor>,
}

impl ModelData {
    pub fn load(dir: &Path, files: &DataFiles) -> Result<Self> {
        Ok(Self {
            calib: DataSet::load(dir, &files.calib, &files.calib_labels)?,
            val: DataSet::load(dir, &files.val, &files.val_labels)?,
            ood_calib: files
                .ood_calib
                .as_ref()
                .map(|f| single(dir, f))
                .transpose()?,
        })
    }
}

/// Load a model's trained parameters (MPQT tensors in `params` order).
pub fn load_weights(dir: &Path, entry: &ModelEntry) -> Result<Vec<Tensor>> {
    let ts = io::read_tensors(dir.join(&entry.weights_file))
        .with_context(|| format!("loading {}", entry.weights_file))?;
    if ts.len() != entry.params.len() {
        bail!(
            "{}: {} tensors but manifest lists {} params",
            entry.weights_file,
            ts.len(),
            entry.params.len()
        );
    }
    for (t, p) in ts.iter().zip(&entry.params) {
        if t.shape != p.shape {
            bail!("param {}: file shape {:?} != manifest {:?}", p.name, t.shape, p.shape);
        }
    }
    Ok(ts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Data;

    fn tmp_dataset(n: usize) -> (std::path::PathBuf, String, String) {
        // per-length dir: tests run in parallel and must not clobber each
        // other's fixture files
        let dir = std::env::temp_dir().join(format!("mpq_data_test_{n}"));
        std::fs::create_dir_all(&dir).unwrap();
        let x = Tensor::from_f32(&[n, 3], (0..n * 3).map(|i| i as f32).collect()).unwrap();
        let y = Tensor::from_f32(&[n], (0..n).map(|i| i as f32).collect()).unwrap();
        io::write_tensors(dir.join("x.bin"), &[x]).unwrap();
        io::write_tensors(dir.join("y.bin"), &[y]).unwrap();
        (dir, "x.bin".into(), "y.bin".into())
    }

    #[test]
    fn load_and_batch() {
        let (dir, xf, yf) = tmp_dataset(10);
        let ds = DataSet::load(&dir, &xf, &yf).unwrap();
        assert_eq!(ds.len(), 10);
        let bs = ds.batches(4).unwrap();
        assert_eq!(bs.len(), 2); // ragged tail dropped
        assert_eq!(bs[1].shape, vec![4, 3]);
        assert_eq!(ds.labels_prefix(4).unwrap().shape, vec![8]);
    }

    /// Regression: for every dataset length that is *not* divisible by the
    /// batch size, batching and labels must truncate to the same
    /// `⌊len/batch⌋·batch` sample count (the EvalSet contract) — `n`
    /// derived as `batches.len()·batch` is the number of samples that
    /// actually run, and each batch row still matches its label.
    #[test]
    fn ragged_tail_truncation_is_consistent() {
        for (len, batch) in [(11usize, 4usize), (7, 3), (9, 4), (5, 5), (13, 8)] {
            let (dir, xf, yf) = tmp_dataset(len);
            let ds = DataSet::load(&dir, &xf, &yf).unwrap();
            let bs = ds.batches(batch).unwrap();
            let want_n = (len / batch) * batch;
            assert_eq!(bs.len(), len / batch, "len={len} batch={batch}");
            let n = bs.len() * batch;
            assert_eq!(n, want_n, "len={len} batch={batch}");
            let labels = ds.labels_prefix(batch).unwrap();
            assert_eq!(labels.shape, vec![want_n], "labels must truncate too");
            // alignment survives truncation: y[i] == x[i,0] / 3
            let ys = labels.f32s().unwrap();
            for (bi, b) in bs.iter().enumerate() {
                let xs = b.f32s().unwrap();
                for r in 0..batch {
                    assert_eq!(xs[r * 3] / 3.0, ys[bi * batch + r]);
                }
            }
        }
    }

    #[test]
    fn subsets_are_seeded_and_aligned() {
        let (dir, xf, yf) = tmp_dataset(32);
        let ds = DataSet::load(&dir, &xf, &yf).unwrap();
        let a = ds.subset(8, 1).unwrap();
        let b = ds.subset(8, 1).unwrap();
        let c = ds.subset(8, 2).unwrap();
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, c.x);
        // x/y stay aligned: y[i] == x[i,0] / 3
        if let (Data::F32(xs), Data::F32(ys)) = (&a.x.data, &a.y.data) {
            for i in 0..8 {
                assert_eq!(xs[i * 3] / 3.0, ys[i]);
            }
        } else {
            panic!("dtype");
        }
    }
}
