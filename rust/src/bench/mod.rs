//! Micro-benchmark harness (no `criterion` in the offline crate set).
//!
//! `cargo bench` runs the `harness = false` binaries under `rust/benches/`;
//! each uses [`Bench`] to time closures with warmup, iteration scaling and
//! basic statistics, printing criterion-style lines:
//!
//! ```text
//! phase1/sqnr_probe        time: [ 12.31 ms  12.58 ms  13.02 ms ]  n=32
//! ```
//!
//! [`write_json`] additionally emits the results as machine-readable JSON
//! (`BENCH_<name>.json`) so before/after speedups are tracked across PRs:
//! CI's `scripts/bench_compare` step diffs the fresh microbench JSON
//! against the committed repo-root baseline and fails on >20% regression
//! of the gated hot paths (`phase1/full_sensitivity_sweep`,
//! `phase2/binary_search`) or on the evaluation pool's
//! `phase1_pool/full_sensitivity_sweep_w4` falling under 1.8× the `_w1`
//! baseline.  `min_s` is the comparison basis — the minimum over
//! iterations is the noise-robust statistic for small samples.

use crate::jsonio::Json;
use crate::util::Timer;

pub struct BenchResult {
    pub name: String,
    pub min_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} time: [ {}  {}  {} ]  n={}",
            self.name,
            fmt_time(self.min_s),
            fmt_time(self.mean_s),
            fmt_time(self.max_s),
            self.iters
        );
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:8.3} s ")
    } else if s >= 1e-3 {
        format!("{:8.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:8.3} µs", s * 1e6)
    } else {
        format!("{:8.3} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` throwaway calls, then `iters` measured calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let min_s = samples.iter().copied().fold(f64::INFINITY, f64::min);
    let max_s = samples.iter().copied().fold(0.0, f64::max);
    let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult { name: name.to_string(), min_s, mean_s, max_s, iters: samples.len() };
    r.print();
    r
}

/// Fallible variant — aborts the bench binary on error (artifacts missing
/// is a setup problem, not a measurement).
pub fn bench_result<E: std::fmt::Debug>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> Result<(), E>,
) -> BenchResult {
    bench(name, warmup, iters, || f().expect("bench body failed"))
}

/// Serialize results to `path` as JSON:
/// `{"bench": <name>, "results": {<bench name>: {min_s, mean_s, max_s,
/// iters}, ...}}`.  Consumed by cross-PR speedup tracking.
pub fn write_json(
    path: impl AsRef<std::path::Path>,
    bench_name: &str,
    results: &[BenchResult],
) -> anyhow::Result<()> {
    let entries: Vec<(String, Json)> = results
        .iter()
        .map(|r| {
            (
                r.name.clone(),
                Json::Obj(vec![
                    ("min_s".into(), Json::Num(r.min_s)),
                    ("mean_s".into(), Json::Num(r.mean_s)),
                    ("max_s".into(), Json::Num(r.max_s)),
                    ("iters".into(), Json::Num(r.iters as f64)),
                ]),
            )
        })
        .collect();
    let j = Json::Obj(vec![
        ("bench".into(), Json::Str(bench_name.into())),
        ("results".into(), Json::Obj(entries)),
    ]);
    // atomic temp+rename: a bench_compare gate never reads a torn file
    crate::store::atomic_write(path, (j.to_string() + "\n").as_bytes())
}

/// Standard bench preamble: header + artifacts guard.  Returns false (and
/// prints a notice) when artifacts aren't built, so `cargo bench` stays
/// green in a fresh checkout.
pub fn preamble(bench_name: &str, what: &str) -> bool {
    println!("### bench {bench_name} — {what}");
    let dir = crate::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "SKIP: {}/manifest.json not found — run `make artifacts` first",
            dir.display()
        );
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_counts() {
        let mut n = 0;
        let r = bench("t", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).contains("s"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
    }

    #[test]
    fn write_json_roundtrips() {
        let results = vec![
            BenchResult {
                name: "phase1/full_sensitivity_sweep".into(),
                min_s: 0.5,
                mean_s: 0.625,
                max_s: 0.75,
                iters: 4,
            },
            BenchResult { name: "b".into(), min_s: 1.0, mean_s: 1.0, max_s: 1.0, iters: 1 },
        ];
        let p = std::env::temp_dir().join("mpq_bench_json_test.json");
        write_json(&p, "microbench", &results).unwrap();
        let j = crate::jsonio::parse_file(&p).unwrap();
        assert_eq!(j.req("bench").unwrap().as_str().unwrap(), "microbench");
        let r = j
            .req("results")
            .unwrap()
            .req("phase1/full_sensitivity_sweep")
            .unwrap();
        assert_eq!(r.req("mean_s").unwrap().as_f64().unwrap(), 0.625);
        assert_eq!(r.req("iters").unwrap().as_usize().unwrap(), 4);
        std::fs::remove_file(&p).ok();
    }
}
