//! Micro-benchmark harness (no `criterion` in the offline crate set).
//!
//! `cargo bench` runs the `harness = false` binaries under `rust/benches/`;
//! each uses [`Bench`] to time closures with warmup, iteration scaling and
//! basic statistics, printing criterion-style lines:
//!
//! ```text
//! phase1/sqnr_probe        time: [ 12.31 ms  12.58 ms  13.02 ms ]  n=32
//! ```

use crate::util::Timer;

pub struct BenchResult {
    pub name: String,
    pub min_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} time: [ {}  {}  {} ]  n={}",
            self.name,
            fmt_time(self.min_s),
            fmt_time(self.mean_s),
            fmt_time(self.max_s),
            self.iters
        );
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:8.3} s ")
    } else if s >= 1e-3 {
        format!("{:8.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:8.3} µs", s * 1e6)
    } else {
        format!("{:8.3} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` throwaway calls, then `iters` measured calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let min_s = samples.iter().copied().fold(f64::INFINITY, f64::min);
    let max_s = samples.iter().copied().fold(0.0, f64::max);
    let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult { name: name.to_string(), min_s, mean_s, max_s, iters: samples.len() };
    r.print();
    r
}

/// Fallible variant — aborts the bench binary on error (artifacts missing
/// is a setup problem, not a measurement).
pub fn bench_result<E: std::fmt::Debug>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> Result<(), E>,
) -> BenchResult {
    bench(name, warmup, iters, || f().expect("bench body failed"))
}

/// Standard bench preamble: header + artifacts guard.  Returns false (and
/// prints a notice) when artifacts aren't built, so `cargo bench` stays
/// green in a fresh checkout.
pub fn preamble(bench_name: &str, what: &str) -> bool {
    println!("### bench {bench_name} — {what}");
    let dir = crate::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "SKIP: {}/manifest.json not found — run `make artifacts` first",
            dir.display()
        );
        return false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_counts() {
        let mut n = 0;
        let r = bench("t", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).contains("s"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
    }
}
