//! Phase 1 — per-group sensitivity lists (paper §3.2).
//!
//! The primary metric is **SQNR at the network output** (Eq. 3-4): for each
//! quantizer group `g` and candidate `c`, the network runs with *only* `g`
//! quantized at `c` and the rest in FP32, and
//!
//! `Ω = 10·log10( (1/N) Σ_i  Σ F(x_i)² / Σ e(x_i)² )`,  `e = F − Q(F)`.
//!
//! Labels play no role (§3.2), which is what makes the algorithm robust to
//! calibration-data variation (Fig. 2) and usable with out-of-domain data
//! (Fig. 4).  Two baseline metrics are implemented for the Fig. 2
//! comparison: task-accuracy degradation and the FIT (Fisher) metric.
//!
//! All probes run through [`crate::engine::Evaluator`]: the FP32 reference
//! is one cached forward sweep per `(model, eval-set)` and each probe
//! streams batch-by-batch, so a full sweep costs exactly `1 + probes`
//! forward-sweep-equivalents with no host logit concatenation.  With an
//! [`crate::pool::EvalPool`] the same sweep fans out shard-parallel across
//! N PJRT clients ([`sensitivity_list_pooled`]), bit-identical to the
//! serial list; completed lists can also be persisted on disk ([`cache`])
//! keyed by `(model, calibration-data digest, metric, lattice)` so repeated
//! experiment drivers skip the sweep entirely.
//!
//! With a [`crate::store::JournalScope`] attached, every completed probe
//! score is appended to the crash-safe run journal as it lands (keyed by
//! the sweep's content digest + the probe's `(group, wbits, abits)`), and
//! a `--resume` run skips exactly the journaled probes — serial and
//! pooled sweeps alike, scores bit-equal to an uninterrupted run.  FIT is
//! journaled at sweep granularity (its per-abits accumulation passes
//! share work across every probe, so per-probe checkpoints would not be
//! independently resumable).

pub mod cache;

use crate::engine::Evaluator;
use crate::groups::{Assignment, Candidate, Lattice};
use crate::manifest::{Manifest, ModelEntry};
use crate::model::{EvalSet, ModelHandle, QuantConfig, WeightOverrides};
use crate::pool::{EvalPool, ProbeKind, SetKey};
use crate::quant::{self, ActRanges};
use crate::runtime::{Buffer, Exe, Runtime};
use crate::store::{self, JournalScope};
use crate::tensor::Tensor;
use crate::util::{db10, par_map};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// One `(group, candidate)` sensitivity measurement.  Higher `score` =
/// *less* sensitive = flipped earlier by Phase 2.
#[derive(Clone, Debug)]
pub struct SensEntry {
    pub group: usize,
    pub cand: Candidate,
    pub score: f64,
}

/// Which Phase-1 metric to use (Fig. 2 compares all three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Sqnr,
    Accuracy,
    Fit,
}

/// Does `metric` have a shard-parallel implementation in
/// [`sensitivity_list_pooled`]?  All current metrics do; the coordinator
/// checks this before routing a sweep through the pool and **falls back to
/// the serial path with a warning** (instead of erroring) for any future
/// metric that hasn't grown one yet.
pub fn has_pooled_path(metric: Metric) -> bool {
    match metric {
        Metric::Sqnr | Metric::Accuracy | Metric::Fit => true,
    }
}

/// Per-(layer-bits) AdaRounded weight tensors, keyed by
/// `(param_idx, wbits)` — produced by [`crate::adaround`], consumed here
/// when interweaving AdaRound into Phase 1 (§3.5).
pub type RoundedWeights = HashMap<(usize, u8), Tensor>;

/// SQNR (dB) between FP logits and quantized logits, per Eq. 3.
pub fn sqnr_db(fp: &Tensor, q: &Tensor) -> Result<f64> {
    if fp.shape != q.shape || fp.shape.is_empty() {
        bail!("sqnr shape mismatch {:?} vs {:?}", fp.shape, q.shape);
    }
    let n = fp.shape[0];
    let stride = fp.numel() / n;
    let (a, b) = (fp.f32s()?, q.f32s()?);
    let mut acc = 0f64;
    for i in 0..n {
        let mut sig = 0f64;
        let mut err = 0f64;
        for j in i * stride..(i + 1) * stride {
            let f = a[j] as f64;
            let e = f - b[j] as f64;
            sig += f * f;
            err += e * e;
        }
        acc += sig / err.max(1e-30);
    }
    Ok(db10(acc / n as f64))
}

/// FP32 logits over an eval set (the Phase-1 reference signal).
///
/// Served from the engine's per-`(model, eval-set)` reference cache — at
/// most one forward sweep per set, no matter how many metrics, figures or
/// repeated calls ask for it.  The concatenation is built on demand; the
/// streaming probe paths below never need it.
pub fn fp_logits(handle: &ModelHandle, set: &EvalSet) -> Result<Tensor> {
    handle.fp_reference(set)?.concat()
}

/// Probe configuration: FP everywhere, group `g` at candidate `c`.
/// Pure host math on the manifest entry — pool dispatch builds these
/// without touching any handle.
pub fn probe_config(entry: &ModelEntry, g: usize, c: Candidate) -> QuantConfig {
    let mut cfg = QuantConfig::fp32(entry);
    let grp = &entry.groups[g];
    for &a in &grp.act_q {
        cfg.act[a] = Some(c.abits);
    }
    for &w in &grp.w_q {
        cfg.w[w] = Some(c.wbits);
    }
    cfg
}

/// Weight overrides for a probe when AdaRound is interweaved: the group's
/// parameters replaced by their AdaRounded version at `c.wbits`.
pub fn probe_overrides(
    entry: &ModelEntry,
    g: usize,
    c: Candidate,
    rounded: &RoundedWeights,
) -> WeightOverrides {
    let mut ov = WeightOverrides::new();
    for &wq in &entry.groups[g].w_q {
        let pidx = entry.w_quantizers[wq].param_idx;
        if let Some(t) = rounded.get(&(pidx, c.wbits)) {
            ov.insert(pidx, t.clone());
        }
    }
    ov
}

/// Build the sensitivity list with the requested metric, sorted highest to
/// lowest score (Algorithm 1's sort).
///
/// `rounded`: pass AdaRounded weights to interweave AdaRound into Phase 1.
/// `journal`: append each completed probe to the run journal and skip
/// probes a `--resume` replay already holds.
pub fn sensitivity_list(
    handle: &ModelHandle,
    manifest: &Manifest,
    lattice: &Lattice,
    set: &EvalSet,
    metric: Metric,
    rounded: Option<&RoundedWeights>,
    journal: Option<&JournalScope>,
) -> Result<Vec<SensEntry>> {
    let mut entries = match metric {
        Metric::Sqnr => sqnr_scores(handle, lattice, set, rounded, journal)?,
        Metric::Accuracy => accuracy_scores(handle, lattice, set, rounded, journal)?,
        Metric::Fit => fit_scores(handle, manifest, lattice, set, journal)?,
    };
    // total_cmp: a single NaN score must not panic the whole pipeline —
    // IEEE total order is defined for every bit pattern, so degenerate
    // probes sort deterministically instead of aborting Phase 1.
    entries.sort_by(|x, y| y.score.total_cmp(&x.score));
    Ok(entries)
}

/// Phase-1 sweep dispatched through an [`EvalPool`]: the whole probe list
/// is enqueued at once and every probe is evaluated shard-parallel across
/// the fleet's workers; [`Metric::Fit`] fans its per-`abits` accumulation
/// passes out the same way (raw per-batch outputs merged in global batch
/// order, see [`EvalPool::fit_accumulate`]).
///
/// Produces the *same* sorted list as [`sensitivity_list`] on the same
/// calibration data — bit-identical scores for the SQNR, counting-metric
/// and FIT paths (see the pool's exactness guarantee), and an identical
/// stable sort over the identical probe order.  Callers should check
/// [`has_pooled_path`] first and fall back to [`sensitivity_list`] for any
/// future metric without a pooled implementation.
pub fn sensitivity_list_pooled(
    pool: &EvalPool,
    set: SetKey,
    handle: &ModelHandle,
    lattice: &Lattice,
    metric: Metric,
    rounded: Option<&RoundedWeights>,
    journal: Option<&JournalScope>,
) -> Result<Vec<SensEntry>> {
    let entry = &handle.entry;
    let mut entries = match metric {
        Metric::Fit => fit_scores_pooled(pool, set, handle, lattice, journal)?,
        Metric::Sqnr | Metric::Accuracy => {
            let kind = match metric {
                Metric::Sqnr => ProbeKind::Sqnr,
                _ => ProbeKind::Metric,
            };
            let targets = probe_targets(entry, lattice);
            // replay first: journaled probes never re-enter the fleet;
            // the rest are enqueued at once (shard-parallel), each score
            // journaled as its wait completes — submission order, so
            // barrier ordinals are deterministic
            let mut scores: Vec<Option<f64>> = targets
                .iter()
                .map(|&(g, c)| {
                    journal.and_then(|j| {
                        j.journal.lookup_f64(
                            store::kind::PROBE,
                            store::probe_key(j.base, g, c.wbits, c.abits),
                        )
                    })
                })
                .collect();
            let mut pending = Vec::new();
            for (i, &(g, c)) in targets.iter().enumerate() {
                if scores[i].is_some() {
                    continue;
                }
                let cfg = probe_config(entry, g, c);
                let ov = rounded
                    .map(|r| probe_overrides(entry, g, c, r))
                    .unwrap_or_default();
                pending.push((i, pool.submit(set, kind, &cfg, &ov)?));
            }
            for (i, h) in pending {
                let s = h.wait()?;
                if let Some(j) = journal {
                    let (g, c) = targets[i];
                    j.journal.record_f64(
                        store::kind::PROBE,
                        store::probe_key(j.base, g, c.wbits, c.abits),
                        s,
                    )?;
                }
                scores[i] = Some(s);
            }
            targets
                .iter()
                .zip(scores)
                .map(|(&(group, cand), score)| SensEntry {
                    group,
                    cand,
                    score: score.expect("every probe replayed or evaluated"),
                })
                .collect()
        }
    };
    entries.sort_by(|x, y| y.score.total_cmp(&x.score));
    Ok(entries)
}

fn probe_targets(entry: &ModelEntry, lattice: &Lattice) -> Vec<(usize, Candidate)> {
    let mut out = Vec::new();
    for g in 0..entry.groups.len() {
        if !Assignment::flippable(entry, g) {
            continue;
        }
        for &c in &lattice.candidates {
            if c != lattice.baseline {
                out.push((g, c));
            }
        }
    }
    out
}

/// Serve one probe from the journal, or compute it with `f` and append it
/// as a journal barrier — the shared skeleton of the serial sweeps.
fn probe_journaled(
    journal: Option<&JournalScope>,
    g: usize,
    c: Candidate,
    f: impl FnOnce() -> Result<f64>,
) -> Result<f64> {
    let key = journal.map(|j| store::probe_key(j.base, g, c.wbits, c.abits));
    if let (Some(j), Some(k)) = (journal, key) {
        if let Some(s) = j.journal.lookup_f64(store::kind::PROBE, k) {
            return Ok(s);
        }
    }
    let s = f()?;
    if let (Some(j), Some(k)) = (journal, key) {
        j.journal.record_f64(store::kind::PROBE, k, s)?;
    }
    Ok(s)
}

fn sqnr_scores(
    handle: &ModelHandle,
    lattice: &Lattice,
    set: &EvalSet,
    rounded: Option<&RoundedWeights>,
    journal: Option<&JournalScope>,
) -> Result<Vec<SensEntry>> {
    // One engine evaluator for the whole sweep: the FP reference is built
    // (or served from cache) once, and each probe streams batch-by-batch —
    // exactly `1 + probes` forward-sweep-equivalents, no concatenation.
    let ev = Evaluator::new(handle, set);
    let mut out = Vec::new();
    for (g, c) in probe_targets(&handle.entry, lattice) {
        let score = probe_journaled(journal, g, c, || {
            let cfg = probe_config(&handle.entry, g, c);
            let ov = rounded
                .map(|r| probe_overrides(&handle.entry, g, c, r))
                .unwrap_or_default();
            ev.sqnr(&cfg, &ov)
        })?;
        out.push(SensEntry { group: g, cand: c, score });
    }
    Ok(out)
}

fn accuracy_scores(
    handle: &ModelHandle,
    lattice: &Lattice,
    set: &EvalSet,
    rounded: Option<&RoundedWeights>,
    journal: Option<&JournalScope>,
) -> Result<Vec<SensEntry>> {
    let ev = Evaluator::new(handle, set);
    let mut out = Vec::new();
    for (g, c) in probe_targets(&handle.entry, lattice) {
        let score = probe_journaled(journal, g, c, || {
            let cfg = probe_config(&handle.entry, g, c);
            let ov = rounded
                .map(|r| probe_overrides(&handle.entry, g, c, r))
                .unwrap_or_default();
            ev.metric(&cfg, &ov)
        })?;
        out.push(SensEntry { group: g, cand: c, score });
    }
    Ok(out)
}

/// Raw FIT-executable outputs for one batch: per-weight-quantizer squared
/// loss gradients, per-activation-quantizer squared gradients, and
/// per-activation local quantization errors.  Fleet workers ship these
/// back **unreduced** so the front-end can replay the serial accumulation
/// order term by term — the pooled FIT path's bit-identity mechanism.
#[derive(Clone, Debug)]
pub struct FitBatchRaw {
    pub wgrad2: Vec<f32>,
    pub agrad2: Vec<f32>,
    pub aerr2: Vec<f32>,
}

/// Packed `act_qp[A,5]` rows with every activation quantizer forced on at
/// `abits` (enable irrelevant in fit mode; the exe forces quantization for
/// the error term only) — shared by the serial and pooled FIT paths so the
/// two can never drift apart.
pub(crate) fn fit_act_qp(entry: &ModelEntry, ranges: &ActRanges, abits: u8) -> Result<Tensor> {
    let a_n = entry.n_act();
    let mut act_qp = vec![0f32; a_n * 5];
    for i in 0..a_n {
        let (s, o) = ranges.qparams(i, abits)?;
        let (_, qmax) = quant::act_qrange(abits);
        act_qp[i * 5..(i + 1) * 5].copy_from_slice(&[s, o, 0.0, qmax, 1.0]);
    }
    Tensor::from_f32(&[a_n, 5], act_qp)
}

/// Run the FIT executable over `batches` and return the raw per-batch
/// outputs.  Used by the serial sweep on the full set and by each fleet
/// worker on its shard — the per-batch outputs are identical either way,
/// which is what the pooled fold relies on.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fit_batch_raws(
    rt: &Runtime,
    exe: &Exe,
    param_bufs: &[Buffer],
    pert_bufs: &[Buffer],
    qp_buf: &Buffer,
    batches: &[Buffer],
    labels: &Tensor,
    batch: usize,
) -> Result<Vec<FitBatchRaw>> {
    let mut out = Vec::with_capacity(batches.len());
    for (bi, xb) in batches.iter().enumerate() {
        let yb = rt.buffer(&labels.slice_rows(bi * batch, batch)?)?;
        let mut args: Vec<&Buffer> = vec![xb, &yb];
        args.extend(param_bufs.iter());
        args.extend(pert_bufs.iter());
        args.push(qp_buf);
        let outs = exe.run_b(&args)?;
        if outs.len() != 4 {
            bail!("fit exe returned {} outputs", outs.len());
        }
        out.push(FitBatchRaw {
            wgrad2: outs[1].f32s()?.to_vec(),
            agrad2: outs[2].f32s()?.to_vec(),
            aerr2: outs[3].f32s()?.to_vec(),
        });
    }
    Ok(out)
}

/// Fold one activation-bit-width pass of raw per-batch outputs (global
/// batch order) into the running accumulators — the exact summation the
/// serial loop performs, term for term, so pooled and serial accumulation
/// are bit-identical.
fn fit_fold(
    wgrad2: &mut [f64],
    agrad2: &mut [f64],
    errs: &mut [f64],
    raws: &[FitBatchRaw],
    nb: usize,
    n_abits: usize,
) {
    let scale = 1.0 / (nb * n_abits) as f64;
    for raw in raws {
        for (i, v) in raw.wgrad2.iter().enumerate() {
            wgrad2[i] += *v as f64 * scale; // same across abits; averaged
        }
        for (i, v) in raw.agrad2.iter().enumerate() {
            agrad2[i] += *v as f64 * scale;
        }
        for (i, v) in raw.aerr2.iter().enumerate() {
            errs[i] += *v as f64 / nb as f64;
        }
    }
}

/// Combine the accumulated Fisher terms with the host-side weight
/// quantization errors into the final per-`(group, candidate)` list.
fn fit_finish(
    handle: &ModelHandle,
    lattice: &Lattice,
    wgrad2: &[f64],
    agrad2: &[f64],
    aerr2: &HashMap<u8, Vec<f64>>,
) -> Result<Vec<SensEntry>> {
    let entry = &handle.entry;
    // host-side weight quantization errors per wbits — independent pure
    // host math per quantizer, fanned across threads
    let mut werr2: HashMap<u8, Vec<f64>> = HashMap::new();
    for &wbits in &lattice.wbits_options() {
        let scales = handle
            .w_scales
            .get(&wbits)
            .ok_or_else(|| anyhow!("weight scales for {wbits} missing"))?;
        let weights = &handle.weights;
        let errs = par_map(&entry.w_quantizers, |i, wq| {
            quant::weight_quant_mse(&weights[wq.param_idx], &scales[i], wq.channel_axis, wbits)
        })
        .into_iter()
        .collect::<Result<Vec<_>>>()?;
        werr2.insert(wbits, errs);
    }

    let mut out = Vec::new();
    for (g, c) in probe_targets(entry, lattice) {
        let grp = &entry.groups[g];
        let mut fit = 0f64;
        for &w in &grp.w_q {
            fit += wgrad2[w] * werr2[&c.wbits][w];
        }
        for &a in &grp.act_q {
            fit += agrad2[a] * aerr2[&c.abits][a];
        }
        out.push(SensEntry { group: g, cand: c, score: -fit });
    }
    Ok(out)
}

/// FIT journals at sweep granularity: its per-abits accumulation passes
/// are shared across *all* probes, so a partial sweep is not resumable —
/// either every `(group, candidate)` score is in the journal (replay the
/// whole list, zero compute) or the full sweep runs and records them all.
fn fit_journal_replay(
    entry: &ModelEntry,
    lattice: &Lattice,
    journal: Option<&JournalScope>,
) -> Option<Vec<SensEntry>> {
    let j = journal?;
    let targets = probe_targets(entry, lattice);
    let complete = targets.iter().all(|&(g, c)| {
        j.journal
            .contains(store::kind::PROBE, store::probe_key(j.base, g, c.wbits, c.abits))
    });
    if !complete {
        return None;
    }
    Some(
        targets
            .iter()
            .map(|&(group, cand)| SensEntry {
                group,
                cand,
                score: j
                    .journal
                    .lookup_f64(
                        store::kind::PROBE,
                        store::probe_key(j.base, group, cand.wbits, cand.abits),
                    )
                    .expect("completeness checked above"),
            })
            .collect(),
    )
}

fn fit_journal_record(entries: &[SensEntry], journal: Option<&JournalScope>) -> Result<()> {
    if let Some(j) = journal {
        for e in entries {
            j.journal.record_f64(
                store::kind::PROBE,
                store::probe_key(j.base, e.group, e.cand.wbits, e.cand.abits),
                e.score,
            )?;
        }
    }
    Ok(())
}

/// FIT metric (Zandonati et al., used by the paper as the Fig. 2 Fisher
/// baseline): `FIT(g,c) = Σ_w  E[g_w²]·E[Δ_w(c)²] + Σ_a E[g_a²]·E[Δ_a(c)²]`.
/// Score is `-FIT` so that higher = less sensitive, like the other metrics.
fn fit_scores(
    handle: &ModelHandle,
    manifest: &Manifest,
    lattice: &Lattice,
    set: &EvalSet,
    journal: Option<&JournalScope>,
) -> Result<Vec<SensEntry>> {
    if let Some(list) = fit_journal_replay(&handle.entry, lattice, journal) {
        return Ok(list);
    }
    let entry = &handle.entry;
    let fit_file = entry
        .fit
        .as_ref()
        .ok_or_else(|| anyhow!("{} has no FIT artifact", entry.name))?;
    let exe = handle.rt.load(manifest.path(fit_file))?;
    let shapes = entry
        .fit_act_shapes
        .as_ref()
        .ok_or_else(|| anyhow!("missing fit_act_shapes"))?;

    // zero perturbations, uploaded once; trained parameters reused from the
    // handle's resident copies (uploaded once at open)
    let pert_bufs: Vec<Buffer> = shapes
        .iter()
        .map(|s| handle.rt.buffer(&Tensor::zeros(s)))
        .collect::<Result<_>>()?;

    let abits_opts = lattice.abits_options();
    let ranges = handle
        .act_ranges
        .as_ref()
        .ok_or_else(|| anyhow!("calibrate_ranges() not run"))?;

    // accumulate per-abits: agrad2[A], aerr2[A]; wgrad2[W] shared
    let nb = set.batches.len();
    let mut wgrad2 = vec![0f64; entry.n_w()];
    let mut agrad2 = vec![0f64; entry.n_act()];
    let mut aerr2: HashMap<u8, Vec<f64>> = HashMap::new();
    for &abits in &abits_opts {
        let qp_buf = handle.rt.buffer(&fit_act_qp(entry, ranges, abits)?)?;
        let raws = fit_batch_raws(
            &handle.rt,
            &exe,
            handle.param_buffers(),
            &pert_bufs,
            &qp_buf,
            &set.batches,
            &set.labels,
            set.batch,
        )?;
        let errs = aerr2.entry(abits).or_insert_with(|| vec![0f64; entry.n_act()]);
        fit_fold(&mut wgrad2, &mut agrad2, errs, &raws, nb, abits_opts.len());
    }
    let out = fit_finish(handle, lattice, &wgrad2, &agrad2, &aerr2)?;
    fit_journal_record(&out, journal)?;
    Ok(out)
}

/// FIT accumulation fanned out over an [`EvalPool`]'s shards: one
/// broadcast per activation bit-width, raw per-batch outputs merged in
/// global batch order and folded with the serial accumulation — scores
/// **bit-identical** to [`fit_scores`] at any worker count.
fn fit_scores_pooled(
    pool: &EvalPool,
    set: SetKey,
    handle: &ModelHandle,
    lattice: &Lattice,
    journal: Option<&JournalScope>,
) -> Result<Vec<SensEntry>> {
    if let Some(list) = fit_journal_replay(&handle.entry, lattice, journal) {
        return Ok(list);
    }
    let entry = &handle.entry;
    if entry.fit.is_none() {
        bail!("{} has no FIT artifact", entry.name);
    }
    let ranges = handle
        .act_ranges
        .as_ref()
        .ok_or_else(|| anyhow!("calibrate_ranges() not run"))?;
    let abits_opts = lattice.abits_options();
    let qps: Vec<Tensor> = abits_opts
        .iter()
        .map(|&a| fit_act_qp(entry, ranges, a))
        .collect::<Result<_>>()?;
    let per_abits = pool.fit_accumulate(set, &qps)?;

    let nb = per_abits.first().map(|r| r.len()).unwrap_or(0);
    if nb == 0 {
        bail!("pooled FIT accumulation saw no batches");
    }
    let mut wgrad2 = vec![0f64; entry.n_w()];
    let mut agrad2 = vec![0f64; entry.n_act()];
    let mut aerr2: HashMap<u8, Vec<f64>> = HashMap::new();
    for (&abits, raws) in abits_opts.iter().zip(&per_abits) {
        let errs = aerr2.entry(abits).or_insert_with(|| vec![0f64; entry.n_act()]);
        fit_fold(&mut wgrad2, &mut agrad2, errs, raws, nb, abits_opts.len());
    }
    let out = fit_finish(handle, lattice, &wgrad2, &agrad2, &aerr2)?;
    fit_journal_record(&out, journal)?;
    Ok(out)
}

/// Per-quantizer SQNR at a fixed candidate — Fig. 3's per-network SQNR
/// ranges.  Probes each activation / weight quantizer *individually*,
/// streaming every probe against the engine's cached FP reference.
pub fn per_quantizer_sqnr(
    handle: &ModelHandle,
    set: &EvalSet,
    cand: Candidate,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let ev = Evaluator::new(handle, set);
    let no_ov = WeightOverrides::new();
    let mut act = Vec::with_capacity(handle.entry.n_act());
    for a in 0..handle.entry.n_act() {
        let mut cfg = QuantConfig::fp32(&handle.entry);
        cfg.act[a] = Some(cand.abits);
        act.push(ev.sqnr(&cfg, &no_ov)?);
    }
    let mut w = Vec::with_capacity(handle.entry.n_w());
    for i in 0..handle.entry.n_w() {
        let mut cfg = QuantConfig::fp32(&handle.entry);
        cfg.w[i] = Some(cand.wbits);
        w.push(ev.sqnr(&cfg, &no_ov)?);
    }
    Ok((act, w))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqnr_zero_error_is_large() {
        let a = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let s = sqnr_db(&a, &a).unwrap();
        assert!(s > 100.0, "{s}");
    }

    #[test]
    fn sqnr_known_ratio() {
        // signal power 1.0 per element, error power 0.01 → 20 dB
        let f = Tensor::from_f32(&[1, 4], vec![1.0; 4]).unwrap();
        let q = Tensor::from_f32(&[1, 4], vec![0.9; 4]).unwrap();
        let s = sqnr_db(&f, &q).unwrap();
        assert!((s - 20.0).abs() < 1e-3, "{s}");
    }

    #[test]
    fn sqnr_monotone_in_noise() {
        let f = Tensor::from_f32(&[1, 8], (1..=8).map(|x| x as f32).collect()).unwrap();
        let mk = |eps: f32| {
            Tensor::from_f32(&[1, 8], (1..=8).map(|x| x as f32 + eps).collect()).unwrap()
        };
        let s1 = sqnr_db(&f, &mk(0.01)).unwrap();
        let s2 = sqnr_db(&f, &mk(0.1)).unwrap();
        assert!(s1 > s2);
    }

    #[test]
    fn sqnr_shape_mismatch() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(sqnr_db(&a, &b).is_err());
    }
}
