//! On-disk Phase-1 sensitivity-list cache.
//!
//! A sensitivity list is a pure function of `(trained weights, calibration
//! data, metric, lattice)` — the activation ranges and weight scales it
//! probes under are themselves derived from the same weights and data.  So
//! repeated experiment drivers (re-running a table, sweeping Phase-2
//! budgets over one Phase-1 list, reproducing figures) can skip the probe
//! sweep entirely by persisting the list under a content digest of those
//! inputs (ROADMAP open item).  The digest covers the trained weight
//! tensors, not just the model name, so regenerating the artifacts with
//! different weights invalidates old entries instead of serving them.
//!
//! Files are written via [`crate::jsonio`] as
//! `sens_<model>_<metric>_<digest:016x>.json`; scores round-trip bit-exactly
//! (Rust's `f64` `Display` is shortest-round-trip).  Lists containing
//! non-finite scores are not cached — they aren't representable in JSON and
//! a degenerate probe is worth re-measuring anyway.
//!
//! The cache is opt-in at the [`crate::coordinator::Pipeline`] level
//! (`set_sens_cache_dir`); the experiment drivers and the CLI enable it by
//! default under `<artifacts>/sens_cache` (`MPQ_SENS_CACHE=0` disables, a
//! path overrides) and report hit/miss counters.
//!
//! **Corruption degrades to a miss, never a failed run**: both caches are
//! checksummed (an FNV field in the JSON; the framed
//! [`crate::store`] blob container for the binary reference), loads verify
//! before trusting, and a corrupt/truncated/half-written file is
//! quarantined as `<name>.corrupt` with a warning and a
//! [`crate::store::StoreStats`] counter bump — the sweep then simply
//! regenerates it.  All persists go through the atomic temp+fsync+rename
//! helper, so concurrent runs sharing a cache dir never observe partial
//! files.

use super::{Metric, SensEntry};
use crate::data::DataSet;
use crate::groups::{Candidate, Lattice};
use crate::jsonio::{self, Json};
use crate::manifest::ModelEntry;
use crate::store::{self, StoreStats};
use crate::tensor::{io as tio, Tensor};
use crate::util::Fnv;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

pub fn metric_tag(m: Metric) -> &'static str {
    match m {
        Metric::Sqnr => "sqnr",
        Metric::Accuracy => "accuracy",
        Metric::Fit => "fit",
    }
}

/// Content digest of everything a sensitivity list depends on: the model
/// identity, quantizer topology and **trained weight tensors**, the
/// metric, the candidate lattice, and the exact calibration tensors (which
/// also determine the MSE ranges the probes run under).
pub fn digest(
    entry: &ModelEntry,
    lattice: &Lattice,
    metric: Metric,
    calib: &DataSet,
    weights: &[Tensor],
) -> u64 {
    let mut h = Fnv::new();
    h.write_bytes(entry.name.as_bytes());
    h.write_usize(entry.n_act());
    h.write_usize(entry.n_w());
    h.write_usize(entry.groups.len());
    h.write_bytes(metric_tag(metric).as_bytes());
    h.write_u8(lattice.baseline.wbits);
    h.write_u8(lattice.baseline.abits);
    for c in &lattice.candidates {
        h.write_u8(c.wbits);
        h.write_u8(c.abits);
    }
    h.write_tensor(&calib.x);
    h.write_tensor(&calib.y);
    for w in weights {
        h.write_tensor(w);
    }
    h.finish()
}

pub fn cache_path(dir: &Path, model: &str, metric: Metric, digest: u64) -> PathBuf {
    dir.join(format!("sens_{model}_{}_{digest:016x}.json", metric_tag(metric)))
}

/// FNV checksum over a list's semantic content (group, candidate, exact
/// score bits per entry) — the integrity field `store`/`load` verify.
fn entries_checksum(entries: &[SensEntry]) -> u64 {
    let mut h = Fnv::new();
    for e in entries {
        h.write_usize(e.group);
        h.write_u8(e.cand.wbits);
        h.write_u8(e.cand.abits);
        h.write_u64(e.score.to_bits());
    }
    h.finish()
}

/// Load a cached list; `Ok(None)` when the file doesn't exist **or** is
/// corrupt — a file that fails to parse or fails its checksum (including
/// pre-checksum legacy files) is quarantined and treated as a miss, never
/// an error: the sweep regenerates it.
pub fn load(path: &Path, stats: &StoreStats) -> Result<Option<Vec<SensEntry>>> {
    if !path.exists() {
        return Ok(None);
    }
    match try_load(path) {
        Ok(out) => Ok(Some(out)),
        Err(e) => {
            store::quarantine(path, stats, &format!("corrupt sens cache ({e:#})"));
            stats
                .cache_corrupt_misses
                .set(stats.cache_corrupt_misses.get() + 1);
            Ok(None)
        }
    }
}

fn try_load(path: &Path) -> Result<Vec<SensEntry>> {
    let j = jsonio::parse_file(path).with_context(|| format!("sens cache {}", path.display()))?;
    let mut out = Vec::new();
    for e in j.req("entries")?.as_arr()? {
        out.push(SensEntry {
            group: e.req("group")?.as_usize()?,
            cand: Candidate::new(
                e.req("wbits")?.as_usize()? as u8,
                e.req("abits")?.as_usize()? as u8,
            ),
            score: e.req("score")?.as_f64()?,
        });
    }
    let want = u64::from_str_radix(j.req("checksum")?.as_str()?, 16)
        .context("bad checksum field")?;
    let got = entries_checksum(&out);
    if want != got {
        anyhow::bail!("checksum mismatch: file says {want:016x}, content is {got:016x}");
    }
    Ok(out)
}

/// Persist a list.  Skipped (not an error) when any score is non-finite.
pub fn store(
    path: &Path,
    model: &str,
    metric: Metric,
    digest: u64,
    entries: &[SensEntry],
) -> Result<()> {
    if entries.iter().any(|e| !e.score.is_finite()) {
        return Ok(());
    }
    let arr = entries
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("group".into(), Json::Num(e.group as f64)),
                ("wbits".into(), Json::Num(e.cand.wbits as f64)),
                ("abits".into(), Json::Num(e.cand.abits as f64)),
                ("score".into(), Json::Num(e.score)),
            ])
        })
        .collect();
    let j = Json::Obj(vec![
        ("model".into(), Json::Str(model.into())),
        ("metric".into(), Json::Str(metric_tag(metric).into())),
        ("digest".into(), Json::Str(format!("{digest:016x}"))),
        ("checksum".into(), Json::Str(format!("{:016x}", entries_checksum(entries)))),
        ("entries".into(), Json::Arr(arr)),
    ]);
    store::atomic_write(path, (j.to_string() + "\n").as_bytes())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// FP32 reference cache
// ---------------------------------------------------------------------------
//
// The engine's FP32 reference (per-batch logits, `engine::FpReference`) is
// a pure function of the trained weights and the calibration inputs — the
// same dependency set as the sensitivity lists minus the metric/lattice.
// Persisting it next to the sensitivity cache lets repeated experiment
// drivers skip the reference forward sweep entirely (ROADMAP open item):
// the pipeline installs the restored per-batch logits into the serial
// engine, or ships shard slices to every fleet worker.  Files are a
// `store` blob (checksummed framed container, keyed by the content
// digest) wrapping an MPQT tensor concatenation (`tensor::io`), so logits
// round-trip bit-exactly and any corruption — including a payload bit
// flip raw MPQT could not detect — degrades to a quarantined miss.

/// Content digest of everything the FP32 reference depends on: the model
/// identity and **trained weight tensors** plus the exact calibration
/// tensors.  Deliberately metric/lattice-free — one reference serves every
/// Phase-1 metric swept on the same data.
pub fn ref_digest(entry: &ModelEntry, calib: &DataSet, weights: &[Tensor]) -> u64 {
    let mut h = Fnv::new();
    h.write_bytes(entry.name.as_bytes());
    h.write_usize(entry.batch);
    h.write_tensor(&calib.x);
    h.write_tensor(&calib.y);
    for w in weights {
        h.write_tensor(w);
    }
    h.finish()
}

pub fn ref_path(dir: &Path, model: &str, digest: u64) -> PathBuf {
    dir.join(format!("ref_{model}_{digest:016x}.bin"))
}

/// Load cached per-batch FP32 logits; `Ok(None)` when the file doesn't
/// exist **or** is corrupt/stale — bad container, failed checksum, digest
/// mismatch, undecodable payload and pre-container legacy files are all
/// quarantined and treated as a miss, never an error.
pub fn load_ref(path: &Path, digest: u64, stats: &StoreStats) -> Result<Option<Vec<Tensor>>> {
    let miss = |e: anyhow::Error| {
        store::quarantine(path, stats, &format!("corrupt ref cache ({e:#})"));
        stats
            .cache_corrupt_misses
            .set(stats.cache_corrupt_misses.get() + 1);
        Ok(None)
    };
    match store::read_blob(path, digest) {
        Ok(None) => Ok(None),
        Ok(Some(payload)) => match tio::decode_tensors(&payload)
            .with_context(|| format!("ref cache {}", path.display()))
        {
            Ok(ts) => Ok(Some(ts)),
            Err(e) => miss(e),
        },
        Err(e) => miss(e),
    }
}

/// Persist per-batch FP32 logits (global batch order) under their content
/// digest, atomically.
pub fn store_ref(path: &Path, digest: u64, batches: &[Tensor]) -> Result<()> {
    store::write_blob(path, digest, &tio::encode_tensors(batches))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn fake_list() -> Vec<SensEntry> {
        vec![
            SensEntry { group: 3, cand: Candidate::new(4, 8), score: 17.25 },
            SensEntry { group: 0, cand: Candidate::new(8, 8), score: 0.1 + 0.2 },
            SensEntry { group: 1, cand: Candidate::new(8, 16), score: -3.5e-7 },
        ]
    }

    fn fake_calib(seed: f32) -> DataSet {
        DataSet {
            x: Tensor::from_f32(&[4, 2], vec![seed, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0])
                .unwrap(),
            y: Tensor::from_f32(&[4], vec![0.0, 1.0, 0.0, 1.0]).unwrap(),
        }
    }

    #[test]
    fn store_load_roundtrips_bit_exactly() {
        let dir = std::env::temp_dir().join("mpq_sens_cache_test");
        let stats = StoreStats::default();
        let path = cache_path(&dir, "resnet_s", Metric::Sqnr, 0xabcd);
        let list = fake_list();
        store(&path, "resnet_s", Metric::Sqnr, 0xabcd, &list).unwrap();
        let got = load(&path, &stats).unwrap().expect("cache file written");
        assert_eq!(got.len(), list.len());
        for (g, w) in got.iter().zip(&list) {
            assert_eq!(g.group, w.group);
            assert_eq!(g.cand, w.cand);
            assert_eq!(g.score.to_bits(), w.score.to_bits(), "score must round-trip");
        }
        assert!(!stats.any(), "clean roundtrip must not report degradation");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_missing_is_none_and_nonfinite_not_stored() {
        let dir = std::env::temp_dir().join("mpq_sens_cache_test");
        let stats = StoreStats::default();
        assert!(load(&cache_path(&dir, "x", Metric::Fit, 1), &stats).unwrap().is_none());
        let path = cache_path(&dir, "nanly", Metric::Accuracy, 2);
        let mut list = fake_list();
        list[1].score = f64::NAN;
        store(&path, "nanly", Metric::Accuracy, 2, &list).unwrap();
        assert!(
            load(&path, &stats).unwrap().is_none(),
            "non-finite lists must not be cached"
        );
        assert_eq!(stats.cache_corrupt_misses.get(), 0);
    }

    #[test]
    fn corrupt_sens_cache_quarantines_to_miss() {
        let dir = std::env::temp_dir().join("mpq_sens_cache_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = cache_path(&dir, "m", Metric::Sqnr, 0x77);
        let list = fake_list();

        // truncated JSON
        store(&path, "m", Metric::Sqnr, 0x77, &list).unwrap();
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let stats = StoreStats::default();
        assert!(load(&path, &stats).unwrap().is_none(), "truncation is a miss");
        assert_eq!(stats.cache_corrupt_misses.get(), 1);
        assert_eq!(stats.files_quarantined.get(), 1);
        assert!(!path.exists(), "bad file moved aside");
        let q = dir.join(format!("{}.corrupt", path.file_name().unwrap().to_string_lossy()));
        assert!(q.exists(), "quarantined copy kept for post-mortem");

        // tampered score: parses fine, fails the checksum
        store(&path, "m", Metric::Sqnr, 0x77, &list).unwrap();
        let tampered = std::fs::read_to_string(&path).unwrap().replace("17.25", "18.25");
        assert_ne!(tampered, std::fs::read_to_string(&path).unwrap());
        std::fs::write(&path, tampered).unwrap();
        let stats = StoreStats::default();
        assert!(load(&path, &stats).unwrap().is_none(), "checksum mismatch is a miss");
        assert_eq!(stats.cache_corrupt_misses.get(), 1);

        // legacy file without a checksum field: regenerate, don't trust
        std::fs::write(&path, "{\"entries\": []}\n").unwrap();
        let stats = StoreStats::default();
        assert!(load(&path, &stats).unwrap().is_none());
        assert_eq!(stats.cache_corrupt_misses.get(), 1);
    }

    #[test]
    fn ref_cache_roundtrips_bit_exactly_and_tracks_inputs() {
        let dir = std::env::temp_dir().join("mpq_ref_cache_test");
        let e = crate::bops::tests_support::toy_entry();
        let ds = fake_calib(0.0);
        let w = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, -1.5]).unwrap()];
        let d0 = ref_digest(&e, &ds, &w);
        assert_eq!(d0, ref_digest(&e, &ds, &w), "digest is deterministic");
        assert_ne!(d0, ref_digest(&e, &fake_calib(9.0), &w), "data keyed");
        let w2 = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, 99.0]).unwrap()];
        assert_ne!(d0, ref_digest(&e, &ds, &w2), "weights keyed");

        let path = ref_path(&dir, "toy", d0);
        let stats = StoreStats::default();
        assert!(load_ref(&path, d0, &stats).unwrap().is_none(), "missing file is a miss");
        let batches = vec![
            Tensor::from_f32(&[2, 3], vec![0.1 + 0.2, -1.5, 3.25e-7, 0.0, -0.0, 42.0]).unwrap(),
            Tensor::from_f32(&[2, 3], vec![9.0, 8.0, 7.0, 6.0, 5.0, 4.0]).unwrap(),
        ];
        store_ref(&path, d0, &batches).unwrap();
        let back = load_ref(&path, d0, &stats).unwrap().expect("file written");
        assert_eq!(back, batches, "logits must round-trip bit-exactly");
        assert!(!stats.any(), "clean roundtrip must not report degradation");

        // flip one payload bit: raw MPQT could not catch this — the blob
        // container's checksum must, degrading to a quarantined miss
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_ref(&path, d0, &stats).unwrap().is_none(), "bit flip is a miss");
        assert_eq!(stats.cache_corrupt_misses.get(), 1);
        assert_eq!(stats.files_quarantined.get(), 1);
        assert!(!path.exists());

        // digest mismatch (stale file for other weights): miss as well
        store_ref(&path, d0, &batches).unwrap();
        let stats = StoreStats::default();
        assert!(load_ref(&path, d0 ^ 1, &stats).unwrap().is_none(), "stale digest is a miss");
        assert_eq!(stats.cache_corrupt_misses.get(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn digest_tracks_inputs() {
        let e = crate::bops::tests_support::toy_entry();
        let lat = Lattice::practical();
        let ds = fake_calib(0.0);
        let w = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, -1.5]).unwrap()];
        let d0 = digest(&e, &lat, Metric::Sqnr, &ds, &w);
        assert_eq!(d0, digest(&e, &lat, Metric::Sqnr, &ds, &w), "digest is deterministic");
        assert_ne!(d0, digest(&e, &lat, Metric::Accuracy, &ds, &w), "metric keyed");
        assert_ne!(d0, digest(&e, &Lattice::expanded(), Metric::Sqnr, &ds, &w), "lattice keyed");
        assert_ne!(d0, digest(&e, &lat, Metric::Sqnr, &fake_calib(9.0), &w), "data keyed");
        let w2 = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, 99.0]).unwrap()];
        assert_ne!(
            d0,
            digest(&e, &lat, Metric::Sqnr, &ds, &w2),
            "regenerated weights must invalidate cached lists"
        );
    }
}
