//! On-disk Phase-1 sensitivity-list cache.
//!
//! A sensitivity list is a pure function of `(trained weights, calibration
//! data, metric, lattice)` — the activation ranges and weight scales it
//! probes under are themselves derived from the same weights and data.  So
//! repeated experiment drivers (re-running a table, sweeping Phase-2
//! budgets over one Phase-1 list, reproducing figures) can skip the probe
//! sweep entirely by persisting the list under a content digest of those
//! inputs (ROADMAP open item).  The digest covers the trained weight
//! tensors, not just the model name, so regenerating the artifacts with
//! different weights invalidates old entries instead of serving them.
//!
//! Files are written via [`crate::jsonio`] as
//! `sens_<model>_<metric>_<digest:016x>.json`; scores round-trip bit-exactly
//! (Rust's `f64` `Display` is shortest-round-trip).  Lists containing
//! non-finite scores are not cached — they aren't representable in JSON and
//! a degenerate probe is worth re-measuring anyway.
//!
//! The cache is opt-in at the [`crate::coordinator::Pipeline`] level
//! (`set_sens_cache_dir`); the experiment drivers and the CLI enable it by
//! default under `<artifacts>/sens_cache` (`MPQ_SENS_CACHE=0` disables, a
//! path overrides) and report hit/miss counters.

use super::{Metric, SensEntry};
use crate::data::DataSet;
use crate::groups::{Candidate, Lattice};
use crate::jsonio::{self, Json};
use crate::manifest::ModelEntry;
use crate::tensor::{io as tio, Tensor};
use crate::util::Fnv;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

pub fn metric_tag(m: Metric) -> &'static str {
    match m {
        Metric::Sqnr => "sqnr",
        Metric::Accuracy => "accuracy",
        Metric::Fit => "fit",
    }
}

/// Content digest of everything a sensitivity list depends on: the model
/// identity, quantizer topology and **trained weight tensors**, the
/// metric, the candidate lattice, and the exact calibration tensors (which
/// also determine the MSE ranges the probes run under).
pub fn digest(
    entry: &ModelEntry,
    lattice: &Lattice,
    metric: Metric,
    calib: &DataSet,
    weights: &[Tensor],
) -> u64 {
    let mut h = Fnv::new();
    h.write_bytes(entry.name.as_bytes());
    h.write_usize(entry.n_act());
    h.write_usize(entry.n_w());
    h.write_usize(entry.groups.len());
    h.write_bytes(metric_tag(metric).as_bytes());
    h.write_u8(lattice.baseline.wbits);
    h.write_u8(lattice.baseline.abits);
    for c in &lattice.candidates {
        h.write_u8(c.wbits);
        h.write_u8(c.abits);
    }
    h.write_tensor(&calib.x);
    h.write_tensor(&calib.y);
    for w in weights {
        h.write_tensor(w);
    }
    h.finish()
}

pub fn cache_path(dir: &Path, model: &str, metric: Metric, digest: u64) -> PathBuf {
    dir.join(format!("sens_{model}_{}_{digest:016x}.json", metric_tag(metric)))
}

/// Load a cached list; `Ok(None)` when the file doesn't exist.
pub fn load(path: &Path) -> Result<Option<Vec<SensEntry>>> {
    if !path.exists() {
        return Ok(None);
    }
    let j = jsonio::parse_file(path).with_context(|| format!("sens cache {}", path.display()))?;
    let mut out = Vec::new();
    for e in j.req("entries")?.as_arr()? {
        out.push(SensEntry {
            group: e.req("group")?.as_usize()?,
            cand: Candidate::new(
                e.req("wbits")?.as_usize()? as u8,
                e.req("abits")?.as_usize()? as u8,
            ),
            score: e.req("score")?.as_f64()?,
        });
    }
    Ok(Some(out))
}

/// Persist a list.  Skipped (not an error) when any score is non-finite.
pub fn store(
    path: &Path,
    model: &str,
    metric: Metric,
    digest: u64,
    entries: &[SensEntry],
) -> Result<()> {
    if entries.iter().any(|e| !e.score.is_finite()) {
        return Ok(());
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let arr = entries
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("group".into(), Json::Num(e.group as f64)),
                ("wbits".into(), Json::Num(e.cand.wbits as f64)),
                ("abits".into(), Json::Num(e.cand.abits as f64)),
                ("score".into(), Json::Num(e.score)),
            ])
        })
        .collect();
    let j = Json::Obj(vec![
        ("model".into(), Json::Str(model.into())),
        ("metric".into(), Json::Str(metric_tag(metric).into())),
        ("digest".into(), Json::Str(format!("{digest:016x}"))),
        ("entries".into(), Json::Arr(arr)),
    ]);
    std::fs::write(path, j.to_string() + "\n")
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// FP32 reference cache
// ---------------------------------------------------------------------------
//
// The engine's FP32 reference (per-batch logits, `engine::FpReference`) is
// a pure function of the trained weights and the calibration inputs — the
// same dependency set as the sensitivity lists minus the metric/lattice.
// Persisting it next to the sensitivity cache lets repeated experiment
// drivers skip the reference forward sweep entirely (ROADMAP open item):
// the pipeline installs the restored per-batch logits into the serial
// engine, or ships shard slices to every fleet worker.  Files are MPQT
// tensor concatenations (`tensor::io`), so logits round-trip bit-exactly.

/// Content digest of everything the FP32 reference depends on: the model
/// identity and **trained weight tensors** plus the exact calibration
/// tensors.  Deliberately metric/lattice-free — one reference serves every
/// Phase-1 metric swept on the same data.
pub fn ref_digest(entry: &ModelEntry, calib: &DataSet, weights: &[Tensor]) -> u64 {
    let mut h = Fnv::new();
    h.write_bytes(entry.name.as_bytes());
    h.write_usize(entry.batch);
    h.write_tensor(&calib.x);
    h.write_tensor(&calib.y);
    for w in weights {
        h.write_tensor(w);
    }
    h.finish()
}

pub fn ref_path(dir: &Path, model: &str, digest: u64) -> PathBuf {
    dir.join(format!("ref_{model}_{digest:016x}.bin"))
}

/// Load cached per-batch FP32 logits; `Ok(None)` when the file doesn't
/// exist.
pub fn load_ref(path: &Path) -> Result<Option<Vec<Tensor>>> {
    if !path.exists() {
        return Ok(None);
    }
    let ts = tio::read_tensors(path)
        .with_context(|| format!("ref cache {}", path.display()))?;
    Ok(Some(ts))
}

/// Persist per-batch FP32 logits (global batch order).
pub fn store_ref(path: &Path, batches: &[Tensor]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    tio::write_tensors(path, batches)
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn fake_list() -> Vec<SensEntry> {
        vec![
            SensEntry { group: 3, cand: Candidate::new(4, 8), score: 17.25 },
            SensEntry { group: 0, cand: Candidate::new(8, 8), score: 0.1 + 0.2 },
            SensEntry { group: 1, cand: Candidate::new(8, 16), score: -3.5e-7 },
        ]
    }

    fn fake_calib(seed: f32) -> DataSet {
        DataSet {
            x: Tensor::from_f32(&[4, 2], vec![seed, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0])
                .unwrap(),
            y: Tensor::from_f32(&[4], vec![0.0, 1.0, 0.0, 1.0]).unwrap(),
        }
    }

    #[test]
    fn store_load_roundtrips_bit_exactly() {
        let dir = std::env::temp_dir().join("mpq_sens_cache_test");
        let path = cache_path(&dir, "resnet_s", Metric::Sqnr, 0xabcd);
        let list = fake_list();
        store(&path, "resnet_s", Metric::Sqnr, 0xabcd, &list).unwrap();
        let got = load(&path).unwrap().expect("cache file written");
        assert_eq!(got.len(), list.len());
        for (g, w) in got.iter().zip(&list) {
            assert_eq!(g.group, w.group);
            assert_eq!(g.cand, w.cand);
            assert_eq!(g.score.to_bits(), w.score.to_bits(), "score must round-trip");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_missing_is_none_and_nonfinite_not_stored() {
        let dir = std::env::temp_dir().join("mpq_sens_cache_test");
        assert!(load(&cache_path(&dir, "x", Metric::Fit, 1)).unwrap().is_none());
        let path = cache_path(&dir, "nanly", Metric::Accuracy, 2);
        let mut list = fake_list();
        list[1].score = f64::NAN;
        store(&path, "nanly", Metric::Accuracy, 2, &list).unwrap();
        assert!(load(&path).unwrap().is_none(), "non-finite lists must not be cached");
    }

    #[test]
    fn ref_cache_roundtrips_bit_exactly_and_tracks_inputs() {
        let dir = std::env::temp_dir().join("mpq_ref_cache_test");
        let e = crate::bops::tests_support::toy_entry();
        let ds = fake_calib(0.0);
        let w = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, -1.5]).unwrap()];
        let d0 = ref_digest(&e, &ds, &w);
        assert_eq!(d0, ref_digest(&e, &ds, &w), "digest is deterministic");
        assert_ne!(d0, ref_digest(&e, &fake_calib(9.0), &w), "data keyed");
        let w2 = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, 99.0]).unwrap()];
        assert_ne!(d0, ref_digest(&e, &ds, &w2), "weights keyed");

        let path = ref_path(&dir, "toy", d0);
        assert!(load_ref(&path).unwrap().is_none(), "missing file is a miss");
        let batches = vec![
            Tensor::from_f32(&[2, 3], vec![0.1 + 0.2, -1.5, 3.25e-7, 0.0, -0.0, 42.0]).unwrap(),
            Tensor::from_f32(&[2, 3], vec![9.0, 8.0, 7.0, 6.0, 5.0, 4.0]).unwrap(),
        ];
        store_ref(&path, &batches).unwrap();
        let back = load_ref(&path).unwrap().expect("file written");
        assert_eq!(back, batches, "logits must round-trip bit-exactly");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn digest_tracks_inputs() {
        let e = crate::bops::tests_support::toy_entry();
        let lat = Lattice::practical();
        let ds = fake_calib(0.0);
        let w = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, -1.5]).unwrap()];
        let d0 = digest(&e, &lat, Metric::Sqnr, &ds, &w);
        assert_eq!(d0, digest(&e, &lat, Metric::Sqnr, &ds, &w), "digest is deterministic");
        assert_ne!(d0, digest(&e, &lat, Metric::Accuracy, &ds, &w), "metric keyed");
        assert_ne!(d0, digest(&e, &Lattice::expanded(), Metric::Sqnr, &ds, &w), "lattice keyed");
        assert_ne!(d0, digest(&e, &lat, Metric::Sqnr, &fake_calib(9.0), &w), "data keyed");
        let w2 = vec![Tensor::from_f32(&[2, 2], vec![0.5, -0.5, 1.5, 99.0]).unwrap()];
        assert_ne!(
            d0,
            digest(&e, &lat, Metric::Sqnr, &ds, &w2),
            "regenerated weights must invalidate cached lists"
        );
    }
}
