//! Durable run layer: atomic persisted writes, a checksummed framed
//! record container, and the crash-safe **write-ahead run journal**.
//!
//! The pipeline's expensive phases — the Phase-1 probe sweep, Phase-2
//! prefix evaluations and the per-`(layer, wbits)` AdaRound optimizations
//! — are exactly the work an OOM kill, a preempted node or a ctrl-C
//! throws away.  This module gives the coordinator process-boundary
//! durability, the same discipline the fleet supervisor (PR 6) applies to
//! worker threads:
//!
//! * [`atomic_write`] / [`AtomicFile`] — every final-path persist in the
//!   crate (sensitivity cache, reference cache, bench JSON, report files)
//!   goes through temp-file + fsync + rename, so concurrent runs sharing
//!   an artifacts dir never observe half-written files.
//! * **Framed records** — `len · kind · digest · checksum · payload`
//!   frames behind a versioned magic header ([`FILE_MAGIC`]).  Checksums
//!   are FNV-1a over the frame content, so truncation and bit flips are
//!   *detected*, never parsed into garbage.  [`write_blob`]/[`read_blob`]
//!   wrap a single payload (the FP32 reference cache) in the same
//!   container.
//! * [`RunJournal`] — an append-only frame log (`journal.mpqj` in the
//!   artifacts dir by default) the coordinator appends to at **phase
//!   barriers**: each completed Phase-1 `(group, candidate)` probe score,
//!   each Phase-2 evaluated prefix `(k, metric)`, each AdaRound
//!   `(layer, wbits)` rounded tensor.  Every record is keyed by the same
//!   content digests the sens/ref caches use, so a journal from different
//!   weights/data/config is *ignored*, never trusted.  `--resume` replays
//!   the journal and skips completed work in both the serial and pooled
//!   paths, with results byte-equal to an uninterrupted run.
//!
//! **Durability model:** each appended record is a single `write(2)` that
//! reaches the kernel before the barrier counter advances, so records
//! survive any *process* death — including the `crash@PHASE:N` fault,
//! which aborts at the Nth barrier *after* the Nth record is durable
//! (write-ahead order).  Cache files additionally fsync before rename
//! (power-safe).  A torn final record (machine crash mid-append) fails
//! its checksum on the next open and the journal is truncated back to the
//! last valid record — losing at most the in-flight barrier, never
//! corrupting earlier ones.
//!
//! Telemetry lands in [`StoreStats`], surfaced by the drivers next to the
//! fleet's `FailureStats`.

use crate::util::Fnv;
use anyhow::{bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

/// 8-byte container header: magic + little-endian format version.
pub const FILE_MAGIC: &[u8; 4] = b"MPQJ";
pub const FORMAT_VERSION: u16 = 1;
/// Frame header bytes: `u32 len · u16 kind · u16 reserved · u64 digest ·
/// u64 checksum`.
const FRAME_HEADER: usize = 4 + 2 + 2 + 8 + 8;
const FILE_HEADER: usize = 8;

/// Record kinds — what a frame's payload means.
pub mod kind {
    /// Phase-1 probe score: payload = `f64` score bits (LE).
    pub const PROBE: u16 = 1;
    /// Phase-2 prefix evaluation: payload = `f64` metric bits (LE).
    pub const SEARCH_EVAL: u16 = 2;
    /// AdaRound rounded tensor: payload = one MPQT-encoded tensor.
    pub const ADAROUND: u16 = 3;
    /// Single-payload blob container ([`super::write_blob`]).
    pub const BLOB: u16 = 4;
}

// ---------------------------------------------------------------------------
// Telemetry
// ---------------------------------------------------------------------------

/// Durability telemetry, reported by the drivers next to the fleet's
/// `FailureStats`.  Shared `Rc`-style between the journal, the caches and
/// the pipeline (all on the coordinator thread).
#[derive(Debug, Default)]
pub struct StoreStats {
    /// journal records appended (= barriers crossed) this process
    pub journal_appended: Cell<u64>,
    /// valid records replayed from an existing journal at `--resume`
    pub journal_replayed: Cell<u64>,
    /// completed work units skipped because the journal already held them
    pub journal_skips: Cell<u64>,
    /// journals truncated back to their last valid record
    pub journal_truncations: Cell<u64>,
    /// corrupt/truncated cache files degraded to a miss
    pub cache_corrupt_misses: Cell<u64>,
    /// bad files renamed to `<name>.corrupt` (or deleted) for post-mortem
    pub files_quarantined: Cell<u64>,
}

impl StoreStats {
    pub fn any(&self) -> bool {
        self.journal_appended.get() != 0
            || self.journal_replayed.get() != 0
            || self.journal_skips.get() != 0
            || self.journal_truncations.get() != 0
            || self.cache_corrupt_misses.get() != 0
            || self.files_quarantined.get() != 0
    }

    /// Did any *degradation* happen (corruption, truncation, quarantine)?
    /// Plain journaling traffic doesn't count.
    pub fn any_degraded(&self) -> bool {
        self.journal_truncations.get() != 0
            || self.cache_corrupt_misses.get() != 0
            || self.files_quarantined.get() != 0
    }
}

// ---------------------------------------------------------------------------
// Atomic writes
// ---------------------------------------------------------------------------

/// Monotonic discriminator so concurrent writers in one process never
/// collide on a temp name (different processes differ by pid).
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn temp_path_for(path: &Path) -> PathBuf {
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".into());
    path.with_file_name(format!(
        ".{name}.tmp.{}.{seq}",
        std::process::id()
    ))
}

/// A file that becomes visible at its final path only on [`commit`]
/// (temp file in the same directory + fsync + rename).  Dropping without
/// committing removes the temp file — a crash mid-write leaves at worst
/// an orphaned `.tmp` file, never a half-written final path.
///
/// [`commit`]: AtomicFile::commit
pub struct AtomicFile {
    tmp: PathBuf,
    dest: PathBuf,
    file: Option<std::fs::File>,
}

impl AtomicFile {
    pub fn create(dest: impl AsRef<Path>) -> Result<Self> {
        let dest = dest.as_ref().to_path_buf();
        if let Some(parent) = dest.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let tmp = temp_path_for(&dest);
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating temp file {}", tmp.display()))?;
        Ok(Self { tmp, dest, file: Some(file) })
    }

    /// fsync the data, rename over the destination, best-effort sync the
    /// directory so the rename itself is durable.
    pub fn commit(mut self) -> Result<()> {
        let file = self.file.take().expect("commit called once");
        file.sync_all()
            .with_context(|| format!("syncing {}", self.tmp.display()))?;
        drop(file);
        std::fs::rename(&self.tmp, &self.dest).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.dest.display())
        })?;
        if let Some(parent) = self.dest.parent() {
            if !parent.as_os_str().is_empty() {
                // directory fsync is advisory: some filesystems refuse
                // opening a directory for sync — the rename is already
                // atomic for concurrent readers either way
                if let Ok(d) = std::fs::File::open(parent) {
                    let _ = d.sync_all();
                }
            }
        }
        Ok(())
    }
}

impl std::io::Write for AtomicFile {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.file.as_mut().expect("not committed").write(buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.file.as_mut().expect("not committed").flush()
    }
}

impl Drop for AtomicFile {
    fn drop(&mut self) {
        if self.file.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Atomically replace `path` with `bytes` (temp file + fsync + rename).
pub fn atomic_write(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let mut f = AtomicFile::create(path.as_ref())?;
    f.write_all(bytes)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    f.commit()
}

/// Move a corrupt file out of the way as `<name>.corrupt` (replacing any
/// previous quarantine; falling back to deletion), warn, and count it.
/// Never errors: quarantine is already the degraded path.
pub fn quarantine(path: &Path, stats: &StoreStats, why: &str) {
    let q = {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "file".into());
        path.with_file_name(format!("{name}.corrupt"))
    };
    let _ = std::fs::remove_file(&q);
    let moved = std::fs::rename(path, &q).is_ok();
    if !moved {
        let _ = std::fs::remove_file(path);
    }
    eprintln!(
        "[mpq] warning: {why}: quarantined {} ({})",
        path.display(),
        if moved { "kept as .corrupt" } else { "deleted" }
    );
    stats.files_quarantined.set(stats.files_quarantined.get() + 1);
}

// ---------------------------------------------------------------------------
// Framed records
// ---------------------------------------------------------------------------

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub kind: u16,
    pub digest: u64,
    pub payload: Vec<u8>,
}

fn frame_checksum(kind: u16, digest: u64, payload: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write_u32(kind as u32);
    h.write_u64(digest);
    h.write_bytes(payload);
    h.finish()
}

/// The container header every framed file starts with.
pub fn file_header() -> [u8; FILE_HEADER] {
    let mut h = [0u8; FILE_HEADER];
    h[..4].copy_from_slice(FILE_MAGIC);
    h[4..6].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
    // h[6..8] = flags, reserved
    h
}

/// Encode one frame: `u32 len · u16 kind · u16 reserved · u64 digest ·
/// u64 checksum · payload` (all little-endian).
pub fn encode_record(kind: u16, digest: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&digest.to_le_bytes());
    out.extend_from_slice(&frame_checksum(kind, digest, payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Sequentially decode the frames after the file header.  Returns the
/// valid records and the byte offset of the end of the last valid frame —
/// any trailing bytes past it are a torn append or corruption.  Never
/// errors and never panics: the first bad frame simply ends the valid
/// prefix.
pub fn decode_records(bytes: &[u8]) -> (Vec<Record>, usize) {
    let mut out = Vec::new();
    let mut off = FILE_HEADER.min(bytes.len());
    loop {
        let rest = &bytes[off..];
        if rest.len() < FRAME_HEADER {
            break;
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().unwrap()) as usize;
        if rest.len() - FRAME_HEADER < len {
            break; // truncated payload (or absurd corrupted length)
        }
        let kind = u16::from_le_bytes(rest[4..6].try_into().unwrap());
        let digest = u64::from_le_bytes(rest[8..16].try_into().unwrap());
        let checksum = u64::from_le_bytes(rest[16..24].try_into().unwrap());
        let payload = &rest[FRAME_HEADER..FRAME_HEADER + len];
        if frame_checksum(kind, digest, payload) != checksum {
            break;
        }
        out.push(Record { kind, digest, payload: payload.to_vec() });
        off += FRAME_HEADER + len;
    }
    (out, off)
}

/// Is `bytes` a well-formed container header of the current version?
pub fn header_ok(bytes: &[u8]) -> bool {
    bytes.len() >= FILE_HEADER
        && &bytes[..4] == FILE_MAGIC
        && u16::from_le_bytes(bytes[4..6].try_into().unwrap()) == FORMAT_VERSION
}

// ---------------------------------------------------------------------------
// Streamed frames (the daemon wire protocol's unit)
// ---------------------------------------------------------------------------

/// Write one frame to a byte stream and flush it.  Same frame layout as
/// [`encode_record`]; `mpqd` uses this over a Unix socket with the frame's
/// `digest` field carrying the job id.
pub fn write_frame(w: &mut impl Write, kind: u16, digest: u64, payload: &[u8]) -> Result<()> {
    w.write_all(&encode_record(kind, digest, payload))
        .context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame from a byte stream.  `Ok(None)` on a clean EOF at a
/// frame boundary; errors on a mid-frame EOF, a payload longer than
/// `max_len` (bounded control-plane messages — a huge length is either
/// corruption or abuse) or a checksum mismatch.  Blocks until a full
/// frame arrives, so it is only suitable for sequenced request/reply or
/// subscription streams, which is all the daemon protocol contains.
pub fn read_frame(r: &mut impl std::io::Read, max_len: usize) -> Result<Option<Record>> {
    let mut hdr = [0u8; FRAME_HEADER];
    let mut got = 0;
    while got < hdr.len() {
        match r.read(&mut hdr[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                bail!("stream ended mid frame header ({got}/{FRAME_HEADER} bytes)");
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    if len > max_len {
        bail!("frame payload {len} bytes exceeds the {max_len}-byte control-plane cap");
    }
    let kind = u16::from_le_bytes(hdr[4..6].try_into().unwrap());
    let digest = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
    let checksum = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    if frame_checksum(kind, digest, &payload) != checksum {
        bail!("frame checksum mismatch (kind {kind}, {len}-byte payload)");
    }
    Ok(Some(Record { kind, digest, payload }))
}

// ---------------------------------------------------------------------------
// Single-payload blobs (the reference cache's container)
// ---------------------------------------------------------------------------

/// Atomically write a single checksummed payload under `digest` (used by
/// the FP32 reference cache: payload = MPQT tensor concatenation).
pub fn write_blob(path: impl AsRef<Path>, digest: u64, payload: &[u8]) -> Result<()> {
    let mut bytes = Vec::with_capacity(FILE_HEADER + FRAME_HEADER + payload.len());
    bytes.extend_from_slice(&file_header());
    bytes.extend_from_slice(&encode_record(kind::BLOB, digest, payload));
    atomic_write(path, &bytes)
}

/// Read a [`write_blob`] file back.  `Ok(None)` when the file doesn't
/// exist; `Err` on any corruption (bad header, failed checksum, trailing
/// bytes, digest mismatch) — callers degrade that to a quarantined miss.
pub fn read_blob(path: impl AsRef<Path>, expect_digest: u64) -> Result<Option<Vec<u8>>> {
    let path = path.as_ref();
    if !path.exists() {
        return Ok(None);
    }
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if !header_ok(&bytes) {
        bail!("{}: bad or outdated container header", path.display());
    }
    let (mut records, end) = decode_records(&bytes);
    if records.len() != 1 || end != bytes.len() {
        bail!(
            "{}: corrupt blob ({} valid records, {} trailing bytes)",
            path.display(),
            records.len(),
            bytes.len() - end
        );
    }
    let r = records.pop().unwrap();
    if r.kind != kind::BLOB || r.digest != expect_digest {
        bail!(
            "{}: blob digest {:016x} does not match expected {expect_digest:016x}",
            path.display(),
            r.digest
        );
    }
    Ok(Some(r.payload))
}

// ---------------------------------------------------------------------------
// Record-key derivations (shared by writers and resume readers)
// ---------------------------------------------------------------------------

fn combine(base: u64, tag: u8, fields: &[u64]) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(base);
    h.write_u8(tag);
    for &f in fields {
        h.write_u64(f);
    }
    h.finish()
}

/// Journal key of a Phase-1 probe: `base` is the sensitivity-sweep
/// content digest (`sensitivity::cache::digest`, plus the rounded-weights
/// digest when AdaRound is interweaved).
pub fn probe_key(base: u64, group: usize, wbits: u8, abits: u8) -> u64 {
    combine(base, b'p', &[group as u64, wbits as u64, abits as u64])
}

/// Journal key of a Phase-2 prefix evaluation: `base` is the search-scope
/// digest (model/weights/eval-data/lattice/flip-sequence/rounded).
pub fn eval_key(base: u64, k: usize) -> u64 {
    combine(base, b'e', &[k as u64])
}

/// Journal key of an AdaRound optimization: `base` is the AdaRound-scope
/// digest (model/weights/calibration-data/optimizer config).
pub fn adaround_key(base: u64, param_idx: usize, wbits: u8) -> u64 {
    combine(base, b'a', &[param_idx as u64, wbits as u64])
}

/// `f64` payload encoding (bit-exact round-trip).
pub fn f64_payload(x: f64) -> [u8; 8] {
    x.to_bits().to_le_bytes()
}

/// Decode a [`f64_payload`]; `None` on wrong length (corruption is caught
/// by the frame checksum; this guards mixed-kind programming errors).
pub fn payload_f64(p: &[u8]) -> Option<f64> {
    let arr: [u8; 8] = p.try_into().ok()?;
    Some(f64::from_bits(u64::from_le_bytes(arr)))
}

// ---------------------------------------------------------------------------
// The write-ahead run journal
// ---------------------------------------------------------------------------

/// Append-only write-ahead journal of completed pipeline work.
///
/// * [`open`](RunJournal::open) with `resume = false` starts a fresh
///   journal (truncating any previous one); with `resume = true` it
///   replays every valid record into memory — a corrupt or torn tail is
///   truncated away (counted in [`StoreStats::journal_truncations`]), a
///   bad header quarantines the whole file and starts fresh.
/// * [`lookup`](RunJournal::lookup) serves replayed/recorded payloads by
///   `(kind, key)`; callers skip the work a hit represents.
/// * [`record`](RunJournal::record) appends one frame — a **barrier**:
///   the frame reaches the kernel before the barrier counter advances,
///   and a `crash@PHASE:N` fault scheduled via
///   [`with_crash_barriers`](RunJournal::with_crash_barriers) fires
///   *after* the Nth record is durable (write-ahead order), panicking
///   with the standard `injected fault:` prefix.
///
/// Keys must be derived from content digests ([`probe_key`] /
/// [`eval_key`] / [`adaround_key`]) so records from a different
/// model/data/config simply never match — stale journals are ignored,
/// not trusted.
pub struct RunJournal {
    path: PathBuf,
    file: RefCell<std::fs::File>,
    records: RefCell<HashMap<(u16, u64), Vec<u8>>>,
    barriers: Cell<u64>,
    crash_at: Vec<u64>,
    stats: Rc<StoreStats>,
    /// Barrier observer `(ordinal, kind)` — the daemon turns journal
    /// append points into streamed progress events.  Called after the
    /// record is durable and before any injected crash fires.
    notify: RefCell<Option<Box<dyn Fn(u64, u16)>>>,
}

impl RunJournal {
    /// Open (resume) or start (fresh) the journal at `path`.
    pub fn open(path: impl AsRef<Path>, resume: bool, stats: Rc<StoreStats>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let mut records = HashMap::new();
        if resume && path.exists() {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading journal {}", path.display()))?;
            if !bytes.is_empty() && !header_ok(&bytes) {
                quarantine(&path, &stats, "journal has a bad or outdated header");
            } else if !bytes.is_empty() {
                let (recs, valid_end) = decode_records(&bytes);
                if valid_end < bytes.len() {
                    eprintln!(
                        "[mpq] warning: journal {} has {} corrupt/torn trailing \
                         bytes — truncating to the last valid record ({} kept)",
                        path.display(),
                        bytes.len() - valid_end,
                        recs.len()
                    );
                    let f = std::fs::OpenOptions::new()
                        .write(true)
                        .open(&path)
                        .with_context(|| format!("truncating {}", path.display()))?;
                    f.set_len(valid_end as u64)
                        .with_context(|| format!("truncating {}", path.display()))?;
                    stats
                        .journal_truncations
                        .set(stats.journal_truncations.get() + 1);
                }
                stats
                    .journal_replayed
                    .set(stats.journal_replayed.get() + recs.len() as u64);
                for r in recs {
                    records.insert((r.kind, r.digest), r.payload);
                }
            }
        }
        // an empty file (death between create and header write) restarts
        // fresh too — appending to it would produce a headerless journal
        let fresh = !resume
            || std::fs::metadata(&path).map(|m| m.len() == 0).unwrap_or(true);
        let mut opts = std::fs::OpenOptions::new();
        if fresh {
            opts.write(true).create(true).truncate(true);
        } else {
            opts.append(true);
        }
        let mut file = opts
            .open(&path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        if fresh {
            file.write_all(&file_header())
                .with_context(|| format!("writing journal header {}", path.display()))?;
        }
        Ok(Self {
            path,
            file: RefCell::new(file),
            records: RefCell::new(records),
            barriers: Cell::new(0),
            crash_at: Vec::new(),
            stats,
            notify: RefCell::new(None),
        })
    }

    /// Schedule `crash@PHASE:N` faults: the process panics right after the
    /// Nth appended record becomes durable (1-based ordinals).
    pub fn with_crash_barriers(mut self, ordinals: Vec<u64>) -> Self {
        self.crash_at = ordinals;
        self
    }

    /// Install a barrier observer, called with `(ordinal, kind)` after
    /// each record becomes durable (and before any injected crash fires,
    /// so a subscriber sees the progress event the journal will replay).
    pub fn set_notifier(&self, f: impl Fn(u64, u16) + 'static) {
        *self.notify.borrow_mut() = Some(Box::new(f));
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn stats(&self) -> &Rc<StoreStats> {
        &self.stats
    }

    /// Records currently known (replayed + appended).
    pub fn len(&self) -> usize {
        self.records.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Barriers crossed (records appended) by *this* process.
    pub fn barriers(&self) -> u64 {
        self.barriers.get()
    }

    /// Payload stored under `(kind, key)`, if the journal holds one.
    /// Counts a skip: a hit means the caller avoids redoing the work.
    pub fn lookup(&self, kind: u16, key: u64) -> Option<Vec<u8>> {
        let hit = self.records.borrow().get(&(kind, key)).cloned();
        if hit.is_some() {
            self.stats.journal_skips.set(self.stats.journal_skips.get() + 1);
        }
        hit
    }

    /// Does the journal hold `(kind, key)`?  (No skip accounting — used
    /// for completeness checks before committing to a journaled path.)
    pub fn contains(&self, kind: u16, key: u64) -> bool {
        self.records.borrow().contains_key(&(kind, key))
    }

    /// Append one record — a journal **barrier**.  Idempotent per key: a
    /// record already present (e.g. replayed) is not re-appended and does
    /// not advance the barrier counter.
    pub fn record(&self, kind: u16, key: u64, payload: &[u8]) -> Result<()> {
        if self.records.borrow().contains_key(&(kind, key)) {
            return Ok(());
        }
        {
            let mut f = self.file.borrow_mut();
            // one unbuffered write_all = the frame reaches the kernel
            // before we count the barrier (survives process death; a torn
            // tail from a machine crash is truncated on the next open)
            f.write_all(&encode_record(kind, key, payload))
                .with_context(|| format!("appending to journal {}", self.path.display()))?;
        }
        self.records.borrow_mut().insert((kind, key), payload.to_vec());
        self.stats.journal_appended.set(self.stats.journal_appended.get() + 1);
        let n = self.barriers.get() + 1;
        self.barriers.set(n);
        if let Some(f) = self.notify.borrow().as_ref() {
            f(n, kind);
        }
        if self.crash_at.contains(&n) {
            panic!("injected fault: crash@PHASE:{n}");
        }
        Ok(())
    }

    /// Convenience: journaled `f64` (scores/metrics), bit-exact.
    pub fn lookup_f64(&self, kind: u16, key: u64) -> Option<f64> {
        self.lookup(kind, key).and_then(|p| payload_f64(&p))
    }

    pub fn record_f64(&self, kind: u16, key: u64, x: f64) -> Result<()> {
        self.record(kind, key, &f64_payload(x))
    }
}

/// A journal handle scoped to one unit of work: the shared [`RunJournal`]
/// plus the **base content digest** every record key is derived from
/// (the sensitivity-sweep digest for Phase-1 probes, the search-scope
/// digest for Phase-2 evaluations, the AdaRound-scope digest for rounded
/// tensors).  Cloning shares the journal.
#[derive(Clone)]
pub struct JournalScope {
    pub journal: Rc<RunJournal>,
    pub base: u64,
}

impl JournalScope {
    pub fn new(journal: Rc<RunJournal>, base: u64) -> Self {
        Self { journal, base }
    }

    /// The same journal under a different base digest.
    pub fn rebase(&self, base: u64) -> Self {
        Self { journal: self.journal.clone(), base }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("mpq_store_test").join(name);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn atomic_write_replaces_and_survives_abandon() {
        let d = tdir("atomic");
        let p = d.join("x.json");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second");
        // an abandoned (dropped) writer must not touch the destination
        {
            let mut f = AtomicFile::create(&p).unwrap();
            f.write_all(b"half-written garbage").unwrap();
            // dropped without commit
        }
        assert_eq!(std::fs::read(&p).unwrap(), b"second");
        // and leaves no temp litter behind
        let stray: Vec<_> = std::fs::read_dir(&d)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(stray.is_empty(), "leftover temp files: {stray:?}");
    }

    #[test]
    fn frames_roundtrip_and_detect_corruption() {
        let payload = b"hello frames";
        let mut bytes = file_header().to_vec();
        bytes.extend_from_slice(&encode_record(kind::PROBE, 0xabcd, payload));
        bytes.extend_from_slice(&encode_record(kind::ADAROUND, 0x1234, b""));
        assert!(header_ok(&bytes));
        let (recs, end) = decode_records(&bytes);
        assert_eq!(end, bytes.len());
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].kind, kind::PROBE);
        assert_eq!(recs[0].digest, 0xabcd);
        assert_eq!(recs[0].payload, payload);
        assert_eq!(recs[1].payload, b"");

        // flip one payload bit → that record and everything after it drops
        let mut bad = bytes.clone();
        let payload_off = FILE_HEADER + FRAME_HEADER + 3;
        bad[payload_off] ^= 0x40;
        let (recs2, end2) = decode_records(&bad);
        assert!(recs2.is_empty());
        assert_eq!(end2, FILE_HEADER);

        // truncate mid-second-record → first survives
        let cut = FILE_HEADER + FRAME_HEADER + payload.len() + 5;
        let (recs3, end3) = decode_records(&bytes[..cut]);
        assert_eq!(recs3.len(), 1);
        assert_eq!(end3, FILE_HEADER + FRAME_HEADER + payload.len());
    }

    #[test]
    fn stream_frames_roundtrip_eof_and_caps() {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind::PROBE, 42, b"first").unwrap();
        write_frame(&mut buf, kind::BLOB, 7, b"").unwrap();
        let mut r: &[u8] = &buf;
        let a = read_frame(&mut r, 1024).unwrap().unwrap();
        assert_eq!((a.kind, a.digest, a.payload.as_slice()), (kind::PROBE, 42, &b"first"[..]));
        let b = read_frame(&mut r, 1024).unwrap().unwrap();
        assert_eq!((b.kind, b.digest, b.payload.as_slice()), (kind::BLOB, 7, &b""[..]));
        assert!(read_frame(&mut r, 1024).unwrap().is_none(), "clean EOF is None");

        // payload over the cap is rejected before any allocation
        let mut r2: &[u8] = &buf;
        assert!(read_frame(&mut r2, 4).is_err());
        // EOF mid-header and mid-payload are errors, not None
        let mut torn: &[u8] = &buf[..10];
        assert!(read_frame(&mut torn, 1024).is_err());
        let mut torn2: &[u8] = &buf[..FRAME_HEADER + 2];
        assert!(read_frame(&mut torn2, 1024).is_err());
        // a flipped payload bit fails the checksum
        let mut bad = buf.clone();
        bad[FRAME_HEADER + 1] ^= 0x10;
        let mut r3: &[u8] = &bad;
        assert!(read_frame(&mut r3, 1024).is_err());
    }

    #[test]
    fn journal_notifier_sees_every_barrier_in_order() {
        let d = tdir("notify");
        let p = d.join("journal.mpqj");
        let seen = Rc::new(RefCell::new(Vec::new()));
        let j = RunJournal::open(&p, false, Rc::new(StoreStats::default())).unwrap();
        let sink = seen.clone();
        j.set_notifier(move |n, k| sink.borrow_mut().push((n, k)));
        j.record_f64(kind::PROBE, 1, 0.5).unwrap();
        j.record(kind::ADAROUND, 2, b"t").unwrap();
        j.record_f64(kind::PROBE, 1, 0.5).unwrap(); // duplicate: no event
        assert_eq!(*seen.borrow(), vec![(1, kind::PROBE), (2, kind::ADAROUND)]);
    }

    #[test]
    fn blob_roundtrip_and_digest_check() {
        let d = tdir("blob");
        let p = d.join("ref.bin");
        assert!(read_blob(&p, 7).unwrap().is_none(), "missing file is a miss");
        write_blob(&p, 7, b"payload bytes").unwrap();
        assert_eq!(read_blob(&p, 7).unwrap().unwrap(), b"payload bytes");
        assert!(read_blob(&p, 8).is_err(), "digest mismatch must be rejected");
        // corrupt one byte anywhere → error, never garbage
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 2] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_blob(&p, 7).is_err());
    }

    #[test]
    fn journal_appends_replays_and_truncates_torn_tail() {
        let d = tdir("journal");
        let p = d.join("journal.mpqj");
        let stats = Rc::new(StoreStats::default());
        {
            let j = RunJournal::open(&p, false, stats.clone()).unwrap();
            j.record_f64(kind::PROBE, probe_key(9, 0, 4, 8), 17.25).unwrap();
            j.record_f64(kind::PROBE, probe_key(9, 1, 4, 8), -0.5).unwrap();
            j.record(kind::ADAROUND, adaround_key(9, 2, 4), b"tensorish").unwrap();
            assert_eq!(j.barriers(), 3);
            // idempotent per key: no duplicate frame, no extra barrier
            j.record_f64(kind::PROBE, probe_key(9, 0, 4, 8), 17.25).unwrap();
            assert_eq!(j.barriers(), 3);
        }
        assert_eq!(stats.journal_appended.get(), 3);

        // append a torn half-frame as a machine-crash tail
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[0x99; 11]).unwrap();
        }
        let stats2 = Rc::new(StoreStats::default());
        let j = RunJournal::open(&p, true, stats2.clone()).unwrap();
        assert_eq!(stats2.journal_replayed.get(), 3);
        assert_eq!(stats2.journal_truncations.get(), 1);
        assert_eq!(
            j.lookup_f64(kind::PROBE, probe_key(9, 0, 4, 8)),
            Some(17.25)
        );
        assert_eq!(
            j.lookup(kind::ADAROUND, adaround_key(9, 2, 4)).unwrap(),
            b"tensorish"
        );
        assert_eq!(j.lookup(kind::PROBE, probe_key(8, 0, 4, 8)), None, "stale base ignored");
        assert_eq!(stats2.journal_skips.get(), 2);
        // appending after resume continues the same file
        j.record_f64(kind::SEARCH_EVAL, eval_key(9, 3), 0.75).unwrap();
        drop(j);
        let stats3 = Rc::new(StoreStats::default());
        let j2 = RunJournal::open(&p, true, stats3.clone()).unwrap();
        assert_eq!(stats3.journal_replayed.get(), 4);
        assert_eq!(stats3.journal_truncations.get(), 0, "clean tail: no truncation");
        assert_eq!(j2.lookup_f64(kind::SEARCH_EVAL, eval_key(9, 3)), Some(0.75));
    }

    #[test]
    fn journal_fresh_open_discards_and_bad_header_quarantines() {
        let d = tdir("journal_fresh");
        let p = d.join("journal.mpqj");
        let stats = Rc::new(StoreStats::default());
        {
            let j = RunJournal::open(&p, false, stats.clone()).unwrap();
            j.record_f64(kind::PROBE, 1, 1.0).unwrap();
        }
        // resume=false truncates: the old record is gone
        {
            let j = RunJournal::open(&p, false, stats.clone()).unwrap();
            assert!(j.is_empty());
        }
        // garbage header: quarantined, journal starts fresh
        std::fs::write(&p, b"not a journal at all").unwrap();
        let stats2 = Rc::new(StoreStats::default());
        let j = RunJournal::open(&p, true, stats2.clone()).unwrap();
        assert!(j.is_empty());
        assert_eq!(stats2.files_quarantined.get(), 1);
        assert!(d.join("journal.mpqj.corrupt").exists());
        j.record_f64(kind::PROBE, 1, 2.0).unwrap();
        let j2 = RunJournal::open(&p, true, Rc::new(StoreStats::default())).unwrap();
        assert_eq!(j2.lookup_f64(kind::PROBE, 1), Some(2.0));
    }

    #[test]
    fn crash_barrier_fires_after_record_is_durable() {
        let d = tdir("crash");
        let p = d.join("journal.mpqj");
        let stats = Rc::new(StoreStats::default());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let j = RunJournal::open(&p, false, stats.clone())
                .unwrap()
                .with_crash_barriers(vec![2]);
            j.record_f64(kind::PROBE, 1, 1.5).unwrap();
            j.record_f64(kind::PROBE, 2, 2.5).unwrap(); // fires here
            j.record_f64(kind::PROBE, 3, 3.5).unwrap();
        }));
        let err = caught.expect_err("crash fault must fire");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("injected fault: crash@PHASE:2"), "{msg}");
        // write-ahead: records 1 AND 2 are durable, record 3 never ran
        let j = RunJournal::open(&p, true, Rc::new(StoreStats::default())).unwrap();
        assert_eq!(j.lookup_f64(kind::PROBE, 1), Some(1.5));
        assert_eq!(j.lookup_f64(kind::PROBE, 2), Some(2.5));
        assert_eq!(j.lookup_f64(kind::PROBE, 3), None);
    }

    #[test]
    fn keys_are_distinct_across_kind_and_fields() {
        let ks = [
            probe_key(1, 0, 4, 8),
            probe_key(1, 1, 4, 8),
            probe_key(1, 0, 8, 8),
            probe_key(2, 0, 4, 8),
            eval_key(1, 0),
            eval_key(1, 1),
            adaround_key(1, 0, 4),
            adaround_key(1, 0, 8),
        ];
        for i in 0..ks.len() {
            for j in i + 1..ks.len() {
                assert_ne!(ks[i], ks[j], "key collision at {i},{j}");
            }
        }
        let x = -3.25e-7f64;
        assert_eq!(payload_f64(&f64_payload(x)), Some(x));
        assert!(payload_f64(b"short").is_none());
        let nan = f64::from_bits(0x7ff8_dead_beef_0001);
        assert_eq!(
            payload_f64(&f64_payload(nan)).unwrap().to_bits(),
            nan.to_bits(),
            "NaN payloads must round-trip bit-exactly"
        );
    }
}
