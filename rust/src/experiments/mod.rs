//! Experiment drivers — one function per paper table/figure (DESIGN.md §5).
//!
//! Each driver opens the needed models on a shared PJRT runtime, runs the
//! two-phase algorithm in the paper's configuration, and returns
//! [`crate::report::Table`]s whose rows mirror the paper's.  The CLI
//! (`mpq <table1|...|fig5>`) and the `cargo bench` harnesses both call
//! these.
//!
//! Absolute numbers differ from the paper (miniature zoo, synthetic data —
//! DESIGN.md §3); the *shape* — who wins, roughly by how much, where MP
//! pays off — is the reproduction target recorded in EXPERIMENTS.md.

use crate::adaround::AdaRoundCfg;
use crate::coordinator::{Pipeline, SearchScheme};
use crate::groups::{Candidate, Lattice};
use crate::manifest::Manifest;
use crate::metrics::kendall_tau;
use crate::pool::{EvalFleet, FaultPlan};
use crate::report::{f3, f4, Table};
use crate::runtime::Runtime;
use crate::search::SearchRun;
use crate::sensitivity::{self, Metric};
use crate::store::{RunJournal, StoreStats};
use anyhow::{Context, Result};
use std::rc::Rc;

/// Common experiment options.
#[derive(Clone, Debug)]
pub struct Opts {
    pub dir: std::path::PathBuf,
    pub calib_n: usize,
    pub seed: u64,
    /// restrict to these models (None = experiment default)
    pub models: Option<Vec<String>>,
    /// shrink workloads (CI / smoke): fewer seeds, smaller val subsets
    pub fast: bool,
    /// evaluation-pool width (`--workers`); > 1 attaches an
    /// [`crate::pool::EvalPool`] to every pipeline the drivers open.
    /// Defaults to the host's available parallelism.
    pub workers: usize,
    /// explicit fleet fault-injection schedule (`--fault-plan`, the
    /// `crate::pool::FaultPlan` grammar) — overrides `MPQ_FAULT_PLAN` and
    /// the manifest's `fault_plan` key; `None` falls back to those
    pub fault_plan: Option<String>,
    /// `--resume`: replay the run journal and skip completed Phase-1
    /// probes / prefix evaluations / AdaRound layers instead of starting
    /// the journal fresh
    pub resume: bool,
    /// `--proc`: run fleet lanes as `mpq worker` subprocesses (see the
    /// process-lanes section of [`crate::pool`]) instead of threads
    pub proc: bool,
}

impl Default for Opts {
    fn default() -> Self {
        Self {
            dir: crate::artifacts_dir(),
            calib_n: 256,
            seed: 0,
            models: None,
            fast: std::env::var_os("MPQ_FAST").is_some(),
            workers: crate::util::default_workers(),
            fault_plan: None,
            resume: false,
            proc: false,
        }
    }
}

impl Opts {
    /// validation subset size used by Phase-2 metric evaluations
    pub fn val_n(&self) -> usize {
        if self.fast { 512 } else { 1024 }
    }

    /// On-disk Phase-1 sensitivity cache directory for the drivers:
    /// `<artifacts>/sens_cache` by default, a path in `MPQ_SENS_CACHE`
    /// overrides, `MPQ_SENS_CACHE=0` disables.
    pub fn sens_cache_dir(&self) -> Option<std::path::PathBuf> {
        match std::env::var("MPQ_SENS_CACHE") {
            Ok(v) if v == "0" => None,
            Ok(v) if !v.is_empty() && v != "1" => Some(std::path::PathBuf::from(v)),
            _ => Some(self.dir.join("sens_cache")),
        }
    }

    /// Crash-safe run-journal path for the drivers:
    /// `<artifacts>/journal.mpqj` by default, a path in `MPQ_JOURNAL`
    /// overrides, `MPQ_JOURNAL=0` disables journaling entirely.
    pub fn journal_path(&self) -> Option<std::path::PathBuf> {
        match std::env::var("MPQ_JOURNAL") {
            Ok(v) if v == "0" => None,
            Ok(v) if !v.is_empty() && v != "1" => Some(std::path::PathBuf::from(v)),
            _ => Some(self.dir.join("journal.mpqj")),
        }
    }
}

/// Resolve the effective fault plan the way the fleet does — explicit
/// `--fault-plan` over `MPQ_FAULT_PLAN` over the manifest's `fault_plan`
/// key — so `crash@PHASE:N` barriers fire identically in serial runs
/// (where no fleet exists to do the resolving).
fn resolve_fault_plan(opts: &Opts, manifest: &Manifest) -> Result<FaultPlan> {
    if let Some(spec) = &opts.fault_plan {
        return FaultPlan::parse(spec);
    }
    match std::env::var("MPQ_FAULT_PLAN") {
        Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s),
        _ => match manifest.fault_plan.as_deref() {
            Some(s) => FaultPlan::parse(s),
            None => Ok(FaultPlan::default()),
        },
    }
}

/// Open the crash-safe run journal for a driver/CLI run: path from
/// [`Opts::journal_path`] (`None` = journaling disabled), fresh unless
/// `--resume`, with any `crash@PHASE:N` barriers from the effective fault
/// plan armed.
pub fn open_journal(opts: &Opts, manifest: &Manifest) -> Result<Option<Rc<RunJournal>>> {
    let Some(path) = opts.journal_path() else { return Ok(None) };
    let stats = Rc::new(StoreStats::default());
    let barriers = resolve_fault_plan(opts, manifest)?.crash_barriers();
    Ok(Some(Rc::new(
        RunJournal::open(&path, opts.resume, stats)?.with_crash_barriers(barriers),
    )))
}

pub struct Env {
    pub manifest: Manifest,
    pub rt: Rc<Runtime>,
    /// the process-wide evaluation fleet (`--workers` > 1): spawned once
    /// per driver run, shared by every pipeline/model the driver opens —
    /// worker threads and compiled executables persist across models
    fleet: Option<Rc<EvalFleet>>,
    sens_cache: Option<std::path::PathBuf>,
    /// crash-safe run journal shared by every pipeline the driver opens
    /// (`--resume` replays it; `MPQ_JOURNAL=0` disables)
    journal: Option<Rc<RunJournal>>,
}

impl Env {
    pub fn open(opts: &Opts) -> Result<Self> {
        let manifest = Manifest::load(&opts.dir)?;
        let rt = Rc::new(Runtime::for_manifest(&manifest)?);
        let fleet = if opts.workers > 1 {
            Some(match (&opts.fault_plan, opts.proc) {
                (Some(spec), false) => {
                    EvalFleet::with_faults(&opts.dir, opts.workers, FaultPlan::parse(spec)?)?
                }
                (Some(spec), true) => {
                    EvalFleet::with_faults_proc(&opts.dir, opts.workers, FaultPlan::parse(spec)?)?
                }
                (None, false) => EvalFleet::new(&opts.dir, opts.workers)?,
                (None, true) => EvalFleet::new_proc(&opts.dir, opts.workers)?,
            })
        } else {
            None
        };
        let journal = open_journal(opts, &manifest)?;
        Ok(Self {
            manifest,
            rt,
            fleet,
            sens_cache: opts.sens_cache_dir(),
            journal,
        })
    }

    /// The shared evaluation fleet, when `--workers` enabled one (drivers
    /// can `resize` it between phases).
    pub fn fleet(&self) -> Option<&Rc<EvalFleet>> {
        self.fleet.as_ref()
    }

    pub fn pipeline(&self, model: &str) -> Result<Pipeline> {
        let mut pipe = Pipeline::open_with(self.rt.clone(), &self.manifest, model)?;
        pipe.set_sens_cache_dir(self.sens_cache.clone());
        pipe.set_journal(self.journal.clone());
        if let Some(fleet) = &self.fleet {
            pipe.attach_fleet(fleet)?;
        }
        Ok(pipe)
    }

    /// The shared run journal, when journaling is enabled.
    pub fn journal(&self) -> Option<&Rc<RunJournal>> {
        self.journal.as_ref()
    }

    /// Models that exist in the manifest, intersected with a default list
    /// and the user's `--models` filter.
    pub fn select(&self, opts: &Opts, default: &[&str]) -> Vec<String> {
        let avail: Vec<String> = default
            .iter()
            .filter(|m| self.manifest.models.iter().any(|e| &e.name == *m))
            .map(|s| s.to_string())
            .collect();
        match &opts.models {
            None => avail,
            Some(filter) => avail
                .into_iter()
                .filter(|m| filter.iter().any(|f| f == m))
                .collect(),
        }
    }
}

const TABLE1_MODELS: &[&str] = &[
    "resnet_s",
    "resnet_m",
    "mobilenet_v2_s",
    "mobilenet_v3_s",
    "effnet_lite_s",
    "effnet_b0_s",
    "deeplab_s",
    "bert_s_mnli_s",
    "vit_s",
];

const TABLE2_MODELS: &[&str] = &[
    "resnet_s",
    "resnet_m",
    "effnet_lite_s",
    "mobilenet_v2_s",
    "mobilenet_v3_s",
];

const CNN_MODELS: &[&str] = &[
    "resnet_s",
    "resnet_m",
    "effnet_lite_s",
    "effnet_b0_s",
    "mobilenet_v2_s",
    "mobilenet_v3_s",
    "deeplab_s",
];

/// One-line per-model accounting appended to driver progress output —
/// the consolidated [`crate::telemetry::Snapshot`]'s compact form (cache
/// hit/miss counters, fleet width, and failure/durability sections when
/// those subsystems did something).
fn pipe_note(pipe: &Pipeline) -> String {
    crate::telemetry::Snapshot::from_pipeline(pipe).note()
}

/// MP at a BOPs budget via SQNR Phase 1 (the paper's standard pipeline).
fn mp_at_budget(pipe: &mut Pipeline, lattice: &Lattice, budget: f64) -> Result<SearchRun> {
    let sens = pipe.sensitivity_sqnr(lattice)?;
    let flips = pipe.flips(lattice, &sens);
    pipe.search_bops_budget(lattice, &flips, budget)
}

// ---------------------------------------------------------------------------
// Table 1 — MP vs fixed precision, practical space {W4A8, W8A8, W8A16}
// ---------------------------------------------------------------------------

pub fn table1(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Table 1 — MP (W4A8/W8A8/W8A16) vs fixed precision",
        &["Model", "FP32", "W8A8 (r=0.50)", "PTQ MP (r=0.50)", "W6A8 (r=0.375)", "PTQ MP (r=0.375)"],
    );
    let lat = Lattice::practical();
    for m in env.select(opts, TABLE1_MODELS) {
        let mut pipe = env.pipeline(&m).with_context(|| m.clone())?;
        pipe.calibrate(opts.calib_n, opts.seed)?;
        pipe.limit_val(opts.val_n(), 7)?;
        let fp = pipe.eval_fp32()?;
        let w8a8 = pipe.eval_fixed(Candidate::new(8, 8), None)?;
        let w6a8 = pipe.eval_fixed(Candidate::new(6, 8), None)?;
        let sens = pipe.sensitivity_sqnr(&lat)?;
        let flips = pipe.flips(&lat, &sens);
        let mp50 = pipe.search_bops_budget(&lat, &flips, 0.50)?;
        let mp375 = pipe.search_bops_budget(&lat, &flips, 0.375)?;
        t.row(vec![
            m.clone(),
            f4(fp),
            f4(w8a8),
            format!("{} (r={})", f4(mp50.final_metric), f3(mp50.final_rel_bops)),
            f4(w6a8),
            format!("{} (r={})", f4(mp375.final_metric), f3(mp375.final_rel_bops)),
        ]);
        println!("[table1] {m} done ({})", pipe_note(&pipe));
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 2 — expanded low-bit space
// ---------------------------------------------------------------------------

pub fn table2(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Table 2 — MP on expanded space {W4A4..W8A16} at low BOPs",
        &["Model", "FP32", "W6A6 (r=0.281)", "PTQ MP (r=0.281)", "W4A8 (r=0.25)", "PTQ MP (r=0.25)"],
    );
    let lat = Lattice::expanded();
    for m in env.select(opts, TABLE2_MODELS) {
        let mut pipe = env.pipeline(&m)?;
        pipe.calibrate(opts.calib_n, opts.seed)?;
        pipe.limit_val(opts.val_n(), 7)?;
        let fp = pipe.eval_fp32()?;
        let w6a6 = pipe.eval_fixed(Candidate::new(6, 6), None)?;
        let w4a8 = pipe.eval_fixed(Candidate::new(4, 8), None)?;
        let sens = pipe.sensitivity_sqnr(&lat)?;
        let flips = pipe.flips(&lat, &sens);
        let mp281 = pipe.search_bops_budget(&lat, &flips, 0.28125)?;
        let mp25 = pipe.search_bops_budget(&lat, &flips, 0.25)?;
        t.row(vec![
            m.clone(),
            f4(fp),
            f4(w6a6),
            format!("{} (r={})", f4(mp281.final_metric), f3(mp281.final_rel_bops)),
            f4(w4a8),
            format!("{} (r={})", f4(mp25.final_metric), f3(mp25.final_rel_bops)),
        ]);
        println!("[table2] {m} done ({})", pipe_note(&pipe));
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 3 — BERT on the five GLUE-style tasks
// ---------------------------------------------------------------------------

pub fn table3(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Table 3 — BERT GLUE tasks, MP (W4A8/W8A8/W8A16)",
        &["Task", "FP32", "W8A8 (r=0.5)", "PTQ MP (r=0.5)"],
    );
    let lat = Lattice::practical();
    let tasks = ["rte_s", "mrpc_s", "sst2_s", "stsb_s", "mnli_s"];
    let models: Vec<String> = tasks.iter().map(|t| format!("bert_s_{t}")).collect();
    let model_refs: Vec<&str> = models.iter().map(|s| s.as_str()).collect();
    for m in env.select(opts, &model_refs) {
        let mut pipe = env.pipeline(&m)?;
        pipe.calibrate(opts.calib_n, opts.seed)?;
        pipe.limit_val(opts.val_n(), 7)?;
        let fp = pipe.eval_fp32()?;
        let w8a8 = pipe.eval_fixed(Candidate::new(8, 8), None)?;
        let run = mp_at_budget(&mut pipe, &lat, 0.50)?;
        t.row(vec![
            m.trim_start_matches("bert_s_").to_string(),
            f4(fp),
            f4(w8a8),
            format!("{} (r={})", f4(run.final_metric), f3(run.final_rel_bops)),
        ]);
        println!("[table3] {m} done ({})", pipe_note(&pipe));
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 4 — AdaRound-integrated MP
// ---------------------------------------------------------------------------

pub fn table4(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Table 4 — fixed AdaRound vs AdaRound-integrated MP",
        &["Model", "FP32", "W8A8 AR (r=0.50)", "MP AR (r=0.50)", "W6A8 AR (r=0.375)", "MP AR (r=0.375)"],
    );
    let lat = Lattice::practical();
    let mut ar_cfg = AdaRoundCfg::default();
    if opts.fast {
        ar_cfg.steps = 40;
    }
    for m in env.select(opts, CNN_MODELS) {
        let mut pipe = env.pipeline(&m)?;
        pipe.calibrate(opts.calib_n, opts.seed)?;
        pipe.limit_val(opts.val_n(), 7)?;
        // rounded weights for every wbits used below (4/8 from the lattice,
        // 6 for the fixed-W6A8 column)
        let mut lat_bits = lat.clone();
        lat_bits.candidates.push(Candidate::new(6, 8));
        let rounded = pipe.adaround(&lat_bits, &ar_cfg)?;
        let fp = pipe.eval_fp32()?;
        let w8a8 = pipe.eval_fixed(Candidate::new(8, 8), Some(&rounded))?;
        let w6a8 = pipe.eval_fixed(Candidate::new(6, 8), Some(&rounded))?;
        // Phase 1 with AdaRounded weights (§3.5), stitched Phase 2
        let sens = pipe.sensitivity(&lat, Metric::Sqnr, Some(&rounded))?;
        let flips = pipe.flips(&lat, &sens);
        let mut ctx_budget = |budget: f64, flips: &[crate::search::FlipStep]| -> Result<SearchRun> {
            let asg_run = pipe.search_bops_budget(&lat, flips, budget)?;
            let metric = pipe.eval_assignment(&asg_run.assignment, Some(&rounded))?;
            Ok(SearchRun { final_metric: metric, ..asg_run })
        };
        let mp50 = ctx_budget(0.50, &flips)?;
        let mp375 = ctx_budget(0.375, &flips)?;
        t.row(vec![
            m.clone(),
            f4(fp),
            f4(w8a8),
            format!("{} (r={})", f4(mp50.final_metric), f3(mp50.final_rel_bops)),
            f4(w6a8),
            format!("{} (r={})", f4(mp375.final_metric), f3(mp375.final_rel_bops)),
        ]);
        println!("[table4] {m} done ({})", pipe_note(&pipe));
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 5 — Phase-2 run-time: sequential vs binary vs binary+interp
// ---------------------------------------------------------------------------

pub fn table5(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Table 5 — Phase-2 search run-time (accuracy targets)",
        &[
            "Model",
            "Target",
            "Seq (s / evals)",
            "Binary (s / evals)",
            "Bin+Interp (s / evals)",
            "r (seq)",
            "r (bin)",
            "r (b+i)",
        ],
    );
    let lat = Lattice::practical();
    let models: &[&str] = if opts.fast {
        &["mobilenet_v2_s"]
    } else {
        &["resnet_m", "effnet_lite_s", "mobilenet_v2_s", "mobilenet_v3_s"]
    };
    for m in env.select(opts, models) {
        let mut pipe = env.pipeline(&m)?;
        pipe.calibrate(opts.calib_n, opts.seed)?;
        pipe.limit_val(opts.val_n(), 7)?;
        let fp = pipe.eval_fp32()?;
        let sens = pipe.sensitivity_sqnr(&lat)?;
        let flips = pipe.flips(&lat, &sens);
        for drop in [0.01, 0.05] {
            let target = fp - drop;
            let seq =
                pipe.search_accuracy_target(&lat, &flips, target, SearchScheme::Sequential, None)?;
            let bin =
                pipe.search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)?;
            let hyb =
                pipe.search_accuracy_target(&lat, &flips, target, SearchScheme::Hybrid, None)?;
            // `evals` are distinct eval-set passes; `+Nm` are engine memo
            // hits (re-visited prefixes that cost zero forward calls)
            t.row(vec![
                m.clone(),
                format!("{:.4} (-{:.0}%)", target, drop * 100.0),
                format!("{:.2} / {}+{}m", seq.wall_secs, seq.evals, seq.memo_hits),
                format!("{:.2} / {}+{}m", bin.wall_secs, bin.evals, bin.memo_hits),
                format!("{:.2} / {}+{}m", hyb.wall_secs, hyb.evals, hyb.memo_hits),
                f3(seq.final_rel_bops),
                f3(bin.final_rel_bops),
                f3(hyb.final_rel_bops),
            ]);
        }
        println!(
            "[table5] {m} done (fwd_calls={} ref_builds={} ref_hits={}, {})",
            pipe.model.fwd_calls.borrow(),
            pipe.model.engine.ref_builds.get(),
            pipe.model.engine.ref_hits.get(),
            pipe_note(&pipe)
        );
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Fig. 2 — metric robustness across calibration subsets + Kendall-τ
// ---------------------------------------------------------------------------

pub fn fig2(opts: &Opts) -> Result<(Table, Table)> {
    let env = Env::open(opts)?;
    let model = opts
        .models
        .as_ref()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "mobilenet_v2_s".to_string());
    let lat = Lattice::practical_no16();
    let n_seeds = if opts.fast { 2 } else { 5 };

    // (a-c): pareto-curve variation across seeds per metric
    let mut curves = Table::new(
        format!("Fig 2(a-c) — pareto variation over {n_seeds} calib subsets ({model})"),
        &["Metric", "Seed", "Curve (r_W8A8-relative : metric)"],
    );
    // (d): Kendall-τ vs number of calibration images
    let mut ktau = Table::new(
        "Fig 2(d) — Kendall-τ of sensitivity list vs ground truth",
        &["Metric", "N images", "Kendall-tau"],
    );

    // ground-truth list: accuracy degradation on the full validation set
    let mut pipe = env.pipeline(&model)?;
    pipe.calibrate(opts.calib_n, opts.seed)?;
    pipe.limit_val(opts.val_n(), 7)?;
    let gt = {
        let ds = pipe.model.data.val.clone();
        let set = pipe.model.eval_set(&ds)?;
        // ground truth is a one-off diagnostic sweep — never journaled
        sensitivity::sensitivity_list(
            &pipe.model,
            &pipe.manifest,
            &lat,
            &set,
            Metric::Accuracy,
            None,
            None,
        )?
    };
    let canon = |list: &[sensitivity::SensEntry]| -> Vec<f64> {
        // scores ordered by (group, cand) — rank-comparable across metrics
        let mut v: Vec<(usize, u8, u8, f64)> = list
            .iter()
            .map(|e| (e.group, e.cand.wbits, e.cand.abits, e.score))
            .collect();
        v.sort_by_key(|x| (x.0, x.1, x.2));
        v.into_iter().map(|x| x.3).collect()
    };
    let gt_scores = canon(&gt);

    for metric in [Metric::Accuracy, Metric::Sqnr, Metric::Fit] {
        let mname = match metric {
            Metric::Accuracy => "accuracy",
            Metric::Sqnr => "sqnr",
            Metric::Fit => "fit",
        };
        // (a-c) curves across seeds
        for seed in 0..n_seeds {
            pipe.calibrate(opts.calib_n, seed as u64)?;
            pipe.limit_val(opts.val_n(), 7)?;
            let sens = pipe.sensitivity(&lat, metric, None)?;
            let flips = pipe.flips(&lat, &sens);
            let run = pipe.pareto_curve_val(&lat, &flips, None)?;
            let pts: Vec<String> = run
                .curve
                .iter()
                .map(|(r, m)| format!("{:.3}:{:.4}", r / 0.5, m))
                .collect();
            curves.row(vec![mname.into(), seed.to_string(), pts.join(" ")]);
        }
        // (d) ktau vs images
        let sizes: &[usize] = if opts.fast { &[64, 256] } else { &[32, 64, 128, 256, 512] };
        for &n in sizes {
            pipe.calibrate(n, opts.seed)?;
            let sens = pipe.sensitivity(&lat, metric, None)?;
            let tau = kendall_tau(&canon(&sens), &gt_scores);
            ktau.row(vec![mname.into(), n.to_string(), f3(tau)]);
        }
        println!("[fig2] metric {mname} done ({})", pipe_note(&pipe));
    }
    Ok((curves, ktau))
}

// ---------------------------------------------------------------------------
// Fig. 3 — per-network SQNR ranges at W8A8
// ---------------------------------------------------------------------------

pub fn fig3(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Fig 3 — per-quantizer SQNR range at W8A8 (wide range ⇒ MP helps)",
        &["Model", "min dB", "p25", "median", "max dB", "range dB"],
    );
    for m in env.select(opts, TABLE1_MODELS) {
        let mut pipe = env.pipeline(&m)?;
        pipe.calibrate(opts.calib_n, opts.seed)?;
        let set = pipe.calib_set()?;
        let (mut act, w) = sensitivity::per_quantizer_sqnr(&pipe.model, set, Candidate::new(8, 8))?;
        act.extend(w);
        // total_cmp: one degenerate probe must not panic the whole figure
        act.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| act[(p * (act.len() - 1) as f64).round() as usize];
        t.row(vec![
            m.clone(),
            format!("{:.1}", q(0.0)),
            format!("{:.1}", q(0.25)),
            format!("{:.1}", q(0.5)),
            format!("{:.1}", q(1.0)),
            format!("{:.1}", q(1.0) - q(0.0)),
        ]);
        println!("[fig3] {m} done ({})", pipe_note(&pipe));
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Fig. 4 — out-of-domain calibration
// ---------------------------------------------------------------------------

pub fn fig4(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let mut t = Table::new(
        "Fig 4 — task-data vs out-of-domain calibration pareto curves",
        &["Model", "Calib data", "Curve (r : metric)"],
    );
    let lat = Lattice::practical_no16();
    let models: &[&str] = if opts.fast {
        &["mobilenet_v2_s"]
    } else {
        &["mobilenet_v2_s", "effnet_lite_s"]
    };
    for m in env.select(opts, models) {
        for ood in [false, true] {
            let mut pipe = env.pipeline(&m)?;
            if ood {
                let x = pipe
                    .model
                    .data
                    .ood_calib
                    .clone()
                    .context("no OOD calibration data")?;
                let sub = x.slice_rows(0, opts.calib_n.min(x.shape[0]))?;
                pipe.calibrate_unlabeled(&sub)?;
            } else {
                pipe.calibrate(opts.calib_n, opts.seed)?;
                pipe.limit_val(opts.val_n(), 7)?;
            }
            let sens = pipe.sensitivity_sqnr(&lat)?;
            let flips = pipe.flips(&lat, &sens);
            let run = pipe.pareto_curve_val(&lat, &flips, None)?;
            let pts: Vec<String> = run
                .curve
                .iter()
                .map(|(r, mm)| format!("{:.3}:{:.4}", r, mm))
                .collect();
            t.row(vec![
                m.to_string(),
                if ood { "synthood (OOD)" } else { "synthnet (task)" }.into(),
                pts.join(" "),
            ]);
        }
        println!("[fig4] {m} done");
    }
    print_curves(&t, 2, "rel BOPs", "metric");
    Ok(t)
}

// ---------------------------------------------------------------------------
// Fig. 5 — AdaRound interweaving ablation
// ---------------------------------------------------------------------------

pub fn fig5(opts: &Opts) -> Result<Table> {
    let env = Env::open(opts)?;
    let model = opts
        .models
        .as_ref()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "mobilenet_v2_s".to_string());
    let mut t = Table::new(
        format!("Fig 5 — AdaRound ablation on expanded space ({model})"),
        &["Mode", "Curve (r : metric)"],
    );
    let lat = Lattice::expanded();
    let mut ar_cfg = AdaRoundCfg::default();
    if opts.fast {
        ar_cfg.steps = 40;
    }
    let mut pipe = env.pipeline(&model)?;
    pipe.calibrate(opts.calib_n, opts.seed)?;
    pipe.limit_val(opts.val_n(), 7)?;
    let rounded = pipe.adaround(&lat, &ar_cfg)?;

    // 1. plain PTQ MP
    let sens = pipe.sensitivity(&lat, Metric::Sqnr, None)?;
    let flips = pipe.flips(&lat, &sens);
    let ptq = pipe.pareto_curve_val(&lat, &flips, None)?;
    // 2. AdaRound applied on top of the PTQ-MP flip order (Phase 2 only)
    let over = pipe.pareto_curve_val(&lat, &flips, Some(&rounded))?;
    // 3. AdaRound interweaved in both phases (§3.5)
    let sens_ar = pipe.sensitivity(&lat, Metric::Sqnr, Some(&rounded))?;
    let flips_ar = pipe.flips(&lat, &sens_ar);
    let both = pipe.pareto_curve_val(&lat, &flips_ar, Some(&rounded))?;

    for (name, run) in [
        ("PTQ MP", &ptq),
        ("AdaRound over PTQ MP", &over),
        ("Phase 1&2 AdaRound MP", &both),
    ] {
        let pts: Vec<String> = run
            .curve
            .iter()
            .map(|(r, m)| format!("{:.3}:{:.4}", r, m))
            .collect();
        t.row(vec![name.into(), pts.join(" ")]);
    }
    println!("[fig5] {model} done ({})", pipe_note(&pipe));
    print_curves(&t, 1, "rel BOPs", "metric");
    Ok(t)
}

/// ASCII-plot the curve column of a figure table (last column holds
/// "r:metric …" strings; `label_cols` leading columns name the series).
fn print_curves(t: &Table, label_cols: usize, xlabel: &str, ylabel: &str) {
    use crate::report::plot;
    let series: Vec<plot::Series> = t
        .rows
        .iter()
        .map(|r| {
            plot::Series::new(
                r[..label_cols].join(" / "),
                plot::parse_curve(r.last().unwrap()),
            )
        })
        .collect();
    print!("{}", plot::render(&t.title, xlabel, ylabel, &series, 64, 16));
}
