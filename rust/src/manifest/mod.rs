//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`).
//!
//! The manifest is the contract between the build path and the Rust
//! coordinator: executable file names, the ordered parameter list (= PJRT
//! input order), quantizer inventories, per-op MAC counts for the BOPs
//! ledger (Eq. 5), and the quantizer groups (§3.4).

use crate::jsonio::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
    /// execution backend the artifacts were built for: `"pjrt"` (HLO-text
    /// executables, the default) or `"sim"` (pure-Rust interpreter programs
    /// from [`crate::sim`]) — consumed by `Runtime::for_manifest`
    pub backend: String,
    /// optional deterministic fault-injection schedule for the evaluation
    /// fleet (`crate::pool::FaultPlan` grammar) — written by
    /// `sim::generate` for hermetic fault tests; absent in production
    /// artifacts.  `MPQ_FAULT_PLAN` and `EvalFleet::with_faults` override.
    pub fault_plan: Option<String>,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub task: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub input_is_i32: bool,
    pub forward: String,
    pub stats: String,
    pub stats_bits: Vec<u8>,
    pub stats_ratios: Vec<f64>,
    pub weights_file: String,
    pub params: Vec<ParamInfo>,
    pub out_shape: Vec<usize>,
    pub act_quantizers: Vec<ActQ>,
    pub w_quantizers: Vec<WQ>,
    pub layers: Vec<Layer>,
    pub groups: Vec<Group>,
    pub total_macs: u64,
    pub cmax: usize,
    pub fp32_val_metric: f64,
    pub data: DataFiles,
    pub taps: Option<String>,
    pub adaround: Vec<AdaRoundLayer>,
    pub fit: Option<String>,
    pub fit_act_shapes: Option<Vec<Vec<usize>>>,
}

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ActQ {
    pub name: String,
    pub numel: usize,
}

#[derive(Clone, Debug)]
pub struct WQ {
    pub name: String,
    /// parameter this quantizer applies to (index into `params`)
    pub param_idx: usize,
    pub channels: usize,
    pub channel_axis: usize,
}

#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub macs: u64,
    pub w_q: usize,
    pub in_acts: Vec<usize>,
}

/// Quantizer group (§3.4): flipped as a unit by Phase 2.
#[derive(Clone, Debug)]
pub struct Group {
    pub w_q: Vec<usize>,
    pub act_q: Vec<usize>,
    pub macs: u64,
}

#[derive(Clone, Debug)]
pub struct DataFiles {
    pub calib: String,
    pub calib_labels: String,
    pub val: String,
    pub val_labels: String,
    pub ood_calib: Option<String>,
}

#[derive(Clone, Debug)]
pub struct AdaRoundLayer {
    pub layer: String,
    pub exe: String,
    pub tap_index: usize,
    pub param: String,
    pub bias: String,
    pub kind: String,
    pub channels: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let j = jsonio::parse_file(dir.join("manifest.json"))
            .context("parsing manifest.json — run `make artifacts` first")?;
        let models_j = j.req("models")?.as_obj()?;
        let mut models = Vec::new();
        for (name, m) in models_j {
            models.push(
                Self::parse_model(name, m)
                    .with_context(|| format!("model '{name}'"))?,
            );
        }
        let backend = match j.get("backend") {
            None => "pjrt".to_string(),
            Some(v) => v
                .as_str()
                .context("manifest 'backend' must be a string")?
                .to_string(),
        };
        let fault_plan = match j.get("fault_plan") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .context("manifest 'fault_plan' must be a string")?
                    .to_string(),
            ),
        };
        Ok(Self { dir, models, backend, fault_plan })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "model '{name}' not in manifest (have: {})",
                    self.models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    fn parse_model(name: &str, m: &Json) -> Result<ModelEntry> {
        let params: Vec<ParamInfo> = m
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p.req("shape")?.usize_vec()?,
                })
            })
            .collect::<Result<_>>()?;
        let pidx = |pname: &str| -> Result<usize> {
            params
                .iter()
                .position(|p| p.name == pname)
                .ok_or_else(|| anyhow!("param '{pname}' not found"))
        };

        let w_quantizers = m
            .req("w_quantizers")?
            .as_arr()?
            .iter()
            .map(|q| {
                Ok(WQ {
                    name: q.req("name")?.as_str()?.to_string(),
                    param_idx: pidx(q.req("weight")?.as_str()?)?,
                    channels: q.req("channels")?.as_usize()?,
                    channel_axis: q.req("channel_axis")?.as_usize()?,
                })
            })
            .collect::<Result<_>>()?;

        let d = m.req("data")?;
        let opt_str = |j: &Json, k: &str| -> Option<String> {
            j.get(k)
                .filter(|v| !v.is_null())
                .and_then(|v| v.as_str().ok())
                .map(String::from)
        };

        Ok(ModelEntry {
            name: name.to_string(),
            task: m.req("task")?.as_str()?.to_string(),
            batch: m.req("batch")?.as_usize()?,
            input_shape: m.req("input")?.req("shape")?.usize_vec()?,
            input_is_i32: m.req("input")?.req("dtype")?.as_str()? == "i32",
            forward: m.req("forward")?.as_str()?.to_string(),
            stats: m.req("stats")?.as_str()?.to_string(),
            stats_bits: m
                .req("stats_bits")?
                .usize_vec()?
                .into_iter()
                .map(|b| b as u8)
                .collect(),
            stats_ratios: m.req("stats_ratios")?.f64_vec()?,
            weights_file: m.req("weights_file")?.as_str()?.to_string(),
            params,
            out_shape: m.req("out_shape")?.usize_vec()?,
            act_quantizers: m
                .req("act_quantizers")?
                .as_arr()?
                .iter()
                .map(|q| {
                    Ok(ActQ {
                        name: q.req("name")?.as_str()?.to_string(),
                        numel: q.get("numel").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
                    })
                })
                .collect::<Result<_>>()?,
            w_quantizers,
            layers: m
                .req("layers")?
                .as_arr()?
                .iter()
                .map(|l| {
                    Ok(Layer {
                        name: l.req("name")?.as_str()?.to_string(),
                        macs: l.req("macs")?.as_f64()? as u64,
                        w_q: l.req("w_q")?.as_usize()?,
                        in_acts: l.req("in_acts")?.usize_vec()?,
                    })
                })
                .collect::<Result<_>>()?,
            groups: m
                .req("groups")?
                .as_arr()?
                .iter()
                .map(|g| {
                    Ok(Group {
                        w_q: g.req("w_q")?.usize_vec()?,
                        act_q: g.req("act_q")?.usize_vec()?,
                        macs: g.req("macs")?.as_f64()? as u64,
                    })
                })
                .collect::<Result<_>>()?,
            total_macs: m.req("total_macs")?.as_f64()? as u64,
            cmax: m.req("cmax")?.as_usize()?,
            fp32_val_metric: m.req("fp32_val_metric")?.as_f64()?,
            data: DataFiles {
                calib: d.req("calib")?.as_str()?.to_string(),
                calib_labels: d.req("calib_labels")?.as_str()?.to_string(),
                val: d.req("val")?.as_str()?.to_string(),
                val_labels: d.req("val_labels")?.as_str()?.to_string(),
                ood_calib: opt_str(d, "ood_calib"),
            },
            taps: opt_str(m, "taps"),
            adaround: m
                .req("adaround")?
                .as_arr()?
                .iter()
                .map(|a| {
                    Ok(AdaRoundLayer {
                        layer: a.req("layer")?.as_str()?.to_string(),
                        exe: a.req("exe")?.as_str()?.to_string(),
                        tap_index: a.req("tap_index")?.as_usize()?,
                        param: a.req("param")?.as_str()?.to_string(),
                        bias: a.req("bias")?.as_str()?.to_string(),
                        kind: a.req("kind")?.as_str()?.to_string(),
                        channels: a.req("channels")?.as_usize()?,
                    })
                })
                .collect::<Result<_>>()?,
            fit: opt_str(m, "fit"),
            fit_act_shapes: m
                .get("fit_act_shapes")
                .filter(|v| !v.is_null())
                .map(|v| {
                    v.as_arr()?
                        .iter()
                        .map(|s| s.usize_vec())
                        .collect::<Result<Vec<_>>>()
                })
                .transpose()?,
        })
    }
}

impl ModelEntry {
    /// Index of a parameter by name.
    pub fn param_idx(&self, name: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| anyhow!("param '{name}' not found in {}", self.name))
    }

    /// Number of activation / weight quantizers.
    pub fn n_act(&self) -> usize {
        self.act_quantizers.len()
    }
    pub fn n_w(&self) -> usize {
        self.w_quantizers.len()
    }
}
