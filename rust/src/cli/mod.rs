//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `mpq <subcommand> [positional...] [--flag] [--key value]`.
//!
//! Shared flags get typed accessors here; notably `--workers N` sizes the
//! multi-client evaluation pool ([`crate::pool::EvalPool`]) and defaults to
//! the host's available parallelism.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn opt_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// `--workers N` — evaluation-pool width; defaults to the host's
    /// available parallelism ([`crate::util::default_workers`]).
    pub fn opt_workers(&self) -> Result<usize> {
        self.opt_usize("workers", crate::util::default_workers())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse("search resnet_s --budget 0.5 --metric sqnr --verbose");
        assert_eq!(a.positional, vec!["search", "resnet_s"]);
        assert_eq!(a.opt("budget"), Some("0.5"));
        assert_eq!(a.opt("metric"), Some("sqnr"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("x --k=v --f");
        assert_eq!(a.opt("k"), Some("v"));
        assert!(a.flag("f"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--n 32 --r 0.25");
        assert_eq!(a.opt_usize("n", 1).unwrap(), 32);
        assert_eq!(a.opt_f64("r", 1.0).unwrap(), 0.25);
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
        let bad = parse("--n xyz");
        assert!(bad.opt_usize("n", 1).is_err());
    }

    #[test]
    fn workers_flag_defaults_to_parallelism() {
        let a = parse("run --workers 3");
        assert_eq!(a.opt_workers().unwrap(), 3);
        let b = parse("run");
        assert_eq!(b.opt_workers().unwrap(), crate::util::default_workers());
        assert!(parse("run --workers zebra").opt_workers().is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b val");
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("val"));
    }
}
