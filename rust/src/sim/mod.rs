//! The sim execution backend — a pure-Rust interpreter for a synthetic
//! linear+fake-quant model family, plus the artifact generator that makes
//! it a drop-in model zoo.
//!
//! ## Why it exists
//!
//! Every integration test used to skip without PJRT artifacts, so the
//! Phase-1 sweep, all Phase-2 searches and the whole `pool` parallel path
//! shipped verified only by hermetic unit tests.  The sim backend closes
//! that gap the way QBitOpt-style reproductions validate their searches on
//! cheap proxy evaluations: a tiny model family whose forward pass is
//! interpretable in-process, wired behind the *same*
//! [`crate::runtime::Backend`] trait the PJRT path implements.  The entire
//! L3 stack — `ModelHandle::open`, range calibration, weight-scale search,
//! the engine's reference/memo/patching, `EvalPool` sharding, every search
//! — runs unchanged on it, end-to-end, with zero artifacts and zero skips.
//!
//! ## The model family
//!
//! `sim_mlp` is a dense chain mirroring `python/compile`'s `dense` op
//! semantics exactly (so an HLO-lowered MLP of the same shape is
//! comparable, see [`export_from_artifacts`]):
//!
//! ```text
//! h = fq_act(x, row 0)                       # input quantizer
//! for i in 0..L:
//!     y = h @ fq_w(W_i, scales_i, meta_i) + b_i
//!     if i < L-1: y = relu(y)
//!     h = fq_act(y, row i+1)                 # layer-output quantizer
//! logits = h
//! ```
//!
//! Quantizer parameters arrive as the **same packed runtime tensors** the
//! lowered HLO consumes (`act_qp[A,5]` rows `(scale, offset, qmin, qmax,
//! enable)`, `w_scales[W,Cmax]`, `w_qmeta[W,3]` rows `(qmin, qmax,
//! enable)`; see `python/compile/quantize.py` and
//! [`crate::engine::Materializer`]), with `enable = 0` rows bypassing the
//! quantizer exactly — FP32 evaluation is the all-disabled config on the
//! same "executable".  Fake-quant uses [`crate::quant::fq`] (round half
//! away from zero); the jax lowering rounds half to even, which is why
//! PJRT↔sim parity is asserted *to tolerance*, not bit-exactly.
//!
//! Two artifact kinds exist, as tiny JSON programs next to the manifest:
//! `<m>.fwd.sim.json` (quantized forward; args `x, params...,
//! act_qp, w_scales, w_qmeta`, returns logits) and `<m>.stats.sim.json`
//! (FP forward returning every act quantizer's input, for MSE range
//! estimation) — the same contract as the `.hlo.txt` artifacts.
//!
//! Determinism: the interpreter is plain sequential f32 host math, so any
//! sharding of an eval set reproduces the serial per-batch partials
//! bit-exactly — the pool's exactness guarantee is *exercised*, not just
//! asserted, by the hermetic tier (`rust/tests/sim_e2e.rs`).

use crate::jsonio::{self, Json};
use crate::metrics;
use crate::quant;
use crate::runtime::{Backend, Buffer, Executable};
use crate::tensor::{io, Tensor};
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// The pure-Rust execution backend (stateless; "uploads" are clones).
pub struct SimBackend;

impl Backend for SimBackend {
    fn platform(&self) -> String {
        "sim-host".into()
    }

    fn compile(&self, path: &Path) -> Result<Box<dyn Executable>> {
        Ok(Box::new(SimProgram::load(path)?))
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        Ok(Buffer::Host(t.clone()))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Forward,
    Stats,
    /// FP layer-input capture (+ logits) — the AdaRound taps contract.
    /// For this dense-chain family the layer inputs *are* the activation
    /// quantizer inputs, so the capture loop is shared with `Stats`; the
    /// last output doubles as the logits the contract appends.
    Taps,
    /// One AdaRound loss+gradient step for a single dense layer
    /// (`dims = [din, dout]`), mirroring `python/compile/aot.py`'s
    /// `lower_adaround_step` dense branch.
    AdaRound,
    /// FIT probe: FP forward + per-quantizer Fisher terms, mirroring
    /// `lower_fit` (classify10 cross-entropy loss).
    Fit,
}

/// A parsed sim artifact: which probe it is plus the chain dimensions
/// `d_0 → d_1 → … → d_L` (L dense layers, relu between hidden layers).
pub struct SimProgram {
    kind: Kind,
    /// layer widths, length `L + 1`
    pub dims: Vec<usize>,
}

impl SimProgram {
    pub fn load(path: &Path) -> Result<Self> {
        let j = jsonio::parse_file(path)
            .with_context(|| format!("parsing sim program {}", path.display()))?;
        if j.req("sim_program")?.as_usize()? != 1 {
            bail!("{}: unsupported sim program version", path.display());
        }
        let kind = match j.req("kind")?.as_str()? {
            "forward" => Kind::Forward,
            "stats" => Kind::Stats,
            "taps" => Kind::Taps,
            "adaround" => Kind::AdaRound,
            "fit" => Kind::Fit,
            k => bail!("{}: unknown sim program kind '{k}'", path.display()),
        };
        let dims = j.req("dims")?.usize_vec()?;
        if dims.len() < 2 || dims.iter().any(|&d| d == 0) {
            bail!("{}: bad dims {dims:?}", path.display());
        }
        Ok(Self { kind, dims })
    }

    fn layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Split `args` into `(x, per-layer (w, b), trailing)`, flattening the
    /// input to `[B, d0]` and checking every shape.
    fn split_args<'a>(
        &self,
        args: &[&'a Buffer],
        trailing: usize,
    ) -> Result<(Vec<f32>, usize, Vec<(&'a [f32], &'a [f32])>, Vec<&'a Tensor>)> {
        let l = self.layers();
        if args.len() != 1 + 2 * l + trailing {
            bail!(
                "sim exe got {} args, want {} (x + {} params + {trailing})",
                args.len(),
                1 + 2 * l + trailing,
                2 * l
            );
        }
        let x = args[0].host()?;
        let b = x.shape.first().copied().unwrap_or(0);
        let numel: usize = x.shape[1..].iter().product();
        if numel != self.dims[0] {
            bail!("sim input numel {numel} != d0 {}", self.dims[0]);
        }
        let xv = x.f32s().context("sim input must be f32")?.to_vec();
        let mut params = Vec::with_capacity(l);
        for i in 0..l {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let w = args[1 + 2 * i].host()?;
            let bias = args[2 + 2 * i].host()?;
            if w.shape != [din, dout] {
                bail!("sim layer {i}: weight shape {:?}, want [{din}, {dout}]", w.shape);
            }
            if bias.shape != [dout] {
                bail!("sim layer {i}: bias shape {:?}, want [{dout}]", bias.shape);
            }
            params.push((w.f32s()?, bias.f32s()?));
        }
        let rest = args[1 + 2 * l..].iter().map(|a| a.host()).collect::<Result<_>>()?;
        Ok((xv, b, params, rest))
    }

    /// Quantized forward — mirrors the lowered HLO contract:
    /// `x, params..., act_qp[A,5], w_scales[W,Cmax], w_qmeta[W,3]` → logits.
    fn forward(&self, args: &[&Buffer]) -> Result<Tensor> {
        let l = self.layers();
        let (mut h, batch, params, rest) = self.split_args(args, 3)?;
        let (act_qp, w_scales, w_qmeta) = (rest[0], rest[1], rest[2]);
        if act_qp.shape != [l + 1, 5] {
            bail!("act_qp shape {:?}, want [{}, 5]", act_qp.shape, l + 1);
        }
        if w_qmeta.shape != [l, 3] {
            bail!("w_qmeta shape {:?}, want [{l}, 3]", w_qmeta.shape);
        }
        let cmax = match w_scales.shape.as_slice() {
            [w, c] if *w == l && *c >= self.dims[1..].iter().copied().max().unwrap_or(1) => *c,
            s => bail!("w_scales shape {s:?} too small for dims {:?}", self.dims),
        };
        let (qp, sc, meta) = (act_qp.f32s()?, w_scales.f32s()?, w_qmeta.f32s()?);

        fq_act(&mut h, &qp[0..5]);
        for i in 0..l {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let (w, bias) = params[i];
            let wq = fq_weight(w, din, dout, &sc[i * cmax..i * cmax + dout], &meta[i * 3..i * 3 + 3]);
            let mut y = matmul_bias(&h, batch, din, &wq, dout, bias);
            if i + 1 < l {
                for v in &mut y {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            fq_act(&mut y, &qp[(i + 1) * 5..(i + 2) * 5]);
            h = y;
        }
        Tensor::from_f32(&[batch, self.dims[l]], h)
    }

    /// FP forward returning every act quantizer's input (range
    /// calibration): `x, params...` → one tensor per quantizer.
    fn stats(&self, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        let l = self.layers();
        let (mut h, batch, params, _) = self.split_args(args, 0)?;
        let mut caps = Vec::with_capacity(l + 1);
        caps.push(Tensor::from_f32(&[batch, self.dims[0]], h.clone())?);
        for i in 0..l {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let (w, bias) = params[i];
            let mut y = matmul_bias(&h, batch, din, w, dout, bias);
            if i + 1 < l {
                for v in &mut y {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            caps.push(Tensor::from_f32(&[batch, dout], y.clone())?);
            h = y;
        }
        Ok(caps)
    }

    /// One AdaRound step for a dense layer (`dims = [din, dout]`):
    /// `x[B,din], w[din,dout], b[dout], v[din,dout], s[dout], meta[4]` →
    /// `(loss, dL/dV)`, with
    /// `loss = mean((x@W+b − x@Ŵ(V)−b)²) + λ·mean(1 − |2h−1|^β)`,
    /// `Ŵ = s·clip(⌊W/s⌋ + h, qmin, qmax)`, `h = clip(1.2σ(V)−0.1, 0, 1)`
    /// — the analytic gradient of what `lower_adaround_step` hands to
    /// `jax.value_and_grad` (clip/relu subgradients taken as pass-through
    /// on the closed interval; ties are measure-zero on this data).
    fn adaround_step(&self, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        if self.dims.len() != 2 {
            bail!("adaround sim program wants dims [din, dout], got {:?}", self.dims);
        }
        let (din, dout) = (self.dims[0], self.dims[1]);
        if args.len() != 6 {
            bail!("adaround sim exe got {} args, want 6", args.len());
        }
        let x = args[0].host()?;
        let w = args[1].host()?;
        let b = args[2].host()?;
        let v = args[3].host()?;
        let s = args[4].host()?;
        let meta = args[5].host()?;
        let bsz = x.shape.first().copied().unwrap_or(0);
        if x.shape != [bsz, din] || w.shape != [din, dout] || b.shape != [dout] {
            bail!("adaround sim exe: bad x/w/b shapes {:?}/{:?}/{:?}", x.shape, w.shape, b.shape);
        }
        if v.shape != [din, dout] || s.shape != [dout] || meta.shape != [4] {
            bail!("adaround sim exe: bad v/s/meta shapes {:?}/{:?}/{:?}", v.shape, s.shape, meta.shape);
        }
        let (xv, wv, bv) = (x.f32s()?, w.f32s()?, b.f32s()?);
        let (vv, sv, mv) = (v.f32s()?, s.f32s()?, meta.f32s()?);
        let (qmin, qmax, beta, lam) = (mv[0], mv[1], mv[2], mv[3]);

        let n = din * dout;
        let mut h = vec![0f32; n]; // rectified sigmoid h(V)
        let mut dh = vec![0f32; n]; // dh/dV (0 where the clip is active)
        let mut wq = vec![0f32; n]; // soft-quantized weight Ŵ(V)
        let mut pass = vec![false; n]; // qmin ≤ ⌊W/s⌋+h ≤ qmax (clip pass-through)
        for k in 0..din {
            for c in 0..dout {
                let i = k * dout + c;
                let sc = sv[c].max(1e-12);
                let sig = 1.0 / (1.0 + (-vv[i]).exp());
                let hraw = 1.2 * sig - 0.1;
                h[i] = hraw.clamp(0.0, 1.0);
                dh[i] = if hraw > 0.0 && hraw < 1.0 { 1.2 * sig * (1.0 - sig) } else { 0.0 };
                let p = (wv[i] / sc).floor() + h[i];
                pass[i] = p >= qmin && p <= qmax;
                wq[i] = sc * p.clamp(qmin, qmax);
            }
        }

        let y_fp = matmul_bias(xv, bsz, din, wv, dout, bv);
        let y_q = matmul_bias(xv, bsz, din, &wq, dout, bv);
        let n_mse = (bsz * dout).max(1) as f32;
        let mut mse = 0f32;
        let mut e = vec![0f32; bsz * dout]; // y_q − y_fp
        for j in 0..bsz * dout {
            let d = y_fp[j] - y_q[j];
            mse += d * d;
            e[j] = y_q[j] - y_fp[j];
        }
        mse /= n_mse;
        let mut reg = 0f32;
        for &hi in &h {
            reg += 1.0 - (2.0 * hi - 1.0).abs().powf(beta);
        }
        reg /= n as f32;
        let loss = mse + lam * reg;

        let mut g = vec![0f32; n];
        for k in 0..din {
            for c in 0..dout {
                let i = k * dout + c;
                // dMSE/dŴ_{kc} = Σ_r x_{rk} · 2(y_q − y_fp)_{rc} / n_mse
                let mut gm = 0f32;
                for r in 0..bsz {
                    gm += xv[r * din + k] * e[r * dout + c];
                }
                gm *= 2.0 / n_mse;
                let sc = sv[c].max(1e-12);
                let mut gi = if pass[i] { gm * sc } else { 0.0 };
                // d reg/dh = −β·|2h−1|^{β−1}·sign(2h−1)·2 / n
                let t2 = 2.0 * h[i] - 1.0;
                if t2 != 0.0 {
                    gi += lam * (-beta * t2.abs().powf(beta - 1.0) * t2.signum() * 2.0 / n as f32);
                }
                g[i] = gi * dh[i];
            }
        }
        Ok(vec![
            Tensor::from_f32(&[1], vec![loss])?,
            Tensor::from_f32(&[din, dout], g)?,
        ])
    }

    /// FIT probe: `x, y, params..., perts..., act_qp[A,5]` →
    /// `(loss, wgrad2[W], agrad2[A], aerr2[A])`.  FP forward with zero
    /// perturbations added at every activation-quantizer point; loss is
    /// classify10 cross-entropy; `*grad2` are mean squared loss-gradients
    /// (Fisher diagonal) w.r.t. each quantized weight tensor / each
    /// perturbation, and `aerr2` is each activation's local quantization
    /// MSE under the given `act_qp` rows (quantization forced on) —
    /// mirroring `lower_fit` + `QCtx.fit_mode`.
    fn fit(&self, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        let l = self.layers();
        let want = 2 + 2 * l + (l + 1) + 1;
        if args.len() != want {
            bail!("fit sim exe got {} args, want {want}", args.len());
        }
        let x = args[0].host()?;
        let y = args[1].host()?;
        let bsz = x.shape.first().copied().unwrap_or(0);
        if x.shape != [bsz, self.dims[0]] {
            bail!("fit sim exe: input shape {:?}, want [{bsz}, {}]", x.shape, self.dims[0]);
        }
        let mut params = Vec::with_capacity(l);
        for i in 0..l {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let w = args[2 + 2 * i].host()?;
            let bias = args[3 + 2 * i].host()?;
            if w.shape != [din, dout] || bias.shape != [dout] {
                bail!("fit sim exe: layer {i} param shapes {:?}/{:?}", w.shape, bias.shape);
            }
            params.push((w.f32s()?, bias.f32s()?));
        }
        let mut perts = Vec::with_capacity(l + 1);
        for (qi, a) in args[2 + 2 * l..3 + 3 * l].iter().enumerate() {
            let p = a.host()?;
            if p.shape != [bsz, self.dims[qi]] {
                bail!("fit sim exe: pert {qi} shape {:?}, want [{bsz}, {}]", p.shape, self.dims[qi]);
            }
            perts.push(p.f32s()?);
        }
        let act_qp = args[want - 1].host()?;
        if act_qp.shape != [l + 1, 5] {
            bail!("fit sim exe: act_qp shape {:?}, want [{}, 5]", act_qp.shape, l + 1);
        }
        let qp = act_qp.f32s()?;

        // FP forward, capturing pre-relu sums (relu mask), post-pert
        // layer inputs, and each quantizer's local quantization error
        let mut aerr2 = vec![0f32; l + 1];
        let mut hs: Vec<Vec<f32>> = Vec::with_capacity(l + 1); // layer inputs (post-pert)
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(l); // pre-relu linear outputs
        let xv = x.f32s()?;
        aerr2[0] = forced_quant_err(xv, &qp[0..5]);
        hs.push(xv.iter().zip(perts[0]).map(|(a, p)| a + p).collect());
        for i in 0..l {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let (w, bias) = params[i];
            let z = matmul_bias(&hs[i], bsz, din, w, dout, bias);
            let a: Vec<f32> = if i + 1 < l {
                z.iter().map(|&v| if v < 0.0 { 0.0 } else { v }).collect()
            } else {
                z.clone()
            };
            zs.push(z);
            aerr2[i + 1] = forced_quant_err(&a, &qp[(i + 1) * 5..(i + 2) * 5]);
            hs.push(a.iter().zip(perts[i + 1]).map(|(x, p)| x + p).collect());
        }

        // cross-entropy loss + gradient at the logits
        let c = self.dims[l];
        let yv = y.f32s()?;
        if yv.len() != bsz {
            bail!("fit sim exe: {} labels for batch {bsz}", yv.len());
        }
        let logits = &hs[l];
        let mut loss = 0f32;
        let mut gh = vec![0f32; bsz * c];
        for r in 0..bsz {
            let row = &logits[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut se = 0f32;
            for &v in row {
                se += (v - m).exp();
            }
            let lse = m + se.ln();
            let label = (yv[r] as usize).min(c - 1);
            loss -= row[label] - lse;
            for j in 0..c {
                let soft = (row[j] - lse).exp();
                gh[r * c + j] = (soft - if j == label { 1.0 } else { 0.0 }) / bsz as f32;
            }
        }
        loss /= bsz as f32;

        // backprop through the FP chain; pert gradients are the
        // activation gradients at each quantizer point
        let mut wgrad2 = vec![0f32; l];
        let mut agrad2 = vec![0f32; l + 1];
        agrad2[l] = mean_sq(&gh);
        for i in (0..l).rev() {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let gz: Vec<f32> = if i + 1 < l {
                gh.iter()
                    .zip(&zs[i])
                    .map(|(&g, &z)| if z > 0.0 { g } else { 0.0 })
                    .collect()
            } else {
                gh
            };
            let hi = &hs[i];
            let mut gw = vec![0f32; din * dout];
            for r in 0..bsz {
                for k in 0..din {
                    let hv = hi[r * din + k];
                    for cc in 0..dout {
                        gw[k * dout + cc] += hv * gz[r * dout + cc];
                    }
                }
            }
            wgrad2[i] = mean_sq(&gw);
            let w = params[i].0;
            let mut ghp = vec![0f32; bsz * din];
            for r in 0..bsz {
                for k in 0..din {
                    let mut acc = 0f32;
                    for cc in 0..dout {
                        acc += gz[r * dout + cc] * w[k * dout + cc];
                    }
                    ghp[r * din + k] = acc;
                }
            }
            agrad2[i] = mean_sq(&ghp);
            gh = ghp;
        }

        Ok(vec![
            Tensor::from_f32(&[1], vec![loss])?,
            Tensor::from_f32(&[l], wgrad2)?,
            Tensor::from_f32(&[l + 1], agrad2)?,
            Tensor::from_f32(&[l + 1], aerr2)?,
        ])
    }
}

impl Executable for SimProgram {
    fn run(&self, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        match self.kind {
            Kind::Forward => Ok(vec![self.forward(args)?]),
            // the taps contract (layer inputs + logits) coincides with the
            // stats captures for a dense chain — see Kind::Taps
            Kind::Stats | Kind::Taps => self.stats(args),
            Kind::AdaRound => self.adaround_step(args),
            Kind::Fit => self.fit(args),
        }
    }
}

/// `mean((x − fq(x, row))²)` with quantization forced on (the FIT error
/// term; `row[4]` is ignored, mirroring `QCtx.fit_mode`).
fn forced_quant_err(v: &[f32], row: &[f32]) -> f32 {
    let mut s = 0f32;
    for &x in v {
        let d = x - quant::fq(x, row[0], row[1], row[2], row[3]);
        s += d * d;
    }
    s / v.len().max(1) as f32
}

fn mean_sq(v: &[f32]) -> f32 {
    v.iter().map(|x| x * x).sum::<f32>() / v.len().max(1) as f32
}

/// In-place fake-quant of a slice under one packed `act_qp` row
/// `(scale, offset, qmin, qmax, enable)` — `enable = 0` bypasses exactly.
fn fq_act(v: &mut [f32], row: &[f32]) {
    if row[4] == 0.0 {
        return;
    }
    let (s, o, qmin, qmax) = (row[0], row[1], row[2], row[3]);
    for x in v {
        *x = quant::fq(*x, s, o, qmin, qmax);
    }
}

/// Per-output-channel symmetric fake-quant of a `[din, dout]` weight under
/// one packed `w_qmeta` row `(qmin, qmax, enable)` — same formula as
/// [`quant::quantize_weight`] with `channel_axis = 1`.
fn fq_weight(w: &[f32], din: usize, dout: usize, scales: &[f32], meta: &[f32]) -> Vec<f32> {
    let mut out = w.to_vec();
    if meta[2] == 0.0 {
        return out;
    }
    let (qmin, qmax) = (meta[0], meta[1]);
    for r in 0..din {
        for c in 0..dout {
            let i = r * dout + c;
            out[i] = quant::fq(w[i], scales[c], 0.0, qmin, qmax);
        }
    }
    out
}

/// `x[B, din] @ w[din, dout] + bias[dout]`, sequential f32 accumulation —
/// deterministic for any sharding of the batch dimension.
fn matmul_bias(x: &[f32], batch: usize, din: usize, w: &[f32], dout: usize, bias: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; batch * dout];
    for r in 0..batch {
        let xr = &x[r * din..(r + 1) * din];
        let or = &mut out[r * dout..(r + 1) * dout];
        for (k, &xk) in xr.iter().enumerate() {
            let wr = &w[k * dout..(k + 1) * dout];
            for c in 0..dout {
                or[c] += xk * wr[c];
            }
        }
        for c in 0..dout {
            or[c] += bias[c];
        }
    }
    out
}

// ---------------------------------------------------------------------------
// artifact generation
// ---------------------------------------------------------------------------

/// Shape of a generated sim model zoo (one MLP model + datasets).
#[derive(Clone, Debug)]
pub struct SimSpec {
    pub name: String,
    pub batch: usize,
    /// layer widths `d_0 → … → d_L` (last = class count)
    pub dims: Vec<usize>,
    pub calib_n: usize,
    pub val_n: usize,
    /// unlabeled out-of-domain calibration pool (0 = none)
    pub ood_n: usize,
    pub seed: u64,
    /// optional fault-injection schedule written into the manifest's
    /// `"fault_plan"` key (`crate::pool::FaultPlan` grammar) — lets a
    /// generated zoo carry a deterministic failure scenario for the
    /// self-healing fleet tests; `None` (the default) omits the key
    pub fault_plan: Option<String>,
}

impl Default for SimSpec {
    fn default() -> Self {
        Self {
            name: "sim_mlp".into(),
            batch: 8,
            dims: vec![16, 24, 16, 10],
            calib_n: 192,
            val_n: 192,
            ood_n: 64,
            seed: 7,
            fault_plan: None,
        }
    }
}

/// Write a complete, self-contained sim artifacts directory — manifest,
/// program files, weights and datasets — that `Manifest::load` +
/// `ModelHandle::open` consume exactly like a PJRT artifacts dir.
///
/// Labels are the FP32 model's own argmax, so `fp32_val_metric` is exactly
/// the recorded top-1 and quantization noise degrades it smoothly (samples
/// near the decision boundary flip first).  A couple of outlier-scaled
/// weight columns widen the per-group sensitivity spread, so Phase-1 lists
/// have non-trivial order and Phase-2 curves have real shape.
pub fn generate(dir: impl AsRef<Path>, spec: &SimSpec) -> Result<()> {
    generate_zoo(dir, std::slice::from_ref(spec))
}

/// Write a **multi-model** sim zoo: one manifest, several models (distinct
/// names required) — the workload that exercises a shared
/// [`crate::pool::EvalFleet`] across model attach/detach for real.
pub fn generate_zoo(dir: impl AsRef<Path>, specs: &[SimSpec]) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    if specs.is_empty() {
        bail!("sim zoo needs at least one model spec");
    }
    let mut models = Vec::with_capacity(specs.len());
    for spec in specs {
        if models.iter().any(|(n, _)| n == &spec.name) {
            bail!("duplicate sim model name '{}'", spec.name);
        }
        let entry = generate_model(dir, spec)?;
        models.push((spec.name.clone(), entry));
    }
    let mut top = vec![
        ("backend".into(), Json::Str("sim".into())),
        ("models".into(), Json::Obj(models)),
    ];
    // first spec with a fault plan wins (the plan is fleet-wide, not
    // per-model)
    if let Some(plan) = specs.iter().find_map(|s| s.fault_plan.clone()) {
        top.push(("fault_plan".into(), Json::Str(plan)));
    }
    let manifest = Json::Obj(top);
    std::fs::write(dir.join("manifest.json"), manifest.to_string() + "\n")
        .with_context(|| format!("writing {}/manifest.json", dir.display()))?;
    Ok(())
}

/// Generate one model's artifacts (programs, weights, datasets) into
/// `dir`; returns its manifest entry.
fn generate_model(dir: &Path, spec: &SimSpec) -> Result<Json> {
    if spec.dims.len() < 2 || spec.dims.iter().any(|&d| d == 0) {
        // same validity rule SimProgram::load applies — fail at generation,
        // not at first open of the broken zoo
        bail!("sim spec needs >= 1 layer of nonzero width (dims {:?})", spec.dims);
    }
    let l = spec.dims.len() - 1;
    if spec.calib_n % spec.batch != 0 || spec.val_n % spec.batch != 0 {
        bail!("calib_n/val_n must be multiples of batch (EvalSet truncation)");
    }
    let mut rng = Rng::new(spec.seed);

    // weights: uniform in ±sqrt(6/(din+dout)); layer 1 gets two hot output
    // columns (the outlier-channel pathology that makes MP interesting)
    let mut weights: Vec<Tensor> = Vec::with_capacity(2 * l);
    for i in 0..l {
        let (din, dout) = (spec.dims[i], spec.dims[i + 1]);
        let a = (6.0 / (din + dout) as f64).sqrt() as f32;
        let mut w: Vec<f32> = (0..din * dout)
            .map(|_| (rng.f64() as f32 * 2.0 - 1.0) * a)
            .collect();
        if i == 1.min(l - 1) {
            for &hot in &[1usize, dout.saturating_sub(1)] {
                if hot < dout {
                    for r in 0..din {
                        w[r * dout + hot] *= 6.0;
                    }
                }
            }
        }
        weights.push(Tensor::from_f32(&[din, dout], w)?);
        weights.push(Tensor::zeros(&[dout]));
    }

    let fwd = SimProgram { kind: Kind::Forward, dims: spec.dims.clone() };
    let logits_of = |x: &Tensor| -> Result<Tensor> {
        // FP32 logits via the real interpreter path (all quantizers off)
        let act_qp = fp_act_qp(l + 1);
        let w_scales = Tensor::from_f32(
            &[l, spec.dims[1..].iter().copied().max().unwrap()],
            vec![1.0; l * spec.dims[1..].iter().copied().max().unwrap()],
        )?;
        let w_qmeta = fp_w_qmeta(l);
        let mut bufs: Vec<Buffer> = vec![Buffer::Host(x.clone())];
        for t in &weights {
            bufs.push(Buffer::Host(t.clone()));
        }
        bufs.push(Buffer::Host(act_qp));
        bufs.push(Buffer::Host(w_scales));
        bufs.push(Buffer::Host(w_qmeta));
        let refs: Vec<&Buffer> = bufs.iter().collect();
        fwd.forward(&refs)
    };

    let make_set = |rng: &mut Rng, n: usize| -> Result<(Tensor, Tensor, Tensor)> {
        let d0 = spec.dims[0];
        let x: Vec<f32> = (0..n * d0).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
        let x = Tensor::from_f32(&[n, d0], x)?;
        let logits = logits_of(&x)?;
        let (lv, c) = (logits.f32s()?, spec.dims[l]);
        let y: Vec<f32> = (0..n)
            .map(|i| {
                let row = &lv[i * c..(i + 1) * c];
                let mut best = 0usize;
                let mut bv = f32::NEG_INFINITY;
                for (j, &v) in row.iter().enumerate() {
                    if v > bv {
                        bv = v;
                        best = j;
                    }
                }
                best as f32
            })
            .collect();
        let y = Tensor::from_f32(&[n], y)?;
        Ok((x, y, logits))
    };

    let (cx, cy, _) = make_set(&mut rng, spec.calib_n)?;
    let (vx, vy, vlogits) = make_set(&mut rng, spec.val_n)?;
    let fp_metric = metrics::top1(&vlogits, &vy)?;

    let n = &spec.name;
    io::write_tensors(dir.join(format!("{n}.weights.bin")), &weights)?;
    io::write_tensors(dir.join(format!("{n}.calib.x.bin")), std::slice::from_ref(&cx))?;
    io::write_tensors(dir.join(format!("{n}.calib.y.bin")), std::slice::from_ref(&cy))?;
    io::write_tensors(dir.join(format!("{n}.val.x.bin")), std::slice::from_ref(&vx))?;
    io::write_tensors(dir.join(format!("{n}.val.y.bin")), std::slice::from_ref(&vy))?;
    let ood_file = if spec.ood_n > 0 {
        // out-of-domain pool: shifted uniform, unlabeled (Fig. 4 path)
        let d0 = spec.dims[0];
        let x: Vec<f32> = (0..spec.ood_n * d0)
            .map(|_| rng.f64() as f32 * 1.5 + 0.25)
            .collect();
        let t = Tensor::from_f32(&[spec.ood_n, d0], x)?;
        io::write_tensors(dir.join(format!("{n}.ood.x.bin")), std::slice::from_ref(&t))?;
        Some(format!("{n}.ood.x.bin"))
    } else {
        None
    };

    write_program(dir, &format!("{n}.fwd.sim.json"), "forward", &spec.dims)?;
    write_program(dir, &format!("{n}.stats.sim.json"), "stats", &spec.dims)?;
    // AdaRound + FIT artifacts: taps (= FP layer inputs + logits), one
    // per-layer adaround step program, and the FIT probe — so the pooled
    // AdaRound/FIT paths run hermetically on the sim backend too
    write_program(dir, &format!("{n}.taps.sim.json"), "taps", &spec.dims)?;
    for i in 0..l {
        write_program(
            dir,
            &format!("{n}.ar.fc{i}.sim.json"),
            "adaround",
            &[spec.dims[i], spec.dims[i + 1]],
        )?;
    }
    write_program(dir, &format!("{n}.fit.sim.json"), "fit", &spec.dims)?;

    Ok(mlp_entry_json(spec, fp_metric, ood_file.as_deref()))
}

fn fp_act_qp(a: usize) -> Tensor {
    let mut v = vec![0f32; a * 5];
    for i in 0..a {
        v[i * 5..(i + 1) * 5].copy_from_slice(&[1.0, 0.0, 0.0, 1.0, 0.0]);
    }
    Tensor::from_f32(&[a, 5], v).unwrap()
}

fn fp_w_qmeta(w: usize) -> Tensor {
    let mut v = vec![0f32; w * 3];
    for i in 0..w {
        v[i * 3..(i + 1) * 3].copy_from_slice(&[-1.0, 1.0, 0.0]);
    }
    Tensor::from_f32(&[w, 3], v).unwrap()
}

fn write_program(dir: &Path, file: &str, kind: &str, dims: &[usize]) -> Result<()> {
    let j = Json::Obj(vec![
        ("sim_program".into(), Json::Num(1.0)),
        ("kind".into(), Json::Str(kind.into())),
        (
            "dims".into(),
            Json::Arr(dims.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
    ]);
    std::fs::write(dir.join(file), j.to_string() + "\n")
        .with_context(|| format!("writing {}/{file}", dir.display()))
}

/// The manifest entry for a generated MLP — same schema
/// `python/compile/aot.py` emits, so `Manifest::parse_model` is untouched.
fn mlp_entry_json(spec: &SimSpec, fp_metric: f64, ood: Option<&str>) -> Json {
    let l = spec.dims.len() - 1;
    let n = &spec.name;
    let num = |x: usize| Json::Num(x as f64);
    let mut params = Vec::new();
    let mut act_q = vec![Json::Obj(vec![
        ("name".into(), Json::Str("input".into())),
        ("numel".into(), num(spec.dims[0])),
    ])];
    let mut w_q = Vec::new();
    let mut layers = Vec::new();
    let mut groups = Vec::new();
    let mut total_macs = 0usize;
    for i in 0..l {
        let (din, dout) = (spec.dims[i], spec.dims[i + 1]);
        params.push(Json::Obj(vec![
            ("name".into(), Json::Str(format!("fc{i}.w"))),
            ("shape".into(), Json::Arr(vec![num(din), num(dout)])),
        ]));
        params.push(Json::Obj(vec![
            ("name".into(), Json::Str(format!("fc{i}.b"))),
            ("shape".into(), Json::Arr(vec![num(dout)])),
        ]));
        act_q.push(Json::Obj(vec![
            ("name".into(), Json::Str(format!("fc{i}.out"))),
            ("numel".into(), num(dout)),
        ]));
        w_q.push(Json::Obj(vec![
            ("name".into(), Json::Str(format!("fc{i}.w"))),
            ("weight".into(), Json::Str(format!("fc{i}.w"))),
            ("channels".into(), num(dout)),
            ("channel_axis".into(), num(1)),
        ]));
        let macs = din * dout;
        total_macs += macs;
        layers.push(Json::Obj(vec![
            ("name".into(), Json::Str(format!("fc{i}"))),
            ("macs".into(), num(macs)),
            ("w_q".into(), num(i)),
            ("in_acts".into(), Json::Arr(vec![num(i)])),
        ]));
        groups.push(Json::Obj(vec![
            ("w_q".into(), Json::Arr(vec![num(i)])),
            ("act_q".into(), Json::Arr(vec![num(i)])),
            ("macs".into(), num(macs)),
        ]));
    }
    // the logits quantizer feeds no weighted op: weightless group, pinned
    // to the baseline by Phase 2 (same convention as the lowered zoo)
    groups.push(Json::Obj(vec![
        ("w_q".into(), Json::Arr(vec![])),
        ("act_q".into(), Json::Arr(vec![num(l)])),
        ("macs".into(), num(0)),
    ]));
    Json::Obj(vec![
        ("task".into(), Json::Str("classify10".into())),
        ("batch".into(), num(spec.batch)),
        (
            "input".into(),
            Json::Obj(vec![
                ("shape".into(), Json::Arr(vec![num(spec.batch), num(spec.dims[0])])),
                ("dtype".into(), Json::Str("f32".into())),
            ]),
        ),
        ("forward".into(), Json::Str(format!("{n}.fwd.sim.json"))),
        ("stats".into(), Json::Str(format!("{n}.stats.sim.json"))),
        (
            "stats_bits".into(),
            Json::Arr(vec![num(4), num(6), num(8), num(16)]),
        ),
        (
            "stats_ratios".into(),
            Json::Arr(quant::default_ratios().into_iter().map(Json::Num).collect()),
        ),
        ("weights_file".into(), Json::Str(format!("{n}.weights.bin"))),
        ("params".into(), Json::Arr(params)),
        (
            "out_shape".into(),
            Json::Arr(vec![num(spec.batch), num(spec.dims[l])]),
        ),
        ("act_quantizers".into(), Json::Arr(act_q)),
        ("w_quantizers".into(), Json::Arr(w_q)),
        ("layers".into(), Json::Arr(layers)),
        ("groups".into(), Json::Arr(groups)),
        ("total_macs".into(), num(total_macs)),
        ("cmax".into(), num(spec.dims[1..].iter().copied().max().unwrap())),
        ("fp32_val_metric".into(), Json::Num(fp_metric)),
        (
            "data".into(),
            Json::Obj(vec![
                ("calib".into(), Json::Str(format!("{n}.calib.x.bin"))),
                ("calib_labels".into(), Json::Str(format!("{n}.calib.y.bin"))),
                ("val".into(), Json::Str(format!("{n}.val.x.bin"))),
                ("val_labels".into(), Json::Str(format!("{n}.val.y.bin"))),
                (
                    "ood_calib".into(),
                    ood.map(|f| Json::Str(f.into())).unwrap_or(Json::Null),
                ),
            ]),
        ),
        ("taps".into(), Json::Str(format!("{n}.taps.sim.json"))),
        (
            "adaround".into(),
            Json::Arr(
                (0..l)
                    .map(|i| {
                        Json::Obj(vec![
                            ("layer".into(), Json::Str(format!("fc{i}"))),
                            ("exe".into(), Json::Str(format!("{n}.ar.fc{i}.sim.json"))),
                            ("tap_index".into(), num(i)),
                            ("param".into(), Json::Str(format!("fc{i}.w"))),
                            ("bias".into(), Json::Str(format!("fc{i}.b"))),
                            ("kind".into(), Json::Str("dense".into())),
                            ("channels".into(), num(spec.dims[i + 1])),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("fit".into(), Json::Str(format!("{n}.fit.sim.json"))),
        (
            "fit_act_shapes".into(),
            Json::Arr(
                (0..=l)
                    .map(|i| Json::Arr(vec![num(spec.batch), num(spec.dims[i])]))
                    .collect(),
            ),
        ),
    ])
}

// ---------------------------------------------------------------------------
// PJRT ↔ sim parity export
// ---------------------------------------------------------------------------

/// Re-export an HLO-lowered dense-chain model (e.g. `mlp_parity_s` from
/// `python/compile/models.py`) as a sim artifacts directory sharing the
/// *same* trained weights and datasets, so the two backends can be compared
/// on identical inputs (the artifacts-gated parity smoke test).
///
/// Validates that the entry really is the sim family — params alternate
/// `fc.w [din, dout]` / `fc.b [dout]`, weight quantizers are per-output
/// channel (`channel_axis = 1`) — and fails loudly otherwise rather than
/// silently interpreting a different graph.
pub fn export_from_artifacts(
    src_dir: impl AsRef<Path>,
    model: &str,
    out_dir: impl AsRef<Path>,
) -> Result<()> {
    let (src, out) = (src_dir.as_ref(), out_dir.as_ref());
    let j = jsonio::parse_file(src.join("manifest.json"))?;
    let entry = j
        .req("models")?
        .get(model)
        .ok_or_else(|| anyhow!("model '{model}' not in {}", src.display()))?;

    // recover and validate the chain dimensions from the parameter list
    let params = entry.req("params")?.as_arr()?;
    if params.len() < 2 || params.len() % 2 != 0 {
        bail!("'{model}' is not a dense chain ({} params)", params.len());
    }
    let l = params.len() / 2;
    let mut dims = Vec::with_capacity(l + 1);
    for i in 0..l {
        let w = params[2 * i].req("shape")?.usize_vec()?;
        let b = params[2 * i + 1].req("shape")?.usize_vec()?;
        if w.len() != 2 || b != [w[1]] {
            bail!("'{model}' layer {i}: shapes {w:?}/{b:?} are not dense w/b");
        }
        if i == 0 {
            dims.push(w[0]);
        } else if dims[i] != w[0] {
            bail!("'{model}' layer {i}: input dim {} != previous output {}", w[0], dims[i]);
        }
        dims.push(w[1]);
    }
    let in_numel: usize = entry
        .req("input")?
        .req("shape")?
        .usize_vec()?[1..]
        .iter()
        .product();
    if in_numel != dims[0] {
        bail!("'{model}': input numel {in_numel} != first dense input {}", dims[0]);
    }
    let wqs = entry.req("w_quantizers")?.as_arr()?;
    if wqs.len() != l {
        bail!("'{model}' has {} weight quantizers, want {l} (one per dense layer)", wqs.len());
    }
    for (i, q) in wqs.iter().enumerate() {
        if q.req("channel_axis")?.as_usize()? != 1 || q.req("channels")?.as_usize()? != dims[i + 1]
        {
            bail!("'{model}' w quantizer {i} is not per-output-channel dense");
        }
    }

    std::fs::create_dir_all(out).with_context(|| format!("creating {}", out.display()))?;
    let mut copy = |key: &str| -> Result<()> {
        let f = entry.req("data")?.req(key)?.as_str()?.to_string();
        std::fs::copy(src.join(&f), out.join(&f))
            .with_context(|| format!("copying {f}"))?;
        Ok(())
    };
    for key in ["calib", "calib_labels", "val", "val_labels"] {
        copy(key)?;
    }
    let wfile = entry.req("weights_file")?.as_str()?.to_string();
    std::fs::copy(src.join(&wfile), out.join(&wfile))
        .with_context(|| format!("copying {wfile}"))?;

    write_program(out, &format!("{model}.fwd.sim.json"), "forward", &dims)?;
    write_program(out, &format!("{model}.stats.sim.json"), "stats", &dims)?;

    // clone the entry, retargeting the executables at the sim programs and
    // dropping PJRT-only artifacts (taps / AdaRound / FIT / OOD files that
    // weren't copied)
    let mut e = entry.clone();
    obj_set(&mut e, "forward", Json::Str(format!("{model}.fwd.sim.json")));
    obj_set(&mut e, "stats", Json::Str(format!("{model}.stats.sim.json")));
    obj_set(&mut e, "taps", Json::Null);
    obj_set(&mut e, "adaround", Json::Arr(vec![]));
    obj_set(&mut e, "fit", Json::Null);
    obj_set(&mut e, "fit_act_shapes", Json::Null);
    if let Some(d) = e.get("data").cloned() {
        let mut d2 = d;
        obj_set(&mut d2, "ood_calib", Json::Null);
        obj_set(&mut e, "data", d2);
    }
    let manifest = Json::Obj(vec![
        ("backend".into(), Json::Str("sim".into())),
        ("models".into(), Json::Obj(vec![(model.to_string(), e)])),
    ]);
    std::fs::write(out.join("manifest.json"), manifest.to_string() + "\n")
        .with_context(|| format!("writing {}/manifest.json", out.display()))?;
    Ok(())
}

/// Set (or append) a key in a `Json::Obj`.
fn obj_set(obj: &mut Json, key: &str, val: Json) {
    if let Json::Obj(kv) = obj {
        if let Some(slot) = kv.iter_mut().find(|(k, _)| k == key) {
            slot.1 = val;
        } else {
            kv.push((key.to_string(), val));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mpq_sim_unit_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn program_roundtrips_and_rejects_garbage() {
        let d = tmp("prog");
        write_program(&d, "p.json", "forward", &[4, 3, 2]).unwrap();
        let p = SimProgram::load(&d.join("p.json")).unwrap();
        assert_eq!(p.kind, Kind::Forward);
        assert_eq!(p.dims, vec![4, 3, 2]);
        std::fs::write(d.join("bad.json"), "{\"sim_program\":1,\"kind\":\"conv\",\"dims\":[2,2]}")
            .unwrap();
        assert!(SimProgram::load(&d.join("bad.json")).is_err());
        std::fs::write(d.join("bad2.json"), "{\"sim_program\":1,\"kind\":\"forward\",\"dims\":[2]}")
            .unwrap();
        assert!(SimProgram::load(&d.join("bad2.json")).is_err());
    }

    /// The interpreter with all quantizers disabled must equal a plain
    /// matmul chain, and enabled rows must equal `quant::fq` applied
    /// element-wise — the non-gated drift guard for the fake-quant path.
    #[test]
    fn forward_matches_host_oracle() {
        let dims = vec![3usize, 4, 2];
        let prog = SimProgram { kind: Kind::Forward, dims: dims.clone() };
        let mut rng = Rng::new(11);
        let mut r = || rng.f64() as f32 * 2.0 - 1.0;
        let x: Vec<f32> = (0..2 * 3).map(|_| r()).collect();
        let w0: Vec<f32> = (0..3 * 4).map(|_| r()).collect();
        let w1: Vec<f32> = (0..4 * 2).map(|_| r()).collect();
        let b0: Vec<f32> = (0..4).map(|_| r()).collect();
        let b1: Vec<f32> = (0..2).map(|_| r()).collect();

        // act row 1 (hidden) enabled at 8 bits; weight 0 enabled at 4 bits
        let mut act_qp = fp_act_qp(3).f32s().unwrap().to_vec();
        act_qp[5..10].copy_from_slice(&[0.02, 3.0, 0.0, 255.0, 1.0]);
        let mut meta = fp_w_qmeta(2).f32s().unwrap().to_vec();
        meta[0..3].copy_from_slice(&[-7.0, 7.0, 1.0]);
        let scales = vec![0.05f32, 0.07, 0.11, 0.13, 1.0, 1.0, 1.0, 1.0]; // [2, 4]

        let bufs: Vec<Buffer> = vec![
            Buffer::Host(Tensor::from_f32(&[2, 3], x.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[3, 4], w0.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[4], b0.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[4, 2], w1.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[2], b1.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[3, 5], act_qp.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[2, 4], scales.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[2, 3], meta.clone()).unwrap()),
        ];
        let refs: Vec<&Buffer> = bufs.iter().collect();
        let got = prog.forward(&refs).unwrap();

        // independent oracle: same math, straight-line
        let mut h = x;
        let mut y0 = vec![0f32; 2 * 4];
        let wq0: Vec<f32> = (0..12)
            .map(|i| quant::fq(w0[i], scales[i % 4], 0.0, -7.0, 7.0))
            .collect();
        for rix in 0..2 {
            for c in 0..4 {
                let mut acc = 0f32;
                for k in 0..3 {
                    acc += h[rix * 3 + k] * wq0[k * 4 + c];
                }
                acc += b0[c];
                if acc < 0.0 {
                    acc = 0.0;
                }
                y0[rix * 4 + c] = quant::fq(acc, 0.02, 3.0, 0.0, 255.0);
            }
        }
        h = y0;
        let mut y1 = vec![0f32; 2 * 2];
        for rix in 0..2 {
            for c in 0..2 {
                let mut acc = 0f32;
                for k in 0..4 {
                    acc += h[rix * 4 + k] * w1[k * 2 + c];
                }
                y1[rix * 2 + c] = acc + b1[c];
            }
        }
        for (g, w) in got.f32s().unwrap().iter().zip(&y1) {
            assert_eq!(g.to_bits(), w.to_bits(), "interpreter drifted from oracle");
        }
    }

    /// The taps program returns layer inputs + logits — for this dense
    /// chain that is exactly the stats capture list, and `capture_taps`'s
    /// `n_layers + 1` output contract must hold.
    #[test]
    fn taps_program_matches_stats_captures() {
        let dims = vec![3usize, 4, 2];
        let taps = SimProgram { kind: Kind::Taps, dims: dims.clone() };
        let stats = SimProgram { kind: Kind::Stats, dims: dims.clone() };
        let mut rng = Rng::new(3);
        let mut r = || rng.f64() as f32 * 2.0 - 1.0;
        let bufs: Vec<Buffer> = vec![
            Buffer::Host(Tensor::from_f32(&[2, 3], (0..6).map(|_| r()).collect()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[3, 4], (0..12).map(|_| r()).collect()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[4], (0..4).map(|_| r()).collect()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[4, 2], (0..8).map(|_| r()).collect()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[2], (0..2).map(|_| r()).collect()).unwrap()),
        ];
        let refs: Vec<&Buffer> = bufs.iter().collect();
        let (a, b) = (taps.run(&refs).unwrap(), stats.run(&refs).unwrap());
        assert_eq!(a.len(), dims.len() - 1 + 1, "taps contract: L taps + logits");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    /// The adaround step's analytic dL/dV must match a central finite
    /// difference of its own loss output (inputs chosen inside every
    /// clip's interior so the loss is smooth where we probe).
    #[test]
    fn adaround_step_gradient_matches_finite_difference() {
        let prog = SimProgram { kind: Kind::AdaRound, dims: vec![3, 2] };
        let mut rng = Rng::new(5);
        let mut r = || rng.f64() as f32 * 0.8 - 0.4;
        let bsz = 4usize;
        let x: Vec<f32> = (0..bsz * 3).map(|_| r()).collect();
        let w: Vec<f32> = (0..6).map(|_| r()).collect();
        let b: Vec<f32> = (0..2).map(|_| r()).collect();
        let v: Vec<f32> = (0..6).map(|_| r()).collect();
        let scales = vec![0.11f32, 0.17];
        let meta = vec![-7.0f32, 7.0, 3.0, 0.05]; // qmin qmax beta lambda
        let run = |vv: &[f32]| -> (f32, Vec<f32>) {
            let bufs: Vec<Buffer> = vec![
                Buffer::Host(Tensor::from_f32(&[bsz, 3], x.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[3, 2], w.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[2], b.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[3, 2], vv.to_vec()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[2], scales.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[4], meta.clone()).unwrap()),
            ];
            let refs: Vec<&Buffer> = bufs.iter().collect();
            let outs = prog.adaround_step(&refs).unwrap();
            (outs[0].f32s().unwrap()[0], outs[1].f32s().unwrap().to_vec())
        };
        let (loss, g) = run(&v);
        assert!(loss.is_finite() && loss > 0.0, "degenerate loss {loss}");
        let eps = 1e-3f32;
        for i in 0..v.len() {
            let mut vp = v.clone();
            vp[i] += eps;
            let mut vm = v.clone();
            vm[i] -= eps;
            let num = (run(&vp).0 - run(&vm).0) / (2.0 * eps);
            let tol = 1e-3 + 0.05 * num.abs().max(g[i].abs());
            assert!(
                (num - g[i]).abs() < tol,
                "dL/dV[{i}]: analytic {} vs numeric {num}",
                g[i]
            );
        }
    }

    /// FIT program vs a closed-form oracle on a single dense layer (no
    /// relu): cross-entropy gradients w.r.t. the weight and both
    /// perturbation points, plus the forced local quantization errors.
    #[test]
    fn fit_program_matches_single_layer_oracle() {
        let (bsz, din, c) = (4usize, 3usize, 4usize);
        let prog = SimProgram { kind: Kind::Fit, dims: vec![din, c] };
        let mut rng = Rng::new(17);
        let mut r = || rng.f64() as f32 * 2.0 - 1.0;
        let x: Vec<f32> = (0..bsz * din).map(|_| r()).collect();
        let w: Vec<f32> = (0..din * c).map(|_| r()).collect();
        let b: Vec<f32> = (0..c).map(|_| r()).collect();
        let y: Vec<f32> = (0..bsz).map(|i| (i % c) as f32).collect();
        // act_qp rows: input at some scale, output row too (forced on)
        let qp: Vec<f32> = vec![0.05, 0.0, -127.0, 127.0, 1.0, 0.1, 3.0, 0.0, 255.0, 1.0];
        let bufs: Vec<Buffer> = vec![
            Buffer::Host(Tensor::from_f32(&[bsz, din], x.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[bsz], y.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[din, c], w.clone()).unwrap()),
            Buffer::Host(Tensor::from_f32(&[c], b.clone()).unwrap()),
            Buffer::Host(Tensor::zeros(&[bsz, din])),
            Buffer::Host(Tensor::zeros(&[bsz, c])),
            Buffer::Host(Tensor::from_f32(&[2, 5], qp.clone()).unwrap()),
        ];
        let refs: Vec<&Buffer> = bufs.iter().collect();
        let outs = prog.fit(&refs).unwrap();
        assert_eq!(outs[1].shape, [1]);
        assert_eq!(outs[2].shape, [2]);
        assert_eq!(outs[3].shape, [2]);

        // oracle: logits = x@w+b, CE grad, gw = x^T@glog, gpert0 = glog@w^T
        let logits = matmul_bias(&x, bsz, din, &w, c, &b);
        let mut glog = vec![0f32; bsz * c];
        for rix in 0..bsz {
            let row = &logits[rix * c..(rix + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
            for j in 0..c {
                let soft = (row[j] - lse).exp();
                glog[rix * c + j] =
                    (soft - if j == y[rix] as usize { 1.0 } else { 0.0 }) / bsz as f32;
            }
        }
        let mut gw = vec![0f32; din * c];
        for rix in 0..bsz {
            for k in 0..din {
                for j in 0..c {
                    gw[k * c + j] += x[rix * din + k] * glog[rix * c + j];
                }
            }
        }
        let mut gp0 = vec![0f32; bsz * din];
        for rix in 0..bsz {
            for k in 0..din {
                gp0[rix * din + k] =
                    (0..c).map(|j| glog[rix * c + j] * w[k * c + j]).sum::<f32>();
            }
        }
        let msq = |v: &[f32]| v.iter().map(|z| z * z).sum::<f32>() / v.len() as f32;
        let close = |a: f32, b: f32, what: &str| {
            assert!(
                (a - b).abs() <= 1e-6 + 1e-4 * a.abs().max(b.abs()),
                "{what}: {a} vs {b}"
            );
        };
        close(outs[1].f32s().unwrap()[0], msq(&gw), "wgrad2");
        close(outs[2].f32s().unwrap()[1], msq(&glog), "agrad2[logits]");
        close(outs[2].f32s().unwrap()[0], msq(&gp0), "agrad2[input]");
        // forced quantization errors
        let err = |v: &[f32], row: &[f32]| -> f32 {
            v.iter()
                .map(|&z| {
                    let d = z - quant::fq(z, row[0], row[1], row[2], row[3]);
                    d * d
                })
                .sum::<f32>()
                / v.len() as f32
        };
        close(outs[3].f32s().unwrap()[0], err(&x, &qp[0..5]), "aerr2[input]");
        close(outs[3].f32s().unwrap()[1], err(&logits, &qp[5..10]), "aerr2[logits]");
    }

    /// Relu masking in the FIT backward pass: the input-perturbation
    /// Fisher term of a 2-layer chain must match a finite-difference
    /// gradient of the program's own loss output.
    #[test]
    fn fit_program_input_grad_matches_finite_difference() {
        let (bsz, dims) = (2usize, vec![3usize, 4, 3]);
        let prog = SimProgram { kind: Kind::Fit, dims: dims.clone() };
        let mut rng = Rng::new(29);
        let mut r = || rng.f64() as f32 * 2.0 - 1.0;
        let x: Vec<f32> = (0..bsz * 3).map(|_| r()).collect();
        let w0: Vec<f32> = (0..12).map(|_| r()).collect();
        let b0: Vec<f32> = (0..4).map(|_| r()).collect();
        let w1: Vec<f32> = (0..12).map(|_| r()).collect();
        let b1: Vec<f32> = (0..3).map(|_| r()).collect();
        let y = vec![0f32, 2.0];
        let qp: Vec<f32> = (0..3).flat_map(|_| [0.05, 0.0, -127.0, 127.0, 1.0]).collect();
        let run = |p0: &[f32]| -> (f32, Vec<f32>) {
            let bufs: Vec<Buffer> = vec![
                Buffer::Host(Tensor::from_f32(&[bsz, 3], x.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[bsz], y.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[3, 4], w0.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[4], b0.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[4, 3], w1.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[3], b1.clone()).unwrap()),
                Buffer::Host(Tensor::from_f32(&[bsz, 3], p0.to_vec()).unwrap()),
                Buffer::Host(Tensor::zeros(&[bsz, 4])),
                Buffer::Host(Tensor::zeros(&[bsz, 3])),
                Buffer::Host(Tensor::from_f32(&[3, 5], qp.clone()).unwrap()),
            ];
            let refs: Vec<&Buffer> = bufs.iter().collect();
            let outs = prog.fit(&refs).unwrap();
            (outs[0].f32s().unwrap()[0], outs[2].f32s().unwrap().to_vec())
        };
        let zeros = vec![0f32; bsz * 3];
        let (_, agrad2) = run(&zeros);
        // numeric dL/dpert_0, element by element
        let eps = 5e-3f32;
        let mut g0 = vec![0f32; bsz * 3];
        for i in 0..g0.len() {
            let mut pp = zeros.clone();
            pp[i] += eps;
            let mut pm = zeros.clone();
            pm[i] -= eps;
            g0[i] = (run(&pp).0 - run(&pm).0) / (2.0 * eps);
        }
        let num = g0.iter().map(|z| z * z).sum::<f32>() / g0.len() as f32;
        assert!(
            (num - agrad2[0]).abs() <= 0.1 * num.abs().max(agrad2[0].abs()) + 1e-8,
            "agrad2[0] {} vs finite-difference {num}",
            agrad2[0]
        );
    }

    #[test]
    fn generate_zoo_writes_multiple_models() {
        let d = tmp("zoo2");
        let a = SimSpec { calib_n: 16, val_n: 16, ood_n: 0, ..Default::default() };
        let b = SimSpec {
            name: "sim_mlp_b".into(),
            dims: vec![12, 14, 10],
            calib_n: 16,
            val_n: 16,
            ood_n: 0,
            seed: 11,
            ..Default::default()
        };
        generate_zoo(&d, &[a.clone(), b.clone()]).unwrap();
        let man = crate::manifest::Manifest::load(&d).unwrap();
        assert_eq!(man.models.len(), 2);
        assert!(man.model(&a.name).is_ok() && man.model(&b.name).is_ok());
        // duplicate names must be rejected
        assert!(generate_zoo(&d, &[a.clone(), a]).is_err());
    }

    #[test]
    fn generated_zoo_opens_and_reports_its_metric() {
        let d = tmp("gen");
        let spec = SimSpec { calib_n: 32, val_n: 32, ood_n: 16, ..Default::default() };
        generate(&d, &spec).unwrap();
        let man = crate::manifest::Manifest::load(&d).unwrap();
        assert_eq!(man.backend, "sim");
        let entry = man.model(&spec.name).unwrap();
        assert_eq!(entry.n_w(), spec.dims.len() - 1);
        assert_eq!(entry.n_act(), spec.dims.len());
        crate::groups::Assignment::validate_partition(entry).unwrap();
        assert_eq!(
            entry.total_macs,
            entry.groups.iter().map(|g| g.macs).sum::<u64>()
        );
        let rt = std::rc::Rc::new(Runtime::for_manifest(&man).unwrap());
        let handle = crate::model::ModelHandle::open(rt, &man, &spec.name).unwrap();
        let val = handle.data.val.clone();
        let set = handle.eval_set(&val).unwrap();
        let cfg = crate::model::QuantConfig::fp32(&handle.entry);
        let fp = handle.eval_config(&set, &cfg).unwrap();
        assert!(
            (fp - handle.entry.fp32_val_metric).abs() < 1e-12,
            "fp32 {fp} != recorded {}",
            handle.entry.fp32_val_metric
        );
    }
}
