//! MPQT binary tensor format — the Rust counterpart of
//! `python/compile/tensorio.py`.
//!
//! Layout (little-endian):
//! `u32 magic "MPQT"` · `u8 dtype (0=f32,1=i32)` · `u8 ndim` ·
//! `u16 reserved` · `u32 dims[ndim]` · payload.  Files may concatenate
//! several tensors.

use super::{Data, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

pub const MAGIC: u32 = 0x4D50_5154;

pub fn read_tensor(r: &mut impl Read) -> Result<Option<Tensor>> {
    let mut hdr = [0u8; 8];
    match r.read_exact(&mut hdr[..1]) {
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        other => other.context("reading header")?,
    }
    r.read_exact(&mut hdr[1..])?;
    let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad MPQT magic {magic:#x}");
    }
    let dtype = hdr[4];
    let ndim = hdr[5] as usize;
    let mut dims = vec![0usize; ndim];
    let mut d4 = [0u8; 4];
    for d in dims.iter_mut() {
        r.read_exact(&mut d4)?;
        *d = u32::from_le_bytes(d4) as usize;
    }
    let n: usize = dims.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
    let mut raw = vec![0u8; n * 4];
    r.read_exact(&mut raw)?;
    let data = match dtype {
        0 => Data::F32(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        1 => Data::I32(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        d => bail!("unknown dtype tag {d}"),
    };
    Ok(Some(Tensor { shape: dims, data }))
}

pub fn read_tensors(path: impl AsRef<std::path::Path>) -> Result<Vec<Tensor>> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow!("opening {}: {e}", path.as_ref().display()))?;
    let mut r = std::io::BufReader::new(f);
    let mut out = Vec::new();
    while let Some(t) = read_tensor(&mut r)? {
        out.push(t);
    }
    Ok(out)
}

pub fn write_tensor(w: &mut impl Write, t: &Tensor) -> Result<()> {
    w.write_all(&MAGIC.to_le_bytes())?;
    let dtype: u8 = if t.is_f32() { 0 } else { 1 };
    w.write_all(&[dtype, t.shape.len() as u8, 0, 0])?;
    for &d in &t.shape {
        w.write_all(&(d as u32).to_le_bytes())?;
    }
    match &t.data {
        Data::F32(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Data::I32(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

pub fn write_tensors(path: impl AsRef<std::path::Path>, ts: &[Tensor]) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    for t in ts {
        write_tensor(&mut w, t)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed() {
        let a = Tensor::from_f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -1.0]).unwrap();
        let b = Tensor::from_i32(&[4], vec![1, -2, 3, -4]).unwrap();
        let dir = std::env::temp_dir().join("mpqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("roundtrip.bin");
        write_tensors(&p, &[a.clone(), b.clone()]).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back, vec![a, b]);
    }

    #[test]
    fn empty_file_ok() {
        let dir = std::env::temp_dir().join("mpqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("empty.bin");
        std::fs::write(&p, b"").unwrap();
        assert!(read_tensors(&p).unwrap().is_empty());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("mpqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 16]).unwrap();
        assert!(read_tensors(&p).is_err());
    }
}
