//! MPQT binary tensor format — the Rust counterpart of
//! `python/compile/tensorio.py`.
//!
//! Layout (little-endian):
//! `u32 magic "MPQT"` · `u8 dtype (0=f32,1=i32)` · `u8 ndim` ·
//! `u16 reserved` · `u32 dims[ndim]` · payload.  Files may concatenate
//! several tensors.
//!
//! Decoding is hardened against truncated and bit-flipped inputs: the
//! payload size is bounds-checked (`checked_mul`, compared against the
//! bytes actually available) *before* any allocation, so a corrupted
//! dim can neither OOM the process nor produce garbage-shaped tensors —
//! every structural problem is a clean `Err` with context.  Writes go
//! through [`crate::store::AtomicFile`] (temp + fsync + rename), so
//! concurrent readers never observe a half-written file.

use super::{Data, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

pub const MAGIC: u32 = 0x4D50_5154;

/// Decode one tensor from the front of `bytes`.  Returns the tensor and
/// the number of bytes it occupied; `Ok(None)` on an empty slice (clean
/// end of a concatenated stream).  Truncation, bad magic, unknown dtype
/// and overflowing dims are all explicit errors — never a panic, an
/// unbounded allocation, or silently wrong data.
pub fn decode_tensor(bytes: &[u8]) -> Result<Option<(Tensor, usize)>> {
    if bytes.is_empty() {
        return Ok(None);
    }
    if bytes.len() < 8 {
        bail!("truncated MPQT header ({} bytes left)", bytes.len());
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad MPQT magic {magic:#x}");
    }
    let dtype = bytes[4];
    if dtype > 1 {
        bail!("unknown dtype tag {dtype}");
    }
    let ndim = bytes[5] as usize;
    let dims_end = 8 + ndim * 4;
    if bytes.len() < dims_end {
        bail!("truncated MPQT dims (ndim={ndim}, {} bytes left)", bytes.len());
    }
    let mut dims = Vec::with_capacity(ndim);
    let mut n: usize = 1;
    for d in 0..ndim {
        let v = u32::from_le_bytes(bytes[8 + d * 4..12 + d * 4].try_into().unwrap()) as usize;
        n = n
            .checked_mul(v)
            .ok_or_else(|| anyhow!("MPQT dims overflow: {dims:?} x {v}"))?;
        dims.push(v);
    }
    let payload = n
        .checked_mul(4)
        .ok_or_else(|| anyhow!("MPQT payload size overflows ({n} elements)"))?;
    // bound BEFORE allocating: a bit-flipped dim must not OOM the process
    if bytes.len() - dims_end < payload {
        bail!(
            "truncated MPQT payload: need {payload} bytes for shape {dims:?}, \
             {} left",
            bytes.len() - dims_end
        );
    }
    let raw = &bytes[dims_end..dims_end + payload];
    let data = match dtype {
        0 => Data::F32(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        _ => Data::I32(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
    };
    Ok(Some((Tensor { shape: dims, data }, dims_end + payload)))
}

/// Decode a full concatenated MPQT byte stream (e.g. a journal payload).
pub fn decode_tensors(mut bytes: &[u8]) -> Result<Vec<Tensor>> {
    let mut out = Vec::new();
    while let Some((t, used)) = decode_tensor(bytes)? {
        out.push(t);
        bytes = &bytes[used..];
    }
    Ok(out)
}

/// Encode tensors as a concatenated MPQT byte stream.
pub fn encode_tensors(ts: &[Tensor]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in ts {
        write_tensor(&mut out, t).expect("Vec<u8> writes are infallible");
    }
    out
}

/// Streaming single-tensor read.  `Ok(None)` at a clean end-of-stream.
/// Allocation is bounded by the bytes the reader actually yields (a
/// corrupted dim count hits end-of-stream and errors, it does not
/// pre-allocate), but prefer [`decode_tensor`] when the input is already
/// in memory — it validates sizes up front.
pub fn read_tensor(r: &mut impl Read) -> Result<Option<Tensor>> {
    let mut hdr = [0u8; 8];
    match r.read_exact(&mut hdr[..1]) {
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        other => other.context("reading header")?,
    }
    r.read_exact(&mut hdr[1..]).context("truncated MPQT header")?;
    let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad MPQT magic {magic:#x}");
    }
    let dtype = hdr[4];
    if dtype > 1 {
        bail!("unknown dtype tag {dtype}");
    }
    let ndim = hdr[5] as usize;
    let mut dims = vec![0usize; ndim];
    let mut d4 = [0u8; 4];
    let mut n: usize = 1;
    for d in dims.iter_mut() {
        r.read_exact(&mut d4).context("truncated MPQT dims")?;
        *d = u32::from_le_bytes(d4) as usize;
        n = n
            .checked_mul(*d)
            .ok_or_else(|| anyhow!("MPQT dims overflow at {d}"))?;
    }
    let payload = n
        .checked_mul(4)
        .ok_or_else(|| anyhow!("MPQT payload size overflows ({n} elements)"))?;
    // read incrementally via take(): allocation tracks bytes actually
    // present, so a bit-flipped dim errors out instead of OOMing
    let mut raw = Vec::new();
    let got = r
        .take(payload as u64)
        .read_to_end(&mut raw)
        .context("reading MPQT payload")?;
    if got < payload {
        bail!("truncated MPQT payload: need {payload} bytes for shape {dims:?}, got {got}");
    }
    let data = match dtype {
        0 => Data::F32(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
        _ => Data::I32(
            raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ),
    };
    Ok(Some(Tensor { shape: dims, data }))
}

pub fn read_tensors(path: impl AsRef<std::path::Path>) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path.as_ref())
        .map_err(|e| anyhow!("opening {}: {e}", path.as_ref().display()))?;
    decode_tensors(&bytes).with_context(|| format!("decoding {}", path.as_ref().display()))
}

pub fn write_tensor(w: &mut impl Write, t: &Tensor) -> Result<()> {
    w.write_all(&MAGIC.to_le_bytes())?;
    let dtype: u8 = if t.is_f32() { 0 } else { 1 };
    w.write_all(&[dtype, t.shape.len() as u8, 0, 0])?;
    for &d in &t.shape {
        w.write_all(&(d as u32).to_le_bytes())?;
    }
    match &t.data {
        Data::F32(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Data::I32(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

pub fn write_tensors(path: impl AsRef<std::path::Path>, ts: &[Tensor]) -> Result<()> {
    let f = crate::store::AtomicFile::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    for t in ts {
        write_tensor(&mut w, t)?;
    }
    w.into_inner()
        .map_err(|e| anyhow!("flushing tensor file: {e}"))?
        .commit()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed() {
        let a = Tensor::from_f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -1.0]).unwrap();
        let b = Tensor::from_i32(&[4], vec![1, -2, 3, -4]).unwrap();
        let dir = std::env::temp_dir().join("mpqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("roundtrip.bin");
        write_tensors(&p, &[a.clone(), b.clone()]).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back, vec![a.clone(), b.clone()]);
        // slice codec agrees with the file codec
        let bytes = encode_tensors(&[a.clone(), b.clone()]);
        assert_eq!(bytes, std::fs::read(&p).unwrap());
        assert_eq!(decode_tensors(&bytes).unwrap(), vec![a, b]);
    }

    #[test]
    fn empty_file_ok() {
        let dir = std::env::temp_dir().join("mpqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("empty.bin");
        std::fs::write(&p, b"").unwrap();
        assert!(read_tensors(&p).unwrap().is_empty());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("mpqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 16]).unwrap();
        assert!(read_tensors(&p).is_err());
    }

    #[test]
    fn corrupt_dims_error_without_allocating() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut bytes = encode_tensors(std::slice::from_ref(&t));
        // blow up dim 0 to ~4 billion: must be a clean error, not an OOM
        bytes[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_tensors(&bytes).unwrap_err().to_string();
        assert!(err.contains("MPQT"), "unexpected error: {err}");
        // truncation mid-payload is an error too, at every cut point
        let bytes = encode_tensors(std::slice::from_ref(&t));
        for cut in 1..bytes.len() {
            assert!(
                decode_tensors(&bytes[..cut]).is_err(),
                "cut at {cut} must not decode"
            );
        }
    }
}
