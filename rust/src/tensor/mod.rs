//! Host-side tensors and the MPQT binary interchange format.
//!
//! [`Tensor`] is the crate's lingua franca between artifact files, PJRT
//! literals and the algorithm code.  Only the two dtypes that cross the
//! python↔rust boundary exist: `f32` and `i32`.

pub mod io;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: Data::F32(vec![0.0; shape.iter().product()]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(Self { shape: shape.to_vec(), data: Data::F32(data) })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(Self { shape: shape.to_vec(), data: Data::I32(data) })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, Data::F32(_))
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Copy of rows `[start, start+len)` along the first axis.
    pub fn slice_rows(&self, start: usize, len: usize) -> Result<Tensor> {
        if self.shape.is_empty() {
            bail!("cannot row-slice a scalar");
        }
        let n0 = self.shape[0];
        if start + len > n0 {
            bail!("row slice {start}+{len} out of bounds (n0={n0})");
        }
        let stride: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = len;
        Ok(match &self.data {
            Data::F32(v) => Tensor {
                shape,
                data: Data::F32(v[start * stride..(start + len) * stride].to_vec()),
            },
            Data::I32(v) => Tensor {
                shape,
                data: Data::I32(v[start * stride..(start + len) * stride].to_vec()),
            },
        })
    }

    /// Gather rows by index along the first axis (calibration subsets).
    pub fn gather_rows(&self, idx: &[usize]) -> Result<Tensor> {
        if self.shape.is_empty() {
            bail!("cannot gather a scalar");
        }
        let stride: usize = self.shape[1..].iter().product();
        let n0 = self.shape[0];
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        Ok(match &self.data {
            Data::F32(v) => {
                let mut out = Vec::with_capacity(idx.len() * stride);
                for &i in idx {
                    if i >= n0 {
                        bail!("gather index {i} >= {n0}");
                    }
                    out.extend_from_slice(&v[i * stride..(i + 1) * stride]);
                }
                Tensor { shape, data: Data::F32(out) }
            }
            Data::I32(v) => {
                let mut out = Vec::with_capacity(idx.len() * stride);
                for &i in idx {
                    if i >= n0 {
                        bail!("gather index {i} >= {n0}");
                    }
                    out.extend_from_slice(&v[i * stride..(i + 1) * stride]);
                }
                Tensor { shape, data: Data::I32(out) }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::from_i32(&[2], vec![1, 2, 3]).is_err());
    }

    #[test]
    fn slice_rows_basic() {
        let t = Tensor::from_f32(&[4, 2], (0..8).map(|x| x as f32).collect()).unwrap();
        let s = t.slice_rows(1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.f32s().unwrap(), &[2.0, 3.0, 4.0, 5.0]);
        assert!(t.slice_rows(3, 2).is_err());
    }

    #[test]
    fn gather_rows_basic() {
        let t = Tensor::from_i32(&[3, 2], vec![0, 1, 10, 11, 20, 21]).unwrap();
        let g = t.gather_rows(&[2, 0]).unwrap();
        assert_eq!(g.i32s().unwrap(), &[20, 21, 0, 1]);
        assert!(t.gather_rows(&[5]).is_err());
    }

    #[test]
    fn dtype_guards() {
        let t = Tensor::zeros(&[2]);
        assert!(t.f32s().is_ok());
        assert!(t.i32s().is_err());
    }
}
