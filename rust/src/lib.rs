//! # mpq — post-training mixed-precision quantization
//!
//! A from-scratch reproduction of *“A Practical Mixed Precision Algorithm
//! for Post-Training Quantization”* (Pandey et al., Qualcomm AI Research,
//! 2023) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's algorithm and every substrate it
//!   needs: PJRT runtime, MSE range estimation, SQNR/accuracy/FIT
//!   sensitivity (Phase 1), quantizer groups, BOPs accounting, the greedy
//!   pareto flip plus sequential/binary/interpolation searches (Phase 2),
//!   and the AdaRound integration.  Every Phase-1 probe and Phase-2 prefix
//!   evaluation routes through the [`engine`] — a shared, memoizing,
//!   streaming evaluator: one cached FP32 reference sweep per
//!   `(model, eval-set)`, batch-streamed SQNR/task metrics (no host logit
//!   concatenation), per-configuration memoization with hit counters next
//!   to `fwd_calls`, and packed quant-param tensors row-patched from a
//!   cached baseline.  The [`pool`] scales that service horizontally with
//!   one elastic, process-wide **evaluation fleet**: N worker threads,
//!   each with a private backend client, shared across every model in the
//!   process (per-model executables compile lazily and are evicted on
//!   detach; `resize` grows/shrinks the fleet between phases).  Probes,
//!   FIT accumulation and AdaRound optimizations all fan out through it
//!   with results bit-identical to the serial path (`--workers N` on the
//!   CLI).  The [`serve`] daemon (`mpq serve`) exposes that fleet as a
//!   service: concurrent jobs over a Unix socket, phase-interleaved
//!   scheduling, streamed progress, per-job crash/resume journals.
//! * **L2** — the model zoo, lowered once by `python/compile/aot.py` to
//!   HLO-text artifacts whose quantizer parameters are *runtime inputs*.
//! * **L1** — Pallas fake-quant kernels inside those artifacts.
//!
//! Python never runs on the request path: everything here executes
//! AOT-compiled artifacts through [`runtime::Runtime`] — a pluggable
//! facade over two [`runtime::Backend`]s: the PJRT client (default, the
//! `pjrt` cargo feature) and the pure-Rust [`sim`] interpreter, which runs
//! the same Phase-1/Phase-2/pool stack hermetically on a synthetic
//! linear+fake-quant model family (the always-on end-to-end test tier, see
//! `rust/tests/README.md`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use mpq::coordinator::Pipeline;
//! use mpq::groups::Lattice;
//!
//! let mut pipe = Pipeline::open("artifacts", "resnet_s").unwrap();
//! pipe.calibrate(256, 0).unwrap();
//! let lat = Lattice::practical();
//! let sens = pipe.sensitivity_sqnr(&lat).unwrap();
//! let flips = pipe.flips(&lat, &sens);
//! let run = pipe.search_bops_budget(&lat, &flips, 0.5).unwrap();
//! println!("r={:.3} metric={:.4}", run.final_rel_bops, run.final_metric);
//! ```

pub mod adaround;
pub mod bench;
pub mod bops;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod groups;
pub mod jsonio;
pub mod manifest;
pub mod metrics;
pub mod model;
pub mod pool;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod sensitivity;
pub mod serve;
pub mod sim;
pub mod store;
pub mod telemetry;
pub mod tensor;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};

/// Default artifacts directory, overridable with `MPQ_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("MPQ_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
