//! Cached FP32 reference signal and the streaming SQNR accumulator.
//!
//! Every SQNR probe compares quantized logits against the *same* FP32
//! reference (Eq. 3).  Before the engine existed, each Phase-1 caller
//! recomputed that reference with a full forward sweep (`fp_logits`) and
//! concatenated all probe logits into one `O(N×C)` host tensor per probe.
//! [`FpReference`] runs the FP32 sweep once per `(model, eval-set)`, keeps
//! the logits *per batch* (streaming consumers never need the
//! concatenation), and precomputes the per-sample signal power
//! `Σ_j F(x_i)_j²` that Eq. 3's numerator needs — computed once, reused by
//! every probe.

use crate::model::{EvalSet, ModelHandle, QuantConfig};
use crate::tensor::Tensor;
use crate::util::db10;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};

/// The FP32 reference over one eval set: per-batch logits plus per-sample
/// signal power.
pub struct FpReference {
    /// per-batch FP32 logits, in eval-set order (host tensors)
    pub batches: Vec<Tensor>,
    /// per-batch, per-sample signal power `Σ_j F(x_i)_j²`
    pub sig_pow: Vec<Vec<f64>>,
    /// shape of the concatenated logits `[n, ...]`
    pub shape: Vec<usize>,
}

impl FpReference {
    /// One FP32 forward sweep over `set` — the "1" in Phase 1's
    /// `1 + probes` forward-sweep budget.
    pub fn build(handle: &ModelHandle, set: &EvalSet) -> Result<Self> {
        let cfg = QuantConfig::fp32(&handle.entry);
        let cb = handle.config_buffers(&cfg, &HashMap::new())?;
        let mut batches = Vec::with_capacity(set.batches.len());
        let mut sig_pow = Vec::with_capacity(set.batches.len());
        for xb in &set.batches {
            let out = handle.forward(xb, &cb)?;
            sig_pow.push(per_sample_power(&out)?);
            batches.push(out);
        }
        let mut shape = batches[0].shape.clone();
        shape[0] = set.n;
        Ok(Self { batches, sig_pow, shape })
    }

    /// Rebuild a reference from per-batch FP32 logits (the on-disk
    /// reference cache, or a fleet worker's shard slice of it) without any
    /// forward sweep.  The per-sample signal power is recomputed from the
    /// logits — a pure `f64` function of them, so a reference restored
    /// from disk is indistinguishable from a freshly built one.
    pub fn from_batches(batches: Vec<Tensor>) -> Result<Self> {
        let mut sig_pow = Vec::with_capacity(batches.len());
        let mut n = 0usize;
        for b in &batches {
            sig_pow.push(per_sample_power(b)?);
            n += b.shape[0];
        }
        let mut shape = batches.first().map(|b| b.shape.clone()).unwrap_or_else(|| vec![0]);
        shape[0] = n;
        Ok(Self { batches, sig_pow, shape })
    }

    /// Number of samples covered.
    pub fn n(&self) -> usize {
        self.shape[0]
    }

    /// Concatenate the per-batch logits into one tensor — compat path for
    /// consumers that genuinely need the full array (tests, Kendall-τ
    /// ground truth); the streaming paths never call this.
    pub fn concat(&self) -> Result<Tensor> {
        let mut data = Vec::with_capacity(self.shape.iter().product());
        for b in &self.batches {
            data.extend_from_slice(b.f32s()?);
        }
        Tensor::from_f32(&self.shape, data)
    }
}

/// `Σ_j x_j²` per sample (first axis), in `f64`.
fn per_sample_power(t: &Tensor) -> Result<Vec<f64>> {
    if t.shape.is_empty() {
        bail!("per-sample power of a scalar");
    }
    let n = t.shape[0];
    let stride = t.numel() / n.max(1);
    let v = t.f32s()?;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut sig = 0f64;
        for &x in &v[i * stride..(i + 1) * stride] {
            let f = x as f64;
            sig += f * f;
        }
        out.push(sig);
    }
    Ok(out)
}

/// Batch-by-batch accumulator for the network-output SQNR (Eq. 3-4).
///
/// Partial sums are kept **per batch**, keyed by the batch's global index
/// in the eval set, and [`Self::db`] reduces them in index order.  That
/// makes the accumulator mergeable across eval-set shards with a *bit-exact*
/// guarantee: an [`crate::pool::EvalPool`] worker computes the same per-batch
/// partials as the serial path and [`Self::merge`] reassembles them into the
/// same ordered final summation, so any sharding — including none — produces
/// the identical `f64`.  Numerically it matches
/// [`crate::sensitivity::sqnr_db`] on the concatenated logits up to the
/// batch-partial association, without ever materializing the concatenation.
#[derive(Default)]
pub struct StreamingSqnr {
    /// global batch index → `(Σ_i sig_i/err_i over the batch, samples)`
    parts: BTreeMap<u64, (f64, usize)>,
    /// next implicit index for [`Self::push`]
    seq: u64,
}

impl StreamingSqnr {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold in the next batch in eval-set order: `fp` and `q` are same-shape
    /// logits, `sig_pow` the cached per-sample `Σ F²` for this batch.
    pub fn push(&mut self, fp: &Tensor, sig_pow: &[f64], q: &Tensor) -> Result<()> {
        let idx = self.seq;
        self.push_at(idx, fp, sig_pow, q)
    }

    /// Fold in the batch at *global* eval-set index `idx` — pool workers use
    /// this so a shard's partials land at their set-wide positions.
    pub fn push_at(&mut self, idx: u64, fp: &Tensor, sig_pow: &[f64], q: &Tensor) -> Result<()> {
        if fp.shape != q.shape || fp.shape.is_empty() {
            bail!("sqnr shape mismatch {:?} vs {:?}", fp.shape, q.shape);
        }
        let bsz = fp.shape[0];
        if sig_pow.len() != bsz {
            bail!("sig_pow len {} != batch size {bsz}", sig_pow.len());
        }
        let stride = fp.numel() / bsz;
        let (a, b) = (fp.f32s()?, q.f32s()?);
        let mut acc = 0f64;
        for i in 0..bsz {
            let mut err = 0f64;
            for j in i * stride..(i + 1) * stride {
                let e = a[j] as f64 - b[j] as f64;
                err += e * e;
            }
            acc += sig_pow[i] / err.max(1e-30);
        }
        if self.parts.contains_key(&idx) {
            bail!("sqnr batch index {idx} pushed twice");
        }
        self.parts.insert(idx, (acc, bsz));
        self.seq = self.seq.max(idx + 1);
        Ok(())
    }

    /// Fold another accumulator (a disjoint set of batch indices) into this
    /// one.  Index sets must not overlap — a batch measured twice is a
    /// sharding bug, not a bigger sample.
    pub fn merge(&mut self, other: &StreamingSqnr) -> Result<()> {
        if let Some(dup) = other.parts.keys().find(|k| self.parts.contains_key(k)) {
            bail!("sqnr merge: batch index {dup} present in both shards");
        }
        for (&idx, &part) in &other.parts {
            self.parts.insert(idx, part);
        }
        self.seq = self.seq.max(other.seq);
        Ok(())
    }

    /// Decompose into `(seq, [(global batch index, Σ sig/err, samples)])`
    /// for wire transport: the process-lane codec ships the exact partial
    /// sums so a remote shard merges bit-identically to an in-process one.
    pub(crate) fn to_parts(&self) -> (u64, Vec<(u64, f64, usize)>) {
        (
            self.seq,
            self.parts.iter().map(|(&i, &(a, n))| (i, a, n)).collect(),
        )
    }

    /// Rebuild from [`Self::to_parts`] output (inverse, bit-exact).
    pub(crate) fn from_parts(seq: u64, parts: impl IntoIterator<Item = (u64, f64, usize)>) -> Self {
        Self {
            parts: parts.into_iter().map(|(i, a, n)| (i, (a, n))).collect(),
            seq,
        }
    }

    /// `10·log10((1/N)·Σ_i sig_i/err_i)` over everything pushed so far,
    /// reduced in global batch order.
    pub fn db(&self) -> f64 {
        let mut acc = 0f64;
        let mut n = 0usize;
        for &(a, bn) in self.parts.values() {
            acc += a;
            n += bn;
        }
        db10(acc / n.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensitivity::sqnr_db;
    use crate::util::Rng;

    fn random_pair(rng: &mut Rng, n: usize, c: usize) -> (Tensor, Tensor) {
        let fp: Vec<f32> = (0..n * c).map(|_| rng.f64() as f32 * 4.0 - 2.0).collect();
        let q: Vec<f32> = fp
            .iter()
            .map(|&x| x + (rng.f64() as f32 - 0.5) * 0.05)
            .collect();
        (
            Tensor::from_f32(&[n, c], fp).unwrap(),
            Tensor::from_f32(&[n, c], q).unwrap(),
        )
    }

    #[test]
    fn streaming_matches_concatenated_sqnr_db() {
        let mut rng = Rng::new(11);
        for &(n, c, bsz) in &[(12usize, 7usize, 3usize), (16, 10, 4), (8, 5, 8)] {
            let (fp, q) = random_pair(&mut rng, n, c);
            let want = sqnr_db(&fp, &q).unwrap();
            let mut s = StreamingSqnr::new();
            for start in (0..n).step_by(bsz) {
                let fb = fp.slice_rows(start, bsz).unwrap();
                let qb = q.slice_rows(start, bsz).unwrap();
                let sig = per_sample_power(&fb).unwrap();
                s.push(&fb, &sig, &qb).unwrap();
            }
            let got = s.db();
            assert!(
                (got - want).abs() < 1e-9,
                "streaming {got} != concatenated {want}"
            );
        }
    }

    #[test]
    fn streaming_zero_error_is_large() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let sig = per_sample_power(&t).unwrap();
        let mut s = StreamingSqnr::new();
        s.push(&t, &sig, &t).unwrap();
        assert!(s.db() > 100.0);
    }

    #[test]
    fn streaming_rejects_mismatches() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[2, 3]);
        let sig = vec![0.0; 2];
        assert!(StreamingSqnr::new().push(&a, &sig, &b).is_err());
        assert!(StreamingSqnr::new().push(&a, &sig[..1], &a).is_err());
    }

    /// Shard-merged accumulators must be *bit-identical* to one accumulator
    /// pushed serially — the pool's exactness guarantee.
    #[test]
    fn merged_shards_are_bit_identical_to_serial() {
        let mut rng = Rng::new(97);
        let (n, c, bsz) = (24usize, 6usize, 4usize);
        let (fp, q) = random_pair(&mut rng, n, c);
        let mut serial = StreamingSqnr::new();
        // three shards with uneven batch counts, like a real pool split
        let mut shards: Vec<StreamingSqnr> =
            (0..3).map(|_| StreamingSqnr::new()).collect();
        for (bi, start) in (0..n).step_by(bsz).enumerate() {
            let fb = fp.slice_rows(start, bsz).unwrap();
            let qb = q.slice_rows(start, bsz).unwrap();
            let sig = per_sample_power(&fb).unwrap();
            serial.push(&fb, &sig, &qb).unwrap();
            let shard = if bi < 1 { 0 } else if bi < 4 { 1 } else { 2 };
            shards[shard].push_at(bi as u64, &fb, &sig, &qb).unwrap();
        }
        // merge in *reverse* shard order — the BTreeMap restores batch order
        let mut merged = StreamingSqnr::new();
        for s in shards.iter().rev() {
            merged.merge(s).unwrap();
        }
        assert_eq!(merged.db().to_bits(), serial.db().to_bits());
    }

    #[test]
    fn merge_rejects_overlapping_batches() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let sig = per_sample_power(&t).unwrap();
        let mut a = StreamingSqnr::new();
        let mut b = StreamingSqnr::new();
        a.push_at(3, &t, &sig, &t).unwrap();
        b.push_at(3, &t, &sig, &t).unwrap();
        assert!(a.merge(&b).is_err());
        assert!(a.push_at(3, &t, &sig, &t).is_err());
        // plain push continues past the highest explicit index
        a.push(&t, &sig, &t).unwrap();
        assert!(a.push_at(4, &t, &sig, &t).is_err());
    }

    #[test]
    fn per_sample_power_matches_manual() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let p = per_sample_power(&t).unwrap();
        assert_eq!(p, vec![5.0, 25.0]);
    }
}
