//! Incremental configuration materialization (row patching).
//!
//! A Phase-1 probe differs from the FP32 baseline in exactly one group's
//! rows of the three packed quant-param tensors (`act_qp[A,5]`,
//! `w_scales[W,Cmax]`, `w_qmeta[W,3]`), yet the pre-engine path recomputed
//! every row — including the per-row MSE-grid argmin in
//! [`crate::quant::ActRanges::qparams`] — for each of the
//! `O(groups × candidates)` probes.  [`Materializer`] keeps the packed FP32
//! baseline rows and a per-`(quantizer, bits)` activation-row cache, so
//! materializing any configuration is a memcpy of the baseline plus patches
//! for only the quantized rows.
//!
//! In an [`crate::pool::EvalPool`] each worker owns a private materializer
//! (on its handle's `HandleEngine`): the row caches sit behind `RefCell`
//! and never cross threads.  The per-worker `(quantizer, bits)` row caches
//! warm independently — at most `A × bits` cheap argmin recomputations per
//! worker, amortized over the whole sweep — and a `Calibrate` message
//! invalidates them exactly like a local recalibration does.

use crate::manifest::ModelEntry;
use crate::model::{ModelHandle, QuantConfig};
use crate::quant;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

/// Patches packed quant-param tensors from a cached FP32 baseline.
pub struct Materializer {
    n_act: usize,
    n_w: usize,
    cmax: usize,
    /// FP32 baseline rows: every quantizer disabled (`enable = 0`)
    base_act: Vec<f32>,
    base_scales: Vec<f32>,
    base_meta: Vec<f32>,
    /// `[scale, offset, qmin, qmax, enable]` per `(act quantizer, bits)` —
    /// invalidated when ranges are recalibrated
    act_rows: RefCell<HashMap<(usize, u8), [f32; 5]>>,
    /// rows written on top of the baseline (patch-size accounting)
    pub rows_patched: Cell<u64>,
    /// configurations materialized
    pub materializations: Cell<u64>,
}

impl Materializer {
    pub fn new(entry: &ModelEntry) -> Self {
        let (n_act, n_w, cmax) = (entry.n_act(), entry.n_w(), entry.cmax);
        let mut base_act = vec![0f32; n_act * 5];
        for i in 0..n_act {
            base_act[i * 5..(i + 1) * 5].copy_from_slice(&[1.0, 0.0, 0.0, 1.0, 0.0]);
        }
        let base_scales = vec![1f32; n_w * cmax];
        let mut base_meta = vec![0f32; n_w * 3];
        for i in 0..n_w {
            base_meta[i * 3..(i + 1) * 3].copy_from_slice(&[-1.0, 1.0, 0.0]);
        }
        Self {
            n_act,
            n_w,
            cmax,
            base_act,
            base_scales,
            base_meta,
            act_rows: RefCell::new(HashMap::new()),
            rows_patched: Cell::new(0),
            materializations: Cell::new(0),
        }
    }

    /// Drop cached activation rows — must be called whenever the calibrated
    /// ranges change (the weight-scale rows live in `ModelHandle::w_scales`
    /// and depend only on the trained weights).
    pub fn invalidate(&self) {
        self.act_rows.borrow_mut().clear();
    }

    /// Packed `(act_qp, w_scales, w_qmeta)` tensors for `cfg`, patched from
    /// the FP32 baseline.  Requires calibrated ranges for any `Some`
    /// activation row and prepared weight scales for any `Some` weight row.
    pub fn tensors(
        &self,
        handle: &ModelHandle,
        cfg: &QuantConfig,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        if cfg.act.len() != self.n_act || cfg.w.len() != self.n_w {
            bail!("config arity mismatch");
        }
        let mut act_qp = self.base_act.clone();
        let mut w_scales = self.base_scales.clone();
        let mut w_qmeta = self.base_meta.clone();
        let mut patched = 0u64;
        for (i, b) in cfg.act.iter().enumerate() {
            if let Some(bits) = b {
                act_qp[i * 5..(i + 1) * 5].copy_from_slice(&self.act_row(handle, i, *bits)?);
                patched += 1;
            }
        }
        for (i, b) in cfg.w.iter().enumerate() {
            if let Some(bits) = b {
                let scales = handle
                    .w_scales
                    .get(bits)
                    .ok_or_else(|| anyhow!("weight scales for {bits} bits not prepared"))?;
                let sc = &scales[i];
                w_scales[i * self.cmax..i * self.cmax + sc.len()].copy_from_slice(sc);
                let (qmin, qmax) = quant::weight_qrange(*bits);
                w_qmeta[i * 3..(i + 1) * 3].copy_from_slice(&[qmin, qmax, 1.0]);
                patched += 1;
            }
        }
        self.rows_patched.set(self.rows_patched.get() + patched);
        self.materializations.set(self.materializations.get() + 1);
        Ok((
            Tensor::from_f32(&[self.n_act, 5], act_qp)?,
            Tensor::from_f32(&[self.n_w, self.cmax], w_scales)?,
            Tensor::from_f32(&[self.n_w, 3], w_qmeta)?,
        ))
    }

    /// Cached `[scale, offset, 0, qmax, 1]` row for activation quantizer `i`
    /// at `bits` — the MSE-grid argmin behind it runs once per
    /// `(quantizer, bits)`, not once per probe.
    fn act_row(&self, handle: &ModelHandle, i: usize, bits: u8) -> Result<[f32; 5]> {
        if let Some(r) = self.act_rows.borrow().get(&(i, bits)) {
            return Ok(*r);
        }
        let ranges = handle
            .act_ranges
            .as_ref()
            .ok_or_else(|| anyhow!("calibrate_ranges() not run"))?;
        let (s, o) = ranges.qparams(i, bits)?;
        let (_, qmax) = quant::act_qrange(bits);
        let row = [s, o, 0.0, qmax, 1.0];
        self.act_rows.borrow_mut().insert((i, bits), row);
        Ok(row)
    }
}
