//! The evaluation engine — a shared, memoizing, streaming evaluator that
//! every Phase-1 probe and Phase-2 prefix evaluation routes through.
//!
//! The paper's practicality claim (Table 5) rests on search runtime, and in
//! this reproduction >95% of wall time is the `O(groups × candidates)`
//! Phase-1 probe sweep plus the Phase-2 prefix evaluations.  The engine
//! removes every redundancy in that path:
//!
//! * **Reference cache** ([`reference::FpReference`], held per
//!   `(model, eval-set)` in [`HandleEngine`]) — the FP32 logits and
//!   per-sample signal power Eq. 3 needs are computed by *one* forward sweep
//!   and reused by every probe, so a full Phase-1 sweep costs exactly
//!   `1 + probes` forward-sweep-equivalents.
//! * **Streaming metrics** ([`reference::StreamingSqnr`],
//!   [`crate::metrics::StreamingTaskMetric`]) — SQNR and task metrics are
//!   accumulated batch-by-batch, eliminating the per-probe `O(N×C)` host
//!   concatenation the old `logits_on` path materialized.
//! * **Memoization** ([`Memo`]) — results are cached by the canonical
//!   per-quantizer configuration, so a prefix the binary/interpolation
//!   search already measured (including `SearchCtx::finish`'s final
//!   re-evaluation) costs zero additional forward calls.  Hit/miss counters
//!   feed the Table-5 run-time accounting next to `fwd_calls`.
//! * **Incremental materialization** ([`patch::Materializer`]) — probe
//!   configurations differ from the FP32 baseline in one group's rows, so
//!   packed quant-param tensors are patched from a cached baseline instead
//!   of being recomputed row-by-row per probe.
//!
//! §Perf — pool architecture: the engine itself stays single-threaded (its
//! caches sit behind `RefCell` next to a `!Send` PJRT client), and
//! [`crate::pool::EvalPool`] scales it horizontally by giving each of N
//! worker threads a *private* engine + client + eval-set shard.  The
//! division of labour: per-worker `HandleEngine`s cache shard references
//! and patch shard configs; the pool front-end holds the cross-worker
//! probe memo (a probe measured once is memoized for every later
//! submitter, across sweeps and searches).  Exactness: shard partials are
//! per-batch sums keyed by global batch index ([`StreamingSqnr`]) or
//! integer counts (`StreamingTaskMetric`), reduced in batch order, so a
//! pooled evaluation is bit-identical to the serial one for SQNR and the
//! counting metrics (Pearson combines to float rounding).

pub mod patch;
pub mod reference;

pub use patch::Materializer;
pub use reference::{FpReference, StreamingSqnr};

use crate::manifest::ModelEntry;
use crate::model::{EvalSet, ModelHandle, QuantConfig, WeightOverrides};
use anyhow::Result;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

/// References kept per model before the least-recently-used entry is
/// evicted.  Fig-2-style studies recalibrate dozens of times, each with a
/// fresh eval set; an unbounded cache would pin every old set's logits.
const MAX_CACHED_REFERENCES: usize = 4;

/// LRU cache of FP32 references keyed by [`EvalSet::id`].
///
/// Eviction is single-entry: when the cache is full, only the
/// least-recently-used reference is dropped, so a hot reference (the set a
/// sweep is actively probing) survives the churn of one-shot sets instead
/// of being flushed wholesale.
struct RefCache {
    map: HashMap<u64, (u64, Rc<FpReference>)>,
    clock: u64,
}

impl RefCache {
    fn new() -> Self {
        Self { map: HashMap::new(), clock: 0 }
    }

    fn get(&mut self, id: u64) -> Option<Rc<FpReference>> {
        self.clock += 1;
        let now = self.clock;
        self.map.get_mut(&id).map(|e| {
            e.0 = now;
            e.1.clone()
        })
    }

    fn insert(&mut self, id: u64, r: Rc<FpReference>) {
        if self.map.len() >= MAX_CACHED_REFERENCES && !self.map.contains_key(&id) {
            if let Some(evict) = self
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(&k, _)| k)
            {
                self.map.remove(&evict);
            }
        }
        self.clock += 1;
        self.map.insert(id, (self.clock, r));
    }
}

/// Per-[`ModelHandle`] engine state: the FP32 reference cache and the
/// incremental config materializer.  Lives on the handle so the caches are
/// shared by every [`Evaluator`], search and sensitivity sweep on the model
/// — and, in a [`crate::pool::EvalPool`], per worker: each worker's handle
/// caches the references for *its shard* of each eval set, so a pooled
/// reference build costs one full-set sweep split across the workers.
pub struct HandleEngine {
    /// incremental packed-tensor materializer (row patching)
    pub mat: Materializer,
    /// FP32 reference per eval set, keyed by [`EvalSet::id`] (LRU)
    refs: RefCell<RefCache>,
    /// reference forward sweeps actually performed
    pub ref_builds: Cell<u64>,
    /// reference requests served from cache
    pub ref_hits: Cell<u64>,
}

impl HandleEngine {
    pub fn new(entry: &ModelEntry) -> Self {
        Self {
            mat: Materializer::new(entry),
            refs: RefCell::new(RefCache::new()),
            ref_builds: Cell::new(0),
            ref_hits: Cell::new(0),
        }
    }

    /// Seed the cache for `set_id` with an externally built reference
    /// (restored from the on-disk reference cache) — later
    /// [`Self::reference`] calls are hits, no forward sweep runs.
    pub fn install_reference(&self, set_id: u64, r: FpReference) {
        self.refs.borrow_mut().insert(set_id, Rc::new(r));
    }

    /// The FP32 reference for `set`, building it with one forward sweep on
    /// first use.  The reference depends only on the trained weights, so it
    /// stays valid across recalibrations of the quantizer ranges.
    pub fn reference(&self, handle: &ModelHandle, set: &EvalSet) -> Result<Rc<FpReference>> {
        if let Some(r) = self.refs.borrow_mut().get(set.id) {
            self.ref_hits.set(self.ref_hits.get() + 1);
            return Ok(r);
        }
        let r = Rc::new(FpReference::build(handle, set)?);
        self.ref_builds.set(self.ref_builds.get() + 1);
        self.refs.borrow_mut().insert(set.id, r.clone());
        Ok(r)
    }
}

/// Evaluation memo keyed by the canonical per-quantizer configuration.
///
/// Kept as its own type (rather than a bare map inside [`Evaluator`]) so the
/// never-recompute contract is unit-testable without a PJRT model: the
/// compute closure must not run again for a key that was already measured.
#[derive(Default)]
pub struct Memo {
    map: RefCell<HashMap<QuantConfig, f64>>,
    hits: Cell<usize>,
    misses: Cell<usize>,
}

impl Memo {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cached value for `cfg`, if it was already measured.
    pub fn get(&self, cfg: &QuantConfig) -> Option<f64> {
        self.map.borrow().get(cfg).copied()
    }

    /// Return the cached value for `cfg` or compute-and-insert it with `f`.
    pub fn get_or_try_insert_with(
        &self,
        cfg: &QuantConfig,
        f: impl FnOnce() -> Result<f64>,
    ) -> Result<f64> {
        if let Some(v) = self.get(cfg) {
            self.hits.set(self.hits.get() + 1);
            return Ok(v);
        }
        let v = f()?;
        self.misses.set(self.misses.get() + 1);
        self.map.borrow_mut().insert(cfg.clone(), v);
        Ok(v)
    }

    /// Evaluations served from cache.
    pub fn hits(&self) -> usize {
        self.hits.get()
    }

    /// Evaluations actually computed.
    pub fn misses(&self) -> usize {
        self.misses.get()
    }
}

/// The shared evaluator: streams metrics batch-by-batch against the cached
/// FP32 reference and memoizes task-metric results per configuration.
///
/// One `Evaluator` is created per sensitivity sweep / search run, so its
/// `evals`/`memo_hits` counters are per-run accounting (Table 5); the
/// expensive caches (reference, materializer rows) live on the
/// [`ModelHandle`] and are shared across evaluators.
pub struct Evaluator<'a> {
    pub handle: &'a ModelHandle,
    pub set: &'a EvalSet,
    memo: Memo,
}

impl<'a> Evaluator<'a> {
    pub fn new(handle: &'a ModelHandle, set: &'a EvalSet) -> Self {
        Self { handle, set, memo: Memo::new() }
    }

    /// The FP32 reference for this evaluator's set (cached on the handle).
    pub fn reference(&self) -> Result<Rc<FpReference>> {
        self.handle.engine.reference(self.handle, self.set)
    }

    /// Distinct full eval-set metric evaluations performed.
    pub fn evals(&self) -> usize {
        self.memo.misses()
    }

    /// Metric evaluations served from the memo.
    pub fn memo_hits(&self) -> usize {
        self.memo.hits()
    }

    /// Memoized metric for `cfg`, if it was already measured.
    pub fn cached(&self, cfg: &QuantConfig) -> Option<f64> {
        self.memo.get(cfg)
    }

    /// Task metric of `cfg`, streamed batch-by-batch and memoized by the
    /// canonical per-quantizer configuration.
    ///
    /// `overrides` must be a pure function of `cfg` within one evaluator's
    /// lifetime (true for both AdaRound probe stitching and Phase-2 prefix
    /// stitching) — the memo key is the configuration alone.
    pub fn metric(&self, cfg: &QuantConfig, overrides: &WeightOverrides) -> Result<f64> {
        self.memo.get_or_try_insert_with(cfg, || {
            let cb = self.handle.config_buffers(cfg, overrides)?;
            self.handle.eval_metric(self.set, &cb)
        })
    }

    /// Network-output SQNR of `cfg` against the cached FP32 reference
    /// (Eq. 3), streamed batch-by-batch — no host concatenation, no repeated
    /// FP reference sweep.
    pub fn sqnr(&self, cfg: &QuantConfig, overrides: &WeightOverrides) -> Result<f64> {
        let fp = self.reference()?;
        let cb = self.handle.config_buffers(cfg, overrides)?;
        let mut s = StreamingSqnr::new();
        for (bi, xb) in self.set.batches.iter().enumerate() {
            let q = self.handle.forward(xb, &cb)?;
            s.push(&fp.batches[bi], &fp.sig_pow[bi], &q)?;
        }
        Ok(s.db())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(bits: Option<u8>) -> QuantConfig {
        QuantConfig { act: vec![bits; 3], w: vec![bits; 2] }
    }

    fn dummy_ref() -> Rc<FpReference> {
        Rc::new(FpReference { batches: vec![], sig_pow: vec![], shape: vec![0] })
    }

    /// Eviction must be least-recently-used and single-entry: a hot
    /// reference survives a cache-filling insert; exactly one cold entry
    /// (the LRU one) is dropped.
    #[test]
    fn reference_cache_evicts_single_lru_entry() {
        let mut c = RefCache::new();
        for id in 0..MAX_CACHED_REFERENCES as u64 {
            c.insert(id, dummy_ref());
        }
        // touch 0 → hottest; 1 becomes the LRU entry
        assert!(c.get(0).is_some());
        c.insert(99, dummy_ref());
        assert!(c.get(0).is_some(), "hot entry must survive eviction");
        assert_eq!(c.map.len(), MAX_CACHED_REFERENCES);
        assert!(c.get(1).is_none(), "the LRU entry is the one evicted");
        for id in [2u64, 3, 99] {
            assert!(c.get(id).is_some(), "entry {id} wrongly evicted");
        }
    }

    #[test]
    fn reference_cache_reinsert_does_not_evict() {
        let mut c = RefCache::new();
        for id in 0..MAX_CACHED_REFERENCES as u64 {
            c.insert(id, dummy_ref());
        }
        // overwriting a resident id must not push anything out
        c.insert(0, dummy_ref());
        assert_eq!(c.map.len(), MAX_CACHED_REFERENCES);
        for id in 0..MAX_CACHED_REFERENCES as u64 {
            assert!(c.get(id).is_some());
        }
    }

    #[test]
    fn memo_never_recomputes_a_measured_key() {
        let memo = Memo::new();
        let mut calls = 0usize;
        for _ in 0..5 {
            let v = memo
                .get_or_try_insert_with(&key(Some(8)), || {
                    calls += 1;
                    Ok(42.0)
                })
                .unwrap();
            assert_eq!(v, 42.0);
        }
        assert_eq!(calls, 1, "compute closure ran again for a cached key");
        assert_eq!(memo.misses(), 1);
        assert_eq!(memo.hits(), 4);
    }

    #[test]
    fn memo_distinguishes_configs() {
        let memo = Memo::new();
        let a = memo.get_or_try_insert_with(&key(Some(4)), || Ok(1.0)).unwrap();
        let b = memo.get_or_try_insert_with(&key(Some(8)), || Ok(2.0)).unwrap();
        let c = memo.get_or_try_insert_with(&key(None), || Ok(3.0)).unwrap();
        assert_eq!((a, b, c), (1.0, 2.0, 3.0));
        assert_eq!(memo.misses(), 3);
        assert_eq!(memo.get(&key(Some(4))), Some(1.0));
        assert_eq!(memo.get(&key(Some(16))), None);
    }

    #[test]
    fn memo_error_is_not_cached() {
        let memo = Memo::new();
        let r = memo.get_or_try_insert_with(&key(Some(8)), || anyhow::bail!("boom"));
        assert!(r.is_err());
        // a later successful compute must run and be cached
        let v = memo.get_or_try_insert_with(&key(Some(8)), || Ok(7.0)).unwrap();
        assert_eq!(v, 7.0);
        assert_eq!(memo.misses(), 1);
    }
}
