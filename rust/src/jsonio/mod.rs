//! Minimal JSON reader/writer.
//!
//! The offline crate set has no `serde_json`, so the manifest interchange
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) is parsed
//! by this hand-rolled recursive-descent parser.  It supports the full JSON
//! grammar; numbers are kept as `f64` (the manifest never exceeds 2^53).

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Ok(kv),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing data at byte {}", p.i);
    }
    Ok(v)
}

pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
    let src = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
    parse(&src)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: only BMP needed for manifests,
                            // but handle pairs for completeness
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.i + 2..self.i + 6],
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    bail!("lone surrogate");
                                }
                            } else {
                                cp
                            };
                            s.push(char::from_u32(ch)
                                .ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x20 => bail!("raw control char in string"),
                c => {
                    // re-assemble multibyte utf-8
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": "x\ny"}"#;
        let j = parse(src).unwrap();
        assert_eq!(j.req("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.req("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.req("c").unwrap().as_str().unwrap(), "x\ny");
        // write → parse fixpoint
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn nested_objects() {
        let j = parse(r#"{"m":{"n":{"o":[{"p":7}]}}}"#).unwrap();
        let p = j.req("m").unwrap().req("n").unwrap().req("o").unwrap();
        assert_eq!(p.as_arr().unwrap()[0].req("p").unwrap().as_usize().unwrap(), 7);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn number_forms() {
        for (s, v) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0)] {
            assert_eq!(parse(s).unwrap().as_f64().unwrap(), v);
        }
    }

    #[test]
    fn utf8_passthrough() {
        let j = parse("\"héllo → ∞\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → ∞");
    }
}
