//! Task metrics (mirroring `python/compile/train.py::metric`) plus the
//! statistical tools the paper's analysis uses: Kendall-τ (Fig. 2d) and
//! Pearson correlation (STS-B).

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Evaluate the task metric for raw logits against labels.
///
/// * `classify10`, `glue:rte_s/sst2_s/mnli_s` → top-1 accuracy
/// * `glue:mrpc_s` → F1 of the positive class (paper Table 3 reports F1)
/// * `glue:stsb_s` → Pearson correlation of the scalar head
/// * `seg`        → mean IoU over the 3 classes
pub fn task_metric(task: &str, logits: &Tensor, labels: &Tensor) -> Result<f64> {
    match task {
        "seg" => miou(logits, labels, 3),
        "glue:mrpc_s" => f1_binary(logits, labels),
        "glue:stsb_s" => pearson_head(logits, labels),
        "classify10" | "glue:rte_s" | "glue:sst2_s" | "glue:mnli_s" => {
            top1(logits, labels)
        }
        t => bail!("unknown task '{t}'"),
    }
}

/// Top-1 accuracy; logits `[N, C]`, labels f32 class indices `[N]`.
pub fn top1(logits: &Tensor, labels: &Tensor) -> Result<f64> {
    let (n, c) = two_d(logits)?;
    let lv = logits.f32s()?;
    let yv = labels.f32s()?;
    if yv.len() != n {
        bail!("labels len {} != n {}", yv.len(), n);
    }
    let mut hits = 0usize;
    for i in 0..n {
        let row = &lv[i * c..(i + 1) * c];
        let pred = argmax(row);
        if pred == yv[i] as usize {
            hits += 1;
        }
    }
    Ok(hits as f64 / n as f64)
}

/// F1 of class 1 for binary logits `[N, 2]`.
pub fn f1_binary(logits: &Tensor, labels: &Tensor) -> Result<f64> {
    let (n, c) = two_d(logits)?;
    if c != 2 {
        bail!("f1 expects 2 classes, got {c}");
    }
    let lv = logits.f32s()?;
    let yv = labels.f32s()?;
    let (mut tp, mut fp, mut fnn) = (0f64, 0f64, 0f64);
    for i in 0..n {
        let pred = argmax(&lv[i * 2..i * 2 + 2]) == 1;
        let pos = yv[i] as usize == 1;
        match (pred, pos) {
            (true, true) => tp += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fnn += 1.0,
            _ => {}
        }
    }
    let denom = 2.0 * tp + fp + fnn;
    Ok(if denom > 0.0 { 2.0 * tp / denom } else { 0.0 })
}

/// Pearson correlation of logits `[N, 1]` against scalar labels.
pub fn pearson_head(logits: &Tensor, labels: &Tensor) -> Result<f64> {
    let (n, _) = two_d(logits)?;
    let lv = logits.f32s()?;
    let c = logits.shape[1];
    let preds: Vec<f64> = (0..n).map(|i| lv[i * c] as f64).collect();
    let ys: Vec<f64> = labels.f32s()?.iter().map(|&x| x as f64).collect();
    Ok(pearson(&preds, &ys))
}

/// Mean IoU; logits `[N, C, H, W]`, labels i32 `[N, H, W]`.
pub fn miou(logits: &Tensor, labels: &Tensor, classes: usize) -> Result<f64> {
    if logits.shape.len() != 4 {
        bail!("miou expects [N,C,H,W], got {:?}", logits.shape);
    }
    let (n, c, h, w) = (
        logits.shape[0],
        logits.shape[1],
        logits.shape[2],
        logits.shape[3],
    );
    if c != classes {
        bail!("expected {classes} classes, got {c}");
    }
    let lv = logits.f32s()?;
    let yv = labels.i32s()?;
    if yv.len() != n * h * w {
        bail!("labels numel {} != {}", yv.len(), n * h * w);
    }
    let mut inter = vec![0f64; classes];
    let mut union = vec![0f64; classes];
    let plane = h * w;
    for i in 0..n {
        for p in 0..plane {
            // argmax over channel axis
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for ch in 0..classes {
                let v = lv[(i * c + ch) * plane + p];
                if v > bv {
                    bv = v;
                    best = ch;
                }
            }
            let t = yv[i * plane + p] as usize;
            for ch in 0..classes {
                let pr = best == ch;
                let gt = t == ch;
                if pr && gt {
                    inter[ch] += 1.0;
                }
                if pr || gt {
                    union[ch] += 1.0;
                }
            }
        }
    }
    let ious: Vec<f64> = (0..classes)
        .filter(|&ch| union[ch] > 0.0)
        .map(|ch| inter[ch] / union[ch])
        .collect();
    Ok(if ious.is_empty() { 0.0 } else { ious.iter().sum::<f64>() / ious.len() as f64 })
}

/// Pearson correlation of two equal-length vectors.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let (x, y) = (a[i] - ma, b[i] - mb);
        num += x * y;
        da += x * x;
        db += y * y;
    }
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da * db).sqrt()
    }
}

/// Kendall-τ (τ-a) rank correlation — Fig. 2(d)'s sensitivity-list quality
/// score.  O(n²), fine for lists of ≤ a few hundred quantizers.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let mut conc = 0i64;
    let mut disc = 0i64;
    for i in 0..n {
        for j in i + 1..n {
            let sx = (a[i] - a[j]).signum();
            let sy = (b[i] - b[j]).signum();
            let prod = sx * sy;
            if prod > 0.0 {
                conc += 1;
            } else if prod < 0.0 {
                disc += 1;
            }
        }
    }
    let total = (n * (n - 1) / 2) as f64;
    (conc - disc) as f64 / total
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

fn two_d(t: &Tensor) -> Result<(usize, usize)> {
    if t.shape.len() != 2 {
        bail!("expected 2-D logits, got {:?}", t.shape);
    }
    Ok((t.shape[0], t.shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_basic() {
        let l = Tensor::from_f32(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 2.0, 1.0]).unwrap();
        let y = Tensor::from_f32(&[3], vec![0.0, 1.0, 1.0]).unwrap();
        assert!((top1(&l, &y).unwrap() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        let l = Tensor::from_f32(&[2, 2], vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        let y = Tensor::from_f32(&[2], vec![1.0, 0.0]).unwrap();
        assert_eq!(f1_binary(&l, &y).unwrap(), 1.0);
        let y0 = Tensor::from_f32(&[2], vec![0.0, 0.0]).unwrap();
        let l0 = Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 1.0, 0.0]).unwrap();
        assert_eq!(f1_binary(&l0, &y0).unwrap(), 0.0);
    }

    #[test]
    fn pearson_signs() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_tau_extremes() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert!((kendall_tau(&a, &b) - 1.0).abs() < 1e-12);
        let r: Vec<f64> = b.iter().rev().copied().collect();
        assert!((kendall_tau(&a, &r) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_tau_partial() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 3.0, 2.0];
        assert!((kendall_tau(&a, &b) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn miou_perfect() {
        // 1 sample, 2x2, 3 classes; logits one-hot matching labels
        let mut lv = vec![0f32; 3 * 4];
        let labels = [0i32, 1, 2, 1];
        for (p, &t) in labels.iter().enumerate() {
            lv[(t as usize) * 4 + p] = 1.0;
        }
        let l = Tensor::from_f32(&[1, 3, 2, 2], lv).unwrap();
        let y = Tensor::from_i32(&[1, 2, 2], labels.to_vec()).unwrap();
        assert!((miou(&l, &y, 3).unwrap() - 1.0).abs() < 1e-12);
    }
}
