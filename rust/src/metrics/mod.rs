//! Task metrics (mirroring `python/compile/train.py::metric`) plus the
//! statistical tools the paper's analysis uses: Kendall-τ (Fig. 2d) and
//! Pearson correlation (STS-B).

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Evaluate the task metric for raw logits against labels.
///
/// * `classify10`, `glue:rte_s/sst2_s/mnli_s` → top-1 accuracy
/// * `glue:mrpc_s` → F1 of the positive class (paper Table 3 reports F1)
/// * `glue:stsb_s` → Pearson correlation of the scalar head
/// * `seg`        → mean IoU over the 3 classes
pub fn task_metric(task: &str, logits: &Tensor, labels: &Tensor) -> Result<f64> {
    // one-shot = streaming accumulator fed a single batch, so the task menu
    // (and e.g. seg's class count) exists in exactly one place
    let mut acc = StreamingTaskMetric::new(task)?;
    acc.push(logits, labels)?;
    Ok(acc.finalize())
}

/// Top-1 accuracy; logits `[N, C]`, labels f32 class indices `[N]`.
pub fn top1(logits: &Tensor, labels: &Tensor) -> Result<f64> {
    let (n, hits) = top1_counts(logits, labels)?;
    Ok(hits as f64 / n as f64)
}

/// `(samples, correct)` — the streamable core of [`top1`].
fn top1_counts(logits: &Tensor, labels: &Tensor) -> Result<(usize, usize)> {
    let (n, c) = two_d(logits)?;
    let lv = logits.f32s()?;
    let yv = labels.f32s()?;
    if yv.len() != n {
        bail!("labels len {} != n {}", yv.len(), n);
    }
    let mut hits = 0usize;
    for i in 0..n {
        let row = &lv[i * c..(i + 1) * c];
        let pred = argmax(row);
        if pred == yv[i] as usize {
            hits += 1;
        }
    }
    Ok((n, hits))
}

/// F1 of class 1 for binary logits `[N, 2]`.
pub fn f1_binary(logits: &Tensor, labels: &Tensor) -> Result<f64> {
    let (tp, fp, fnn) = f1_counts(logits, labels)?;
    Ok(f1_from_counts(tp, fp, fnn))
}

/// `(tp, fp, fn)` for the positive class — the streamable core of
/// [`f1_binary`].
fn f1_counts(logits: &Tensor, labels: &Tensor) -> Result<(f64, f64, f64)> {
    let (n, c) = two_d(logits)?;
    if c != 2 {
        bail!("f1 expects 2 classes, got {c}");
    }
    let lv = logits.f32s()?;
    let yv = labels.f32s()?;
    let (mut tp, mut fp, mut fnn) = (0f64, 0f64, 0f64);
    for i in 0..n {
        let pred = argmax(&lv[i * 2..i * 2 + 2]) == 1;
        let pos = yv[i] as usize == 1;
        match (pred, pos) {
            (true, true) => tp += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fnn += 1.0,
            _ => {}
        }
    }
    Ok((tp, fp, fnn))
}

fn f1_from_counts(tp: f64, fp: f64, fnn: f64) -> f64 {
    let denom = 2.0 * tp + fp + fnn;
    if denom > 0.0 {
        2.0 * tp / denom
    } else {
        0.0
    }
}

/// Pearson correlation of logits `[N, 1]` against scalar labels.
pub fn pearson_head(logits: &Tensor, labels: &Tensor) -> Result<f64> {
    let (n, _) = two_d(logits)?;
    let lv = logits.f32s()?;
    let c = logits.shape[1];
    let preds: Vec<f64> = (0..n).map(|i| lv[i * c] as f64).collect();
    let ys: Vec<f64> = labels.f32s()?.iter().map(|&x| x as f64).collect();
    Ok(pearson(&preds, &ys))
}

/// Mean IoU; logits `[N, C, H, W]`, labels i32 `[N, H, W]`.
pub fn miou(logits: &Tensor, labels: &Tensor, classes: usize) -> Result<f64> {
    let mut inter = vec![0f64; classes];
    let mut union = vec![0f64; classes];
    miou_accumulate(logits, labels, classes, &mut inter, &mut union)?;
    Ok(miou_from_counts(classes, &inter, &union))
}

/// Fold one batch's per-class intersection/union counts into
/// `inter`/`union` — the streamable core of [`miou`].
fn miou_accumulate(
    logits: &Tensor,
    labels: &Tensor,
    classes: usize,
    inter: &mut [f64],
    union: &mut [f64],
) -> Result<()> {
    if logits.shape.len() != 4 {
        bail!("miou expects [N,C,H,W], got {:?}", logits.shape);
    }
    let (n, c, h, w) = (
        logits.shape[0],
        logits.shape[1],
        logits.shape[2],
        logits.shape[3],
    );
    if c != classes {
        bail!("expected {classes} classes, got {c}");
    }
    let lv = logits.f32s()?;
    let yv = labels.i32s()?;
    if yv.len() != n * h * w {
        bail!("labels numel {} != {}", yv.len(), n * h * w);
    }
    let plane = h * w;
    for i in 0..n {
        for p in 0..plane {
            // argmax over channel axis
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for ch in 0..classes {
                let v = lv[(i * c + ch) * plane + p];
                if v > bv {
                    bv = v;
                    best = ch;
                }
            }
            let t = yv[i * plane + p] as usize;
            for ch in 0..classes {
                let pr = best == ch;
                let gt = t == ch;
                if pr && gt {
                    inter[ch] += 1.0;
                }
                if pr || gt {
                    union[ch] += 1.0;
                }
            }
        }
    }
    Ok(())
}

fn miou_from_counts(classes: usize, inter: &[f64], union: &[f64]) -> f64 {
    let ious: Vec<f64> = (0..classes)
        .filter(|&ch| union[ch] > 0.0)
        .map(|ch| inter[ch] / union[ch])
        .collect();
    if ious.is_empty() {
        0.0
    } else {
        ious.iter().sum::<f64>() / ious.len() as f64
    }
}

/// Streaming task-metric accumulator: fold in per-batch logits/labels, then
/// [`Self::finalize`] — same result as [`task_metric`] on the concatenated
/// logits (exactly for the counting metrics, to float precision for the
/// Pearson head) without ever materializing the concatenation.  This is
/// what lets the evaluation engine keep Phase-1/Phase-2 metric passes
/// `O(batch)` in host memory.
pub enum StreamingTaskMetric {
    Top1 { hits: usize, n: usize },
    F1 { tp: f64, fp: f64, fnn: f64 },
    Pearson(PearsonAccum),
    Miou { classes: usize, inter: Vec<f64>, union: Vec<f64> },
}

impl StreamingTaskMetric {
    /// Accumulator for a manifest task string (same menu as [`task_metric`]).
    pub fn new(task: &str) -> Result<Self> {
        Ok(match task {
            "seg" => Self::Miou { classes: 3, inter: vec![0.0; 3], union: vec![0.0; 3] },
            "glue:mrpc_s" => Self::F1 { tp: 0.0, fp: 0.0, fnn: 0.0 },
            "glue:stsb_s" => Self::Pearson(PearsonAccum::default()),
            "classify10" | "glue:rte_s" | "glue:sst2_s" | "glue:mnli_s" => {
                Self::Top1 { hits: 0, n: 0 }
            }
            t => bail!("unknown task '{t}'"),
        })
    }

    /// Fold in one batch of logits and its labels.
    pub fn push(&mut self, logits: &Tensor, labels: &Tensor) -> Result<()> {
        match self {
            Self::Top1 { hits, n } => {
                let (bn, h) = top1_counts(logits, labels)?;
                *hits += h;
                *n += bn;
            }
            Self::F1 { tp, fp, fnn } => {
                let (a, b, c) = f1_counts(logits, labels)?;
                *tp += a;
                *fp += b;
                *fnn += c;
            }
            Self::Pearson(p) => {
                let (n, c) = two_d(logits)?;
                let lv = logits.f32s()?;
                let yv = labels.f32s()?;
                if yv.len() != n {
                    bail!("labels len {} != n {}", yv.len(), n);
                }
                for i in 0..n {
                    p.push(lv[i * c] as f64, yv[i] as f64);
                }
            }
            Self::Miou { classes, inter, union } => {
                miou_accumulate(logits, labels, *classes, inter, union)?;
            }
        }
        Ok(())
    }

    /// The metric over everything pushed so far.
    pub fn finalize(&self) -> f64 {
        match self {
            Self::Top1 { hits, n } => {
                if *n == 0 {
                    0.0
                } else {
                    *hits as f64 / *n as f64
                }
            }
            Self::F1 { tp, fp, fnn } => f1_from_counts(*tp, *fp, *fnn),
            Self::Pearson(p) => p.r(),
            Self::Miou { classes, inter, union } => miou_from_counts(*classes, inter, union),
        }
    }

    /// Fold another accumulator — fed a *disjoint shard* of the same eval
    /// set — into this one, so per-worker partials reduce to the full-set
    /// metric ([`crate::pool::EvalPool`] merges shard partials in shard
    /// order).
    ///
    /// Exactness: the counting metrics (top-1, F1, mIoU) accumulate integer
    /// counts, so the merged result is *bit-identical* to single-pass
    /// accumulation regardless of how the set was sharded.  The Pearson head
    /// combines Welford states ([`PearsonAccum::merge`]), which matches the
    /// single-pass result to float rounding (same caveat [`task_metric`]
    /// already documents for streaming).
    pub fn merge(&mut self, other: &StreamingTaskMetric) -> Result<()> {
        match (self, other) {
            (Self::Top1 { hits, n }, Self::Top1 { hits: h2, n: n2 }) => {
                *hits += *h2;
                *n += *n2;
            }
            (Self::F1 { tp, fp, fnn }, Self::F1 { tp: a, fp: b, fnn: c }) => {
                *tp += *a;
                *fp += *b;
                *fnn += *c;
            }
            (Self::Pearson(p), Self::Pearson(q)) => p.merge(q),
            (
                Self::Miou { classes, inter, union },
                Self::Miou { classes: c2, inter: i2, union: u2 },
            ) => {
                if *classes != *c2 {
                    bail!("miou merge: {} classes vs {}", classes, c2);
                }
                for (x, y) in inter.iter_mut().zip(i2) {
                    *x += *y;
                }
                for (x, y) in union.iter_mut().zip(u2) {
                    *x += *y;
                }
            }
            _ => bail!("cannot merge task accumulators of different tasks"),
        }
        Ok(())
    }
}

/// Single-pass Pearson correlation via Welford-style co-moment updates —
/// numerically stable without a second pass over the predictions.
#[derive(Clone, Default)]
pub struct PearsonAccum {
    n: f64,
    mx: f64,
    my: f64,
    m2x: f64,
    m2y: f64,
    cxy: f64,
}

impl PearsonAccum {
    pub fn push(&mut self, x: f64, y: f64) {
        self.n += 1.0;
        let dx = x - self.mx;
        self.mx += dx / self.n;
        let dy = y - self.my;
        self.my += dy / self.n;
        self.m2x += dx * (x - self.mx);
        self.cxy += dx * (y - self.my);
        self.m2y += dy * (y - self.my);
    }

    /// Combine with another accumulator over a disjoint sample set
    /// (Chan et al. parallel co-moment update).  Equal to pushing the other
    /// accumulator's samples one-by-one up to float rounding.
    pub fn merge(&mut self, o: &PearsonAccum) {
        if o.n == 0.0 {
            return;
        }
        if self.n == 0.0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let dx = o.mx - self.mx;
        let dy = o.my - self.my;
        let w = self.n * o.n / n;
        self.m2x += o.m2x + dx * dx * w;
        self.m2y += o.m2y + dy * dy * w;
        self.cxy += o.cxy + dx * dy * w;
        self.mx += dx * o.n / n;
        self.my += dy * o.n / n;
        self.n = n;
    }

    pub fn r(&self) -> f64 {
        if self.n < 2.0 || self.m2x == 0.0 || self.m2y == 0.0 {
            0.0
        } else {
            self.cxy / (self.m2x * self.m2y).sqrt()
        }
    }

    /// The raw Welford state `[n, mx, my, m2x, m2y, cxy]` for wire
    /// transport — shipped bit-exact so a process-lane merge reproduces the
    /// in-process result to the last ulp.
    pub(crate) fn raw(&self) -> [f64; 6] {
        [self.n, self.mx, self.my, self.m2x, self.m2y, self.cxy]
    }

    /// Rebuild from [`Self::raw`] output (inverse, bit-exact).
    pub(crate) fn from_raw(v: [f64; 6]) -> Self {
        Self { n: v[0], mx: v[1], my: v[2], m2x: v[3], m2y: v[4], cxy: v[5] }
    }
}

/// Pearson correlation of two equal-length vectors.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let (x, y) = (a[i] - ma, b[i] - mb);
        num += x * y;
        da += x * x;
        db += y * y;
    }
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da * db).sqrt()
    }
}

/// Kendall-τ (τ-a) rank correlation — Fig. 2(d)'s sensitivity-list quality
/// score.
///
/// O(n log n) via Knight's algorithm (Knight 1966): sort by `(a, b)`, count
/// strict inversions of the `b` sequence with a merge sort (each inversion
/// is exactly one strictly discordant pair), and correct for ties, which
/// are neither concordant nor discordant (standard τ-a):
///
/// `C − D = n0 − n1 − n2 + n3 − 2·inversions`
///
/// with `n0` all pairs, `n1`/`n2` pairs tied in `a`/`b`, `n3` pairs tied in
/// both.  The counts are exact integers, so on tie-free data the result is
/// bit-identical to the quadratic pair scan this replaced.  On ties it
/// *fixes* that scan: `f64::signum(+0.0) == 1.0`, so the old code counted
/// a tied pair as concordant or discordant depending on element order —
/// here tied pairs contribute zero, matching Kendall's definition.
/// Comparisons use IEEE total order, so NaN scores sort deterministically
/// as their own value class instead of silently dropping pairs.
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| a[i].total_cmp(&a[j]).then(b[i].total_cmp(&b[j])));

    let pairs = |t: u64| t * t.saturating_sub(1) / 2;
    // n1 (ties in a) and n3 (joint ties): groups are contiguous after the
    // (a, b) sort.
    let (mut n1, mut n3) = (0u64, 0u64);
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && a[idx[j]].total_cmp(&a[idx[i]]).is_eq() {
            j += 1;
        }
        n1 += pairs((j - i) as u64);
        let mut k = i;
        while k < j {
            let mut l = k + 1;
            while l < j && b[idx[l]].total_cmp(&b[idx[k]]).is_eq() {
                l += 1;
            }
            n3 += pairs((l - k) as u64);
            k = l;
        }
        i = j;
    }

    // b in a-sorted order; the merge sort counts inversions and leaves the
    // slice sorted, which the n2 (ties in b) pass reuses.
    let mut bs: Vec<f64> = idx.iter().map(|&i| b[i]).collect();
    let mut buf = bs.clone();
    let inversions = sort_count_inversions(&mut bs, &mut buf);
    let mut n2 = 0u64;
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && bs[j].total_cmp(&bs[i]).is_eq() {
            j += 1;
        }
        n2 += pairs((j - i) as u64);
        i = j;
    }

    let n0 = pairs(n as u64);
    let num = n0 as i128 - n1 as i128 - n2 as i128 + n3 as i128 - 2 * inversions as i128;
    num as f64 / n0 as f64
}

/// Merge sort `v` ascending (IEEE total order), returning the number of
/// strict inversions (`i < j` with `v[i] > v[j]`).  `buf` is scratch of the
/// same length.
fn sort_count_inversions(v: &mut [f64], buf: &mut [f64]) -> u64 {
    let n = v.len();
    if n < 2 {
        return 0;
    }
    let mid = n / 2;
    let mut inv = {
        let (vl, vr) = v.split_at_mut(mid);
        let (bl, br) = buf.split_at_mut(mid);
        sort_count_inversions(vl, bl) + sort_count_inversions(vr, br)
    };
    let (mut i, mut j) = (0usize, mid);
    for slot in buf[..n].iter_mut() {
        if i < mid && (j >= n || !v[i].total_cmp(&v[j]).is_gt()) {
            *slot = v[i];
            i += 1;
        } else {
            if i < mid {
                // v[j] jumps ahead of every remaining left element
                inv += (mid - i) as u64;
            }
            *slot = v[j];
            j += 1;
        }
    }
    v.copy_from_slice(&buf[..n]);
    inv
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

fn two_d(t: &Tensor) -> Result<(usize, usize)> {
    if t.shape.len() != 2 {
        bail!("expected 2-D logits, got {:?}", t.shape);
    }
    Ok((t.shape[0], t.shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_basic() {
        let l = Tensor::from_f32(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 2.0, 1.0]).unwrap();
        let y = Tensor::from_f32(&[3], vec![0.0, 1.0, 1.0]).unwrap();
        assert!((top1(&l, &y).unwrap() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        let l = Tensor::from_f32(&[2, 2], vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        let y = Tensor::from_f32(&[2], vec![1.0, 0.0]).unwrap();
        assert_eq!(f1_binary(&l, &y).unwrap(), 1.0);
        let y0 = Tensor::from_f32(&[2], vec![0.0, 0.0]).unwrap();
        let l0 = Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 1.0, 0.0]).unwrap();
        assert_eq!(f1_binary(&l0, &y0).unwrap(), 0.0);
    }

    #[test]
    fn pearson_signs() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_tau_extremes() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert!((kendall_tau(&a, &b) - 1.0).abs() < 1e-12);
        let r: Vec<f64> = b.iter().rev().copied().collect();
        assert!((kendall_tau(&a, &r) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_tau_partial() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 3.0, 2.0];
        assert!((kendall_tau(&a, &b) - 1.0 / 3.0).abs() < 1e-12);
    }

    /// Streaming accumulation over batch splits must match the one-shot
    /// metric on the concatenated logits for every task type.
    #[test]
    fn streaming_matches_batch_metric() {
        let mut rng = crate::util::Rng::new(21);
        let n = 24usize;
        let bsz = 4usize;
        for task in ["classify10", "glue:mrpc_s", "glue:stsb_s", "seg"] {
            let (logits, labels) = match task {
                "seg" => {
                    let (c, h, w) = (3usize, 2usize, 2usize);
                    let lv: Vec<f32> =
                        (0..n * c * h * w).map(|_| rng.f64() as f32).collect();
                    let yv: Vec<i32> =
                        (0..n * h * w).map(|_| rng.below(c) as i32).collect();
                    (
                        Tensor::from_f32(&[n, c, h, w], lv).unwrap(),
                        Tensor::from_i32(&[n, h, w], yv).unwrap(),
                    )
                }
                "glue:stsb_s" => {
                    let lv: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 5.0).collect();
                    let yv: Vec<f32> = lv.iter().map(|&x| x + rng.f64() as f32).collect();
                    (
                        Tensor::from_f32(&[n, 1], lv).unwrap(),
                        Tensor::from_f32(&[n], yv).unwrap(),
                    )
                }
                _ => {
                    let c = if task == "classify10" { 10 } else { 2 };
                    let lv: Vec<f32> = (0..n * c).map(|_| rng.f64() as f32).collect();
                    let yv: Vec<f32> = (0..n).map(|_| rng.below(c) as f32).collect();
                    (
                        Tensor::from_f32(&[n, c], lv).unwrap(),
                        Tensor::from_f32(&[n], yv).unwrap(),
                    )
                }
            };
            let want = task_metric(task, &logits, &labels).unwrap();
            let mut acc = StreamingTaskMetric::new(task).unwrap();
            for start in (0..n).step_by(bsz) {
                acc.push(
                    &logits.slice_rows(start, bsz).unwrap(),
                    &labels.slice_rows(start, bsz).unwrap(),
                )
                .unwrap();
            }
            let got = acc.finalize();
            assert!(
                (got - want).abs() < 1e-9,
                "{task}: streaming {got} != batch {want}"
            );
        }
    }

    #[test]
    fn streaming_rejects_unknown_task() {
        assert!(StreamingTaskMetric::new("nope").is_err());
    }

    #[test]
    fn pearson_accum_matches_two_pass() {
        let a: Vec<f64> = (0..50).map(|i| (i as f64) * 0.3 - 2.0).collect();
        let b: Vec<f64> = a.iter().map(|x| 1.5 * x + (x * 7.0).sin()).collect();
        let mut acc = PearsonAccum::default();
        for (x, y) in a.iter().zip(&b) {
            acc.push(*x, *y);
        }
        assert!((acc.r() - pearson(&a, &b)).abs() < 1e-12);
    }

    /// Quadratic τ-a oracle with standard tie handling — tied pairs
    /// contribute nothing.  On tie-free data this is exactly the signum
    /// pair scan `kendall_tau` replaced; on ties it is what that scan
    /// *should* have computed (`signum(+0.0) == 1.0` made the old code's
    /// tied pairs count as ±1 depending on element order).
    fn kendall_tau_naive(a: &[f64], b: &[f64]) -> f64 {
        use std::cmp::Ordering;
        let n = a.len();
        if n < 2 {
            return 0.0;
        }
        let sign = |x: f64, y: f64| match x.partial_cmp(&y) {
            Some(Ordering::Greater) => 1i64,
            Some(Ordering::Less) => -1,
            _ => 0,
        };
        let mut num = 0i64;
        for i in 0..n {
            for j in i + 1..n {
                num += sign(a[i], a[j]) * sign(b[i], b[j]);
            }
        }
        num as f64 / (n * (n - 1) / 2) as f64
    }

    #[test]
    fn kendall_tau_matches_naive_with_ties() {
        let mut rng = crate::util::Rng::new(0xBEEF);
        for n in [2usize, 3, 5, 17, 64, 257] {
            // coarse grid → plenty of ties in both lists
            let a: Vec<f64> = (0..n).map(|_| rng.below(7) as f64).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.below(5) as f64).collect();
            let fast = kendall_tau(&a, &b);
            let naive = kendall_tau_naive(&a, &b);
            assert_eq!(fast.to_bits(), naive.to_bits(), "n={n}: {fast} vs {naive}");
            // continuous scores (no ties)
            let c: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let d: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            assert_eq!(kendall_tau(&c, &d).to_bits(), kendall_tau_naive(&c, &d).to_bits());
        }
    }

    #[test]
    fn kendall_tau_degenerate() {
        assert_eq!(kendall_tau(&[], &[]), 0.0);
        assert_eq!(kendall_tau(&[1.0], &[2.0]), 0.0);
        // all-tied lists: every pair is a joint tie → τ = 0
        assert_eq!(kendall_tau(&[3.0; 8], &[5.0; 8]), 0.0);
    }

    /// Shard-merged accumulators must reproduce the single-pass metric —
    /// exactly for the counting metrics, to float rounding for Pearson.
    #[test]
    fn merged_shards_match_single_pass() {
        let mut rng = crate::util::Rng::new(33);
        let n = 24usize;
        let bsz = 4usize;
        for task in ["classify10", "glue:mrpc_s", "glue:stsb_s", "seg"] {
            let (logits, labels) = match task {
                "seg" => {
                    let (c, h, w) = (3usize, 2usize, 2usize);
                    let lv: Vec<f32> =
                        (0..n * c * h * w).map(|_| rng.f64() as f32).collect();
                    let yv: Vec<i32> =
                        (0..n * h * w).map(|_| rng.below(c) as i32).collect();
                    (
                        Tensor::from_f32(&[n, c, h, w], lv).unwrap(),
                        Tensor::from_i32(&[n, h, w], yv).unwrap(),
                    )
                }
                "glue:stsb_s" => {
                    let lv: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 5.0).collect();
                    let yv: Vec<f32> = lv.iter().map(|&x| x + rng.f64() as f32).collect();
                    (
                        Tensor::from_f32(&[n, 1], lv).unwrap(),
                        Tensor::from_f32(&[n], yv).unwrap(),
                    )
                }
                _ => {
                    let c = if task == "classify10" { 10 } else { 2 };
                    let lv: Vec<f32> = (0..n * c).map(|_| rng.f64() as f32).collect();
                    let yv: Vec<f32> = (0..n).map(|_| rng.below(c) as f32).collect();
                    (
                        Tensor::from_f32(&[n, c], lv).unwrap(),
                        Tensor::from_f32(&[n], yv).unwrap(),
                    )
                }
            };
            let mut single = StreamingTaskMetric::new(task).unwrap();
            // three shards of 1, 2 and 3 batches — uneven like a real pool
            let mut shards: Vec<StreamingTaskMetric> =
                (0..3).map(|_| StreamingTaskMetric::new(task).unwrap()).collect();
            for (bi, start) in (0..n).step_by(bsz).enumerate() {
                let lb = logits.slice_rows(start, bsz).unwrap();
                let yb = labels.slice_rows(start, bsz).unwrap();
                single.push(&lb, &yb).unwrap();
                let shard = if bi < 1 { 0 } else if bi < 3 { 1 } else { 2 };
                shards[shard].push(&lb, &yb).unwrap();
            }
            let mut merged = StreamingTaskMetric::new(task).unwrap();
            for s in &shards {
                merged.merge(s).unwrap();
            }
            let (got, want) = (merged.finalize(), single.finalize());
            if task == "glue:stsb_s" {
                assert!((got - want).abs() < 1e-12, "{task}: {got} vs {want}");
            } else {
                assert_eq!(got.to_bits(), want.to_bits(), "{task}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn merge_rejects_mismatched_tasks() {
        let mut a = StreamingTaskMetric::new("classify10").unwrap();
        let b = StreamingTaskMetric::new("glue:mrpc_s").unwrap();
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn pearson_merge_matches_sequential() {
        let xs: Vec<f64> = (0..60).map(|i| (i as f64) * 0.7 - 3.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.4 * x + (x * 3.0).cos()).collect();
        let mut full = PearsonAccum::default();
        let mut left = PearsonAccum::default();
        let mut right = PearsonAccum::default();
        for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
            full.push(x, y);
            if i < 23 { left.push(x, y) } else { right.push(x, y) }
        }
        let mut merged = PearsonAccum::default();
        merged.merge(&left); // merge into empty = copy
        merged.merge(&right);
        assert!((merged.r() - full.r()).abs() < 1e-12);
        // merging an empty accumulator is a no-op
        merged.merge(&PearsonAccum::default());
        assert!((merged.r() - full.r()).abs() < 1e-12);
    }

    #[test]
    fn miou_perfect() {
        // 1 sample, 2x2, 3 classes; logits one-hot matching labels
        let mut lv = vec![0f32; 3 * 4];
        let labels = [0i32, 1, 2, 1];
        for (p, &t) in labels.iter().enumerate() {
            lv[(t as usize) * 4 + p] = 1.0;
        }
        let l = Tensor::from_f32(&[1, 3, 2, 2], lv).unwrap();
        let y = Tensor::from_i32(&[1, 2, 2], labels.to_vec()).unwrap();
        assert!((miou(&l, &y, 3).unwrap() - 1.0).abs() < 1e-12);
    }
}
