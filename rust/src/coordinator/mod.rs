//! The coordinator: wires runtime, calibration, Phase 1 and Phase 2 into
//! the end-to-end [`Pipeline`] — the paper's Algorithm 1 as a service.
//!
//! A `Pipeline` owns one model. Typical flow:
//!
//! ```no_run
//! # use mpq::coordinator::Pipeline;
//! # use mpq::groups::Lattice;
//! let mut pipe = Pipeline::open("artifacts", "mobilenet_v3_s").unwrap();
//! pipe.calibrate(256, 0).unwrap();                       // MSE ranges + FP logits
//! let lat = Lattice::practical();
//! let sens = pipe.sensitivity_sqnr(&lat).unwrap();       // Phase 1
//! let flips = pipe.flips(&lat, &sens);
//! let run = pipe.search_bops_budget(&lat, &flips, 0.5).unwrap(); // Phase 2
//! ```

use crate::adaround::{self, AdaRoundCfg};
use crate::data::DataSet;
use crate::groups::{Assignment, Candidate, Lattice};
use crate::manifest::Manifest;
use crate::model::{EvalSet, ModelHandle, QuantConfig};
use crate::runtime::Runtime;
use crate::search::{self, FlipStep, SearchCtx, SearchRun};
use crate::sensitivity::{self, Metric, RoundedWeights, SensEntry};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

pub struct Pipeline {
    pub manifest: Manifest,
    pub rt: Rc<Runtime>,
    pub model: ModelHandle,
    /// calibration eval set (built by [`Self::calibrate`])
    pub calib_set: Option<EvalSet>,
    /// validation eval set (lazily built)
    pub val_set: Option<EvalSet>,
}

impl Pipeline {
    /// Open a model from the artifacts directory with a fresh PJRT client.
    pub fn open(dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let rt = Rc::new(Runtime::cpu()?);
        let model = ModelHandle::open(rt.clone(), &manifest, model)?;
        Ok(Self { manifest, rt, model, calib_set: None, val_set: None })
    }

    /// Open sharing an existing runtime (multi-model experiments reuse the
    /// PJRT client and its executable cache).
    pub fn open_with(rt: Rc<Runtime>, manifest: &Manifest, model: &str) -> Result<Self> {
        let model = ModelHandle::open(rt.clone(), manifest, model)?;
        Ok(Self {
            manifest: manifest.clone(),
            rt,
            model,
            calib_set: None,
            val_set: None,
        })
    }

    /// Select a seeded calibration subset of `n` samples, estimate all
    /// quantizer ranges on it (MSE criteria) and upload it for Phase 1.
    pub fn calibrate(&mut self, n: usize, seed: u64) -> Result<()> {
        let sub = self.model.data.calib.subset(n, seed)?;
        self.calibrate_on(&sub)
    }

    /// Calibrate on an explicit dataset (used by the OOD study, Fig. 4).
    pub fn calibrate_on(&mut self, ds: &DataSet) -> Result<()> {
        let set = self.model.eval_set(ds)?;
        self.model.calibrate_ranges(&self.manifest, &set)?;
        self.calib_set = Some(set);
        Ok(())
    }

    /// Calibrate ranges AND run Phase 1 on unlabeled out-of-domain inputs.
    pub fn calibrate_unlabeled(&mut self, x: &crate::tensor::Tensor) -> Result<()> {
        let set = self.model.eval_set_unlabeled(x)?;
        self.model.calibrate_ranges(&self.manifest, &set)?;
        self.calib_set = Some(set);
        Ok(())
    }

    pub fn calib_set(&self) -> Result<&EvalSet> {
        self.calib_set
            .as_ref()
            .ok_or_else(|| anyhow!("calibrate() not run"))
    }

    /// Validation eval set (built on first use).
    pub fn val_set(&mut self) -> Result<&EvalSet> {
        if self.val_set.is_none() {
            let ds = self.model.data.val.clone();
            self.val_set = Some(self.model.eval_set(&ds)?);
        }
        Ok(self.val_set.as_ref().unwrap())
    }

    /// Evaluate Phase-2 metrics on a fixed `n`-sample validation subset
    /// instead of the full set (experiment drivers use this to bound
    /// wall-time on the single-core testbed; seeded for reproducibility).
    pub fn limit_val(&mut self, n: usize, seed: u64) -> Result<()> {
        let sub = self.model.data.val.subset(n, seed)?;
        self.val_set = Some(self.model.eval_set(&sub)?);
        Ok(())
    }

    // -- Phase 1 ---------------------------------------------------------------

    pub fn sensitivity_sqnr(&self, lattice: &Lattice) -> Result<Vec<SensEntry>> {
        sensitivity::sensitivity_list(
            &self.model,
            &self.manifest,
            lattice,
            self.calib_set()?,
            Metric::Sqnr,
            None,
        )
    }

    pub fn sensitivity(
        &self,
        lattice: &Lattice,
        metric: Metric,
        rounded: Option<&RoundedWeights>,
    ) -> Result<Vec<SensEntry>> {
        sensitivity::sensitivity_list(
            &self.model,
            &self.manifest,
            lattice,
            self.calib_set()?,
            metric,
            rounded,
        )
    }

    // -- AdaRound ---------------------------------------------------------------

    /// Precompute AdaRounded weights for every layer × weight-bit option.
    pub fn adaround(&self, lattice: &Lattice, cfg: &AdaRoundCfg) -> Result<RoundedWeights> {
        let set = self.calib_set()?;
        let taps = adaround::capture_taps(
            &self.model,
            &self.manifest,
            &set.batches,
            cfg.tap_batches,
        )?;
        adaround::adaround_all(
            &self.model,
            &self.manifest,
            &taps,
            &lattice.wbits_options(),
            cfg,
        )
    }

    // -- Phase 2 ---------------------------------------------------------------

    pub fn flips(&self, lattice: &Lattice, sens: &[SensEntry]) -> Vec<FlipStep> {
        search::flip_sequence(&self.model.entry, lattice, sens)
    }

    fn ctx<'a>(
        &'a self,
        lattice: &'a Lattice,
        flips: &'a [FlipStep],
        set: &'a EvalSet,
        rounded: Option<&'a RoundedWeights>,
    ) -> SearchCtx<'a> {
        SearchCtx::new(&self.model, lattice, flips, set, rounded)
    }

    /// Phase 2 under a BOPs budget; final metric measured on the val set.
    pub fn search_bops_budget(
        &mut self,
        lattice: &Lattice,
        flips: &[FlipStep],
        budget_r: f64,
    ) -> Result<SearchRun> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let ctx = SearchCtx::new(&self.model, lattice, flips, set, None);
        search::bops_budget(&ctx, budget_r)
    }

    /// Convenience used by examples: sensitivity → flips → BOPs search.
    pub fn mixed_precision_for_budget(
        &mut self,
        lattice: &Lattice,
        budget_r: f64,
    ) -> Result<SearchRun> {
        let sens = self.sensitivity_sqnr(lattice)?;
        let flips = self.flips(lattice, &sens);
        self.search_bops_budget(lattice, &flips, budget_r)
    }

    /// Evaluate a homogeneous fixed-precision configuration on the val set
    /// (the paper's comparison columns).
    pub fn eval_fixed(&mut self, cand: Candidate, rounded: Option<&RoundedWeights>) -> Result<f64> {
        let cfg = QuantConfig::fixed(&self.model.entry, cand.wbits, cand.abits);
        self.eval_cfg_with(cfg, cand.wbits, rounded)
    }

    /// Evaluate the FP32 model on the val set (consistency check against
    /// the manifest's `fp32_val_metric`).
    pub fn eval_fp32(&mut self) -> Result<f64> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let cfg = QuantConfig::fp32(&self.model.entry);
        self.model.eval_config(set, &cfg)
    }

    /// Evaluate an arbitrary assignment on the val set.
    pub fn eval_assignment(
        &mut self,
        asg: &Assignment,
        rounded: Option<&RoundedWeights>,
    ) -> Result<f64> {
        let (act, w) = asg.per_quantizer(&self.model.entry);
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let cfg = QuantConfig { act, w };
        let mut ov = HashMap::new();
        if let Some(r) = rounded {
            let (_, wbits) = asg.per_quantizer(&self.model.entry);
            for (i, wq) in self.model.entry.w_quantizers.iter().enumerate() {
                if let Some(bits) = wbits[i] {
                    if let Some(t) = r.get(&(wq.param_idx, bits)) {
                        ov.insert(wq.param_idx, t.clone());
                    }
                }
            }
        }
        let cb = self.model.config_buffers(&cfg, &ov)?;
        self.model.eval_metric(set, &cb)
    }

    fn eval_cfg_with(
        &mut self,
        cfg: QuantConfig,
        wbits: u8,
        rounded: Option<&RoundedWeights>,
    ) -> Result<f64> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let mut ov = HashMap::new();
        if let Some(r) = rounded {
            for wq in &self.model.entry.w_quantizers {
                if let Some(t) = r.get(&(wq.param_idx, wbits)) {
                    ov.insert(wq.param_idx, t.clone());
                }
            }
        }
        let cb = self.model.config_buffers(&cfg, &ov)?;
        self.model.eval_metric(set, &cb)
    }

    /// Accuracy-target search with the chosen scheme; evaluations run on
    /// the val set, mirroring the paper's Table 5 setup.
    pub fn search_accuracy_target(
        &mut self,
        lattice: &Lattice,
        flips: &[FlipStep],
        target: f64,
        scheme: SearchScheme,
        rounded: Option<&RoundedWeights>,
    ) -> Result<SearchRun> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let ctx = self.ctx(lattice, flips, set, rounded);
        match scheme {
            SearchScheme::Sequential => search::sequential_accuracy(&ctx, target),
            SearchScheme::Binary => search::binary_accuracy(&ctx, target),
            SearchScheme::Hybrid => search::hybrid_accuracy(&ctx, target),
        }
    }

    /// Full pareto curve on the *calibration* set (Fig. 2/4/5 draw these).
    pub fn pareto_curve(
        &self,
        lattice: &Lattice,
        flips: &[FlipStep],
        rounded: Option<&RoundedWeights>,
    ) -> Result<SearchRun> {
        let set = self.calib_set()?;
        let ctx = self.ctx(lattice, flips, set, rounded);
        search::full_curve(&ctx)
    }

    /// Full pareto curve evaluated on the val set.
    pub fn pareto_curve_val(
        &mut self,
        lattice: &Lattice,
        flips: &[FlipStep],
        rounded: Option<&RoundedWeights>,
    ) -> Result<SearchRun> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let ctx = self.ctx(lattice, flips, set, rounded);
        search::full_curve(&ctx)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchScheme {
    Sequential,
    Binary,
    Hybrid,
}

impl SearchScheme {
    pub fn label(&self) -> &'static str {
        match self {
            Self::Sequential => "sequential",
            Self::Binary => "binary",
            Self::Hybrid => "binary+interp",
        }
    }
}
