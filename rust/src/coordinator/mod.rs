//! The coordinator: wires runtime, calibration, Phase 1 and Phase 2 into
//! the end-to-end [`Pipeline`] — the paper's Algorithm 1 as a service.
//!
//! A `Pipeline` owns one model.  Evaluation parallelism comes from the
//! process-wide [`crate::pool::EvalFleet`]: [`Pipeline::attach_fleet`]
//! joins a shared fleet (multi-model drivers spawn it once;
//! worker runtimes and compiled executables persist across models), while
//! [`Pipeline::enable_pool`] spawns a private single-model fleet — either
//! way every probe / prefix / config evaluation after that fans out
//! shard-parallel, bit-identical to the serial path, and FIT sweeps and
//! AdaRound optimizations route through the same workers.
//! [`Pipeline::set_sens_cache_dir`] persists Phase-1 lists *and* the FP32
//! reference on disk so repeated drivers skip both the sweep and the
//! reference forward pass.
//!
//! # Durability & resume
//!
//! [`Pipeline::set_journal`] attaches a crash-safe
//! [`RunJournal`](crate::store::RunJournal): each completed Phase-1
//! `(group, candidate)` probe, each Phase-2 prefix evaluation and each
//! AdaRounded `(layer, wbits)` tensor is appended *after* it completes and
//! *before* any dependent work starts, keyed by a scope digest over
//! everything the result depends on (model identity, trained weights, the
//! exact calibration/validation tensors, lattice, metric — plus the flip
//! sequence for searches and the full optimizer config for AdaRound).  On
//! `--resume` the journal replays and matching records are served back
//! bit-exactly, so a killed run re-runs **zero** completed probes or
//! AdaRound layers; a journal written under different data, bits or
//! rounding never matches and is simply ignored.  Corrupt or truncated
//! cache files degrade to a miss (quarantined to `<name>.corrupt`, counted
//! in [`Pipeline::store_stats`]) instead of failing the run.  Typical flow:
//!
//! ```no_run
//! # use mpq::coordinator::Pipeline;
//! # use mpq::groups::Lattice;
//! let mut pipe = Pipeline::open("artifacts", "mobilenet_v3_s").unwrap();
//! pipe.calibrate(256, 0).unwrap();                       // MSE ranges + FP logits
//! let lat = Lattice::practical();
//! let sens = pipe.sensitivity_sqnr(&lat).unwrap();       // Phase 1
//! let flips = pipe.flips(&lat, &sens);
//! let run = pipe.search_bops_budget(&lat, &flips, 0.5).unwrap(); // Phase 2
//! ```

use crate::adaround::{self, AdaRoundCfg};
use crate::data::DataSet;
use crate::engine::FpReference;
use crate::groups::{Assignment, Candidate, Lattice};
use crate::manifest::Manifest;
use crate::model::{EvalSet, ModelHandle, QuantConfig, WeightOverrides};
use crate::pool::{self, EvalFleet, EvalPool, ProbeKind};
use crate::runtime::Runtime;
use crate::search::{self, FlipStep, SearchCtx, SearchRun};
use crate::sensitivity::{self, cache as sens_cache, Metric, RoundedWeights, SensEntry};
use crate::store::{self, JournalScope, RunJournal, StoreStats};
use crate::tensor::Tensor;
use crate::util::Fnv;
use anyhow::{anyhow, bail, Result};
use std::cell::Cell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

pub struct Pipeline {
    pub manifest: Manifest,
    pub rt: Rc<Runtime>,
    pub model: ModelHandle,
    /// calibration eval set (built by [`Self::calibrate`])
    pub calib_set: Option<EvalSet>,
    /// validation eval set (lazily built)
    pub val_set: Option<EvalSet>,
    /// multi-client evaluation pool ([`Self::enable_pool`]); when present,
    /// Phase-1 sweeps, Phase-2 prefix evaluations and one-off config
    /// evaluations all fan out shard-parallel across its workers
    pub pool: Option<EvalPool>,
    /// host copies of the current calibration / validation data — what the
    /// pool shards from, and what the sensitivity cache digests
    calib_ds: Option<DataSet>,
    val_ds: Option<DataSet>,
    /// on-disk Phase-1 sensitivity + FP32-reference cache dir
    /// (None = disabled)
    sens_cache_dir: Option<PathBuf>,
    sens_cache_hits: Cell<u64>,
    sens_cache_misses: Cell<u64>,
    ref_cache_hits: Cell<u64>,
    ref_cache_misses: Cell<u64>,
    /// crash-safe run journal ([`Self::set_journal`]); `None` = journaling
    /// disabled, everything recomputes
    journal: Option<Rc<RunJournal>>,
    /// durability telemetry, shared with the journal and the on-disk
    /// caches so replay/skip/corruption counters land in one place
    store_stats: Rc<StoreStats>,
}

impl Pipeline {
    /// Open a model from the artifacts directory with a fresh runtime on
    /// the backend the manifest names (PJRT client or sim interpreter).
    pub fn open(dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let rt = Rc::new(Runtime::for_manifest(&manifest)?);
        let model = ModelHandle::open(rt.clone(), &manifest, model)?;
        Ok(Self::assemble(manifest, rt, model))
    }

    /// Open sharing an existing runtime (multi-model experiments reuse the
    /// backend client and its executable cache).
    pub fn open_with(rt: Rc<Runtime>, manifest: &Manifest, model: &str) -> Result<Self> {
        let model = ModelHandle::open(rt.clone(), manifest, model)?;
        Ok(Self::assemble(manifest.clone(), rt, model))
    }

    fn assemble(manifest: Manifest, rt: Rc<Runtime>, model: ModelHandle) -> Self {
        Self {
            manifest,
            rt,
            model,
            calib_set: None,
            val_set: None,
            pool: None,
            calib_ds: None,
            val_ds: None,
            sens_cache_dir: None,
            sens_cache_hits: Cell::new(0),
            sens_cache_misses: Cell::new(0),
            ref_cache_hits: Cell::new(0),
            ref_cache_misses: Cell::new(0),
            journal: None,
            store_stats: Rc::new(StoreStats::default()),
        }
    }

    // -- evaluation fleet ------------------------------------------------------

    /// Spawn a **private** `workers`-client single-model fleet and route
    /// all subsequent probe/prefix evaluations through it.  `workers == 0`
    /// disables pooling (serial single-client path); `workers == 1` is a
    /// valid degenerate pool (used by the equivalence tests).  Any state
    /// already on the pipeline (calibration, eval sets) is pushed to the
    /// new workers.  Multi-model drivers should share one fleet via
    /// [`Self::attach_fleet`] instead.
    pub fn enable_pool(&mut self, workers: usize) -> Result<()> {
        if workers == 0 {
            self.pool = None;
            return Ok(());
        }
        self.pool = Some(EvalPool::new(
            &self.manifest.dir,
            &self.model.entry.name,
            workers,
        )?);
        self.pool_push_calibration()?;
        self.pool_push_val()
    }

    /// Attach this pipeline's model to a shared process-wide
    /// [`EvalFleet`]: worker threads, runtimes and already-compiled
    /// executables are reused across every model on the fleet.  Any state
    /// already on the pipeline is pushed to the workers.
    pub fn attach_fleet(&mut self, fleet: &Rc<EvalFleet>) -> Result<()> {
        if fleet.dir() != self.manifest.dir {
            bail!(
                "fleet serves artifacts at {}, pipeline opened {}",
                fleet.dir().display(),
                self.manifest.dir.display()
            );
        }
        self.pool = Some(EvalPool::attach(fleet, &self.model.entry.name)?);
        self.pool_push_calibration()?;
        self.pool_push_val()
    }

    /// Enable/disable the on-disk Phase-1 caches ([`sens_cache`]): the
    /// sensitivity lists *and* the FP32 reference live side by side in the
    /// same directory.
    pub fn set_sens_cache_dir(&mut self, dir: Option<PathBuf>) {
        self.sens_cache_dir = dir;
    }

    /// Attach (or detach) the crash-safe run journal.  The pipeline adopts
    /// the journal's [`StoreStats`], so replay/skip counters from the
    /// journal and corruption counters from the caches are one set.
    pub fn set_journal(&mut self, journal: Option<Rc<RunJournal>>) {
        if let Some(j) = &journal {
            self.store_stats = Rc::clone(j.stats());
        }
        self.journal = journal;
    }

    /// Durability telemetry: journal appends/replays/skips/truncations and
    /// cache-corruption counters (drivers report these next to the fleet's
    /// failure stats).
    pub fn store_stats(&self) -> &StoreStats {
        &self.store_stats
    }

    /// `(hits, misses)` of the on-disk sensitivity cache for this pipeline.
    pub fn sens_cache_stats(&self) -> (u64, u64) {
        (self.sens_cache_hits.get(), self.sens_cache_misses.get())
    }

    /// `(hits, misses)` of the on-disk FP32-reference cache.
    pub fn ref_cache_stats(&self) -> (u64, u64) {
        (self.ref_cache_hits.get(), self.ref_cache_misses.get())
    }

    /// Drop the pool's probe memo (benchmarks measure steady-state sweeps).
    pub fn clear_eval_memo(&self) {
        if let Some(p) = &self.pool {
            p.clear_memo();
        }
    }

    /// Push calibrated state + the calibration shard to the fleet
    /// (pipelined: the H→D shard upload overlaps the caller's subsequent
    /// probe construction), then reconcile the FP32 reference with the
    /// on-disk cache.
    fn pool_push_calibration(&self) -> Result<()> {
        if let Some(p) = &self.pool {
            if let Some(r) = &self.model.act_ranges {
                p.set_calibration(r, &self.model.w_scales)?;
            }
            if let Some(ds) = &self.calib_ds {
                p.load_set(pool::CALIB_SET, ds)?;
            }
        }
        self.sync_reference()
    }

    /// Reconcile the calibration set's FP32 reference with the on-disk
    /// reference cache (stored next to the sensitivity lists, keyed by
    /// model + calibration-data/weights digest):
    ///
    /// * cache **hit** — install the per-batch logits without any forward
    ///   sweep (into every fleet worker's shard cache, or the serial
    ///   engine);
    /// * cache **miss**, pooled — build eagerly (one sweep split across
    ///   the workers' shards, overlapped with later probe enqueueing),
    ///   fetch the merged full-set logits back and persist them;
    /// * cache **miss**, serial — stay lazy (the first SQNR probe builds
    ///   it); [`Self::sensitivity`] persists it after the sweep;
    /// * cache disabled — pooled keeps the eager build, serial stays lazy
    ///   (the pre-fleet behaviour, unchanged).
    fn sync_reference(&self) -> Result<()> {
        let Some(ds) = &self.calib_ds else { return Ok(()) };
        let Some((slot, digest)) = self.ref_cache_slot(ds) else {
            if let Some(p) = &self.pool {
                p.build_references(pool::CALIB_SET)?;
            }
            return Ok(());
        };
        let mut cached = sens_cache::load_ref(&slot, digest, &self.store_stats)?;
        if let Some(batches) = &cached {
            // digest and checksum passed but the shape doesn't match the
            // eval set — degrade to a quarantined miss and rebuild, never
            // poison the engine with a wrong-shaped reference
            let set = self.calib_set()?;
            if batches.len() != set.batches.len() {
                store::quarantine(
                    &slot,
                    &self.store_stats,
                    &format!(
                        "reference cache holds {} batches, eval set has {}",
                        batches.len(),
                        set.batches.len()
                    ),
                );
                self.store_stats
                    .cache_corrupt_misses
                    .set(self.store_stats.cache_corrupt_misses.get() + 1);
                cached = None;
            }
        }
        match cached {
            Some(batches) => {
                self.ref_cache_hits.set(self.ref_cache_hits.get() + 1);
                match &self.pool {
                    Some(p) => p.install_references(pool::CALIB_SET, &batches)?,
                    None => {
                        let set = self.calib_set()?;
                        self.model
                            .engine
                            .install_reference(set.id, FpReference::from_batches(batches)?);
                    }
                }
            }
            None => {
                self.ref_cache_misses.set(self.ref_cache_misses.get() + 1);
                if let Some(p) = &self.pool {
                    p.build_references(pool::CALIB_SET)?;
                    let batches = p.fetch_reference(pool::CALIB_SET)?;
                    sens_cache::store_ref(&slot, digest, &batches)?;
                }
            }
        }
        Ok(())
    }

    /// Path and content digest of the calibration FP32 reference in the
    /// on-disk cache, when the cache is enabled.
    fn ref_cache_slot(&self, ds: &DataSet) -> Option<(PathBuf, u64)> {
        let dir = self.sens_cache_dir.as_ref()?;
        let digest = sens_cache::ref_digest(&self.model.entry, ds, &self.model.weights);
        Some((
            sens_cache::ref_path(dir, &self.model.entry.name, digest),
            digest,
        ))
    }

    /// Serial-path counterpart of the reference persistence: after a sweep
    /// that built the reference lazily, store it if the cache wants it.
    fn persist_serial_reference(&self) -> Result<()> {
        let (Some(ds), Some(set)) = (&self.calib_ds, &self.calib_set) else { return Ok(()) };
        let Some((slot, digest)) = self.ref_cache_slot(ds) else { return Ok(()) };
        if slot.exists() {
            return Ok(());
        }
        // served from the engine's in-memory cache — zero forward calls
        let r = self.model.engine.reference(&self.model, set)?;
        sens_cache::store_ref(&slot, digest, &r.batches)
    }

    fn pool_push_val(&self) -> Result<()> {
        let Some(p) = &self.pool else { return Ok(()) };
        if let Some(ds) = &self.val_ds {
            p.load_set(pool::VAL_SET, ds)?;
        }
        Ok(())
    }

    /// Select a seeded calibration subset of `n` samples, estimate all
    /// quantizer ranges on it (MSE criteria) and upload it for Phase 1.
    pub fn calibrate(&mut self, n: usize, seed: u64) -> Result<()> {
        let sub = self.model.data.calib.subset(n, seed)?;
        self.calibrate_on(&sub)
    }

    /// Calibrate on an explicit dataset (used by the OOD study, Fig. 4).
    pub fn calibrate_on(&mut self, ds: &DataSet) -> Result<()> {
        let set = self.model.eval_set(ds)?;
        self.model.calibrate_ranges(&self.manifest, &set)?;
        self.calib_set = Some(set);
        self.calib_ds = Some(ds.clone());
        self.pool_push_calibration()
    }

    /// Calibrate ranges AND run Phase 1 on unlabeled out-of-domain inputs.
    pub fn calibrate_unlabeled(&mut self, x: &Tensor) -> Result<()> {
        let set = self.model.eval_set_unlabeled(x)?;
        self.model.calibrate_ranges(&self.manifest, &set)?;
        self.calib_set = Some(set);
        // zero labels keep the host-side dataset well-formed; unlabeled
        // sets only ever serve SQNR probes, which ignore labels
        self.calib_ds = Some(DataSet {
            x: x.clone(),
            y: Tensor::zeros(&[x.shape[0]]),
        });
        self.pool_push_calibration()
    }

    pub fn calib_set(&self) -> Result<&EvalSet> {
        self.calib_set
            .as_ref()
            .ok_or_else(|| anyhow!("calibrate() not run"))
    }

    /// Validation eval set (built on first use).
    pub fn val_set(&mut self) -> Result<&EvalSet> {
        if self.val_set.is_none() {
            let ds = self.model.data.val.clone();
            self.val_set = Some(self.model.eval_set(&ds)?);
            self.val_ds = Some(ds);
            self.pool_push_val()?;
        }
        Ok(self.val_set.as_ref().unwrap())
    }

    /// Evaluate Phase-2 metrics on a fixed `n`-sample validation subset
    /// instead of the full set (experiment drivers use this to bound
    /// wall-time on the single-core testbed; seeded for reproducibility).
    pub fn limit_val(&mut self, n: usize, seed: u64) -> Result<()> {
        let sub = self.model.data.val.subset(n, seed)?;
        self.val_set = Some(self.model.eval_set(&sub)?);
        self.val_ds = Some(sub);
        self.pool_push_val()
    }

    // -- Phase 1 ---------------------------------------------------------------

    pub fn sensitivity_sqnr(&self, lattice: &Lattice) -> Result<Vec<SensEntry>> {
        self.sensitivity(lattice, Metric::Sqnr, None)
    }

    /// Build the Phase-1 sensitivity list: served from the on-disk cache
    /// when enabled and fresh, otherwise swept — shard-parallel through the
    /// fleet when one is attached (SQNR, accuracy *and* FIT all have
    /// pooled paths; a future metric without one falls back to the serial
    /// path with a warning instead of erroring).  AdaRound-stitched sweeps
    /// are never disk-cached since the stitched weights aren't part of the
    /// digest.
    pub fn sensitivity(
        &self,
        lattice: &Lattice,
        metric: Metric,
        rounded: Option<&RoundedWeights>,
    ) -> Result<Vec<SensEntry>> {
        let calib = self.calib_set()?;
        let slot = if rounded.is_none() { self.sens_cache_slot(lattice, metric) } else { None };
        if let Some((path, _)) = &slot {
            if let Some(list) = sens_cache::load(path, &self.store_stats)? {
                self.sens_cache_hits.set(self.sens_cache_hits.get() + 1);
                return Ok(list);
            }
            self.sens_cache_misses.set(self.sens_cache_misses.get() + 1);
        }
        let jscope = self.phase1_scope(lattice, metric, rounded);
        let pooled = match &self.pool {
            Some(p) if sensitivity::has_pooled_path(metric) => Some(p),
            Some(_) => {
                eprintln!(
                    "[mpq] warning: Phase-1 metric {metric:?} has no pooled \
                     implementation; falling back to the serial single-client path"
                );
                None
            }
            None => None,
        };
        let list = match pooled {
            Some(p) => sensitivity::sensitivity_list_pooled(
                p,
                pool::CALIB_SET,
                &self.model,
                lattice,
                metric,
                rounded,
                jscope.as_ref(),
            )?,
            None => {
                let list = sensitivity::sensitivity_list(
                    &self.model,
                    &self.manifest,
                    lattice,
                    calib,
                    metric,
                    rounded,
                    jscope.as_ref(),
                )?;
                if metric == Metric::Sqnr {
                    // the sweep just built the FP reference lazily —
                    // persist it for later drivers (cache-gated no-op
                    // otherwise)
                    self.persist_serial_reference()?;
                }
                list
            }
        };
        if let Some((path, digest)) = slot {
            sens_cache::store(&path, &self.model.entry.name, metric, digest, &list)?;
        }
        Ok(list)
    }

    fn sens_cache_slot(&self, lattice: &Lattice, metric: Metric) -> Option<(PathBuf, u64)> {
        let (Some(dir), Some(ds)) = (self.sens_cache_dir.as_ref(), self.calib_ds.as_ref())
        else {
            return None;
        };
        let digest =
            sens_cache::digest(&self.model.entry, lattice, metric, ds, &self.model.weights);
        Some((
            sens_cache::cache_path(dir, &self.model.entry.name, metric, digest),
            digest,
        ))
    }

    /// Journal scope for a Phase-1 sweep: the sensitivity-cache digest
    /// (model identity + weights + lattice + metric + exact calibration
    /// tensors), with the stitched AdaRound tensors folded in when the
    /// sweep runs on rounded weights — a journal written under different
    /// data, bits or rounding never replays.
    fn phase1_scope(
        &self,
        lattice: &Lattice,
        metric: Metric,
        rounded: Option<&RoundedWeights>,
    ) -> Option<JournalScope> {
        let j = self.journal.as_ref()?;
        let ds = self.calib_ds.as_ref()?;
        let mut base =
            sens_cache::digest(&self.model.entry, lattice, metric, ds, &self.model.weights);
        if let Some(r) = rounded {
            let mut h = Fnv::new();
            h.write_u64(base);
            h.write_u64(rounded_digest(r));
            base = h.finish();
        }
        Some(JournalScope::new(Rc::clone(j), base))
    }

    // -- AdaRound ---------------------------------------------------------------

    /// Precompute AdaRounded weights for every layer × weight-bit option.
    /// Taps are captured once on this pipeline's client; the independent
    /// `(layer, wbits)` optimizations then anneal concurrently across the
    /// fleet when one is attached (bit-identical to the serial path).
    /// With a run journal attached, already-optimized tensors replay from
    /// it — and when the journal covers *every* `(layer, wbits)` pair, the
    /// tap capture (a full forward sweep) is skipped entirely.
    pub fn adaround(&self, lattice: &Lattice, cfg: &AdaRoundCfg) -> Result<RoundedWeights> {
        let wbits = lattice.wbits_options();
        let jscope = self.adaround_scope(cfg);
        if let Some(j) = &jscope {
            let keys = adaround::expected_keys(&self.model.entry, &wbits)?;
            let complete = !keys.is_empty()
                && keys.iter().all(|&(p, b)| {
                    j.journal
                        .contains(store::kind::ADAROUND, store::adaround_key(j.base, p, b))
                });
            if complete {
                let mut out = RoundedWeights::new();
                for key in keys {
                    let t = adaround::journal_lookup(j, key)?.ok_or_else(|| {
                        anyhow!("journaled AdaRound record for {key:?} vanished mid-run")
                    })?;
                    out.insert(key, t);
                }
                return Ok(out);
            }
        }
        let set = self.calib_set()?;
        let taps = adaround::capture_taps(
            &self.model,
            &self.manifest,
            &set.batches,
            cfg.tap_batches,
        )?;
        match &self.pool {
            Some(p) => {
                adaround::adaround_all_pooled(p, &self.model, &taps, &wbits, cfg, jscope.as_ref())
            }
            None => adaround::adaround_all(
                &self.model,
                &self.manifest,
                &taps,
                &wbits,
                cfg,
                jscope.as_ref(),
            ),
        }
    }

    /// Journal scope for AdaRound: model identity + trained weights +
    /// exact calibration tensors + every optimizer hyperparameter
    /// (bit-exact floats), so a rounded tensor only ever replays into an
    /// identical optimization.
    fn adaround_scope(&self, cfg: &AdaRoundCfg) -> Option<JournalScope> {
        let j = self.journal.as_ref()?;
        let ds = self.calib_ds.as_ref()?;
        let mut h = Fnv::new();
        h.write_bytes(self.model.entry.name.as_bytes());
        for w in &self.model.weights {
            h.write_tensor(w);
        }
        h.write_tensor(&ds.x);
        h.write_tensor(&ds.y);
        h.write_usize(cfg.steps);
        h.write_u32(cfg.lr.to_bits());
        h.write_u32(cfg.lambda.to_bits());
        h.write_u32(cfg.beta_hi.to_bits());
        h.write_u32(cfg.beta_lo.to_bits());
        h.write_usize(cfg.tap_batches);
        h.write_u64(cfg.seed);
        Some(JournalScope::new(Rc::clone(j), h.finish()))
    }

    // -- Phase 2 ---------------------------------------------------------------

    pub fn flips(&self, lattice: &Lattice, sens: &[SensEntry]) -> Vec<FlipStep> {
        search::flip_sequence(&self.model.entry, lattice, sens)
    }

    /// A search context on `set`; prefix evaluations fan out through the
    /// pool when one is enabled (`set_key` names the set's pool
    /// registration) and journal/replay through the run journal when one
    /// is attached.
    fn ctx<'a>(
        &'a self,
        lattice: &'a Lattice,
        flips: &'a [FlipStep],
        set: &'a EvalSet,
        set_key: pool::SetKey,
        rounded: Option<&'a RoundedWeights>,
    ) -> SearchCtx<'a> {
        let pooled = self.pool.as_ref().map(|p| (p, set_key));
        let mut ctx = SearchCtx::with_pool(&self.model, lattice, flips, set, rounded, pooled);
        if let Some(scope) = self.search_scope(lattice, flips, set_key, rounded) {
            ctx = ctx.with_journal(scope);
        }
        ctx
    }

    /// Journal scope for a Phase-2 search: model identity + weights + the
    /// host copy of the evaluated set + lattice + the **exact flip
    /// sequence** (group, bits, score and BOPs bits per step) + stitched
    /// rounding.  A journaled prefix index `k` only means something under
    /// this exact ordering, so any of these changing voids the records.
    fn search_scope(
        &self,
        lattice: &Lattice,
        flips: &[FlipStep],
        set_key: pool::SetKey,
        rounded: Option<&RoundedWeights>,
    ) -> Option<JournalScope> {
        let j = self.journal.as_ref()?;
        let ds = if set_key == pool::CALIB_SET {
            self.calib_ds.as_ref()?
        } else {
            self.val_ds.as_ref()?
        };
        let mut h = Fnv::new();
        h.write_bytes(self.model.entry.name.as_bytes());
        for w in &self.model.weights {
            h.write_tensor(w);
        }
        h.write_u64(set_key);
        h.write_tensor(&ds.x);
        h.write_tensor(&ds.y);
        h.write_u8(lattice.baseline.wbits);
        h.write_u8(lattice.baseline.abits);
        for c in &lattice.candidates {
            h.write_u8(c.wbits);
            h.write_u8(c.abits);
        }
        for f in flips {
            h.write_usize(f.group);
            h.write_u8(f.cand.wbits);
            h.write_u8(f.cand.abits);
            h.write_u8(f.prev.wbits);
            h.write_u8(f.prev.abits);
            h.write_u64(f.rel_bops.to_bits());
            h.write_u64(f.score.to_bits());
        }
        if let Some(r) = rounded {
            h.write_u64(rounded_digest(r));
        }
        Some(JournalScope::new(Rc::clone(j), h.finish()))
    }

    /// Phase 2 under a BOPs budget; final metric measured on the val set.
    pub fn search_bops_budget(
        &mut self,
        lattice: &Lattice,
        flips: &[FlipStep],
        budget_r: f64,
    ) -> Result<SearchRun> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let ctx = self.ctx(lattice, flips, set, pool::VAL_SET, None);
        search::bops_budget(&ctx, budget_r)
    }

    /// Convenience used by examples: sensitivity → flips → BOPs search.
    pub fn mixed_precision_for_budget(
        &mut self,
        lattice: &Lattice,
        budget_r: f64,
    ) -> Result<SearchRun> {
        let sens = self.sensitivity_sqnr(lattice)?;
        let flips = self.flips(lattice, &sens);
        self.search_bops_budget(lattice, &flips, budget_r)
    }

    /// Evaluate a homogeneous fixed-precision configuration on the val set
    /// (the paper's comparison columns).
    pub fn eval_fixed(&mut self, cand: Candidate, rounded: Option<&RoundedWeights>) -> Result<f64> {
        let cfg = QuantConfig::fixed(&self.model.entry, cand.wbits, cand.abits);
        self.eval_cfg_with(cfg, cand.wbits, rounded)
    }

    /// Evaluate the FP32 model on the val set (consistency check against
    /// the manifest's `fp32_val_metric`).
    pub fn eval_fp32(&mut self) -> Result<f64> {
        let cfg = QuantConfig::fp32(&self.model.entry);
        self.eval_val_metric(&cfg, &WeightOverrides::new())
    }

    /// One task-metric evaluation on the val set — shard-parallel through
    /// the pool when one is enabled, single-client otherwise.
    fn eval_val_metric(&mut self, cfg: &QuantConfig, ov: &WeightOverrides) -> Result<f64> {
        self.val_set()?;
        if let Some(p) = &self.pool {
            return p.submit(pool::VAL_SET, ProbeKind::Metric, cfg, ov)?.wait();
        }
        let set = self.val_set.as_ref().unwrap();
        let cb = self.model.config_buffers(cfg, ov)?;
        self.model.eval_metric(set, &cb)
    }

    /// Evaluate an arbitrary assignment on the val set.
    pub fn eval_assignment(
        &mut self,
        asg: &Assignment,
        rounded: Option<&RoundedWeights>,
    ) -> Result<f64> {
        let (act, w) = asg.per_quantizer(&self.model.entry);
        let cfg = QuantConfig { act, w };
        let mut ov = HashMap::new();
        if let Some(r) = rounded {
            let (_, wbits) = asg.per_quantizer(&self.model.entry);
            for (i, wq) in self.model.entry.w_quantizers.iter().enumerate() {
                if let Some(bits) = wbits[i] {
                    if let Some(t) = r.get(&(wq.param_idx, bits)) {
                        ov.insert(wq.param_idx, t.clone());
                    }
                }
            }
        }
        self.eval_val_metric(&cfg, &ov)
    }

    fn eval_cfg_with(
        &mut self,
        cfg: QuantConfig,
        wbits: u8,
        rounded: Option<&RoundedWeights>,
    ) -> Result<f64> {
        let mut ov = HashMap::new();
        if let Some(r) = rounded {
            for wq in &self.model.entry.w_quantizers {
                if let Some(t) = r.get(&(wq.param_idx, wbits)) {
                    ov.insert(wq.param_idx, t.clone());
                }
            }
        }
        self.eval_val_metric(&cfg, &ov)
    }

    /// Accuracy-target search with the chosen scheme; evaluations run on
    /// the val set, mirroring the paper's Table 5 setup.
    pub fn search_accuracy_target(
        &mut self,
        lattice: &Lattice,
        flips: &[FlipStep],
        target: f64,
        scheme: SearchScheme,
        rounded: Option<&RoundedWeights>,
    ) -> Result<SearchRun> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let ctx = self.ctx(lattice, flips, set, pool::VAL_SET, rounded);
        match scheme {
            SearchScheme::Sequential => search::sequential_accuracy(&ctx, target),
            SearchScheme::Binary => search::binary_accuracy(&ctx, target),
            SearchScheme::Hybrid => search::hybrid_accuracy(&ctx, target),
        }
    }

    /// Full pareto curve on the *calibration* set (Fig. 2/4/5 draw these).
    pub fn pareto_curve(
        &self,
        lattice: &Lattice,
        flips: &[FlipStep],
        rounded: Option<&RoundedWeights>,
    ) -> Result<SearchRun> {
        let set = self.calib_set()?;
        let ctx = self.ctx(lattice, flips, set, pool::CALIB_SET, rounded);
        search::full_curve(&ctx)
    }

    /// Full pareto curve evaluated on the val set.
    pub fn pareto_curve_val(
        &mut self,
        lattice: &Lattice,
        flips: &[FlipStep],
        rounded: Option<&RoundedWeights>,
    ) -> Result<SearchRun> {
        self.val_set()?;
        let set = self.val_set.as_ref().unwrap();
        let ctx = self.ctx(lattice, flips, set, pool::VAL_SET, rounded);
        search::full_curve(&ctx)
    }
}

/// Digest of stitched AdaRound tensors: sorted `(param_idx, wbits)` keys,
/// each folded with its full tensor content — deterministic regardless of
/// `HashMap` iteration order.
fn rounded_digest(r: &RoundedWeights) -> u64 {
    let mut keys: Vec<_> = r.keys().copied().collect();
    keys.sort_unstable();
    let mut h = Fnv::new();
    for (p, b) in keys {
        h.write_usize(p);
        h.write_u8(b);
        h.write_tensor(&r[&(p, b)]);
    }
    h.finish()
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchScheme {
    Sequential,
    Binary,
    Hybrid,
}

impl SearchScheme {
    pub fn label(&self) -> &'static str {
        match self {
            Self::Sequential => "sequential",
            Self::Binary => "binary",
            Self::Hybrid => "binary+interp",
        }
    }
}
