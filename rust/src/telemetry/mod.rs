//! One consolidated view of the crate's runtime counters.
//!
//! Before this module every subsystem reported its own numbers through
//! its own door: the fleet's `model_opens`/`probes_computed`/`memo_hits`
//! and per-worker [`WorkerStats`], the supervisor's [`FailureStats`], the
//! pipeline's sens/ref cache hit/miss cells, and the durable store's
//! [`StoreStats`].  The drivers stitched a human one-liner together ad
//! hoc and nothing machine-readable existed at all.
//!
//! [`Snapshot`] is the single collection point:
//!
//! * [`Snapshot::from_pipeline`] gathers every counter a pipeline can see
//!   (drivers call it once per model),
//!   [`Snapshot::from_parts`] builds one from a fleet + store pair (the
//!   daemon's `Status` reply, where no single pipeline is in scope).
//! * [`Snapshot::note`] renders the exact compact one-liner the drivers
//!   have always printed (conditional sections appear only when their
//!   subsystem actually did something).
//! * [`Snapshot::to_json`] is the machine-readable form: one JSON object,
//!   stable keys, served verbatim by `mpqd`'s `Status` reply and written
//!   next to the driver reports.
//!
//! Collection is cheap (atomic loads and `Cell` reads); only
//! [`FleetTelemetry::collect_full`] talks to the workers (a tracked
//! `Stats` broadcast), so use it only between phases — the plain
//! [`collect`](FleetTelemetry::collect) never touches the fleet's job
//! channels.

use crate::coordinator::Pipeline;
use crate::jsonio::Json;
use crate::pool::{EvalFleet, FailureStats, WireCounters, WorkerStats};
use crate::store::StoreStats;

/// Fleet-side counters: compile/memo accounting, failure telemetry and
/// (optionally) the per-worker compile caches.
#[derive(Clone, Debug)]
pub struct FleetTelemetry {
    pub workers: usize,
    /// model handles opened (= lazy compiles) across all workers, ever
    pub model_opens: usize,
    /// probes dispatched to workers (memo misses)
    pub probes_computed: usize,
    pub memo_hits: usize,
    pub failures: FailureStats,
    /// per-worker compile-cache counters; empty unless collected via
    /// [`collect_full`](FleetTelemetry::collect_full)
    pub worker_stats: Vec<WorkerStats>,
}

impl FleetTelemetry {
    /// Cheap collection: counter loads only, no worker traffic.
    pub fn collect(fleet: &EvalFleet) -> Self {
        Self {
            workers: fleet.workers(),
            model_opens: fleet.model_opens(),
            probes_computed: fleet.probes_computed(),
            memo_hits: fleet.memo_hits(),
            failures: fleet.failure_stats(),
            worker_stats: Vec::new(),
        }
    }

    /// Also query each worker's compile cache (a tracked broadcast — only
    /// call between phases).  Worker-stat failures degrade to an empty
    /// list rather than failing the snapshot.
    pub fn collect_full(fleet: &EvalFleet) -> Self {
        let mut t = Self::collect(fleet);
        t.worker_stats = fleet.worker_stats().unwrap_or_default();
        t
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("workers".into(), num(self.workers as u64)),
            ("model_opens".into(), num(self.model_opens as u64)),
            ("probes_computed".into(), num(self.probes_computed as u64)),
            ("memo_hits".into(), num(self.memo_hits as u64)),
            (
                "failures".into(),
                Json::Obj(vec![
                    ("worker_restarts".into(), num(self.failures.worker_restarts as u64)),
                    ("jobs_requeued".into(), num(self.failures.jobs_requeued as u64)),
                    ("faults_injected".into(), num(self.failures.faults_injected as u64)),
                    (
                        "degraded_events".into(),
                        Json::Arr(
                            self.failures
                                .degraded_events
                                .iter()
                                .map(|s| Json::Str(s.clone()))
                                .collect(),
                        ),
                    ),
                    (
                        "last_deaths".into(),
                        Json::Arr(
                            self.failures
                                .last_deaths
                                .iter()
                                .map(|s| Json::Str(s.clone()))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "worker_stats".into(),
                Json::Arr(
                    self.worker_stats
                        .iter()
                        .map(|w| {
                            Json::Obj(vec![
                                ("compiled".into(), num(w.compiled as u64)),
                                ("models_open".into(), num(w.models_open as u64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Plain-value copy of [`StoreStats`] (which is `Cell`-based and
/// deliberately not `Clone`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreCounters {
    pub journal_appended: u64,
    pub journal_replayed: u64,
    pub journal_skips: u64,
    pub journal_truncations: u64,
    pub cache_corrupt_misses: u64,
    pub files_quarantined: u64,
}

impl StoreCounters {
    pub fn from_stats(ss: &StoreStats) -> Self {
        Self {
            journal_appended: ss.journal_appended.get(),
            journal_replayed: ss.journal_replayed.get(),
            journal_skips: ss.journal_skips.get(),
            journal_truncations: ss.journal_truncations.get(),
            cache_corrupt_misses: ss.cache_corrupt_misses.get(),
            files_quarantined: ss.files_quarantined.get(),
        }
    }

    pub fn any(&self) -> bool {
        self.journal_appended != 0
            || self.journal_replayed != 0
            || self.journal_skips != 0
            || self.any_degraded()
    }

    pub fn any_degraded(&self) -> bool {
        self.journal_truncations != 0
            || self.cache_corrupt_misses != 0
            || self.files_quarantined != 0
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("journal_appended".into(), num(self.journal_appended)),
            ("journal_replayed".into(), num(self.journal_replayed)),
            ("journal_skips".into(), num(self.journal_skips)),
            ("journal_truncations".into(), num(self.journal_truncations)),
            ("cache_corrupt_misses".into(), num(self.cache_corrupt_misses)),
            ("files_quarantined".into(), num(self.files_quarantined)),
        ])
    }
}

/// The consolidated counter snapshot.  See the module docs.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// on-disk Phase-1 sensitivity cache `(hits, misses)`
    pub sens_cache: (u64, u64),
    /// on-disk FP32-reference cache `(hits, misses)`
    pub ref_cache: (u64, u64),
    pub store: StoreCounters,
    /// present when an evaluation fleet is in play
    pub fleet: Option<FleetTelemetry>,
    /// wire-plane counters (heartbeats, injected transport faults,
    /// sheds, deadline cancels); all-zero in a healthy fault-free run
    pub wire: WireCounters,
}

impl Snapshot {
    /// Everything one pipeline can see: its cache cells, its store stats
    /// and (when pooled) the attached fleet's counters.
    pub fn from_pipeline(pipe: &Pipeline) -> Self {
        Self {
            sens_cache: pipe.sens_cache_stats(),
            ref_cache: pipe.ref_cache_stats(),
            store: StoreCounters::from_stats(pipe.store_stats()),
            fleet: pipe.pool.as_ref().map(|p| FleetTelemetry::collect(p.fleet())),
            wire: pipe
                .pool
                .as_ref()
                .map(|p| p.fleet().wire_counters())
                .unwrap_or_default(),
        }
    }

    /// Snapshot from a fleet + store pair with no pipeline in scope (the
    /// daemon's `Status` reply; cache cells live per-pipeline so they
    /// read zero here).
    pub fn from_parts(fleet: Option<&EvalFleet>, store: &StoreStats) -> Self {
        Self {
            sens_cache: (0, 0),
            ref_cache: (0, 0),
            store: StoreCounters::from_stats(store),
            fleet: fleet.map(FleetTelemetry::collect),
            wire: fleet.map(|f| f.wire_counters()).unwrap_or_default(),
        }
    }

    /// The drivers' compact one-line accounting.  Failure and durability
    /// sections appear only when those subsystems actually did something,
    /// so fault-free runs keep the familiar short form.
    pub fn note(&self) -> String {
        let (h, m) = self.sens_cache;
        let (rh, rm) = self.ref_cache;
        let w = self.fleet.as_ref().map(|f| f.workers).unwrap_or(0);
        let mut note = format!("sens-cache {h}h/{m}m, ref-cache {rh}h/{rm}m, fleet w={w}");
        if let Some(f) = &self.fleet {
            if f.failures.any() {
                note.push_str(&format!(
                    ", faults {} (restarts {}, requeued {}, degraded {})",
                    f.failures.faults_injected,
                    f.failures.worker_restarts,
                    f.failures.jobs_requeued,
                    f.failures.degraded_events.len()
                ));
            }
        }
        if self.store.any() {
            note.push_str(&format!(
                ", journal {}a/{}r/{}s",
                self.store.journal_appended,
                self.store.journal_replayed,
                self.store.journal_skips
            ));
            if self.store.any_degraded() {
                note.push_str(&format!(
                    " (truncated {}, corrupt-miss {}, quarantined {})",
                    self.store.journal_truncations,
                    self.store.cache_corrupt_misses,
                    self.store.files_quarantined
                ));
            }
        }
        if self.wire.any() {
            note.push_str(&format!(
                ", wire inj {} (hb {}p/{}x, retries {}, sheds {}, deadline-cancels {})",
                self.wire.injected(),
                self.wire.heartbeats_sent,
                self.wire.heartbeat_deaths,
                self.wire.retries,
                self.wire.sheds,
                self.wire.deadline_cancels
            ));
        }
        note
    }

    /// The machine-readable form: one JSON object with stable keys.
    pub fn to_json(&self) -> Json {
        let mut obj = vec![
            (
                "sens_cache".into(),
                Json::Obj(vec![
                    ("hits".into(), num(self.sens_cache.0)),
                    ("misses".into(), num(self.sens_cache.1)),
                ]),
            ),
            (
                "ref_cache".into(),
                Json::Obj(vec![
                    ("hits".into(), num(self.ref_cache.0)),
                    ("misses".into(), num(self.ref_cache.1)),
                ]),
            ),
            ("store".into(), self.store.to_json()),
        ];
        obj.push((
            "fleet".into(),
            match &self.fleet {
                Some(f) => f.to_json(),
                None => Json::Null,
            },
        ));
        obj.push((
            "wire".into(),
            Json::Obj(vec![
                ("frames_dropped".into(), num(self.wire.frames_dropped)),
                ("frames_corrupted".into(), num(self.wire.frames_corrupted)),
                ("frames_delayed".into(), num(self.wire.frames_delayed)),
                ("splits".into(), num(self.wire.splits)),
                ("resets".into(), num(self.wire.resets)),
                ("heartbeats_sent".into(), num(self.wire.heartbeats_sent)),
                ("heartbeat_deaths".into(), num(self.wire.heartbeat_deaths)),
                ("retries".into(), num(self.wire.retries)),
                ("deadline_cancels".into(), num(self.wire.deadline_cancels)),
                ("sheds".into(), num(self.wire.sheds)),
            ]),
        ));
        Json::Obj(obj)
    }
}

fn num(x: u64) -> Json {
    Json::Num(x as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            sens_cache: (3, 1),
            ref_cache: (2, 0),
            store: StoreCounters {
                journal_appended: 5,
                journal_replayed: 2,
                journal_skips: 2,
                ..Default::default()
            },
            fleet: Some(FleetTelemetry {
                workers: 4,
                model_opens: 2,
                probes_computed: 10,
                memo_hits: 6,
                failures: FailureStats::default(),
                worker_stats: vec![WorkerStats { compiled: 1, models_open: 1 }],
            }),
            wire: WireCounters::default(),
        }
    }

    #[test]
    fn note_matches_the_historical_driver_format() {
        let mut s = sample();
        assert_eq!(
            s.note(),
            "sens-cache 3h/1m, ref-cache 2h/0m, fleet w=4, journal 5a/2r/2s"
        );
        s.store = StoreCounters::default();
        s.fleet = None;
        assert_eq!(s.note(), "sens-cache 3h/1m, ref-cache 2h/0m, fleet w=0");
        s.store.files_quarantined = 1;
        assert_eq!(
            s.note(),
            "sens-cache 3h/1m, ref-cache 2h/0m, fleet w=0, journal 0a/0r/0s \
             (truncated 0, corrupt-miss 0, quarantined 1)"
        );
    }

    #[test]
    fn json_roundtrips_through_the_parser() {
        let s = sample();
        let text = s.to_json().to_string();
        let back = crate::jsonio::parse(&text).unwrap();
        assert_eq!(
            back.req("store").unwrap().req("journal_appended").unwrap().as_f64().unwrap(),
            5.0
        );
        assert_eq!(back.req("fleet").unwrap().req("workers").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(
            back.req("fleet").unwrap().req("worker_stats").unwrap().as_arr().unwrap().len(),
            1
        );
        let none = Snapshot { fleet: None, ..s };
        let back2 = crate::jsonio::parse(&none.to_json().to_string()).unwrap();
        assert!(back2.req("fleet").unwrap().is_null());
    }

    #[test]
    fn wire_counters_surface_only_when_something_happened() {
        let mut s = sample();
        // all-zero wire: the note keeps its historical shape
        assert!(!s.note().contains("wire"), "{}", s.note());
        let w = s.to_json().to_string();
        let back = crate::jsonio::parse(&w).unwrap();
        assert_eq!(
            back.req("wire").unwrap().req("heartbeats_sent").unwrap().as_f64().unwrap(),
            0.0
        );
        s.wire.frames_dropped = 2;
        s.wire.heartbeats_sent = 7;
        s.wire.heartbeat_deaths = 1;
        s.wire.sheds = 3;
        assert_eq!(s.wire.injected(), 2);
        let note = s.note();
        assert!(
            note.contains("wire inj 2 (hb 7p/1x, retries 0, sheds 3, deadline-cancels 0)"),
            "{note}"
        );
        let back = crate::jsonio::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.req("wire").unwrap().req("sheds").unwrap().as_f64().unwrap(), 3.0);
    }
}
