//! AdaRound integration (paper §3.5; Nagel et al. 2020).
//!
//! AdaRound learns, per layer, whether each weight rounds up or down, by
//! minimizing the layer-output MSE over calibration activations with an
//! annealed rounding regularizer:
//!
//! `loss(V) = ‖op(x, W) − op(x, Ŵ(V))‖² + λ Σ (1 − |2h(V)−1|^β)`,
//! `Ŵ(V) = s · clip(⌊W/s⌋ + h(V), qmin, qmax)`, `h = clip(1.2σ(V)−0.1, 0, 1)`.
//!
//! The split of labour follows the three-layer architecture: the per-layer
//! loss+gradient is an AOT artifact (`<m>.ar.<layer>.hlo.txt`, lowered with
//! `jax.value_and_grad`; the sim backend's `adaround` program kind mirrors
//! it), while the Adam loop, β annealing and the final hard rounding run
//! here.  Layer input activations come from the `taps` artifact, captured
//! once per calibration batch.
//!
//! Because AdaRound is *sequential and layer-wise* (paper §3.5), rounded
//! weights are computed once per `(layer, wbits)` and stitched into any
//! Phase-2 configuration — the cheap reuse the paper highlights.
//!
//! §Perf — fleet dispatch: the `(layer, wbits)` optimizations are mutually
//! independent, so [`plan_jobs`] materializes each one as a self-contained
//! [`AdaRoundJob`] (exe name, tap tensors, scales, Adam settings) and
//! [`adaround_all_pooled`] ships them to [`crate::pool::EvalPool`] workers
//! round-robin — layers anneal concurrently on N private clients.  A job
//! is deterministic given its inputs (the Adam loop is seeded by
//! `cfg.seed ^ param_idx` and the executables are deterministic per
//! backend), so pooled results are **bit-identical** to
//! [`adaround_all`]'s, which runs the same jobs on the caller's client.

//!
//! With a [`crate::store::JournalScope`] attached, every completed
//! `(layer, wbits)` rounded tensor is appended to the crash-safe run
//! journal (MPQT-encoded, keyed by the AdaRound-scope content digest),
//! and a `--resume` run replays journaled tensors bit-exactly, running
//! only the optimizations the crash interrupted; when *all* are
//! journaled the caller can skip tap capture entirely
//! ([`expected_keys`]).

use crate::manifest::{Manifest, ModelEntry};
use crate::model::ModelHandle;
use crate::pool::EvalPool;
use crate::quant;
use crate::runtime::{Buffer, Exe, Runtime};
use crate::sensitivity::RoundedWeights;
use crate::store::{self, JournalScope};
use crate::tensor::{io as tio, Tensor};
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};

/// AdaRound optimizer settings.
#[derive(Clone, Debug)]
pub struct AdaRoundCfg {
    pub steps: usize,
    pub lr: f32,
    pub lambda: f32,
    /// β anneals linearly from `beta_hi` to `beta_lo` after a 20% warmup
    pub beta_hi: f32,
    pub beta_lo: f32,
    /// number of calibration batches to capture taps for
    pub tap_batches: usize,
    pub seed: u64,
}

impl Default for AdaRoundCfg {
    fn default() -> Self {
        Self {
            steps: 120,
            lr: 2e-2,
            lambda: 0.01,
            beta_hi: 20.0,
            beta_lo: 2.0,
            tap_batches: 2,
            seed: 0,
        }
    }
}

/// Captured layer-input activations: `taps[layer][batch]`.
pub struct Taps {
    per_layer: Vec<Vec<Tensor>>,
}

/// One self-contained `(layer, wbits)` rounding optimization — everything
/// a fleet worker (or the local client) needs, with no handle state
/// attached.  Weights themselves are *not* shipped: workers hold their own
/// bit-identical copy of the trained parameters.
#[derive(Clone, Debug)]
pub struct AdaRoundJob {
    /// per-layer step artifact (manifest-relative file name)
    pub exe: String,
    /// FP layer-input activations for this layer (host tensors)
    pub taps: Vec<Tensor>,
    pub param_idx: usize,
    pub bias_idx: usize,
    /// per-channel MSE-optimal scales at `bits`
    pub scales: Vec<f32>,
    pub channel_axis: usize,
    pub bits: u8,
    pub cfg: AdaRoundCfg,
}

/// Capture layer inputs by running the FP taps executable on calibration
/// batches.
pub fn capture_taps(
    handle: &ModelHandle,
    manifest: &Manifest,
    batches: &[Buffer],
    n_batches: usize,
) -> Result<Taps> {
    let taps_file = handle
        .entry
        .taps
        .as_ref()
        .ok_or_else(|| anyhow!("{} has no taps artifact", handle.entry.name))?;
    let exe = handle.rt.load(manifest.path(taps_file))?;
    let n_layers = handle.entry.layers.len();
    let mut per_layer = vec![Vec::new(); n_layers];
    for xb in batches.iter().take(n_batches) {
        // trained parameters are already device-resident on the handle —
        // no per-batch re-upload
        let mut args: Vec<&Buffer> = vec![xb];
        args.extend(handle.param_buffers().iter());
        let outs = exe.run_b(&args)?;
        if outs.len() != n_layers + 1 {
            bail!("taps exe returned {} outputs, want {}", outs.len(), n_layers + 1);
        }
        for (l, t) in outs.into_iter().take(n_layers).enumerate() {
            per_layer[l].push(t);
        }
    }
    Ok(Taps { per_layer })
}

/// Materialize the independent `(layer, wbits)` optimizations for every
/// AdaRound-capable layer at each of `wbits_options`, keyed by
/// `(param_idx, wbits)` — the unit of work both the serial and the pooled
/// path execute.
pub fn plan_jobs(
    handle: &ModelHandle,
    taps: &Taps,
    wbits_options: &[u8],
    cfg: &AdaRoundCfg,
) -> Result<Vec<((usize, u8), AdaRoundJob)>> {
    let entry = &handle.entry;
    let mut out = Vec::new();
    for &bits in wbits_options {
        for ar in &entry.adaround {
            let pidx = entry.param_idx(&ar.param)?;
            let wq_idx = entry
                .w_quantizers
                .iter()
                .position(|q| q.param_idx == pidx)
                .ok_or_else(|| anyhow!("no weight quantizer for {}", ar.param))?;
            let scales = handle
                .w_scales
                .get(&bits)
                .ok_or_else(|| anyhow!("weight scales for {bits} bits missing"))?[wq_idx]
                .clone();
            if ar.tap_index >= taps.per_layer.len() {
                bail!("tap index {} out of range for {}", ar.tap_index, ar.layer);
            }
            out.push((
                (pidx, bits),
                AdaRoundJob {
                    exe: ar.exe.clone(),
                    taps: taps.per_layer[ar.tap_index].clone(),
                    param_idx: pidx,
                    bias_idx: entry.param_idx(&ar.bias)?,
                    scales,
                    channel_axis: entry.w_quantizers[wq_idx].channel_axis,
                    bits,
                    cfg: cfg.clone(),
                },
            ));
        }
    }
    Ok(out)
}

/// Every `(param_idx, wbits)` key a full AdaRound pass over
/// `wbits_options` produces — computable *without* taps or scales, so a
/// resuming caller can test journal completeness (and skip tap capture)
/// before doing any work.  Same iteration order as [`plan_jobs`].
pub fn expected_keys(entry: &ModelEntry, wbits_options: &[u8]) -> Result<Vec<(usize, u8)>> {
    let mut out = Vec::new();
    for &bits in wbits_options {
        for ar in &entry.adaround {
            out.push((entry.param_idx(&ar.param)?, bits));
        }
    }
    Ok(out)
}

/// Journal lookup of one rounded tensor (MPQT payload, bit-exact).
pub fn journal_lookup(journal: &JournalScope, key: (usize, u8)) -> Result<Option<Tensor>> {
    let k = store::adaround_key(journal.base, key.0, key.1);
    match journal.journal.lookup(store::kind::ADAROUND, k) {
        None => Ok(None),
        Some(payload) => {
            let mut ts = tio::decode_tensors(&payload)
                .with_context(|| format!("journaled AdaRound tensor for {key:?}"))?;
            if ts.len() != 1 {
                bail!("journaled AdaRound record for {key:?} holds {} tensors", ts.len());
            }
            Ok(Some(ts.pop().unwrap()))
        }
    }
}

fn journal_record(journal: Option<&JournalScope>, key: (usize, u8), t: &Tensor) -> Result<()> {
    if let Some(j) = journal {
        j.journal.record(
            store::kind::ADAROUND,
            store::adaround_key(j.base, key.0, key.1),
            &tio::encode_tensors(std::slice::from_ref(t)),
        )?;
    }
    Ok(())
}

/// Run one planned `(layer, wbits)` job on the caller's client — the unit
/// both [`adaround_all`] and a resumed partial pass execute.
pub fn run_job(handle: &ModelHandle, manifest: &Manifest, job: &AdaRoundJob) -> Result<Tensor> {
    let exe = handle.rt.load(manifest.path(&job.exe))?;
    optimize_rounding(
        &handle.rt,
        &exe,
        &handle.weights[job.param_idx],
        &handle.weights[job.bias_idx],
        job,
    )
}

/// Run AdaRound for every layer at each of `wbits_options` on the caller's
/// client; returns the stitchable rounded-weight cache.  With a journal
/// attached, journaled `(layer, wbits)` tensors are replayed bit-exactly
/// and each freshly optimized tensor is appended as a barrier.
pub fn adaround_all(
    handle: &ModelHandle,
    manifest: &Manifest,
    taps: &Taps,
    wbits_options: &[u8],
    cfg: &AdaRoundCfg,
    journal: Option<&JournalScope>,
) -> Result<RoundedWeights> {
    let mut out = RoundedWeights::new();
    for (key, job) in plan_jobs(handle, taps, wbits_options, cfg)? {
        if let Some(j) = journal {
            if let Some(t) = journal_lookup(j, key)? {
                out.insert(key, t);
                continue;
            }
        }
        let rounded = run_job(handle, manifest, &job)?;
        journal_record(journal, key, &rounded)?;
        out.insert(key, rounded);
    }
    Ok(out)
}

/// Like [`adaround_all`], but each `(layer, wbits)` optimization is
/// dispatched as a fleet job — independent layers anneal concurrently, and
/// the rounded tensors are bit-identical to the serial path's.  Journaled
/// jobs never enter the fleet; fresh results are journaled in dispatch
/// order as they are collected.
pub fn adaround_all_pooled(
    pool: &EvalPool,
    handle: &ModelHandle,
    taps: &Taps,
    wbits_options: &[u8],
    cfg: &AdaRoundCfg,
    journal: Option<&JournalScope>,
) -> Result<RoundedWeights> {
    let planned = plan_jobs(handle, taps, wbits_options, cfg)?;
    let mut out = RoundedWeights::new();
    let mut todo_keys = Vec::new();
    let mut todo_jobs = Vec::new();
    for (key, job) in planned {
        match journal.map(|j| journal_lookup(j, key)).transpose()?.flatten() {
            Some(t) => {
                out.insert(key, t);
            }
            None => {
                todo_keys.push(key);
                todo_jobs.push(job);
            }
        }
    }
    if !todo_jobs.is_empty() {
        let rounded = pool.adaround_jobs(todo_jobs)?;
        for (key, t) in todo_keys.into_iter().zip(rounded) {
            journal_record(journal, key, &t)?;
            out.insert(key, t);
        }
    }
    Ok(out)
}

/// Optimize one layer's rounding variables and return the hard-rounded,
/// fake-quantized weight tensor.  Pure function of its inputs: the Adam
/// loop is seeded from `job.cfg.seed ^ job.param_idx`, so the serial
/// client and any fleet worker produce the same tensor.
pub fn optimize_rounding(
    rt: &Runtime,
    exe: &Exe,
    w: &Tensor,
    b: &Tensor,
    job: &AdaRoundJob,
) -> Result<Tensor> {
    let (taps, scales, cfg) = (&job.taps, &job.scales[..], &job.cfg);
    if taps.is_empty() {
        bail!("no taps captured");
    }
    let (qmin, qmax) = quant::weight_qrange(job.bits);

    // initialize V so that h(V) equals the fractional part of w/s — i.e.
    // the soft rounding starts at nearest-rounding (Nagel et al. §4)
    let wv = w.f32s()?;
    let view_shape = &w.shape;
    let mut v0 = vec![0f32; wv.len()];
    let cview = ChannelIter::new(view_shape, scales.len(), job.channel_axis);
    for c in 0..scales.len() {
        let s = scales[c].max(1e-12);
        cview.for_each(c, |i| {
            let frac = (wv[i] / s - (wv[i] / s).floor()).clamp(0.01, 0.99);
            // h(V) = clip(1.2σ(V) − 0.1) ⇒ σ(V) = (h+0.1)/1.2
            let sig = ((frac + 0.1) / 1.2).clamp(1e-4, 1.0 - 1e-4);
            v0[i] = (sig / (1.0 - sig)).ln();
        });
    }

    // backend-resident constants
    let w_buf = rt.buffer(w)?;
    let b_buf = rt.buffer(b)?;
    let s_buf = rt.buffer(&Tensor::from_f32(&[scales.len()], scales.to_vec())?)?;
    let tap_bufs: Vec<Buffer> = taps.iter().map(|t| rt.buffer(t)).collect::<Result<_>>()?;

    // Adam state
    let mut v = v0;
    let mut m = vec![0f32; v.len()];
    let mut s2 = vec![0f32; v.len()];
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let mut rng = Rng::new(cfg.seed ^ job.param_idx as u64);
    let warmup = cfg.steps / 5;

    for step in 0..cfg.steps {
        let beta = if step < warmup {
            cfg.beta_hi
        } else {
            let t = (step - warmup) as f32 / (cfg.steps - warmup).max(1) as f32;
            cfg.beta_hi + (cfg.beta_lo - cfg.beta_hi) * t
        };
        let meta = Tensor::from_f32(&[4], vec![qmin, qmax, beta, cfg.lambda])?;
        let v_t = Tensor::from_f32(&w.shape, v.clone())?;
        let xb = &tap_bufs[rng.below(tap_bufs.len())];
        let v_buf = rt.buffer(&v_t)?;
        let meta_buf = rt.buffer(&meta)?;
        let args: Vec<&Buffer> = vec![xb, &w_buf, &b_buf, &v_buf, &s_buf, &meta_buf];
        let outs = exe.run_b(&args)?;
        if outs.len() != 2 {
            bail!("adaround exe returned {} outputs", outs.len());
        }
        let g = outs[1].f32s()?;
        let t = (step + 1) as f32;
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        for i in 0..v.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            s2[i] = b2 * s2[i] + (1.0 - b2) * g[i] * g[i];
            v[i] -= cfg.lr * (m[i] / bc1) / ((s2[i] / bc2).sqrt() + eps);
        }
    }

    // hard rounding: Ŵ = s · clip(⌊W/s⌋ + (h(V) ≥ 0.5), qmin, qmax)
    let mut out = vec![0f32; wv.len()];
    for c in 0..scales.len() {
        let s = scales[c].max(1e-12);
        cview.for_each(c, |i| {
            let h = (1.2 / (1.0 + (-v[i]).exp()) - 0.1).clamp(0.0, 1.0);
            let up = if h >= 0.5 { 1.0 } else { 0.0 };
            let q = ((wv[i] / s).floor() + up).clamp(qmin, qmax);
            out[i] = q * s;
        });
    }
    Tensor::from_f32(&w.shape, out)
}

/// Channel-major index iteration (same layout logic as `quant`).
struct ChannelIter {
    outer: usize,
    channels: usize,
    inner: usize,
}

impl ChannelIter {
    fn new(shape: &[usize], channels: usize, channel_axis: usize) -> Self {
        let outer: usize = shape[..channel_axis].iter().product();
        let inner: usize = shape[channel_axis + 1..].iter().product();
        Self { outer, channels, inner }
    }

    fn for_each(&self, c: usize, mut f: impl FnMut(usize)) {
        for o in 0..self.outer {
            let base = (o * self.channels + c) * self.inner;
            for i in 0..self.inner {
                f(base + i);
            }
        }
    }
}
