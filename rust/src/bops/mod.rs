//! Bit-Operations ledger (paper Eq. 5):
//! `BOPS(φ) = Σ_ops bits(φ_i) · MAC(op_i)` with `bits = b_w · b_a`.
//!
//! BOPs is the platform-independent efficiency surrogate; all results are
//! reported as *relative* BOPs `r` w.r.t. the fixed W8A16 network, matching
//! the paper's tables (W8A8 → r=0.50, W6A8 → 0.375, W6A6 → 0.281, …).

use crate::groups::{Assignment, Candidate, Lattice};
use crate::manifest::ModelEntry;

/// Absolute BOPs of an assignment.
pub fn bops(entry: &ModelEntry, asg: &Assignment) -> u64 {
    entry
        .groups
        .iter()
        .zip(&asg.per_group)
        .map(|(g, c)| g.macs * c.bops_factor())
        .sum()
}

/// BOPs of the homogeneous `cand` network.
pub fn bops_fixed(entry: &ModelEntry, cand: Candidate) -> u64 {
    entry.total_macs * cand.bops_factor()
}

/// Relative BOPs `r` w.r.t. fixed W8A16 (the paper's reference point).
pub fn rel_bops(entry: &ModelEntry, asg: &Assignment) -> f64 {
    bops(entry, asg) as f64 / bops_fixed(entry, Candidate::new(8, 16)) as f64
}

/// Relative BOPs of a homogeneous configuration.
pub fn rel_bops_fixed(cand: Candidate) -> f64 {
    cand.bops_factor() as f64 / Candidate::new(8, 16).bops_factor() as f64
}

/// BOPs reduction obtained by flipping group `g` to `cand` from its current
/// assignment (0 if not an improvement).
pub fn flip_gain(entry: &ModelEntry, asg: &Assignment, g: usize, cand: Candidate) -> u64 {
    let cur = asg.per_group[g].bops_factor();
    let new = cand.bops_factor();
    if new >= cur {
        0
    } else {
        entry.groups[g].macs * (cur - new)
    }
}

/// Lower bound on achievable `r` for a lattice (everything at the cheapest
/// candidate; weightless groups pinned at baseline contribute 0 MACs).
pub fn min_rel_bops(entry: &ModelEntry, lattice: &Lattice) -> f64 {
    let cheapest = lattice
        .candidates
        .iter()
        .copied()
        .min_by_key(|c| c.bops_factor())
        .unwrap_or(lattice.baseline);
    let mut asg = Assignment::baseline(entry, lattice);
    for g in 0..entry.groups.len() {
        if Assignment::flippable(entry, g) {
            asg.set(g, cheapest);
        }
    }
    rel_bops(entry, &asg)
}

/// Hand-built fixtures shared by unit tests across modules.
#[cfg(test)]
pub mod tests_support {
    use crate::manifest::{ActQ, DataFiles, Group, Layer, ModelEntry, ParamInfo, WQ};

    /// Two weighted groups (300/700 MACs) plus one weightless group.
    pub fn toy_entry() -> ModelEntry {
        ModelEntry {
            name: "toy".into(),
            task: "classify10".into(),
            batch: 1,
            input_shape: vec![1],
            input_is_i32: false,
            forward: String::new(),
            stats: String::new(),
            stats_bits: vec![4, 8],
            stats_ratios: vec![1.0],
            weights_file: String::new(),
            params: vec![
                ParamInfo { name: "a.w".into(), shape: vec![4, 4] },
                ParamInfo { name: "b.w".into(), shape: vec![4, 4] },
            ],
            out_shape: vec![1, 10],
            act_quantizers: vec![
                ActQ { name: "in".into(), numel: 16 },
                ActQ { name: "a.out".into(), numel: 16 },
                ActQ { name: "b.out".into(), numel: 16 },
            ],
            w_quantizers: vec![
                WQ { name: "a.w".into(), param_idx: 0, channels: 4, channel_axis: 0 },
                WQ { name: "b.w".into(), param_idx: 1, channels: 4, channel_axis: 0 },
            ],
            layers: vec![
                Layer { name: "a".into(), macs: 300, w_q: 0, in_acts: vec![0] },
                Layer { name: "b".into(), macs: 700, w_q: 1, in_acts: vec![1] },
            ],
            groups: vec![
                Group { w_q: vec![0], act_q: vec![0], macs: 300 },
                Group { w_q: vec![1], act_q: vec![1], macs: 700 },
                Group { w_q: vec![], act_q: vec![2], macs: 0 },
            ],
            total_macs: 1000,
            cmax: 4,
            fp32_val_metric: 1.0,
            data: DataFiles {
                calib: String::new(),
                calib_labels: String::new(),
                val: String::new(),
                val_labels: String::new(),
                ood_calib: None,
            },
            taps: None,
            adaround: vec![],
            fit: None,
            fit_act_shapes: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::toy_entry;
    use super::*;

    #[test]
    fn baseline_r_is_one() {
        let e = toy_entry();
        let l = Lattice::practical();
        let asg = Assignment::baseline(&e, &l);
        assert!((rel_bops(&e, &asg) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_r_matches_hand_count() {
        let e = toy_entry();
        let l = Lattice::practical();
        let mut asg = Assignment::baseline(&e, &l);
        asg.set(0, Candidate::new(4, 8)); // 300 macs * 32
        // group1 stays 8*16=128 → (300*32 + 700*128) / (1000*128)
        let want = (300.0 * 32.0 + 700.0 * 128.0) / 128000.0;
        assert!((rel_bops(&e, &asg) - want).abs() < 1e-12);
    }

    #[test]
    fn flip_gain_zero_for_upgrades() {
        let e = toy_entry();
        let l = Lattice::practical();
        let mut asg = Assignment::baseline(&e, &l);
        asg.set(0, Candidate::new(4, 8));
        assert_eq!(flip_gain(&e, &asg, 0, Candidate::new(8, 8)), 0);
        assert_eq!(flip_gain(&e, &asg, 1, Candidate::new(8, 8)), 700 * 64);
    }

    #[test]
    fn min_rel_bops_practical() {
        let e = toy_entry();
        let l = Lattice::practical();
        // all flippable groups at W4A8: 1000*32/128000
        assert!((min_rel_bops(&e, &l) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn partition_validates() {
        let e = toy_entry();
        Assignment::validate_partition(&e).unwrap();
    }

    #[test]
    fn per_quantizer_expansion() {
        let e = toy_entry();
        let l = Lattice::practical();
        let mut asg = Assignment::baseline(&e, &l);
        asg.set(0, Candidate::new(4, 8));
        let (act, w) = asg.per_quantizer(&e);
        assert_eq!(w, vec![Some(4), Some(8)]);
        assert_eq!(act, vec![Some(8), Some(16), Some(16)]);
    }
}
