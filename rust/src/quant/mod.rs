//! Uniform affine quantization substrate (paper §3.1, Eq. 1-2).
//!
//! Conventions (fixed across the whole stack, mirrored by the L1 kernels):
//!
//! * **Weights** — symmetric per-channel: integer grid
//!   `[-(2^(b-1)-1), 2^(b-1)-1]`, offset 0, one scale per output channel.
//! * **Activations** — asymmetric per-tensor: grid `[0, 2^b-1]`, scale +
//!   integer offset (zero-point).
//!
//! Ranges are estimated with the paper's *MSE based criteria* (§4): weights
//! are grid-searched here over clipping ratios of the per-channel abs-max;
//! activation grids are evaluated **inside** the AOT `stats` executable
//! (the activations only exist on device) and the argmin ratio is selected
//! here — see [`ActRanges`].

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Clipping-ratio grid shared with `python/compile/aot.py` (`STATS_RATIOS`).
pub fn default_ratios() -> Vec<f64> {
    (0..15).map(|i| 0.30 + 0.05 * i as f64).collect()
}

/// Integer grid for a symmetric signed b-bit weight quantizer.
pub fn weight_qrange(bits: u8) -> (f32, f32) {
    let m = ((1i64 << (bits - 1)) - 1) as f32;
    (-m, m)
}

/// Integer grid for an asymmetric unsigned b-bit activation quantizer.
pub fn act_qrange(bits: u8) -> (f32, f32) {
    (0.0, ((1i64 << bits) - 1) as f32)
}

/// Fake-quantize one value (reference scalar path, used by tests and the
/// AdaRound stitcher).
#[inline]
pub fn fq(x: f32, scale: f32, offset: f32, qmin: f32, qmax: f32) -> f32 {
    let s = scale.max(1e-12);
    let q = (x / s + offset).round().clamp(qmin, qmax);
    (q - offset) * s
}

/// Per-channel symmetric weight scales for `bits`, MSE-search over clipping
/// ratios of the channel abs-max.
///
/// `w` is viewed as `(C, rest)` after moving `channel_axis` to the front.
pub fn weight_scales_mse(
    w: &Tensor,
    channels: usize,
    channel_axis: usize,
    bits: u8,
    ratios: &[f64],
) -> Result<Vec<f32>> {
    let (_, qmax) = weight_qrange(bits);
    let v = w.f32s()?;
    let view = ChannelView::new(&w.shape, channels, channel_axis)?;
    let mut scales = vec![0f32; channels];
    for c in 0..channels {
        let mut amax = 0f32;
        view.for_each(v, c, |x| amax = amax.max(x.abs()));
        if amax == 0.0 {
            scales[c] = 1e-8;
            continue;
        }
        let mut best = (f64::INFINITY, amax / qmax);
        for &r in ratios {
            let s = (amax * r as f32) / qmax;
            let mut err = 0f64;
            view.for_each(v, c, |x| {
                let d = x - fq(x, s, 0.0, -qmax, qmax);
                err += (d * d) as f64;
            });
            if err < best.0 {
                best = (err, s);
            }
        }
        scales[c] = best.1;
    }
    Ok(scales)
}

/// Fake-quantize a weight tensor per channel (host-side; used for FIT's
/// weight error terms and tests — the hot path runs the L1 kernel).
pub fn quantize_weight(
    w: &Tensor,
    scales: &[f32],
    channel_axis: usize,
    bits: u8,
) -> Result<Tensor> {
    let (qmin, qmax) = weight_qrange(bits);
    let v = w.f32s()?;
    let view = ChannelView::new(&w.shape, scales.len(), channel_axis)?;
    let mut out = v.to_vec();
    for c in 0..scales.len() {
        view.for_each_idx(c, |i| {
            out[i] = fq(v[i], scales[c], 0.0, qmin, qmax);
        });
    }
    Tensor::from_f32(&w.shape, out)
}

/// Mean squared quantization error of a weight tensor at `bits`.
pub fn weight_quant_mse(
    w: &Tensor,
    scales: &[f32],
    channel_axis: usize,
    bits: u8,
) -> Result<f64> {
    let q = quantize_weight(w, scales, channel_axis, bits)?;
    let (a, b) = (w.f32s()?, q.f32s()?);
    let mut err = 0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (x - y) as f64;
        err += d * d;
    }
    Ok(err / a.len() as f64)
}

/// Iterate elements of channel `c` when the tensor is viewed as
/// `(..., C at channel_axis, ...)`.
struct ChannelView {
    outer: usize,
    channels: usize,
    inner: usize,
}

impl ChannelView {
    fn new(shape: &[usize], channels: usize, channel_axis: usize) -> Result<Self> {
        if channel_axis >= shape.len() || shape[channel_axis] != channels {
            bail!(
                "channel axis {channel_axis} (C={channels}) invalid for shape {shape:?}"
            );
        }
        let outer: usize = shape[..channel_axis].iter().product();
        let inner: usize = shape[channel_axis + 1..].iter().product();
        Ok(Self { outer, channels, inner })
    }

    fn for_each_idx(&self, c: usize, mut f: impl FnMut(usize)) {
        for o in 0..self.outer {
            let base = (o * self.channels + c) * self.inner;
            for i in 0..self.inner {
                f(base + i);
            }
        }
    }

    fn for_each(&self, v: &[f32], c: usize, mut f: impl FnMut(f32)) {
        self.for_each_idx(c, |i| f(v[i]));
    }
}

/// Per-activation-quantizer range state, distilled from the AOT `stats`
/// executable's output grids.
#[derive(Clone, Debug)]
pub struct ActRanges {
    /// global (min, max) per activation quantizer
    pub minmax: Vec<(f32, f32)>,
    /// averaged MSE grid `[A][NB][NK]`
    pub mse: Vec<Vec<Vec<f64>>>,
    pub bits: Vec<u8>,
    pub ratios: Vec<f64>,
}

impl ActRanges {
    pub fn new(n_act: usize, bits: Vec<u8>, ratios: Vec<f64>) -> Self {
        Self {
            minmax: vec![(f32::INFINITY, f32::NEG_INFINITY); n_act],
            mse: vec![vec![vec![0.0; ratios.len()]; bits.len()]; n_act],
            bits,
            ratios,
        }
    }

    /// Fold in one batch of captured activations (one tensor per act
    /// quantizer, from the AOT `stats` capture executable).
    ///
    /// Per tensor: global (min, max) are tracked exactly; the per-(bits,
    /// ratio) quantization MSE is the rounding error on a strided
    /// `SAMPLE`-element subsample plus the clipping error on the full
    /// tensor (a subsample alone under-observes the tails and biases the
    /// argmin toward over-aggressive clipping).
    pub fn accumulate(&mut self, acts: &[Tensor], batches_total: usize) -> Result<()> {
        const SAMPLE: usize = 4096;
        let a = self.minmax.len();
        if acts.len() != a {
            bail!("captured {} act tensors, want {a}", acts.len());
        }
        let (nb, nk) = (self.bits.len(), self.ratios.len());
        let w = 1.0 / batches_total as f64;
        for i in 0..a {
            let v = acts[i].f32s()?;
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in v {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            self.minmax[i].0 = self.minmax[i].0.min(lo);
            self.minmax[i].1 = self.minmax[i].1.max(hi);
            let stride = (v.len() / SAMPLE).max(1);
            for k in 0..nk {
                let r = self.ratios[k] as f32;
                let (lo_r, hi_r) = (lo * r, hi * r);
                // clipping error, full tensor (bits-independent)
                let mut clip = 0f64;
                for &x in v {
                    let d = (x - x.clamp(lo_r, hi_r)) as f64;
                    clip += d * d;
                }
                clip /= v.len() as f64;
                for b in 0..nb {
                    let levels = ((1i64 << self.bits[b]) - 1) as f32;
                    let s = ((hi_r - lo_r) / levels).max(1e-12);
                    let o = (-lo_r / s).round().clamp(0.0, levels);
                    let mut round = 0f64;
                    let mut n = 0usize;
                    let mut j = 0usize;
                    while j < v.len() && n < SAMPLE {
                        let xc = v[j].clamp(lo_r, hi_r);
                        let q = (xc / s + o).round().clamp(0.0, levels);
                        let d = (xc - (q - o) * s) as f64;
                        round += d * d;
                        n += 1;
                        j += stride;
                    }
                    self.mse[i][b][k] += (round / n.max(1) as f64 + clip) * w;
                }
            }
        }
        Ok(())
    }

    /// MSE-optimal (scale, offset) for activation quantizer `aq` at `bits`.
    pub fn qparams(&self, aq: usize, bits: u8) -> Result<(f32, f32)> {
        let b = self
            .bits
            .iter()
            .position(|&x| x == bits)
            .ok_or_else(|| anyhow::anyhow!("bits {bits} not in stats grid {:?}", self.bits))?;
        let grid = &self.mse[aq][b];
        // total_cmp: a NaN grid cell (degenerate stats batch) must not
        // panic range selection
        let k = grid
            .iter()
            .enumerate()
            .min_by(|x, y| x.1.total_cmp(y.1))
            .map(|(k, _)| k)
            .unwrap_or(self.ratios.len() - 1);
        let r = self.ratios[k] as f32;
        let (lo, hi) = self.minmax[aq];
        let (lo_r, hi_r) = (lo * r, hi * r);
        let levels = ((1i64 << bits) - 1) as f32;
        let s = ((hi_r - lo_r) / levels).max(1e-12);
        let o = (-lo_r / s).round().clamp(0.0, levels);
        Ok((s, o))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qranges() {
        assert_eq!(weight_qrange(8), (-127.0, 127.0));
        assert_eq!(weight_qrange(4), (-7.0, 7.0));
        assert_eq!(act_qrange(8), (0.0, 255.0));
        assert_eq!(act_qrange(4), (0.0, 15.0));
    }

    #[test]
    fn fq_is_idempotent() {
        // property: fake-quantizing a fake-quantized value is a fixpoint
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..500 {
            let x = (rng.f64() as f32 - 0.5) * 8.0;
            let s = 0.01 + rng.f64() as f32 * 0.2;
            let y = fq(x, s, 0.0, -127.0, 127.0);
            let z = fq(y, s, 0.0, -127.0, 127.0);
            assert!((y - z).abs() < 1e-6, "x={x} y={y} z={z}");
        }
    }

    #[test]
    fn fq_error_bounded_by_half_scale_in_range() {
        let s = 0.05;
        for i in -100..100 {
            let x = i as f32 * 0.031;
            if x.abs() < 127.0 * s {
                let y = fq(x, s, 0.0, -127.0, 127.0);
                assert!((x - y).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn weight_scales_lower_bits_bigger_error() {
        let mut rng = crate::util::Rng::new(3);
        let data: Vec<f32> = (0..4 * 18).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect();
        let w = Tensor::from_f32(&[4, 18], data).unwrap();
        let ratios = default_ratios();
        let s8 = weight_scales_mse(&w, 4, 0, 8, &ratios).unwrap();
        let s4 = weight_scales_mse(&w, 4, 0, 4, &ratios).unwrap();
        let e8 = weight_quant_mse(&w, &s8, 0, 8).unwrap();
        let e4 = weight_quant_mse(&w, &s4, 0, 4).unwrap();
        assert!(e4 > e8 * 10.0, "e4={e4} e8={e8}");
    }

    #[test]
    fn weight_scales_respect_channel_axis() {
        // channel 0 small magnitude, channel 1 large — scales must differ
        let w = Tensor::from_f32(&[8, 2], (0..16).map(|i| if i % 2 == 0 { 0.01 } else { 5.0 }).collect()).unwrap();
        let s = weight_scales_mse(&w, 2, 1, 8, &default_ratios()).unwrap();
        assert!(s[1] > s[0] * 50.0, "{s:?}");
    }

    #[test]
    fn act_ranges_uniform_data_picks_full_range() {
        // uniform data in [-1, 3]: no tail to clip, so at high bits the
        // argmin ratio must be ~1.0 and the scale must cover the range
        let mut ar = ActRanges::new(1, vec![4, 16], default_ratios());
        let n = 8192;
        let data: Vec<f32> = (0..n).map(|i| -1.0 + 4.0 * i as f32 / (n - 1) as f32).collect();
        let t = Tensor::from_f32(&[n], data).unwrap();
        ar.accumulate(&[t], 1).unwrap();
        let (s, o) = ar.qparams(0, 16).unwrap();
        assert!((s * 65535.0 - 4.0).abs() < 0.05, "covered range {}", s * 65535.0);
        assert!((o - (1.0f32 / s).round()).abs() <= 1.0);
        assert!(ar.qparams(0, 6).is_err());
    }

    #[test]
    fn act_ranges_heavy_tail_clips() {
        // 99% of mass in [0,1], a few samples at 100: MSE-optimal 4-bit
        // range should clip far below 100
        let mut ar = ActRanges::new(1, vec![4, 16], default_ratios());
        let mut data = vec![0f32; 10000];
        let mut rng = crate::util::Rng::new(5);
        for x in data.iter_mut() {
            *x = rng.f64() as f32;
        }
        data[0] = 10.0;
        data[5000] = 10.0;
        let t = Tensor::from_f32(&[10000], data).unwrap();
        ar.accumulate(&[t], 1).unwrap();
        let (s4, _) = ar.qparams(0, 4).unwrap();
        assert!(s4 * 15.0 < 6.0, "4-bit covered range {}", s4 * 15.0);
        // 16-bit still covers (rounding error negligible, clipping dominates)
        let (s16, _) = ar.qparams(0, 16).unwrap();
        assert!(s16 * 65535.0 > 6.0, "16-bit covered range {}", s16 * 65535.0);
    }

    #[test]
    fn act_ranges_batch_count_mismatch() {
        let mut ar = ActRanges::new(2, vec![8], default_ratios());
        let t = Tensor::zeros(&[4]);
        assert!(ar.accumulate(&[t], 1).is_err());
    }
}
