//! Fleet worker thread: one private backend client serving every attached
//! model.
//!
//! A worker owns exactly one [`Runtime`] (PJRT client or sim interpreter)
//! for its whole life, created inside the thread — PJRT state is `!Send`
//! and never crosses the channel.  On top of that one runtime the worker
//! keeps a **per-model slot map**: a [`ModelHandle`] (compiled forward
//! executable + resident trained parameters + engine caches) plus the
//! worker's shard of every eval set registered for that model.  Slots are
//! opened **lazily on first use** and dropped on `Detach`, and because the
//! runtime's executable cache is shared across models and outlives them
//! (until the *worker* dies), attaching a second model never recompiles
//! the first model's executables — the property the fleet's compile
//! counters assert.
//!
//! Upload jobs (`LoadSet`, `BuildReference`, `Calibrate`) are
//! fire-and-forget from the front-end: the worker records failures in the
//! affected slot instead of replying, and the stored error is surfaced by
//! the first *tracked* job (a probe, a FIT shard, a reference fetch) that
//! touches the broken state.  The per-worker queue is FIFO, so a probe
//! enqueued after its set's upload is always served after the upload
//! completed — ordering, not blocking, is the correctness mechanism.

use super::fault::FaultState;
use super::{FitShard, Job, Partial, ProbeKind, Request, ResMsg, SetKey, WorkerStats, DEATH_NOTICE};
use crate::adaround;
use crate::engine::{FpReference, StreamingSqnr};
use crate::manifest::Manifest;
use crate::metrics::StreamingTaskMetric;
use crate::model::{EvalSet, ModelHandle};
use crate::runtime::{Buffer, Runtime};
use crate::sensitivity;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// A worker's view of one registered eval set: the resident shard plus
/// where it starts in the full set.  `Failed` keeps the upload error of a
/// fire-and-forget `LoadSet` so the first dependent job reports the root
/// cause instead of a bare "set not loaded".
enum ShardSlot {
    Ready(Shard),
    Failed(String),
}

struct Shard {
    set: EvalSet,
    first_batch: usize,
}

/// One attached model on this worker.
struct WorkerModel {
    handle: ModelHandle,
    shards: HashMap<SetKey, ShardSlot>,
    /// zero perturbation buffers for the FIT executable, uploaded once on
    /// the first `Fit` request and reused across every bit-width pass
    fit_perts: Option<Vec<Buffer>>,
}

/// Lazily opened model slot; a failed open is remembered so every later
/// job for the model reports the original error instead of re-paying the
/// open attempt.
enum Slot {
    Ready(WorkerModel),
    Failed(String),
}

/// The per-incarnation serving state: one private runtime plus the lazy
/// per-model slot map.  Shared between the thread lanes (built inline in
/// [`worker_main`]) and the process lanes (built by the `mpq worker`
/// subprocess via [`init_state`]).
pub(super) struct WorkerState {
    rt: Rc<Runtime>,
    manifest: Manifest,
    models: HashMap<String, Slot>,
    opens: Arc<AtomicUsize>,
}

/// Build a worker incarnation's backend state: load the manifest, stand up
/// the private runtime, and arm an optional injected compile fault
/// (`(1-based cache-miss ordinal, fired-counter)`).  Thread lanes pass the
/// fleet-shared fault state's arming; the `mpq worker` subprocess passes
/// the ordinal it received on its command line with a process-local
/// counter (compile-fire telemetry stays child-side — documented in the
/// module docs of [`super`]).
pub(super) fn init_state(
    dir: &Path,
    opens: Arc<AtomicUsize>,
    compile_fault: Option<(usize, Arc<AtomicUsize>)>,
) -> Result<WorkerState> {
    let manifest = Manifest::load(dir)?;
    let rt = Rc::new(Runtime::for_manifest(&manifest)?);
    if let Some((nth, counter)) = compile_fault {
        rt.inject_compile_fault(nth, counter);
    }
    Ok(WorkerState { rt, manifest, models: HashMap::new(), opens })
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

pub(super) fn worker_main(
    widx: usize,
    lane: usize,
    dir: PathBuf,
    rx: mpsc::Receiver<Job>,
    res: mpsc::Sender<ResMsg>,
    init: mpsc::Sender<(usize, Result<(), String>)>,
    opens: Arc<AtomicUsize>,
    faults: Arc<FaultState>,
) {
    // All backend state (PJRT client or sim interpreter) is created here,
    // inside the thread, and never leaves.  Init only builds the runtime —
    // models compile lazily on their first job, which is what lets one
    // fleet serve models it has never seen at spawn time.
    let built = std::panic::catch_unwind({
        let faults = faults.clone();
        move || {
            let cf = faults.arm_compile(lane).map(|n| (n, faults.injected_counter()));
            init_state(&dir, opens, cf)
        }
    });
    let mut state = match built {
        Ok(Ok(state)) => {
            let _ = init.send((widx, Ok(())));
            // release the init channel so the fleet sees a disconnect (not
            // a hang) if any *other* worker dies before reporting
            drop(init);
            state
        }
        Ok(Err(e)) => {
            let _ = init.send((widx, Err(format!("{e:#}"))));
            return;
        }
        Err(p) => {
            let _ = init.send((widx, Err(format!("init panicked: {}", panic_text(&p)))));
            return;
        }
    };
    // per-incarnation event counters the fault plan keys on: a respawned
    // replacement starts from zero, which is what lets a *recurring* fault
    // fire once per incarnation while one-shot faults deplete globally
    let slow = faults.slow_ms(lane);
    let mut probes_served = 0usize;
    let mut uploads_served = 0usize;
    while let Ok(job) = rx.recv() {
        let Job { id, req } = job;
        if let Some(ms) = slow {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        let is_probe = matches!(req, Request::Probe { .. });
        let is_upload = matches!(
            req,
            Request::LoadSet { .. } | Request::BuildReference { .. } | Request::InstallReference { .. }
        );
        if is_probe {
            probes_served += 1;
            if faults.fire_stall(lane, probes_served) {
                // block far past any configured deadline; the collect
                // watchdog converts this lane into a death and the stale
                // reply (if the thread ever wakes) carries a retired widx
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        if is_upload {
            uploads_served += 1;
        }
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if is_probe && faults.fire_panic(lane, probes_served) {
                panic!("injected fault: worker panic on probe {probes_served} (lane {lane})");
            }
            if is_upload && faults.fire_upload(lane, uploads_served) {
                let msg =
                    format!("injected fault: upload failure on request {uploads_served} (lane {lane})");
                return inject_upload_failure(&mut state, &req, msg);
            }
            serve(&mut state, req)
        }));
        match outcome {
            Ok(out) => {
                if res.send((id, widx, out.map_err(|e| format!("{e:#}")))).is_err() {
                    return; // fleet dropped
                }
            }
            Err(p) => {
                // announce death and exit WITHOUT failing the job: the
                // supervisor respawns this lane and requeues every
                // unresolved slot (this job and everything still in the
                // dead queue), so in-flight work survives the panic.  The
                // per-sender FIFO guarantees all of this incarnation's
                // replies precede the notice — after it, no stale reply
                // from this widx can exist.
                let msg = format!("worker panicked: {}", panic_text(&p));
                let _ = res.send((DEATH_NOTICE, widx, Err(format!("{msg} (worker exited)"))));
                return;
            }
        }
    }
}

/// An injected upload failure, recorded exactly like a real one: the
/// target shard slot is poisoned so the first *tracked* job that touches
/// it surfaces the root cause (`LoadSet`/`BuildReference` are
/// fire-and-forget); a tracked `InstallReference` fails directly.
pub(super) fn inject_upload_failure(
    state: &mut WorkerState,
    req: &Request,
    msg: String,
) -> Result<Partial> {
    let WorkerState { rt, manifest, models, opens } = state;
    match req {
        Request::LoadSet { model, key, .. } | Request::BuildReference { model, set: key, .. } => {
            let m = ensure_model(models, rt, manifest, opens, model)?;
            m.shards.insert(*key, ShardSlot::Failed(msg));
            Ok(Partial::Unit)
        }
        _ => bail!("{msg}"),
    }
}

/// Fetch (lazily opening) the slot for `name`.  Free function so callers
/// can keep using the state's other fields while the slot is borrowed.
fn ensure_model<'a>(
    models: &'a mut HashMap<String, Slot>,
    rt: &Rc<Runtime>,
    manifest: &Manifest,
    opens: &Arc<AtomicUsize>,
    name: &str,
) -> Result<&'a mut WorkerModel> {
    if !models.contains_key(name) {
        let slot = match ModelHandle::open(rt.clone(), manifest, name) {
            Ok(handle) => {
                opens.fetch_add(1, Ordering::Relaxed);
                Slot::Ready(WorkerModel {
                    handle,
                    shards: HashMap::new(),
                    fit_perts: None,
                })
            }
            Err(e) => Slot::Failed(format!("{e:#}")),
        };
        models.insert(name.to_string(), slot);
    }
    match models.get_mut(name).expect("slot just inserted") {
        Slot::Ready(m) => Ok(m),
        Slot::Failed(e) => bail!("model '{name}' failed to open on this worker: {e}"),
    }
}

fn shard(m: &WorkerModel, key: SetKey) -> Result<&Shard> {
    match m.shards.get(&key) {
        Some(ShardSlot::Ready(s)) => Ok(s),
        Some(ShardSlot::Failed(e)) => bail!("eval set {key} failed to load on this worker: {e}"),
        None => bail!("eval set {key} not loaded into the fleet"),
    }
}

pub(super) fn serve(state: &mut WorkerState, req: Request) -> Result<Partial> {
    let WorkerState { rt, manifest, models, opens } = state;
    match req {
        Request::Calibrate { model, ranges, w_scales } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            m.handle.act_ranges = Some(ranges);
            m.handle.w_scales = w_scales;
            // new ranges invalidate the cached activation qparam rows
            m.handle.engine.mat.invalidate();
            Ok(Partial::Unit)
        }
        Request::LoadSet { model, key, batches, labels, first_batch } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            let slot = match m.handle.eval_set_shard(&batches, labels) {
                Ok(set) => ShardSlot::Ready(Shard { set, first_batch }),
                Err(e) => ShardSlot::Failed(format!("{e:#}")),
            };
            m.shards.insert(key, slot);
            Ok(Partial::Unit)
        }
        Request::BuildReference { model, set } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            let sh = shard(m, set)?;
            if !sh.set.batches.is_empty() {
                m.handle.engine.reference(&m.handle, &sh.set)?;
            }
            Ok(Partial::Unit)
        }
        Request::InstallReference { model, set, batches } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            let sh = shard(m, set)?;
            if batches.len() != sh.set.batches.len() {
                bail!(
                    "reference install has {} batches, shard has {}",
                    batches.len(),
                    sh.set.batches.len()
                );
            }
            if !batches.is_empty() {
                let fp = FpReference::from_batches(batches)?;
                m.handle.engine.install_reference(sh.set.id, fp);
            }
            Ok(Partial::Unit)
        }
        Request::FetchReference { model, set } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            let sh = shard(m, set)?;
            let batches = if sh.set.batches.is_empty() {
                Vec::new()
            } else {
                m.handle.engine.reference(&m.handle, &sh.set)?.batches.clone()
            };
            Ok(Partial::Batches { first_batch: sh.first_batch, batches })
        }
        Request::Probe { model, set, kind, cfg, overrides } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            let m = &*m;
            let sh = shard(m, set)?;
            let (cfg, overrides) = (&*cfg, &*overrides);
            match kind {
                ProbeKind::Metric => {
                    let mut acc = StreamingTaskMetric::new(&m.handle.entry.task)?;
                    if !sh.set.batches.is_empty() {
                        let cb = m.handle.config_buffers(cfg, overrides)?;
                        let b = sh.set.batch;
                        for (bi, xb) in sh.set.batches.iter().enumerate() {
                            let logits = m.handle.forward(xb, &cb)?;
                            acc.push(&logits, &sh.set.labels.slice_rows(bi * b, b)?)?;
                        }
                    }
                    Ok(Partial::Task(acc))
                }
                ProbeKind::Sqnr => {
                    let mut s = StreamingSqnr::new();
                    if !sh.set.batches.is_empty() {
                        let fp = m.handle.engine.reference(&m.handle, &sh.set)?;
                        let cb = m.handle.config_buffers(cfg, overrides)?;
                        for (bi, xb) in sh.set.batches.iter().enumerate() {
                            let q = m.handle.forward(xb, &cb)?;
                            s.push_at(
                                (sh.first_batch + bi) as u64,
                                &fp.batches[bi],
                                &fp.sig_pow[bi],
                                &q,
                            )?;
                        }
                    }
                    Ok(Partial::Sqnr(s))
                }
            }
        }
        Request::Fit { model, set, qp } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            if m.fit_perts.is_none() {
                let shapes = m
                    .handle
                    .entry
                    .fit_act_shapes
                    .as_ref()
                    .ok_or_else(|| anyhow!("missing fit_act_shapes"))?;
                m.fit_perts = Some(
                    shapes
                        .iter()
                        .map(|s| rt.buffer(&Tensor::zeros(s)))
                        .collect::<Result<_>>()?,
                );
            }
            let m = &*m;
            let sh = shard(m, set)?;
            let entry = &m.handle.entry;
            let fit_file = entry
                .fit
                .as_ref()
                .ok_or_else(|| anyhow!("{} has no FIT artifact", entry.name))?;
            let exe = rt.load(manifest.path(fit_file))?;
            let pert_bufs = m.fit_perts.as_ref().expect("fit perts just built");
            let qp_buf = rt.buffer(&qp)?;
            let raws = sensitivity::fit_batch_raws(
                rt,
                &exe,
                m.handle.param_buffers(),
                pert_bufs,
                &qp_buf,
                &sh.set.batches,
                &sh.set.labels,
                sh.set.batch,
            )?;
            Ok(Partial::Fit(FitShard { first_batch: sh.first_batch, raws }))
        }
        Request::AdaRound { model, job } => {
            let m = ensure_model(models, rt, manifest, opens, &model)?;
            let m = &*m;
            let exe = rt.load(manifest.path(&job.exe))?;
            let n = m.handle.weights.len();
            if job.param_idx >= n || job.bias_idx >= n {
                bail!("adaround job param indices out of range ({n} params)");
            }
            let t = adaround::optimize_rounding(
                rt,
                &exe,
                &m.handle.weights[job.param_idx],
                &m.handle.weights[job.bias_idx],
                &job,
            )?;
            Ok(Partial::Rounded(t))
        }
        Request::Detach { model } => {
            models.remove(&*model);
            Ok(Partial::Unit)
        }
        Request::Stats => Ok(Partial::Stats(WorkerStats {
            compiled: rt.compiled_count(),
            models_open: models
                .values()
                .filter(|s| matches!(s, Slot::Ready(_)))
                .count(),
        })),
    }
}
