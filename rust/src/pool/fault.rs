//! Deterministic fault injection for the evaluation fleet.
//!
//! A [`FaultPlan`] is a seeded, fully reproducible schedule of worker
//! failures — the test harness the self-healing fleet is verified
//! against.  Plans are written in a tiny comma-separated grammar and can
//! come from three places, in precedence order:
//!
//! 1. an explicit plan handed to [`super::EvalFleet::with_faults`]
//!    (dedicated fault tests — wins over the environment so they stay
//!    deterministic under the fault-injection CI job),
//! 2. the `MPQ_FAULT_PLAN` environment variable,
//! 3. the manifest's optional top-level `"fault_plan"` key (written by
//!    `sim::generate` when [`crate::sim::SimSpec::fault_plan`] is set).
//!
//! ## Grammar
//!
//! Tokens are comma-separated; `L` is a worker *lane* (its spawn slot —
//! a respawned replacement occupies the same lane, so a recurring fault
//! re-fires on every incarnation), `N` is a 1-based event ordinal within
//! one worker incarnation, `MS` is milliseconds.  A trailing `*` makes a
//! fault recurring (re-arms for every incarnation of the lane); without
//! it a fault fires exactly once across the whole fleet lifetime.
//!
//! | token            | effect                                              |
//! |------------------|-----------------------------------------------------|
//! | `panic@L:N[*]`   | lane L panics while serving its Nth probe            |
//! | `upload@L:N[*]`  | lane L's Nth upload-class request (`LoadSet`,        |
//! |                  | `BuildReference`, `InstallReference`) fails          |
//! | `compile@L[:N][*]`| lane L's Nth cache-miss compile fails (default N=1) |
//! | `slow@L:MS`      | lane L sleeps MS ms before every request             |
//! | `stall@L:N[*]`   | lane L blocks on its Nth probe (watchdog fodder)     |
//! | `crash@PHASE:N`  | the *coordinator process* aborts at its Nth run-     |
//! |                  | journal barrier (after the record is durable) — the  |
//! |                  | `--resume` crash-recovery fault; lane-less, never    |
//! |                  | fires worker-side                                    |
//! | `deadline:MS`    | collect watchdog: no reply for MS ms ⇒ stuck workers |
//! |                  | owing results are declared dead                      |
//! | `budget:N`       | per-lane restart budget (default 3)                  |
//! | `backoff:MS`     | respawn backoff base (default 10 ms, doubled per     |
//! |                  | restart, capped; 0 disables the sleep)               |
//!
//! ## Wire faults
//!
//! The wire-fault family injects at the framed-socket seam
//! ([`super::wire`], wrapping `store::write_frame`) instead of inside
//! worker compute, so both socket control planes — the proc-lane
//! transport (`pool/transport.rs`) and the `mpqd` job protocol
//! (`serve/proto.rs`) — are covered by one mechanism.  Here `N` counts
//! *frames written* on the lane's connection (1-based; PING and BULK
//! frames count too).  For proc fleets `L` is the worker lane; for
//! `mpqd` it is the connection ordinal modulo the daemon's wire-lane
//! count.
//!
//! | token              | effect                                             |
//! |--------------------|----------------------------------------------------|
//! | `wdrop@L:N[*]`     | swallow lane L's Nth outbound frame (the peer      |
//! |                    | never sees it — reply starvation, watchdog fodder) |
//! | `wcorrupt@L:N[*]`  | flip a post-checksum bit in the Nth frame so the   |
//! |                    | reader must reject it (`frame checksum mismatch`)  |
//! | `wdelay@L:MS`      | stall MS ms mid-frame on every write (continuous,  |
//! |                    | like `slow@`; timing only, never consumes a fire)  |
//! | `wsplit@L:N[*]`    | torn write: emit a partial prefix of the Nth frame |
//! |                    | then fail the connection                           |
//! | `wreset@L:N[*]`    | fail the connection instead of writing frame N     |
//! | `wseed:SEED`       | seeded random one-shot wire schedule; a lane's     |
//! |                    | clauses depend only on `(SEED, L)`, so the         |
//! |                    | schedule is identical at any lane count.  Implies  |
//! |                    | `deadline:2000` unless a deadline is given (frame  |
//! |                    | drops need the collect watchdog to heal).          |
//!
//! Every injected failure carries the literal prefix `injected fault:` in
//! its message so tests can distinguish root-cause errors from real bugs.

use crate::util::Rng;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// What a single fault does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic while serving the Nth probe of the incarnation (1-based).
    PanicOnProbe(usize),
    /// Fail the Nth upload-class request (`LoadSet` / `BuildReference` /
    /// `InstallReference`) of the incarnation.
    UploadFail(usize),
    /// Fail the Nth cache-miss compile of the incarnation's runtime.
    CompileFail(usize),
    /// Sleep this many milliseconds before every request (inherently
    /// recurring; never consumes a fire).
    Slow(u64),
    /// Block (sleep far past any deadline) on the Nth probe — converted
    /// to a death by the collect watchdog when `deadline:MS` is set.
    StallOnProbe(usize),
    /// Abort the coordinator *process* at its Nth run-journal barrier
    /// (1-based), after the Nth record is durable — `crash@PHASE:N`.
    /// Lane-less: workers never fire it; the `RunJournal` does, via
    /// [`FaultPlan::crash_barriers`].
    CrashAtBarrier(usize),
    /// Swallow the lane's Nth outbound frame — `wdrop@L:N`.  Wire kinds
    /// are consumed by [`super::wire::WireFaults`], never by the
    /// worker-side `FaultState` predicates.
    WireDrop(usize),
    /// Flip a post-checksum bit in the lane's Nth outbound frame so the
    /// reader must reject it — `wcorrupt@L:N`.
    WireCorrupt(usize),
    /// Stall this many milliseconds mid-frame on every write on the lane
    /// (continuous, like `Slow`; never consumes a fire) — `wdelay@L:MS`.
    WireDelay(u64),
    /// Torn write: emit a partial prefix of the lane's Nth frame, then
    /// fail the connection — `wsplit@L:N`.
    WireSplit(usize),
    /// Fail the connection instead of writing the lane's Nth frame —
    /// `wreset@L:N`.
    WireReset(usize),
}

impl FaultKind {
    /// Wire kinds live at the framed-socket seam ([`super::wire`]) and
    /// are invisible to the worker-side `FaultState` predicates.
    pub fn is_wire(self) -> bool {
        matches!(
            self,
            FaultKind::WireDrop(_)
                | FaultKind::WireCorrupt(_)
                | FaultKind::WireDelay(_)
                | FaultKind::WireSplit(_)
                | FaultKind::WireReset(_)
        )
    }
}

/// One scheduled fault, bound to a worker lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    /// Worker lane (spawn slot) the fault targets.  Respawned
    /// replacements keep their predecessor's lane.
    pub lane: usize,
    pub kind: FaultKind,
    /// Recurring faults re-arm for every incarnation of the lane;
    /// one-shot faults fire exactly once across the fleet's lifetime.
    pub recurring: bool,
}

/// A deterministic fault schedule plus the supervisor knobs it tunes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// Collect watchdog: with no worker reply for this many ms, live
    /// workers still owing results are declared dead.  `None` (the
    /// production default) keeps the blocking wait.
    pub deadline_ms: Option<u64>,
    /// Per-lane restart budget override (default 3).
    pub budget: Option<usize>,
    /// Respawn backoff base in ms (default 10; doubled per restart).
    pub backoff_ms: Option<u64>,
    /// Seed for a derived per-lane random wire schedule (`wseed:SEED`).
    /// Lane L's derived clauses depend only on `(seed, L)`, never on the
    /// lane count — see [`FaultPlan::wire_faults_for_lane`].
    pub wire_seed: Option<u64>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
            && self.deadline_ms.is_none()
            && self.budget.is_none()
            && self.backoff_ms.is_none()
            && self.wire_seed.is_none()
    }

    /// Does this plan carry any wire-seam injection (explicit wire
    /// clauses or a `wseed` schedule)?  Gates construction of the
    /// [`super::wire::WireFaults`] state.
    pub fn has_wire_faults(&self) -> bool {
        self.wire_seed.is_some() || self.faults.iter().any(|f| f.kind.is_wire())
    }

    /// Every wire fault targeting `lane`: the plan's explicit wire
    /// clauses plus, when `wseed:SEED` is set, a derived schedule seeded
    /// by `(SEED, lane)` only — the same lane gets the same clauses at
    /// any lane count (the determinism property `property.rs` pins).
    /// The derived schedule is deliberately gentle: at most one one-shot
    /// fault per lane (roughly half the lanes draw none), so a default
    /// restart budget always heals it and results stay byte-equal.
    pub fn wire_faults_for_lane(&self, lane: usize) -> Vec<Fault> {
        let mut out: Vec<Fault> = self
            .faults
            .iter()
            .filter(|f| f.kind.is_wire() && f.lane == lane)
            .copied()
            .collect();
        if let Some(seed) = self.wire_seed {
            let mut rng =
                Rng::new(seed ^ (lane as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            if rng.below(2) == 0 {
                let kind = match rng.below(5) {
                    0 => FaultKind::WireDrop(1 + rng.below(6)),
                    1 => FaultKind::WireCorrupt(1 + rng.below(6)),
                    2 => FaultKind::WireSplit(1 + rng.below(6)),
                    3 => FaultKind::WireReset(1 + rng.below(6)),
                    _ => FaultKind::WireDelay(1 + rng.below(5) as u64),
                };
                out.push(Fault { lane, kind, recurring: false });
            }
        }
        out
    }

    /// Sorted 1-based journal-barrier ordinals of every `crash@PHASE:N`
    /// fault in the plan — consumed by `store::RunJournal`, never by
    /// workers (the worker-side fire predicates match on exact kinds).
    pub fn crash_barriers(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .faults
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::CrashAtBarrier(n) => Some(n as u64),
                _ => None,
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Parse the comma-separated fault grammar (see the module docs).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        for raw in spec.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            let (tok, recurring) = match tok.strip_suffix('*') {
                Some(t) => (t, true),
                None => (tok, false),
            };
            let (head, rest) = match tok.split_once('@') {
                Some((h, r)) => (h, Some(r)),
                None => match tok.split_once(':') {
                    Some((h, v)) => {
                        // plan-level knobs: deadline:MS budget:N backoff:MS
                        let v: u64 = v
                            .trim()
                            .parse()
                            .map_err(|e| anyhow::anyhow!("fault plan '{raw}': {e}"))?;
                        match h.trim() {
                            "deadline" => plan.deadline_ms = Some(v),
                            "budget" => plan.budget = Some(v as usize),
                            "backoff" => plan.backoff_ms = Some(v),
                            "wseed" => plan.wire_seed = Some(v),
                            k => bail!("unknown fault-plan knob '{k}' in '{raw}'"),
                        }
                        continue;
                    }
                    None => bail!("fault token '{raw}' has no '@lane' target"),
                },
            };
            let rest = rest.expect("fault tokens reach here only with '@'");
            let (lane_s, arg_s) = match rest.split_once(':') {
                Some((l, a)) => (l, Some(a)),
                None => (rest, None),
            };
            if head.trim() == "crash" {
                // coordinator-side fault: lane-less, targets the journal
                if lane_s.trim() != "PHASE" {
                    bail!("fault token '{raw}': crash targets 'PHASE' (crash@PHASE:N)");
                }
                let nth = match arg_s {
                    Some(a) => a.trim().parse::<u64>().map_err(|e| {
                        anyhow::anyhow!("fault token '{raw}': bad barrier ordinal: {e}")
                    })? as usize,
                    None => bail!("fault token '{raw}' needs ':N'"),
                };
                if nth == 0 {
                    bail!("fault token '{raw}': event ordinals are 1-based");
                }
                // lane is meaningless for a coordinator fault; `recurring`
                // is accepted but irrelevant (the process dies on fire)
                plan.faults
                    .push(Fault { lane: 0, kind: FaultKind::CrashAtBarrier(nth), recurring });
                continue;
            }
            let lane: usize = lane_s
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("fault token '{raw}': bad lane: {e}"))?;
            let arg = |default: Option<u64>| -> Result<u64> {
                match (arg_s, default) {
                    (Some(a), _) => a
                        .trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("fault token '{raw}': bad count: {e}")),
                    (None, Some(d)) => Ok(d),
                    (None, None) => bail!("fault token '{raw}' needs ':N'"),
                }
            };
            let kind = match head.trim() {
                "panic" => FaultKind::PanicOnProbe(arg(None)? as usize),
                "upload" => FaultKind::UploadFail(arg(None)? as usize),
                "compile" => FaultKind::CompileFail(arg(Some(1))? as usize),
                "slow" => FaultKind::Slow(arg(None)?),
                "stall" => FaultKind::StallOnProbe(arg(None)? as usize),
                "wdrop" => FaultKind::WireDrop(arg(None)? as usize),
                "wcorrupt" => FaultKind::WireCorrupt(arg(None)? as usize),
                "wdelay" => FaultKind::WireDelay(arg(None)?),
                "wsplit" => FaultKind::WireSplit(arg(None)? as usize),
                "wreset" => FaultKind::WireReset(arg(None)? as usize),
                k => bail!("unknown fault kind '{k}' in '{raw}'"),
            };
            if matches!(kind, FaultKind::PanicOnProbe(0) | FaultKind::UploadFail(0)
                | FaultKind::CompileFail(0) | FaultKind::StallOnProbe(0)
                | FaultKind::WireDrop(0) | FaultKind::WireCorrupt(0)
                | FaultKind::WireSplit(0) | FaultKind::WireReset(0))
            {
                bail!("fault token '{raw}': event ordinals are 1-based");
            }
            plan.faults.push(Fault { lane, kind, recurring });
        }
        if plan.wire_seed.is_some() {
            // a derived schedule may drop frames; without a collect
            // watchdog the starved reply would hang forever
            plan.deadline_ms.get_or_insert(2000);
        }
        Ok(plan)
    }

    /// A seeded random schedule over `lanes` workers — the property-test
    /// generator.  Mixes panics (some recurring, to exercise budget
    /// exhaustion and degradation), upload failures and slow workers;
    /// never stalls (no deadline is set, so a stall would hang).  Backoff
    /// is zeroed so supervised recovery stays fast under test.
    pub fn random(seed: u64, lanes: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
        let lanes = lanes.max(1);
        let n = 1 + rng.below(3);
        let mut faults = Vec::with_capacity(n);
        for _ in 0..n {
            let lane = rng.below(lanes);
            let kind = match rng.below(4) {
                0 => FaultKind::UploadFail(1 + rng.below(3)),
                1 => FaultKind::Slow(1 + rng.below(3) as u64),
                _ => FaultKind::PanicOnProbe(1 + rng.below(5)),
            };
            let recurring = matches!(kind, FaultKind::PanicOnProbe(_)) && rng.below(3) == 0;
            faults.push(Fault { lane, kind, recurring });
        }
        FaultPlan {
            faults,
            deadline_ms: None,
            budget: Some(1 + rng.below(3)),
            backoff_ms: Some(0),
            wire_seed: None,
        }
    }
}

/// Shared fire accounting for one fleet's plan: which faults still have
/// firings left (one-shot faults deplete; recurring faults never do) plus
/// the `faults_injected` telemetry counter.  One instance per fleet,
/// shared with every worker incarnation via `Arc`.
pub(super) struct FaultState {
    plan: FaultPlan,
    /// remaining firings per fault (1 for one-shot, `usize::MAX` for
    /// recurring — never decremented)
    fires: Vec<AtomicUsize>,
    injected: Arc<AtomicUsize>,
}

impl FaultState {
    pub(super) fn new(plan: FaultPlan) -> Self {
        let fires = plan
            .faults
            .iter()
            .map(|f| AtomicUsize::new(if f.recurring { usize::MAX } else { 1 }))
            .collect();
        Self { plan, fires, injected: Arc::new(AtomicUsize::new(0)) }
    }

    pub(super) fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Total discrete fault firings so far (panics, upload failures,
    /// compile failures, stalls — `slow` is continuous and not counted).
    pub(super) fn injected(&self) -> usize {
        self.injected.load(Ordering::Relaxed)
    }

    /// Handle to the injected counter, for hooks that live outside the
    /// pool (the runtime's compile-fault hook).
    pub(super) fn injected_counter(&self) -> Arc<AtomicUsize> {
        self.injected.clone()
    }

    /// Consume one firing of fault `i`; false once a one-shot is spent.
    fn try_consume(&self, i: usize) -> bool {
        let ok = self.fires[i]
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| match v {
                0 => None,
                usize::MAX => Some(usize::MAX),
                v => Some(v - 1),
            })
            .is_ok();
        if ok {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    /// Milliseconds lane L sleeps before every request (largest wins).
    pub(super) fn slow_ms(&self, lane: usize) -> Option<u64> {
        self.plan
            .faults
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::Slow(ms) if f.lane == lane => Some(ms),
                _ => None,
            })
            .max()
    }

    /// Should lane L panic serving its `nth` probe of this incarnation?
    pub(super) fn fire_panic(&self, lane: usize, nth: usize) -> bool {
        self.fire_where(|f| f.lane == lane && f.kind == FaultKind::PanicOnProbe(nth))
    }

    /// Should lane L stall on its `nth` probe of this incarnation?
    pub(super) fn fire_stall(&self, lane: usize, nth: usize) -> bool {
        self.fire_where(|f| f.lane == lane && f.kind == FaultKind::StallOnProbe(nth))
    }

    /// Should lane L's `nth` upload-class request fail?
    pub(super) fn fire_upload(&self, lane: usize, nth: usize) -> bool {
        self.fire_where(|f| f.lane == lane && f.kind == FaultKind::UploadFail(nth))
    }

    /// Arm a compile fault for a fresh incarnation of lane L: returns the
    /// 1-based cache-miss ordinal that must fail.  The fire is consumed at
    /// arm time (the runtime hook has no channel back to this state), so a
    /// one-shot compile fault arms exactly one incarnation.
    pub(super) fn arm_compile(&self, lane: usize) -> Option<usize> {
        for (i, f) in self.plan.faults.iter().enumerate() {
            if let FaultKind::CompileFail(nth) = f.kind {
                if f.lane == lane && self.try_consume(i) {
                    // arming is not yet a firing — the runtime hook
                    // increments `injected` when the compile actually fails
                    self.injected.fetch_sub(1, Ordering::Relaxed);
                    return Some(nth);
                }
            }
        }
        None
    }

    fn fire_where(&self, pred: impl Fn(&Fault) -> bool) -> bool {
        for (i, f) in self.plan.faults.iter().enumerate() {
            if pred(f) && self.try_consume(i) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse(
            "panic@1:3, upload@0:2*, compile@2, slow@3:25, stall@1:4, \
             deadline:300, budget:2, backoff:5",
        )
        .unwrap();
        assert_eq!(p.deadline_ms, Some(300));
        assert_eq!(p.budget, Some(2));
        assert_eq!(p.backoff_ms, Some(5));
        assert_eq!(p.faults.len(), 5);
        assert_eq!(
            p.faults[0],
            Fault { lane: 1, kind: FaultKind::PanicOnProbe(3), recurring: false }
        );
        assert_eq!(
            p.faults[1],
            Fault { lane: 0, kind: FaultKind::UploadFail(2), recurring: true }
        );
        assert_eq!(
            p.faults[2],
            Fault { lane: 2, kind: FaultKind::CompileFail(1), recurring: false }
        );
        assert_eq!(p.faults[3], Fault { lane: 3, kind: FaultKind::Slow(25), recurring: false });
        assert_eq!(
            p.faults[4],
            Fault { lane: 1, kind: FaultKind::StallOnProbe(4), recurring: false }
        );
    }

    #[test]
    fn parses_crash_barriers() {
        let p = FaultPlan::parse("crash@PHASE:3, slow@0:2, crash@PHASE:1").unwrap();
        assert_eq!(p.crash_barriers(), vec![1, 3]);
        assert_eq!(
            p.faults[0],
            Fault { lane: 0, kind: FaultKind::CrashAtBarrier(3), recurring: false }
        );
        // crash faults are coordinator-side: no worker predicate fires them
        let st = FaultState::new(p);
        for nth in 1..=4 {
            assert!(!st.fire_panic(0, nth));
            assert!(!st.fire_stall(0, nth));
            assert!(!st.fire_upload(0, nth));
        }
        assert!(st.arm_compile(0).is_none());
        assert_eq!(st.injected(), 0);
        assert!(FaultPlan::parse("crash@0:1").is_err(), "crash targets PHASE");
        assert!(FaultPlan::parse("crash@PHASE:0").is_err(), "ordinals are 1-based");
        assert!(FaultPlan::parse("crash@PHASE").is_err(), "crash needs :N");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("panic@1").is_err(), "panic needs :N");
        assert!(FaultPlan::parse("panic@x:1").is_err(), "bad lane");
        assert!(FaultPlan::parse("panic@0:0").is_err(), "ordinals are 1-based");
        assert!(FaultPlan::parse("explode@0:1").is_err(), "unknown kind");
        assert!(FaultPlan::parse("deadline").is_err(), "knob needs a value");
        assert!(FaultPlan::parse("turbo:9").is_err(), "unknown knob");
        assert!(FaultPlan::parse("").unwrap().is_empty(), "empty plan parses empty");
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn one_shot_fires_once_recurring_forever() {
        let st = FaultState::new(FaultPlan::parse("panic@0:2,upload@1:1*").unwrap());
        assert!(!st.fire_panic(0, 1), "wrong ordinal must not fire");
        assert!(!st.fire_panic(1, 2), "wrong lane must not fire");
        assert!(st.fire_panic(0, 2));
        assert!(!st.fire_panic(0, 2), "one-shot is spent");
        assert!(st.fire_upload(1, 1));
        assert!(st.fire_upload(1, 1), "recurring re-fires every incarnation");
        assert_eq!(st.injected(), 3);
    }

    #[test]
    fn parses_wire_grammar() {
        let p = FaultPlan::parse("wdrop@0:2, wcorrupt@1:1*, wdelay@2:15, wsplit@0:3, wreset@3:1")
            .unwrap();
        assert_eq!(p.faults.len(), 5);
        assert!(p.has_wire_faults());
        assert_eq!(p.faults[0], Fault { lane: 0, kind: FaultKind::WireDrop(2), recurring: false });
        assert_eq!(
            p.faults[1],
            Fault { lane: 1, kind: FaultKind::WireCorrupt(1), recurring: true }
        );
        assert_eq!(p.faults[2], Fault { lane: 2, kind: FaultKind::WireDelay(15), recurring: false });
        assert_eq!(p.faults[3], Fault { lane: 0, kind: FaultKind::WireSplit(3), recurring: false });
        assert_eq!(p.faults[4], Fault { lane: 3, kind: FaultKind::WireReset(1), recurring: false });
        assert!(p.faults.iter().all(|f| f.kind.is_wire()));
        assert_eq!(p.wire_faults_for_lane(0).len(), 2, "lane 0 owns wdrop + wsplit");
        assert_eq!(p.wire_faults_for_lane(9).len(), 0);
        // wire kinds are invisible to the worker-side fire predicates
        let st = FaultState::new(p);
        for nth in 1..=4 {
            assert!(!st.fire_panic(0, nth) && !st.fire_stall(0, nth) && !st.fire_upload(0, nth));
        }
        assert_eq!(st.injected(), 0);
        assert!(FaultPlan::parse("wdrop@0:0").is_err(), "ordinals are 1-based");
        assert!(FaultPlan::parse("wdrop@0").is_err(), "wdrop needs :N");
        assert!(FaultPlan::parse("wfoo@0:1").is_err(), "unknown wire kind");
    }

    #[test]
    fn wseed_schedules_are_lane_count_independent() {
        let p = FaultPlan::parse("wseed:42").unwrap();
        assert!(p.has_wire_faults());
        assert!(p.faults.is_empty(), "wseed alone adds no explicit clauses");
        assert_eq!(p.deadline_ms, Some(2000), "wseed implies a collect watchdog");
        assert_eq!(
            FaultPlan::parse("wseed:42, deadline:500").unwrap().deadline_ms,
            Some(500),
            "an explicit deadline wins"
        );
        for lane in 0..16 {
            let a = p.wire_faults_for_lane(lane);
            let b = p.wire_faults_for_lane(lane);
            assert_eq!(a, b, "lane {lane}: derived schedule not reproducible");
            assert!(a.len() <= 1, "derived schedule is at most one fault per lane");
            for f in &a {
                assert!(f.kind.is_wire() && !f.recurring && f.lane == lane);
            }
        }
        // at least one lane in 16 draws a fault for this seed, and the
        // schedule differs across seeds (overwhelmingly)
        assert!((0..16).any(|l| !p.wire_faults_for_lane(l).is_empty()));
        let q = FaultPlan::parse("wseed:43").unwrap();
        assert_ne!(
            (0..16).map(|l| p.wire_faults_for_lane(l)).collect::<Vec<_>>(),
            (0..16).map(|l| q.wire_faults_for_lane(l)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn random_plans_are_deterministic_and_bounded() {
        for seed in 0..50u64 {
            let a = FaultPlan::random(seed, 4);
            let b = FaultPlan::random(seed, 4);
            assert_eq!(a, b, "seed {seed}: random plan not reproducible");
            assert!(!a.faults.is_empty() && a.faults.len() <= 3);
            assert!(a.faults.iter().all(|f| f.lane < 4));
            assert!(a.deadline_ms.is_none(), "random plans must never stall-and-wait");
            assert_eq!(a.backoff_ms, Some(0), "random plans keep recovery fast");
            // stalls would hang without a deadline; the generator must not emit them
            assert!(a
                .faults
                .iter()
                .all(|f| !matches!(f.kind, FaultKind::StallOnProbe(_))));
        }
        assert_ne!(
            FaultPlan::random(1, 4),
            FaultPlan::random(2, 4),
            "different seeds should differ (overwhelmingly)"
        );
    }
}
