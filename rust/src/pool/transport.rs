//! Wire codec for process-backed fleet lanes: the coordinator↔worker
//! job/reply surface as MPQJ checksummed frames over a byte stream.
//!
//! Every message is one **control frame** ([`crate::store::write_frame`]
//! format: `u32 len · u16 kind · u16 reserved · u64 digest · u64 checksum ·
//! payload`) whose digest carries the job id, optionally followed by
//! out-of-line **bulk frames** carrying framed MPQT tensor payloads.  The
//! control payload opens with a `u32` bulk-frame count, so the reader knows
//! exactly how many BULK frames to consume before the next message — no
//! sentinels, no lookahead.
//!
//! Tensors below [`CONTROL_BULK_THRESHOLD`] ride inline in the control
//! frame; larger ones are shipped as one BULK frame each, in field order.
//! The threshold keeps control messages small (cheap checksums, bounded
//! copies) while large shard uploads stream as their own checksummed
//! frames.  Floats cross the wire as `to_bits` little-endian words, so
//! every partial (SQNR sums, Welford states, FIT raws) is **bit-exact**
//! end to end — the property that keeps process lanes byte-equal to
//! serial.
//!
//! Message kinds live at 64.. — disjoint from the journal's record kinds
//! (1..=4) and the serve control plane (16..48), so a frame can never be
//! mistaken for the wrong plane.

use super::wire::WireConn;
use super::{FitShard, Partial, ProbeKind, Request, WorkerStats};
use crate::adaround::{AdaRoundCfg, AdaRoundJob};
use crate::engine::StreamingSqnr;
use crate::metrics::{PearsonAccum, StreamingTaskMetric};
use crate::model::QuantConfig;
use crate::quant::ActRanges;
use crate::sensitivity::FitBatchRaw;
use crate::store;
use crate::tensor::{io as tio, Tensor};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::Arc;

/// Tensor payloads at or below this many encoded bytes ride inline in the
/// control frame; larger ones ship as out-of-line BULK frames.  16 KiB
/// keeps every non-tensor control message a single small frame while shard
/// uploads (hundreds of KiB per batch) stream as their own frames.
pub(super) const CONTROL_BULK_THRESHOLD: usize = 16 * 1024;

/// Per-frame size cap on the worker lane (1 GiB).  This is a data plane —
/// unlike the serve control plane's 1 MiB cap, shard uploads are the whole
/// point — but a bound still turns a corrupt length word into an error
/// instead of an allocation bomb.
pub(super) const MAX_IPC_FRAME: usize = 1 << 30;

/// Frame kinds for the worker lane (64.. — disjoint from journal kinds
/// 1..=4 and serve kinds 16..48).
mod kinds {
    /// coordinator → worker: one job; digest = job id
    pub const JOB: u16 = 64;
    /// worker → coordinator: one reply; digest = job id
    pub const REPLY: u16 = 65;
    /// either direction: out-of-line MPQT tensor payload; digest = job id
    pub const BULK: u16 = 66;
    /// worker → coordinator: init outcome, sent once after the handshake
    pub const INIT: u16 = 67;
    /// coordinator → worker: liveness heartbeat; digest = ping sequence,
    /// empty payload.  A raw frame, not a message — no bulk-count word.
    pub const PING: u16 = 68;
    /// worker → coordinator: heartbeat answer echoing the ping sequence.
    /// Sent by the worker's socket-reader thread even while a long
    /// compute is in flight, so a busy lane never reads as dead.
    pub const PONG: u16 = 69;
}

fn kind_name(kind: u16) -> &'static str {
    match kind {
        kinds::JOB => "JOB",
        kinds::REPLY => "REPLY",
        kinds::BULK => "BULK",
        kinds::INIT => "INIT",
        kinds::PING => "PING",
        kinds::PONG => "PONG",
        _ => "UNKNOWN",
    }
}

/// Per-job fault instructions, computed **coordinator-side** from the
/// fleet-shared [`super::fault::FaultState`] and shipped with each job.
/// Deciding at the parent preserves the fault plan's global semantics —
/// one-shot faults deplete across the whole fleet, recurring faults re-arm
/// per incarnation — which a child process (fresh counters every respawn)
/// could not reproduce on its own.  `probes`/`uploads` carry the lane's
/// per-incarnation event ordinals so injected panic messages match the
/// thread lanes' byte for byte.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub(super) struct FaultDirective {
    pub slow_ms: u64,
    pub stall: bool,
    pub panic: bool,
    pub upload_fail: bool,
    pub probes: u64,
    pub uploads: u64,
}

// ---------------------------------------------------------------------------
// primitive encoder / decoder
// ---------------------------------------------------------------------------

/// Control-frame body under construction, plus the out-of-line bulk
/// payloads referenced by it (in field order).
#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
    bulk: Vec<Vec<u8>>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, v: &str) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v.as_bytes());
    }
    fn u8s(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }
    fn f64s(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f64(x);
        }
    }
    /// One tensor slot: MPQT-encoded, inline (`tag 0`) when small, as the
    /// next BULK frame (`tag 1`) when over [`CONTROL_BULK_THRESHOLD`].
    fn tensor(&mut self, t: &Tensor) {
        let raw = tio::encode_tensors(std::slice::from_ref(t));
        if raw.len() <= CONTROL_BULK_THRESHOLD {
            self.u8(0);
            self.u8s(&raw);
        } else {
            self.u8(1);
            self.bulk.push(raw);
        }
    }
    fn tensors(&mut self, ts: &[Tensor]) {
        self.usize(ts.len());
        for t in ts {
            self.tensor(t);
        }
    }
}

/// Cursor over a received control-frame body plus its bulk payloads.
struct Dec {
    buf: Vec<u8>,
    pos: usize,
    bulk: std::vec::IntoIter<Vec<u8>>,
}

impl Dec {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "truncated control frame: need {n} bytes at offset {} of {}",
                    self.pos,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b} in control frame"),
        }
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("usize field overflows this platform")
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)
            .context("string field is not UTF-8")?
            .to_string())
    }
    fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f32()).collect()
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f64()).collect()
    }
    fn tensor(&mut self) -> Result<Tensor> {
        let raw = match self.u8()? {
            0 => self.u8s()?,
            1 => self
                .bulk
                .next()
                .ok_or_else(|| anyhow::anyhow!("control frame references a missing BULK frame"))?,
            t => bail!("invalid tensor slot tag {t}"),
        };
        let (t, used) = tio::decode_tensor(&raw)?
            .ok_or_else(|| anyhow::anyhow!("empty MPQT payload in tensor slot"))?;
        if used != raw.len() {
            bail!("trailing bytes after MPQT tensor ({used} of {} used)", raw.len());
        }
        Ok(t)
    }
    fn tensors(&mut self) -> Result<Vec<Tensor>> {
        let n = self.usize()?;
        (0..n).map(|_| self.tensor()).collect()
    }
    /// Assert the whole message was consumed — a length mismatch means the
    /// two ends disagree on the schema, which must fail loudly.
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "control frame has {} undecoded trailing bytes",
                self.buf.len() - self.pos
            );
        }
        if self.bulk.len() != 0 {
            bail!("{} unconsumed BULK frames after message", self.bulk.len());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one message: the control frame (payload = `u32` bulk count + body)
/// followed by its BULK frames, all stamped with `id` in the digest field.
/// Every frame goes through the caller's [`WireConn`] — the single seam
/// where the fault plan's wire clauses inject (pass [`WireConn::off`] for
/// worker-side writers; injection is coordinator-side only).
fn write_msg(w: &mut impl Write, conn: &WireConn, kind: u16, id: u64, enc: Enc) -> Result<()> {
    let mut payload = Vec::with_capacity(4 + enc.buf.len());
    payload.extend_from_slice(&(enc.bulk.len() as u32).to_le_bytes());
    payload.extend_from_slice(&enc.buf);
    if payload.len() > MAX_IPC_FRAME {
        bail!(
            "{} control frame is {} bytes, over the {MAX_IPC_FRAME}-byte cap",
            kind_name(kind),
            payload.len()
        );
    }
    conn.write_frame(w, kind, id, &payload)
        .with_context(|| format!("writing {} frame", kind_name(kind)))?;
    for b in &enc.bulk {
        if b.len() > MAX_IPC_FRAME {
            bail!("BULK frame is {} bytes, over the {MAX_IPC_FRAME}-byte cap", b.len());
        }
        conn.write_frame(w, kinds::BULK, id, b).context("writing BULK frame")?;
    }
    Ok(())
}

/// Read one message of the expected kind; `Ok(None)` on clean EOF before
/// any frame.  Consumes exactly the declared BULK frames, validating that
/// each carries the control frame's job id.  Heartbeat PONGs can
/// interleave between any two worker→coordinator messages (they exist to
/// reset the reader's liveness timer and carry nothing) — they are
/// consumed and skipped here.
fn read_msg(r: &mut impl Read, want: u16) -> Result<Option<(u64, Dec)>> {
    let frame = loop {
        let Some(frame) = store::read_frame(r, MAX_IPC_FRAME)
            .with_context(|| format!("reading {} frame", kind_name(want)))?
        else {
            return Ok(None);
        };
        if frame.kind == kinds::PONG {
            continue;
        }
        break frame;
    };
    parse_msg(frame, r, want).map(Some)
}

/// Validate a control frame's kind and consume its declared BULK frames.
fn parse_msg(frame: store::Record, r: &mut impl Read, want: u16) -> Result<(u64, Dec)> {
    if frame.kind != want {
        bail!(
            "expected a {} frame, got {} (kind {})",
            kind_name(want),
            kind_name(frame.kind),
            frame.kind
        );
    }
    if frame.payload.len() < 4 {
        bail!("{} control frame shorter than its bulk-count word", kind_name(want));
    }
    let nbulk = u32::from_le_bytes(frame.payload[..4].try_into().unwrap()) as usize;
    // Every BULK frame is referenced by a tag-1 tensor slot in the control
    // body, which costs at least one body byte — so a declared count above
    // the body length is corruption, caught here before it can drive a
    // multi-gigabyte preallocation and 4G blocking reads.
    if nbulk > frame.payload.len() - 4 {
        bail!(
            "{} control frame declares {nbulk} BULK frames but its body is \
             only {} bytes — corrupt bulk-count word",
            kind_name(want),
            frame.payload.len() - 4
        );
    }
    let mut bulk = Vec::with_capacity(nbulk);
    for i in 0..nbulk {
        let Some(b) = store::read_frame(r, MAX_IPC_FRAME).context("reading BULK frame")? else {
            bail!("stream ended at BULK frame {i} of {nbulk}");
        };
        if b.kind != kinds::BULK {
            bail!("expected a BULK frame, got {} (kind {})", kind_name(b.kind), b.kind);
        }
        if b.digest != frame.digest {
            bail!(
                "BULK frame for job {} interleaved into job {}'s message",
                b.digest,
                frame.digest
            );
        }
        bulk.push(b.payload);
    }
    Ok((
        frame.digest,
        Dec { buf: frame.payload, pos: 4, bulk: bulk.into_iter() },
    ))
}

// ---------------------------------------------------------------------------
// sub-codecs
// ---------------------------------------------------------------------------

fn enc_opt_u8s(e: &mut Enc, v: &[Option<u8>]) {
    e.u32(v.len() as u32);
    for x in v {
        match x {
            Some(b) => {
                e.u8(1);
                e.u8(*b);
            }
            None => {
                e.u8(0);
                e.u8(0);
            }
        }
    }
}

fn dec_opt_u8s(d: &mut Dec) -> Result<Vec<Option<u8>>> {
    let n = d.u32()? as usize;
    (0..n)
        .map(|_| {
            let flag = d.u8()?;
            let v = d.u8()?;
            match flag {
                0 => Ok(None),
                1 => Ok(Some(v)),
                f => bail!("invalid Option flag {f}"),
            }
        })
        .collect()
}

fn enc_cfg(e: &mut Enc, cfg: &QuantConfig) {
    enc_opt_u8s(e, &cfg.act);
    enc_opt_u8s(e, &cfg.w);
}

fn dec_cfg(d: &mut Dec) -> Result<QuantConfig> {
    Ok(QuantConfig { act: dec_opt_u8s(d)?, w: dec_opt_u8s(d)? })
}

/// Sorted by key so the encoding is deterministic (hash order is not).
fn enc_overrides(e: &mut Enc, ov: &HashMap<usize, Tensor>) {
    let mut keys: Vec<usize> = ov.keys().copied().collect();
    keys.sort_unstable();
    e.usize(keys.len());
    for k in keys {
        e.usize(k);
        e.tensor(&ov[&k]);
    }
}

fn dec_overrides(d: &mut Dec) -> Result<HashMap<usize, Tensor>> {
    let n = d.usize()?;
    let mut out = HashMap::with_capacity(n);
    for _ in 0..n {
        let k = d.usize()?;
        out.insert(k, d.tensor()?);
    }
    Ok(out)
}

fn enc_ranges(e: &mut Enc, r: &ActRanges) {
    e.u32(r.minmax.len() as u32);
    for &(lo, hi) in &r.minmax {
        e.f32(lo);
        e.f32(hi);
    }
    e.u32(r.mse.len() as u32);
    for per_layer in &r.mse {
        e.u32(per_layer.len() as u32);
        for per_bits in per_layer {
            e.f64s(per_bits);
        }
    }
    e.u8s(&r.bits);
    e.f64s(&r.ratios);
}

fn dec_ranges(d: &mut Dec) -> Result<ActRanges> {
    let n = d.u32()? as usize;
    let minmax = (0..n)
        .map(|_| Ok((d.f32()?, d.f32()?)))
        .collect::<Result<Vec<_>>>()?;
    let n = d.u32()? as usize;
    let mse = (0..n)
        .map(|_| {
            let m = d.u32()? as usize;
            (0..m).map(|_| d.f64s()).collect::<Result<Vec<_>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ActRanges { minmax, mse, bits: d.u8s()?, ratios: d.f64s()? })
}

/// Sorted by bit-width key for a deterministic encoding.
fn enc_w_scales(e: &mut Enc, ws: &HashMap<u8, Vec<Vec<f32>>>) {
    let mut keys: Vec<u8> = ws.keys().copied().collect();
    keys.sort_unstable();
    e.u32(keys.len() as u32);
    for k in keys {
        e.u8(k);
        let per_layer = &ws[&k];
        e.u32(per_layer.len() as u32);
        for v in per_layer {
            e.f32s(v);
        }
    }
}

fn dec_w_scales(d: &mut Dec) -> Result<HashMap<u8, Vec<Vec<f32>>>> {
    let n = d.u32()? as usize;
    let mut out = HashMap::with_capacity(n);
    for _ in 0..n {
        let k = d.u8()?;
        let m = d.u32()? as usize;
        let per_layer = (0..m).map(|_| d.f32s()).collect::<Result<Vec<_>>>()?;
        out.insert(k, per_layer);
    }
    Ok(out)
}

fn enc_adaround(e: &mut Enc, j: &AdaRoundJob) {
    e.str(&j.exe);
    e.tensors(&j.taps);
    e.usize(j.param_idx);
    e.usize(j.bias_idx);
    e.f32s(&j.scales);
    e.usize(j.channel_axis);
    e.u8(j.bits);
    e.usize(j.cfg.steps);
    e.f32(j.cfg.lr);
    e.f32(j.cfg.lambda);
    e.f32(j.cfg.beta_hi);
    e.f32(j.cfg.beta_lo);
    e.usize(j.cfg.tap_batches);
    e.u64(j.cfg.seed);
}

fn dec_adaround(d: &mut Dec) -> Result<AdaRoundJob> {
    Ok(AdaRoundJob {
        exe: d.str()?,
        taps: d.tensors()?,
        param_idx: d.usize()?,
        bias_idx: d.usize()?,
        scales: d.f32s()?,
        channel_axis: d.usize()?,
        bits: d.u8()?,
        cfg: AdaRoundCfg {
            steps: d.usize()?,
            lr: d.f32()?,
            lambda: d.f32()?,
            beta_hi: d.f32()?,
            beta_lo: d.f32()?,
            tap_batches: d.usize()?,
            seed: d.u64()?,
        },
    })
}

fn enc_directive(e: &mut Enc, d: &FaultDirective) {
    e.u64(d.slow_ms);
    e.bool(d.stall);
    e.bool(d.panic);
    e.bool(d.upload_fail);
    e.u64(d.probes);
    e.u64(d.uploads);
}

fn dec_directive(d: &mut Dec) -> Result<FaultDirective> {
    Ok(FaultDirective {
        slow_ms: d.u64()?,
        stall: d.bool()?,
        panic: d.bool()?,
        upload_fail: d.bool()?,
        probes: d.u64()?,
        uploads: d.u64()?,
    })
}

// ---------------------------------------------------------------------------
// request codec
// ---------------------------------------------------------------------------

fn enc_request(e: &mut Enc, req: &Request) {
    match req {
        Request::Calibrate { model, ranges, w_scales } => {
            e.u8(0);
            e.str(model);
            enc_ranges(e, ranges);
            enc_w_scales(e, w_scales);
        }
        Request::LoadSet { model, key, batches, labels, first_batch } => {
            e.u8(1);
            e.str(model);
            e.u64(*key);
            e.tensors(batches);
            e.tensor(labels);
            e.usize(*first_batch);
        }
        Request::BuildReference { model, set } => {
            e.u8(2);
            e.str(model);
            e.u64(*set);
        }
        Request::InstallReference { model, set, batches } => {
            e.u8(3);
            e.str(model);
            e.u64(*set);
            e.tensors(batches);
        }
        Request::FetchReference { model, set } => {
            e.u8(4);
            e.str(model);
            e.u64(*set);
        }
        Request::Probe { model, set, kind, cfg, overrides } => {
            e.u8(5);
            e.str(model);
            e.u64(*set);
            e.u8(match kind {
                ProbeKind::Sqnr => 0,
                ProbeKind::Metric => 1,
            });
            enc_cfg(e, cfg);
            enc_overrides(e, overrides);
        }
        Request::Fit { model, set, qp } => {
            e.u8(6);
            e.str(model);
            e.u64(*set);
            e.tensor(qp);
        }
        Request::AdaRound { model, job } => {
            e.u8(7);
            e.str(model);
            enc_adaround(e, job);
        }
        Request::Detach { model } => {
            e.u8(8);
            e.str(model);
        }
        Request::Stats => e.u8(9),
    }
}

fn dec_request(d: &mut Dec) -> Result<Request> {
    Ok(match d.u8()? {
        0 => Request::Calibrate {
            model: d.str()?.into(),
            ranges: dec_ranges(d)?,
            w_scales: dec_w_scales(d)?,
        },
        1 => Request::LoadSet {
            model: d.str()?.into(),
            key: d.u64()?,
            batches: d.tensors()?,
            labels: d.tensor()?,
            first_batch: d.usize()?,
        },
        2 => Request::BuildReference { model: d.str()?.into(), set: d.u64()? },
        3 => Request::InstallReference {
            model: d.str()?.into(),
            set: d.u64()?,
            batches: d.tensors()?,
        },
        4 => Request::FetchReference { model: d.str()?.into(), set: d.u64()? },
        5 => Request::Probe {
            model: d.str()?.into(),
            set: d.u64()?,
            kind: match d.u8()? {
                0 => ProbeKind::Sqnr,
                1 => ProbeKind::Metric,
                k => bail!("invalid probe kind {k}"),
            },
            cfg: Arc::new(dec_cfg(d)?),
            overrides: Arc::new(dec_overrides(d)?),
        },
        6 => Request::Fit {
            model: d.str()?.into(),
            set: d.u64()?,
            qp: Arc::new(d.tensor()?),
        },
        7 => Request::AdaRound { model: d.str()?.into(), job: Arc::new(dec_adaround(d)?) },
        8 => Request::Detach { model: d.str()?.into() },
        9 => Request::Stats,
        t => bail!("invalid request tag {t}"),
    })
}

// ---------------------------------------------------------------------------
// reply codec
// ---------------------------------------------------------------------------

fn enc_reply(e: &mut Enc, res: &Result<Partial, String>) {
    match res {
        Err(msg) => {
            e.u8(0);
            e.str(msg);
        }
        Ok(Partial::Sqnr(s)) => {
            e.u8(1);
            let (seq, parts) = s.to_parts();
            e.u64(seq);
            e.usize(parts.len());
            for (idx, acc, n) in parts {
                e.u64(idx);
                e.f64(acc);
                e.usize(n);
            }
        }
        Ok(Partial::Task(t)) => {
            e.u8(2);
            match t {
                StreamingTaskMetric::Top1 { hits, n } => {
                    e.u8(0);
                    e.usize(*hits);
                    e.usize(*n);
                }
                StreamingTaskMetric::F1 { tp, fp, fnn } => {
                    e.u8(1);
                    e.f64(*tp);
                    e.f64(*fp);
                    e.f64(*fnn);
                }
                StreamingTaskMetric::Pearson(p) => {
                    e.u8(2);
                    for v in p.raw() {
                        e.f64(v);
                    }
                }
                StreamingTaskMetric::Miou { classes, inter, union } => {
                    e.u8(3);
                    e.usize(*classes);
                    e.f64s(inter);
                    e.f64s(union);
                }
            }
        }
        Ok(Partial::Fit(f)) => {
            e.u8(3);
            e.usize(f.first_batch);
            e.usize(f.raws.len());
            for r in &f.raws {
                e.f32s(&r.wgrad2);
                e.f32s(&r.agrad2);
                e.f32s(&r.aerr2);
            }
        }
        Ok(Partial::Batches { first_batch, batches }) => {
            e.u8(4);
            e.usize(*first_batch);
            e.tensors(batches);
        }
        Ok(Partial::Rounded(t)) => {
            e.u8(5);
            e.tensor(t);
        }
        Ok(Partial::Stats(s)) => {
            e.u8(6);
            e.usize(s.compiled);
            e.usize(s.models_open);
        }
        Ok(Partial::Unit) => e.u8(7),
    }
}

fn dec_reply(d: &mut Dec) -> Result<Result<Partial, String>> {
    Ok(match d.u8()? {
        0 => Err(d.str()?),
        1 => {
            let seq = d.u64()?;
            let n = d.usize()?;
            let parts = (0..n)
                .map(|_| Ok((d.u64()?, d.f64()?, d.usize()?)))
                .collect::<Result<Vec<_>>>()?;
            Ok(Partial::Sqnr(StreamingSqnr::from_parts(seq, parts)))
        }
        2 => Ok(Partial::Task(match d.u8()? {
            0 => StreamingTaskMetric::Top1 { hits: d.usize()?, n: d.usize()? },
            1 => StreamingTaskMetric::F1 { tp: d.f64()?, fp: d.f64()?, fnn: d.f64()? },
            2 => {
                let mut raw = [0f64; 6];
                for v in &mut raw {
                    *v = d.f64()?;
                }
                StreamingTaskMetric::Pearson(PearsonAccum::from_raw(raw))
            }
            3 => StreamingTaskMetric::Miou {
                classes: d.usize()?,
                inter: d.f64s()?,
                union: d.f64s()?,
            },
            t => bail!("invalid task accumulator tag {t}"),
        })),
        3 => {
            let first_batch = d.usize()?;
            let n = d.usize()?;
            let raws = (0..n)
                .map(|_| {
                    Ok(FitBatchRaw {
                        wgrad2: d.f32s()?,
                        agrad2: d.f32s()?,
                        aerr2: d.f32s()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(Partial::Fit(FitShard { first_batch, raws }))
        }
        4 => Ok(Partial::Batches { first_batch: d.usize()?, batches: d.tensors()? }),
        5 => Ok(Partial::Rounded(d.tensor()?)),
        6 => Ok(Partial::Stats(WorkerStats { compiled: d.usize()?, models_open: d.usize()? })),
        7 => Ok(Partial::Unit),
        t => bail!("invalid reply tag {t}"),
    })
}

// ---------------------------------------------------------------------------
// public message API
// ---------------------------------------------------------------------------

/// What the worker's socket-reader sees next: a job to serve, or a
/// liveness ping to answer immediately.
pub(super) enum WorkerIn {
    Job(u64, Request, FaultDirective),
    /// carries the coordinator's ping sequence, echoed back in the PONG
    Ping(u64),
}

/// Ship one job (request + fault directive) under `id`, through the
/// lane's wire seam.
pub(super) fn write_job(
    w: &mut impl Write,
    conn: &WireConn,
    id: u64,
    req: &Request,
    d: &FaultDirective,
) -> Result<()> {
    let mut e = Enc::default();
    enc_directive(&mut e, d);
    enc_request(&mut e, req);
    write_msg(w, conn, kinds::JOB, id, e)
}

/// Receive one job or heartbeat ping; `Ok(None)` on clean EOF
/// (coordinator closed the lane).
pub(super) fn read_job_or_ping(r: &mut impl Read) -> Result<Option<WorkerIn>> {
    let Some(frame) = store::read_frame(r, MAX_IPC_FRAME).context("reading JOB frame")? else {
        return Ok(None);
    };
    if frame.kind == kinds::PING {
        return Ok(Some(WorkerIn::Ping(frame.digest)));
    }
    let (id, mut d) = parse_msg(frame, r, kinds::JOB)?;
    let directive = dec_directive(&mut d)?;
    let req = dec_request(&mut d)?;
    d.done()?;
    Ok(Some(WorkerIn::Job(id, req, directive)))
}

/// Receive one job, rejecting pings (codec tests and single-message
/// readers; the worker serving loop uses [`read_job_or_ping`]).
pub(super) fn read_job(r: &mut impl Read) -> Result<Option<(u64, Request, FaultDirective)>> {
    match read_job_or_ping(r)? {
        None => Ok(None),
        Some(WorkerIn::Job(id, req, d)) => Ok(Some((id, req, d))),
        Some(WorkerIn::Ping(_)) => bail!("unexpected PING frame where a JOB was required"),
    }
}

/// Coordinator → worker liveness probe (raw frame, no bulk-count word).
/// Goes through the wire seam, so wire faults can drop or corrupt pings
/// like any other frame.
pub(super) fn write_ping(w: &mut impl Write, conn: &WireConn, seq: u64) -> Result<()> {
    conn.write_frame(w, kinds::PING, seq, &[]).context("writing PING frame")
}

/// Worker → coordinator heartbeat answer.  Callers hold the worker's
/// shared writer lock, so a pong never interleaves mid-message.
pub(super) fn write_pong(w: &mut impl Write, seq: u64) -> Result<()> {
    store::write_frame(w, kinds::PONG, seq, &[]).context("writing PONG frame")
}

/// Ship one reply under `id` (worker-side: no injection).
pub(super) fn write_reply(
    w: &mut impl Write,
    id: u64,
    res: &Result<Partial, String>,
) -> Result<()> {
    let mut e = Enc::default();
    enc_reply(&mut e, res);
    write_msg(w, &WireConn::off(), kinds::REPLY, id, e)
}

/// Receive one reply; `Ok(None)` on clean EOF (worker exited).
/// Interleaved PONGs are consumed silently — each received frame,
/// pong or reply, resets the caller's read-timeout liveness clock.
pub(super) fn read_reply(r: &mut impl Read) -> Result<Option<(u64, Result<Partial, String>)>> {
    let Some((id, mut d)) = read_msg(r, kinds::REPLY)? else {
        return Ok(None);
    };
    let res = dec_reply(&mut d)?;
    d.done()?;
    Ok(Some((id, res)))
}

/// Ship the worker's one-time init outcome (worker-side: no injection).
pub(super) fn write_init(w: &mut impl Write, res: &Result<(), String>) -> Result<()> {
    let mut e = Enc::default();
    match res {
        Ok(()) => e.u8(1),
        Err(msg) => {
            e.u8(0);
            e.str(msg);
        }
    }
    write_msg(w, &WireConn::off(), kinds::INIT, 0, e)
}

/// Receive the init outcome; `Ok(None)` on EOF before it arrived (the
/// worker process died during init).  Tolerates a PONG arriving first —
/// the feeder may ping before the worker's init completes.
pub(super) fn read_init(r: &mut impl Read) -> Result<Option<Result<(), String>>> {
    let Some((_, mut d)) = read_msg(r, kinds::INIT)? else {
        return Ok(None);
    };
    let res = match d.u8()? {
        1 => Ok(()),
        0 => Err(d.str()?),
        t => bail!("invalid init tag {t}"),
    };
    d.done()?;
    Ok(Some(res))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Encode → decode → re-encode; byte equality proves the decode is a
    /// faithful inverse (all sub-codecs sort map keys, so the encoding is
    /// deterministic).
    fn job_roundtrips(req: Request, d: FaultDirective) -> (u64, Request, FaultDirective) {
        let mut buf = Vec::new();
        write_job(&mut buf, &WireConn::off(), 42, &req, &d).unwrap();
        let mut r: &[u8] = &buf;
        let (id, got, gd) = read_job(&mut r).unwrap().unwrap();
        assert!(read_job(&mut r).unwrap().is_none(), "trailing data after message");
        let mut again = Vec::new();
        write_job(&mut again, &WireConn::off(), 42, &got, &gd).unwrap();
        assert_eq!(buf, again, "re-encode of the decoded job differs");
        assert_eq!(d, gd);
        (id, got, gd)
    }

    fn reply_roundtrips(res: Result<Partial, String>) -> Result<Partial, String> {
        let mut buf = Vec::new();
        write_reply(&mut buf, 7, &res).unwrap();
        let mut r: &[u8] = &buf;
        let (id, got) = read_reply(&mut r).unwrap().unwrap();
        assert_eq!(id, 7);
        assert!(read_reply(&mut r).unwrap().is_none());
        let mut again = Vec::new();
        write_reply(&mut again, 7, &got).unwrap();
        assert_eq!(buf, again, "re-encode of the decoded reply differs");
        got
    }

    fn tensor(n: usize) -> Tensor {
        Tensor::from_f32(&[n], (0..n).map(|i| i as f32 * 0.5 - 3.0).collect()).unwrap()
    }

    #[test]
    fn every_request_variant_roundtrips() {
        let mut w_scales = HashMap::new();
        w_scales.insert(4u8, vec![vec![0.5f32, 0.25], vec![1.0]]);
        w_scales.insert(8u8, vec![vec![2.0f32]]);
        let ranges = ActRanges {
            minmax: vec![(-1.5, 2.5), (0.0, 1.0)],
            mse: vec![vec![vec![0.1, 0.2], vec![0.3]], vec![]],
            bits: vec![4, 8],
            ratios: vec![0.9, 1.1],
        };
        job_roundtrips(
            Request::Calibrate { model: "m".into(), ranges, w_scales },
            FaultDirective { slow_ms: 5, probes: 1, ..Default::default() },
        );

        let (_, got, _) = job_roundtrips(
            Request::LoadSet {
                model: "m".into(),
                key: 1,
                batches: vec![tensor(8), tensor(8)],
                labels: tensor(4),
                first_batch: 3,
            },
            FaultDirective::default(),
        );
        match got {
            Request::LoadSet { first_batch, batches, .. } => {
                assert_eq!(first_batch, 3);
                assert_eq!(batches.len(), 2);
            }
            _ => panic!("wrong variant decoded"),
        }

        job_roundtrips(
            Request::BuildReference { model: "m".into(), set: 0 },
            FaultDirective { upload_fail: true, uploads: 2, ..Default::default() },
        );
        job_roundtrips(
            Request::InstallReference { model: "m".into(), set: 1, batches: vec![tensor(6)] },
            FaultDirective::default(),
        );
        job_roundtrips(
            Request::FetchReference { model: "m".into(), set: 1 },
            FaultDirective::default(),
        );

        let mut overrides = HashMap::new();
        overrides.insert(2usize, tensor(3));
        overrides.insert(0usize, tensor(5));
        job_roundtrips(
            Request::Probe {
                model: "m".into(),
                set: 0,
                kind: ProbeKind::Metric,
                cfg: Arc::new(QuantConfig {
                    act: vec![Some(8), None, Some(4)],
                    w: vec![None, Some(2)],
                }),
                overrides: Arc::new(overrides),
            },
            FaultDirective { panic: true, probes: 9, ..Default::default() },
        );

        job_roundtrips(
            Request::Fit { model: "m".into(), set: 0, qp: Arc::new(tensor(12)) },
            FaultDirective::default(),
        );
        job_roundtrips(
            Request::AdaRound {
                model: "m".into(),
                job: Arc::new(AdaRoundJob {
                    exe: "tap.bin".into(),
                    taps: vec![tensor(10)],
                    param_idx: 1,
                    bias_idx: 2,
                    scales: vec![0.5, 0.25],
                    channel_axis: 0,
                    bits: 4,
                    cfg: AdaRoundCfg {
                        steps: 100,
                        lr: 1e-2,
                        lambda: 0.01,
                        beta_hi: 20.0,
                        beta_lo: 2.0,
                        tap_batches: 4,
                        seed: 77,
                    },
                }),
            },
            FaultDirective::default(),
        );
        job_roundtrips(Request::Detach { model: "m".into() }, FaultDirective::default());
        job_roundtrips(Request::Stats, FaultDirective { stall: true, probes: 3, ..Default::default() });
    }

    #[test]
    fn every_reply_variant_roundtrips_bit_exact() {
        reply_roundtrips(Err("worker exploded".into()));
        // NaN and signed-zero partials must survive bit-exactly: the codec
        // ships to_bits words, never a float format.
        let sqnr = StreamingSqnr::from_parts(
            5,
            [(0u64, f64::NAN, 4usize), (4, -0.0, 4), (2, 1.5e-300, 4)],
        );
        match reply_roundtrips(Ok(Partial::Sqnr(sqnr))) {
            Ok(Partial::Sqnr(s)) => {
                let (seq, parts) = s.to_parts();
                assert_eq!(seq, 5);
                assert!(parts[0].1.is_nan());
                assert_eq!(parts[1].0, 2);
            }
            _ => panic!("wrong reply decoded"),
        }
        reply_roundtrips(Ok(Partial::Task(StreamingTaskMetric::Top1 { hits: 3, n: 9 })));
        reply_roundtrips(Ok(Partial::Task(StreamingTaskMetric::F1 {
            tp: 1.0,
            fp: 0.5,
            fnn: 0.25,
        })));
        reply_roundtrips(Ok(Partial::Task(StreamingTaskMetric::Pearson(
            PearsonAccum::from_raw([4.0, 0.1, -0.2, 2.0, 3.0, -1.0]),
        ))));
        reply_roundtrips(Ok(Partial::Task(StreamingTaskMetric::Miou {
            classes: 3,
            inter: vec![1.0, 2.0, 3.0],
            union: vec![4.0, 5.0, 6.0],
        })));
        reply_roundtrips(Ok(Partial::Fit(FitShard {
            first_batch: 2,
            raws: vec![FitBatchRaw {
                wgrad2: vec![0.1, f32::NAN],
                agrad2: vec![0.2],
                aerr2: vec![],
            }],
        })));
        reply_roundtrips(Ok(Partial::Batches {
            first_batch: 1,
            batches: vec![tensor(4), tensor(2)],
        }));
        reply_roundtrips(Ok(Partial::Rounded(tensor(7))));
        reply_roundtrips(Ok(Partial::Stats(WorkerStats { compiled: 2, models_open: 1 })));
        reply_roundtrips(Ok(Partial::Unit));
    }

    #[test]
    fn large_tensors_ship_as_bulk_frames() {
        // 5000 f32s ≫ the 16 KiB threshold → exactly one BULK frame.
        let big = tensor(5000);
        let mut buf = Vec::new();
        write_job(
            &mut buf,
            &WireConn::off(),
            3,
            &Request::Fit { model: "m".into(), set: 0, qp: Arc::new(big.clone()) },
            &FaultDirective::default(),
        )
        .unwrap();
        // frame-level structure: one JOB control frame + one BULK frame
        let mut r: &[u8] = &buf;
        let ctl = store::read_frame(&mut r, MAX_IPC_FRAME).unwrap().unwrap();
        assert_eq!((ctl.kind, ctl.digest), (kinds::JOB, 3));
        assert!(
            ctl.payload.len() < CONTROL_BULK_THRESHOLD,
            "control frame must stay small when tensors go out of line"
        );
        let blk = store::read_frame(&mut r, MAX_IPC_FRAME).unwrap().unwrap();
        assert_eq!((blk.kind, blk.digest), (kinds::BULK, 3));
        assert!(store::read_frame(&mut r, MAX_IPC_FRAME).unwrap().is_none());
        // and the message-level decode reassembles the tensor bit-exactly
        let mut r: &[u8] = &buf;
        let (_, req, _) = read_job(&mut r).unwrap().unwrap();
        match req {
            Request::Fit { qp, .. } => {
                assert_eq!(qp.shape, big.shape);
                assert_eq!(qp.f32s().unwrap(), big.f32s().unwrap());
            }
            _ => panic!("wrong variant decoded"),
        }
        // a small tensor stays inline: single frame, no BULK
        let mut buf = Vec::new();
        write_job(
            &mut buf,
            &WireConn::off(),
            4,
            &Request::Fit { model: "m".into(), set: 0, qp: Arc::new(tensor(8)) },
            &FaultDirective::default(),
        )
        .unwrap();
        let mut r: &[u8] = &buf;
        store::read_frame(&mut r, MAX_IPC_FRAME).unwrap().unwrap();
        assert!(store::read_frame(&mut r, MAX_IPC_FRAME).unwrap().is_none());
    }

    #[test]
    fn heartbeats_interleave_transparently() {
        // PING surfaces to the worker's serving loop as WorkerIn::Ping
        let mut buf = Vec::new();
        write_ping(&mut buf, &WireConn::off(), 11).unwrap();
        write_job(&mut buf, &WireConn::off(), 5, &Request::Stats, &FaultDirective::default())
            .unwrap();
        let mut r: &[u8] = &buf;
        match read_job_or_ping(&mut r).unwrap().unwrap() {
            WorkerIn::Ping(seq) => assert_eq!(seq, 11),
            WorkerIn::Job(..) => panic!("ping decoded as a job"),
        }
        match read_job_or_ping(&mut r).unwrap().unwrap() {
            WorkerIn::Job(id, Request::Stats, _) => assert_eq!(id, 5),
            _ => panic!("job after ping decoded wrong"),
        }
        assert!(read_job_or_ping(&mut r).unwrap().is_none());
        // ...but the strict single-message reader rejects it
        let mut buf = Vec::new();
        write_ping(&mut buf, &WireConn::off(), 1).unwrap();
        let mut r: &[u8] = &buf;
        assert!(read_job(&mut r).is_err());

        // PONGs vanish inside the coordinator-side readers: replies and
        // init outcomes decode as if the pongs were never there
        let mut buf = Vec::new();
        write_pong(&mut buf, 1).unwrap();
        write_reply(&mut buf, 9, &Ok(Partial::Unit)).unwrap();
        write_pong(&mut buf, 2).unwrap();
        write_pong(&mut buf, 3).unwrap();
        write_reply(&mut buf, 10, &Err("boom".into())).unwrap();
        let mut r: &[u8] = &buf;
        let (id, res) = read_reply(&mut r).unwrap().unwrap();
        assert!(matches!((id, res), (9, Ok(Partial::Unit))));
        let (id, res) = read_reply(&mut r).unwrap().unwrap();
        assert_eq!((id, res.unwrap_err().as_str()), (10, "boom"));
        assert!(read_reply(&mut r).unwrap().is_none());
        let mut buf = Vec::new();
        write_pong(&mut buf, 4).unwrap();
        write_init(&mut buf, &Ok(())).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(read_init(&mut r).unwrap().unwrap(), Ok(()));
    }

    #[test]
    fn init_outcomes_roundtrip() {
        for res in [Ok(()), Err("runtime failed to start".to_string())] {
            let mut buf = Vec::new();
            write_init(&mut buf, &res).unwrap();
            let mut r: &[u8] = &buf;
            assert_eq!(read_init(&mut r).unwrap().unwrap(), res);
            assert!(read_init(&mut r).unwrap().is_none());
        }
    }

    #[test]
    fn kind_mismatch_and_truncation_fail_loudly() {
        let mut buf = Vec::new();
        write_reply(&mut buf, 1, &Ok(Partial::Unit)).unwrap();
        let mut r: &[u8] = &buf;
        let err = read_job(&mut r).unwrap_err().to_string();
        assert!(err.contains("JOB") && err.contains("REPLY"), "{err}");

        let mut buf = Vec::new();
        write_job(&mut buf, &WireConn::off(), 1, &Request::Stats, &FaultDirective::default())
            .unwrap();
        let mut r: &[u8] = &buf[..buf.len() - 1];
        assert!(read_job(&mut r).is_err(), "truncated frame must error, not EOF");
    }
}
