//! The wire seam: fault injection + telemetry for framed-socket I/O.
//!
//! Both socket control planes — the proc-lane worker transport
//! (`pool/transport.rs`) and the `mpqd` job protocol (`serve/proto.rs`)
//! — funnel every frame through a [`WireConn`], a thin wrapper over
//! [`store::write_frame`]/[`store::read_frame`].  With no wire faults
//! armed the wrapper is pass-through; with a [`WireFaults`] state
//! attached it realizes the `wdrop`/`wcorrupt`/`wdelay`/`wsplit`/
//! `wreset` clauses of the [`FaultPlan`](super::FaultPlan) grammar
//! **on the write side only**, so the *reader* always exercises its
//! real decode/reject paths (checksum mismatch, torn frame, clean EOF)
//! rather than a mock.
//!
//! Frame ordinals are per-connection: a [`WireConn`] counts the frames
//! written through it, and `wdrop@L:3` fires on the 3rd frame written
//! on lane L's connection (PING and BULK frames count).  A respawned
//! worker gets a fresh `WireConn`, so — exactly like the compute-fault
//! family — ordinals are per *incarnation* while one-shot consumption
//! is fleet-lifetime (shared [`WireFaults`]).
//!
//! [`WireStats`] is the always-on counter block (heartbeats, deadline
//! cancels, sheds, retries live here too, incremented by the fleet /
//! daemon / client directly); [`WireCounters`] is its plain snapshot
//! for `telemetry::Snapshot`.

use super::fault::{Fault, FaultKind, FaultPlan};
use crate::store::{self, Record};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Always-on wire telemetry, shared by every connection of one fleet or
/// daemon.  Injection counters are bumped by [`WireConn`]; the liveness
/// / deadline / retry / shed counters are bumped by the code that owns
/// those policies (supervisor, daemon scheduler, client).
#[derive(Debug, Default)]
pub struct WireStats {
    /// Frames swallowed by `wdrop`.
    pub frames_dropped: AtomicU64,
    /// Frames bit-flipped by `wcorrupt` (reader must checksum-reject).
    pub frames_corrupted: AtomicU64,
    /// Frames stalled mid-write by `wdelay`.
    pub frames_delayed: AtomicU64,
    /// Torn partial writes from `wsplit`.
    pub splits: AtomicU64,
    /// Connections failed by `wreset`.
    pub resets: AtomicU64,
    /// Heartbeat PING frames sent by the coordinator.
    pub heartbeats_sent: AtomicU64,
    /// Lanes declared dead for missing the liveness deadline.
    pub heartbeat_deaths: AtomicU64,
    /// Client-side reconnect/resubmit attempts.
    pub retries: AtomicU64,
    /// Jobs cancelled for exceeding their per-job deadline.
    pub deadline_cancels: AtomicU64,
    /// Submissions shed with a typed `RETRY_AFTER` reply.
    pub sheds: AtomicU64,
}

impl WireStats {
    /// Plain snapshot for telemetry.
    pub fn counters(&self) -> WireCounters {
        WireCounters {
            frames_dropped: self.frames_dropped.load(Ordering::Relaxed),
            frames_corrupted: self.frames_corrupted.load(Ordering::Relaxed),
            frames_delayed: self.frames_delayed.load(Ordering::Relaxed),
            splits: self.splits.load(Ordering::Relaxed),
            resets: self.resets.load(Ordering::Relaxed),
            heartbeats_sent: self.heartbeats_sent.load(Ordering::Relaxed),
            heartbeat_deaths: self.heartbeat_deaths.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            deadline_cancels: self.deadline_cancels.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of [`WireStats`] — the `telemetry::Snapshot.wire` field.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireCounters {
    pub frames_dropped: u64,
    pub frames_corrupted: u64,
    pub frames_delayed: u64,
    pub splits: u64,
    pub resets: u64,
    pub heartbeats_sent: u64,
    pub heartbeat_deaths: u64,
    pub retries: u64,
    pub deadline_cancels: u64,
    pub sheds: u64,
}

impl WireCounters {
    /// Anything nonzero?  Gates the conditional telemetry note section.
    pub fn any(&self) -> bool {
        *self != WireCounters::default()
    }

    /// Field-wise accumulate (for merging fleet + daemon stats).
    pub fn add(&mut self, o: &WireCounters) {
        self.frames_dropped += o.frames_dropped;
        self.frames_corrupted += o.frames_corrupted;
        self.frames_delayed += o.frames_delayed;
        self.splits += o.splits;
        self.resets += o.resets;
        self.heartbeats_sent += o.heartbeats_sent;
        self.heartbeat_deaths += o.heartbeat_deaths;
        self.retries += o.retries;
        self.deadline_cancels += o.deadline_cancels;
        self.sheds += o.sheds;
    }

    /// Total discrete wire faults injected (delay is continuous and
    /// excluded, mirroring how `slow@` is not counted by `FaultState`).
    pub fn injected(&self) -> u64 {
        self.frames_dropped + self.frames_corrupted + self.splits + self.resets
    }
}

/// One armed wire clause with its remaining-fire accounting (`1` for
/// one-shot, `usize::MAX` for recurring — mirrors `FaultState`).
struct WireClause {
    lane: usize,
    kind: FaultKind,
    fires: AtomicUsize,
}

/// Fleet-lifetime wire-fault state: the materialized clauses (explicit
/// wire tokens plus the `wseed`-derived per-lane schedule), the shared
/// [`WireStats`], and the last fault fired per lane — used to enrich a
/// death reason so a wire-caused death names the injected root cause.
pub struct WireFaults {
    clauses: Vec<WireClause>,
    stats: Arc<WireStats>,
    last: Mutex<HashMap<usize, String>>,
}

impl WireFaults {
    /// Materialize the plan's wire schedule over `lanes` connections
    /// (plus any explicit clause targeting a lane beyond that — a later
    /// `resize` may grow into it).  `None` when the plan carries no
    /// wire faults, keeping the fast path allocation-free.  `wseed`
    /// derivation only covers lanes below `lanes`; lanes added by a
    /// later resize get no derived clauses.
    pub fn new(plan: &FaultPlan, lanes: usize, stats: Arc<WireStats>) -> Option<Arc<Self>> {
        if !plan.has_wire_faults() {
            return None;
        }
        let mut faults: Vec<Fault> = Vec::new();
        for lane in 0..lanes.max(1) {
            faults.extend(plan.wire_faults_for_lane(lane));
        }
        faults.extend(
            plan.faults
                .iter()
                .filter(|f| f.kind.is_wire() && f.lane >= lanes.max(1))
                .copied(),
        );
        let clauses = faults
            .into_iter()
            .map(|f| WireClause {
                lane: f.lane,
                kind: f.kind,
                fires: AtomicUsize::new(if f.recurring { usize::MAX } else { 1 }),
            })
            .collect();
        Some(Arc::new(Self { clauses, stats, last: Mutex::new(HashMap::new()) }))
    }

    /// The shared counter block.
    pub fn stats(&self) -> &WireStats {
        &self.stats
    }

    /// Description of the last wire fault fired on `lane` — appended to
    /// a death reason so supervision errors name the injected cause.
    pub fn last_for(&self, lane: usize) -> Option<String> {
        self.last.lock().unwrap().get(&lane).cloned()
    }

    /// Continuous mid-frame delay for `lane` (largest wins, like
    /// `slow@`); never consumes a fire.
    fn delay_ms(&self, lane: usize) -> Option<u64> {
        self.clauses
            .iter()
            .filter_map(|c| match c.kind {
                FaultKind::WireDelay(ms) if c.lane == lane => Some(ms),
                _ => None,
            })
            .max()
    }

    /// Fire-and-consume the first discrete clause matching frame `nth`
    /// on `lane`.
    fn fire(&self, lane: usize, nth: usize) -> Option<FaultKind> {
        for c in &self.clauses {
            let hit = match c.kind {
                FaultKind::WireDrop(n)
                | FaultKind::WireCorrupt(n)
                | FaultKind::WireSplit(n)
                | FaultKind::WireReset(n) => c.lane == lane && n == nth,
                _ => false,
            };
            if hit
                && c.fires
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| match v {
                        0 => None,
                        usize::MAX => Some(usize::MAX),
                        v => Some(v - 1),
                    })
                    .is_ok()
            {
                return Some(c.kind);
            }
        }
        None
    }

    fn note(&self, lane: usize, msg: &str) {
        self.last.lock().unwrap().insert(lane, msg.to_string());
    }
}

/// Per-connection frame I/O seam.  All frame writes on a faultable
/// connection go through [`WireConn::write_frame`]; reads go through
/// [`WireConn::read_frame`] (pass-through today — injection is
/// write-side so readers exercise their genuine reject paths).
pub struct WireConn {
    faults: Option<Arc<WireFaults>>,
    lane: usize,
    writes: AtomicUsize,
}

impl WireConn {
    /// A connection with injection disabled (worker-side writers, and
    /// every caller running without a wire plan).
    pub fn off() -> Self {
        Self { faults: None, lane: 0, writes: AtomicUsize::new(0) }
    }

    /// A connection bound to `lane`'s clauses in the shared state.
    pub fn new(faults: Option<Arc<WireFaults>>, lane: usize) -> Self {
        Self { faults, lane, writes: AtomicUsize::new(0) }
    }

    /// Write one frame, realizing any armed wire fault for this frame
    /// ordinal.  Injected failures carry the `injected fault:` prefix.
    pub fn write_frame(&self, w: &mut impl Write, kind: u16, digest: u64, payload: &[u8]) -> Result<()> {
        let Some(f) = &self.faults else {
            return store::write_frame(w, kind, digest, payload);
        };
        let nth = self.writes.fetch_add(1, Ordering::Relaxed) + 1;
        let lane = self.lane;
        match f.fire(lane, nth) {
            Some(FaultKind::WireDrop(_)) => {
                f.stats.frames_dropped.fetch_add(1, Ordering::Relaxed);
                f.note(lane, &format!("injected fault: wire drop (lane {lane}, frame {nth})"));
                return Ok(()); // the peer never sees this frame
            }
            Some(FaultKind::WireReset(_)) => {
                f.stats.resets.fetch_add(1, Ordering::Relaxed);
                let msg = format!("injected fault: wire reset (lane {lane}, frame {nth})");
                f.note(lane, &msg);
                return Err(anyhow!(msg));
            }
            Some(FaultKind::WireCorrupt(_)) => {
                f.stats.frames_corrupted.fetch_add(1, Ordering::Relaxed);
                f.note(
                    lane,
                    &format!("injected fault: wire corrupt (lane {lane}, frame {nth})"),
                );
                // flip a bit in the last byte — payload (or the checksum
                // itself when the payload is empty), never the length
                // header, so the reader consumes the whole frame and
                // must reject it with a checksum mismatch
                let mut bytes = store::encode_record(kind, digest, payload);
                let i = bytes.len() - 1;
                bytes[i] ^= 0x01;
                w.write_all(&bytes)?;
                w.flush()?;
                return Ok(());
            }
            Some(FaultKind::WireSplit(_)) => {
                f.stats.splits.fetch_add(1, Ordering::Relaxed);
                let bytes = store::encode_record(kind, digest, payload);
                let cut = (bytes.len() / 2).max(1);
                let msg = format!(
                    "injected fault: wire split (lane {lane}, frame {nth}, {cut}/{} bytes)",
                    bytes.len()
                );
                f.note(lane, &msg);
                // torn prefix, then the connection is declared failed
                let _ = w.write_all(&bytes[..cut]).and_then(|_| w.flush());
                return Err(anyhow!(msg));
            }
            _ => {}
        }
        if let Some(ms) = f.delay_ms(lane) {
            f.stats.frames_delayed.fetch_add(1, Ordering::Relaxed);
            let bytes = store::encode_record(kind, digest, payload);
            let cut = bytes.len() / 2;
            w.write_all(&bytes[..cut])?;
            w.flush()?;
            std::thread::sleep(std::time::Duration::from_millis(ms));
            w.write_all(&bytes[cut..])?;
            w.flush()?;
            return Ok(());
        }
        store::write_frame(w, kind, digest, payload)
    }

    /// Read one frame.  Pass-through to [`store::read_frame`] — the
    /// seam exists so a future read-side family (and the multi-host
    /// lift) lands here without touching the callers again.
    pub fn read_frame(&self, r: &mut impl Read, max_len: usize) -> Result<Option<Record>> {
        store::read_frame(r, max_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn faults(spec: &str, lanes: usize) -> Arc<WireFaults> {
        WireFaults::new(&FaultPlan::parse(spec).unwrap(), lanes, Arc::new(WireStats::default()))
            .expect("plan has wire faults")
    }

    fn read_all(bytes: &[u8]) -> Vec<Record> {
        let mut r = std::io::Cursor::new(bytes);
        let mut out = Vec::new();
        while let Some(rec) = store::read_frame(&mut r, 1 << 20).unwrap() {
            out.push(rec);
        }
        out
    }

    #[test]
    fn off_conn_is_pass_through() {
        let conn = WireConn::off();
        let mut buf = Vec::new();
        conn.write_frame(&mut buf, 7, 99, b"payload").unwrap();
        let recs = read_all(&buf);
        assert_eq!(recs.len(), 1);
        assert_eq!((recs[0].kind, recs[0].digest, recs[0].payload.as_slice()), (7, 99, &b"payload"[..]));
    }

    #[test]
    fn drop_swallows_exactly_the_nth_frame() {
        let f = faults("wdrop@0:2", 1);
        let conn = WireConn::new(Some(f.clone()), 0);
        let mut buf = Vec::new();
        for i in 0..4u64 {
            conn.write_frame(&mut buf, 1, i, b"x").unwrap();
        }
        let digests: Vec<u64> = read_all(&buf).iter().map(|r| r.digest).collect();
        assert_eq!(digests, vec![0, 2, 3], "frame 2 (digest 1) was dropped");
        assert_eq!(f.stats().counters().frames_dropped, 1);
        assert!(f.last_for(0).unwrap().contains("injected fault: wire drop"));
        assert!(f.last_for(1).is_none());
    }

    #[test]
    fn corrupt_forces_a_checksum_rejection() {
        let f = faults("wcorrupt@0:1", 1);
        let conn = WireConn::new(Some(f), 0);
        let mut buf = Vec::new();
        conn.write_frame(&mut buf, 1, 5, b"payload").unwrap();
        let mut r = std::io::Cursor::new(&buf);
        let err = store::read_frame(&mut r, 1 << 20).unwrap_err();
        assert!(format!("{err:#}").contains("frame checksum mismatch"), "got: {err:#}");
        // empty payload: the flipped bit lands in the checksum itself
        let f = faults("wcorrupt@0:1", 1);
        let conn = WireConn::new(Some(f), 0);
        let mut buf = Vec::new();
        conn.write_frame(&mut buf, 1, 5, b"").unwrap();
        let err = store::read_frame(&mut std::io::Cursor::new(&buf), 1 << 20).unwrap_err();
        assert!(format!("{err:#}").contains("frame checksum mismatch"), "got: {err:#}");
    }

    #[test]
    fn split_and_reset_fail_the_writer_with_typed_errors() {
        let f = faults("wsplit@0:1, wreset@1:1", 2);
        let conn = WireConn::new(Some(f.clone()), 0);
        let mut buf = Vec::new();
        let err = conn.write_frame(&mut buf, 1, 5, b"payload").unwrap_err();
        assert!(format!("{err:#}").contains("injected fault: wire split"));
        assert!(!buf.is_empty() && buf.len() < store::encode_record(1, 5, b"payload").len());
        // the torn prefix must not decode as a record
        let err = store::read_frame(&mut std::io::Cursor::new(&buf), 1 << 20).unwrap_err();
        assert!(format!("{err:#}").contains("mid frame"), "got: {err:#}");

        let conn = WireConn::new(Some(f.clone()), 1);
        let mut buf = Vec::new();
        let err = conn.write_frame(&mut buf, 1, 5, b"payload").unwrap_err();
        assert!(format!("{err:#}").contains("injected fault: wire reset"));
        assert!(buf.is_empty(), "reset writes nothing");
        let c = f.stats().counters();
        assert_eq!((c.splits, c.resets, c.injected()), (1, 1, 2));
    }

    #[test]
    fn delay_is_continuous_and_frames_stay_intact() {
        let f = faults("wdelay@0:1", 1);
        let conn = WireConn::new(Some(f.clone()), 0);
        let mut buf = Vec::new();
        for i in 0..3u64 {
            conn.write_frame(&mut buf, 1, i, b"abc").unwrap();
        }
        assert_eq!(read_all(&buf).len(), 3, "delayed frames decode cleanly");
        assert_eq!(f.stats().counters().frames_delayed, 3);
        assert_eq!(f.stats().counters().injected(), 0, "delay is not a discrete fault");
    }

    #[test]
    fn one_shot_consumption_spans_incarnations() {
        // a respawned lane gets a fresh WireConn (ordinals reset) but the
        // shared one-shot clause is already spent
        let f = faults("wdrop@0:1", 1);
        let conn = WireConn::new(Some(f.clone()), 0);
        let mut buf = Vec::new();
        conn.write_frame(&mut buf, 1, 0, b"x").unwrap();
        assert!(buf.is_empty(), "first incarnation: frame 1 dropped");
        let conn2 = WireConn::new(Some(f.clone()), 0);
        let mut buf2 = Vec::new();
        conn2.write_frame(&mut buf2, 1, 0, b"x").unwrap();
        assert_eq!(read_all(&buf2).len(), 1, "respawn: one-shot already spent");
        // recurring re-fires on every incarnation
        let f = faults("wdrop@0:1*", 1);
        for _ in 0..3 {
            let conn = WireConn::new(Some(f.clone()), 0);
            let mut buf = Vec::new();
            conn.write_frame(&mut buf, 1, 0, b"x").unwrap();
            assert!(buf.is_empty());
        }
        assert_eq!(f.stats().counters().frames_dropped, 3);
    }

    #[test]
    fn counters_merge_and_gate() {
        let mut a = WireCounters::default();
        assert!(!a.any());
        let b = WireCounters { sheds: 2, retries: 1, ..Default::default() };
        a.add(&b);
        a.add(&b);
        assert!(a.any());
        assert_eq!((a.sheds, a.retries), (4, 2));
    }
}
