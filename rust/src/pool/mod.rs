//! The evaluation fleet — one process-wide set of worker threads, each
//! owning a **private** backend client, shared by every model and pipeline
//! in the process.
//!
//! ## Why a fleet of whole clients
//!
//! The PJRT client (and everything hanging off it: compiled executables,
//! device buffers, `Rc`-shared runtime state) is not `Send`, so backend
//! state can never cross a thread boundary.  [`EvalFleet`] sidesteps the
//! `!Send` wall by *replication*: each worker thread builds its own
//! [`crate::runtime::Runtime`] and, **lazily on first use**, a per-model
//! [`crate::model::ModelHandle`] (compiled forward executable + resident
//! trained parameters) plus its shard of each registered eval set.  Only
//! host data crosses the channels: configs, override tensors, calibration
//! state in; streaming-accumulator partials out.
//!
//! ## Elasticity and sharing (vs the PR-2 per-pipeline pool)
//!
//! * **One fleet per process** — [`EvalFleet::new`] spawns the workers
//!   once; [`EvalPool::attach`] attaches a model and returns an
//!   [`EvalPool`], the per-model view every pipeline drives.  Worker
//!   runtimes (and their executable caches) outlive model attach/detach,
//!   so a multi-model experiment driver pays thread spawn and runtime
//!   construction once, and attaching a second model performs **zero
//!   recompilations** of the first model's executables (asserted via
//!   [`EvalFleet::worker_stats`] / [`EvalFleet::model_opens`]).  Detaching
//!   the last client of a model evicts its handles, shards and memo
//!   entries everywhere — eagerly by default, or deferred through the
//!   **idle-model warm list** ([`EvalFleet::set_max_idle`]): with a
//!   budget of `n`, the last detach parks the model (host state, worker
//!   slots, memo entries and open handles intact) and only the
//!   least-recently-idled overflow past `n` is evicted, so a long-lived
//!   daemon under model churn bounds resident compiled executables while
//!   re-attaching a warm model costs zero recompiles *and* zero
//!   re-opens.
//! * **`resize(n)`** grows or shrinks the fleet between phases: the
//!   front-end keeps host copies of every model's calibration state,
//!   registered datasets and installed FP32 references, re-shards them
//!   over the new worker count, and replays them (references included, so
//!   no worker pays an extra rebuild sweep); probe results are full-set
//!   scalars, so the memo stays valid across any resize.
//! * **Pipelined (double-buffered) set upload** — `load_set`,
//!   `set_calibration` and `build_references` no longer block on worker
//!   acks.  Upload jobs ride the same FIFO queue as probes, so the
//!   coordinator enqueues an upload and immediately continues building and
//!   enqueueing probe work (and collecting results from other workers)
//!   while each worker's H→D copy is in flight; a probe enqueued behind
//!   its set's upload is correct by queue order.  Upload errors are
//!   recorded worker-side and surfaced by the first tracked job that
//!   touches the broken state.
//!
//! ## Execution model
//!
//! Shard-parallel work ([`EvalPool::submit`] / [`EvalPool::map_probes`] /
//! [`EvalPool::fit_accumulate`]) broadcasts to *all* workers — each
//! evaluates its contiguous shard and returns a partial, and the front-end
//! reduces in global batch order.  Job-parallel work
//! ([`EvalPool::adaround_jobs`]) dispatches each independent
//! `(layer, wbits)` optimization to a *single* worker round-robin, so
//! independent layers anneal concurrently.
//!
//! ## Exactness guarantee
//!
//! Fleet results are **bit-identical** to the serial path for SQNR, the
//! counting task metrics, FIT accumulation and AdaRound, for any worker
//! count:
//!
//! * shards are contiguous batch ranges, and each worker computes exactly
//!   the per-batch partials the serial path computes;
//! * [`StreamingSqnr`] keys partials by *global* batch index and reduces in
//!   index order; top-1 / F1 / mIoU partials are integer counts;
//! * FIT shards return **raw per-batch** gradient/error vectors and the
//!   front-end replays the serial `(abits, batch)` accumulation order
//!   term by term ([`crate::sensitivity`]);
//! * an AdaRound job is a self-contained deterministic optimization — the
//!   same inputs anneal to the same rounding on any worker.
//!
//! The one documented exception is the Pearson (STS-B) head, whose Welford
//! states combine to the serial value up to float rounding.
//!
//! Because every merge is keyed by **global batch index** (not by which
//! worker produced it), the guarantee survives worker death: a requeued or
//! re-sharded job recomputes exactly the batch partials the dead worker
//! owed, and the reduction is insensitive to who computed what.
//!
//! ## Fleet-wide caches
//!
//! * **Memo** — finished probes are memoized by
//!   `(model, set, kind, config, override-digest)`, shared across every
//!   client and search on the fleet.  `set_calibration` and re-loading a
//!   set invalidate the affected entries; detach drops the model's.
//! * **Per-worker references** — each worker's engine caches the FP32
//!   reference for *its shard*; `build_references` triggers the build
//!   eagerly, `install_references` seeds it from a host copy (the on-disk
//!   reference cache), and `fetch_reference` collects the full-set
//!   reference back for persistence.  The front-end retains the installed
//!   / fetched full-set copy in host memory so respawn and resize can
//!   re-install shards without another forward sweep.
//!
//! ## Failure semantics (the self-healing supervisor)
//!
//! The fleet is supervised: worker failure is contained, repaired and
//! accounted for, not propagated.  The moving parts, in the order they
//! engage:
//!
//! 1. **Death notices.**  A worker that panics sends one final
//!    `DEATH_NOTICE` message and exits *without* answering the job it was
//!    serving.  mpsc channels are FIFO per sender, so every reply the dead
//!    incarnation did produce is already queued ahead of the notice — once
//!    the notice is processed, no stale reply from that incarnation can
//!    exist.
//! 2. **Respawn with bounded restarts.**  The supervisor (which runs
//!    inline on the coordinator thread, inside `collect` and the submit
//!    paths) respawns the dead worker's *lane* with exponential backoff,
//!    up to a per-lane restart budget (default 3, tunable via the fault
//!    plan's `budget:N`).  The replacement gets a **fresh incarnation id**
//!    (`widx`), so anything late from a previous incarnation matches no
//!    pending slot and is dropped.
//! 3. **State replay.**  The replacement is rebuilt from the front-end's
//!    host copies: calibration state, its shard of every registered set,
//!    and its slice of any retained FP32 reference (no rebuild sweep).
//! 4. **Requeue.**  Every tracked job slot the dead incarnation still owed
//!    (its in-flight job plus everything queued behind it) retains its
//!    original request; the supervisor re-sends those to the replacement
//!    under the same job id.  Merges are keyed by global batch index, so
//!    results stay bit-identical to the fault-free run.
//! 5. **Graceful degradation.**  When a lane exhausts its restart budget
//!    it is *reaped* — removed from the worker vec entirely, so `workers()`
//!    and round-robin dispatch see the true live count — and the fleet
//!    shrinks to the survivors: host state is re-sharded over the smaller
//!    fleet and every orphaned job is re-dispatched under the new sharding
//!    (waiters follow a redirect from the old job id).  Only at **zero**
//!    live workers do jobs fail, with the stored root-cause death reasons
//!    in the error.
//! 6. **Deadline watchdog.**  With the fault plan's `deadline:MS` set,
//!    `collect` waits at most MS ms between worker replies; on a timeout,
//!    every live worker still owing a result is presumed stuck and
//!    converted into a death (respawn → requeue as above).  The marooned
//!    thread is detached, never joined; its eventual replies carry a
//!    retired `widx` and are dropped.  Off by default: production waits
//!    indefinitely.
//!
//! Fire-and-forget uploads keep their PR-5 semantics under faults: an
//! injected (or real) `LoadSet`/`BuildReference` failure is recorded in
//! the worker's shard slot and surfaced by the first tracked job that
//! touches it, with the root cause (`injected fault: …`) intact.
//!
//! Telemetry: [`EvalFleet::failure_stats`] reports `worker_restarts`,
//! `jobs_requeued`, `faults_injected`, degradation events and the last
//! death reasons.
//!
//! Documented limitation: a job requeued after a death observes the
//! front-end's *latest* host state — if a set was replaced while probes on
//! the old data were still in flight, the requeued probe evaluates the new
//! data.  Pipelines never do this (they drain probes before reloading a
//! set), and the property/e2e tests never hit it.
//!
//! ## Process lanes (`EvalFleet::new_proc`)
//!
//! The same fleet can run its lanes as **`mpq worker` subprocesses**
//! instead of threads.  Each process lane is a private Unix socket (bound
//! inside a freshly created mode-0700 rendezvous directory whose name is
//! unique per spawn — pid plus a process-wide sequence — so concurrent
//! fleets never collide and no other local user can connect first) plus a
//! pair of bridge threads adapting the fleet's mpsc seam to the wire: the
//! serving loop in the child is the same `pool/worker.rs` code, and the
//! job/reply surface crosses the socket as MPQJ checksummed frames
//! (`pool/transport.rs`), with tensors above a **16 KiB control/bulk
//! threshold** shipped as out-of-line framed MPQT payloads.  Floats cross
//! the wire as raw bits, so process-lane results remain **byte-equal to
//! serial** at any lane count — the thread-fleet exactness guarantee
//! survives the address-space boundary.
//!
//! Supervision generalizes rather than changes: a worker process that
//! panics, exits, or is SIGKILLed closes its socket, the lane's reader
//! converts the EOF into the same `DEATH_NOTICE` a panicking thread
//! sends, and respawn / host-state replay / requeue / degradation
//! proceed identically.  Fault plans apply to process lanes too —
//! directives are computed **coordinator-side** per job (preserving
//! global one-shot depletion and per-incarnation recurrence) and ride
//! the JOB frame; `panic@` becomes a real process death in the child.
//! The coordinator re-executes its own binary for workers; set
//! `MPQ_WORKER_BIN` when the current executable is not `mpq` (tests and
//! benches point it at the built binary).
//!
//! Two child-side counters are process-local by construction:
//! [`EvalFleet::model_opens`] counts in-process lanes only, and an
//! injected compile fault's firing is not reflected in the parent's
//! `faults_injected` telemetry.  The dist tier asserts on neither.
//!
//! ### Heartbeats and liveness
//!
//! A process lane is also **heartbeated**: whenever a lane's job queue is
//! idle for `MPQ_HEARTBEAT_MS` ms (default 250; `0` disables), its feeder
//! writes a PING frame, and a dedicated socket-reader thread in the child
//! answers PONG immediately — even while the worker's main thread is deep
//! in a compute, an injected `slow@`, or a `stall@` (both threads share
//! one mutex-guarded writer, locked across whole frames).  The
//! coordinator's reader carries a liveness read-timeout of
//! `max(8 × interval, 1000 ms)`: a lane producing **no frame at all** —
//! neither reply nor pong — for that long is declared dead ("worker
//! heartbeat missed"), reaped, and respawned through the ordinary
//! supervision path.  Healthy-but-busy lanes never trip it; only a
//! wedged, stopped (SIGSTOP-grade), or silently disconnected peer does.
//!
//! ### Wire faults (transport chaos)
//!
//! The fault grammar's **wire family** (`wdrop@L:N`, `wcorrupt@L:N`,
//! `wdelay@L:MS`, `wsplit@L:N`, `wreset@L:N`, and the randomized
//! `wseed:S` schedule — see `pool/fault.rs`) injects faults at the
//! frame-write seam ([`WireConn`], wrapping `store::write_frame`) on the
//! **coordinator side** of each lane's socket, counting that lane's
//! outbound control frames 1-based (PINGs and BULK frames included).
//! Injection is write-side only, so the peer exercises its *real* decode
//! and rejection paths: a corrupted frame is caught by the checksum, a
//! torn `wsplit` surfaces as "stream ended mid frame", a `wdrop`ped JOB
//! starves the reply until the deadline watchdog fires.  Every recovery
//! then flows through the existing supervisor (death → respawn → replay
//! → requeue), which is the point: the chaos tier proves byte-equal
//! results *after* healing, with [`EvalFleet::wire_counters`] exposing
//! what was injected and `"injected fault:"` in every death reason it
//! caused.
//!
//! ## Durability & resume (process-boundary crashes)
//!
//! The supervisor above covers worker-*thread* death; death of the whole
//! coordinator process is covered one layer up by the write-ahead run
//! journal ([`crate::store::RunJournal`], attached via
//! `Pipeline::set_journal`).  The pooled paths participate symmetrically
//! with the serial ones: `sensitivity_list_pooled` replays journaled
//! probes *before* anything enters the fleet (a replayed probe is never
//! submitted) and journals each fresh score in submission order as its
//! wait completes, so barrier ordinals are deterministic at any worker
//! count; `adaround_all_pooled` does the same per `(layer, wbits)` job.
//! Since pooled results are bit-identical to serial ones (the exactness
//! guarantee), a journal written by a pooled run resumes a serial run and
//! vice versa, at any worker count.  The `crash@PHASE:N` fault-plan clause
//! (see [`FaultPlan`]) aborts the process at the Nth journal barrier —
//! write-ahead order, *after* the record is durable — which is how the
//! `resume_e2e` kill/restart matrix drives every crash point.

mod fault;
mod proc;
mod transport;
pub mod wire;
mod worker;

pub use fault::{Fault, FaultKind, FaultPlan};
pub use wire::{WireConn, WireCounters, WireFaults, WireStats};

use crate::adaround::AdaRoundJob;
use crate::data::DataSet;
use crate::engine::StreamingSqnr;
use crate::manifest::Manifest;
use crate::metrics::StreamingTaskMetric;
use crate::model::{QuantConfig, WeightOverrides};
use crate::quant::ActRanges;
use crate::sensitivity::FitBatchRaw;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use fault::FaultState;
use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Identifies a registered eval set within the fleet (per model).
pub type SetKey = u64;

/// Conventional key for the calibration set (Phase 1).
pub const CALIB_SET: SetKey = 0;
/// Conventional key for the validation set (Phase 2).
pub const VAL_SET: SetKey = 1;

/// Per-lane restart budget when the fault plan doesn't override it.
const DEFAULT_RESTART_BUDGET: usize = 3;
/// Respawn backoff base in ms (doubled per restart, capped).
const DEFAULT_BACKOFF_MS: u64 = 10;
const MAX_BACKOFF_MS: u64 = 500;
/// How many death reasons the fleet retains for error reporting.
const LAST_DEATHS_CAP: usize = 8;

/// What a probe measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProbeKind {
    /// Network-output SQNR vs the cached FP32 reference (Eq. 3).
    Sqnr,
    /// The model's task metric (top-1 / F1 / Pearson / mIoU).
    Metric,
}

/// Host-only request shipped to a worker.  Everything here is `Send`; no
/// backend state ever crosses the channel.  Payloads sit behind `Arc` where
/// an N-worker broadcast would otherwise deep-copy them N times.  `Clone`
/// because tracked jobs retain their request until resolved, so the
/// supervisor can requeue a dead worker's slots onto its replacement.
#[derive(Clone)]
enum Request {
    /// Install calibrated quantizer state (host data) on the worker's
    /// handle for `model`.
    Calibrate {
        model: Arc<str>,
        ranges: ActRanges,
        w_scales: HashMap<u8, Vec<Vec<f32>>>,
    },
    /// Upload this worker's shard of an eval set.
    LoadSet {
        model: Arc<str>,
        key: SetKey,
        batches: Vec<Tensor>,
        labels: Tensor,
        first_batch: usize,
    },
    /// Eagerly build the FP32 reference for the worker's shard of `set`.
    BuildReference { model: Arc<str>, set: SetKey },
    /// Seed the worker's reference cache from host logits (the on-disk
    /// reference cache) instead of a forward sweep.
    InstallReference {
        model: Arc<str>,
        set: SetKey,
        batches: Vec<Tensor>,
    },
    /// Return the worker's shard of the FP32 reference (for persistence).
    FetchReference { model: Arc<str>, set: SetKey },
    /// Evaluate one probe on the worker's shard of `set`.
    Probe {
        model: Arc<str>,
        set: SetKey,
        kind: ProbeKind,
        cfg: Arc<QuantConfig>,
        overrides: Arc<WeightOverrides>,
    },
    /// FIT accumulation pass at one activation bit-width: run the FIT
    /// executable over the worker's shard and return the **raw per-batch**
    /// outputs, so the front-end can replay the serial accumulation order.
    Fit {
        model: Arc<str>,
        set: SetKey,
        qp: Arc<Tensor>,
    },
    /// One whole `(layer, wbits)` AdaRound optimization (single-worker
    /// dispatch, not a broadcast).
    AdaRound { model: Arc<str>, job: Arc<AdaRoundJob> },
    /// Drop the model's handle, shards and reference caches.
    Detach { model: Arc<str> },
    /// Report per-worker cache counters.
    Stats,
}

struct Job {
    id: u64,
    req: Request,
}

/// A worker's result for one job.
enum Partial {
    Sqnr(StreamingSqnr),
    Task(StreamingTaskMetric),
    Fit(FitShard),
    Batches { first_batch: usize, batches: Vec<Tensor> },
    Rounded(Tensor),
    Stats(WorkerStats),
    Unit,
}

/// Raw FIT outputs for one worker's shard (global batch order within).
struct FitShard {
    first_batch: usize,
    raws: Vec<FitBatchRaw>,
}

/// Per-worker cache counters (compile-cache assertions in tests/benches).
#[derive(Clone, Copy, Debug)]
pub struct WorkerStats {
    /// distinct executables compiled by this worker's runtime so far
    pub compiled: usize,
    /// model handles currently open on this worker
    pub models_open: usize,
}

/// Failure telemetry for the supervised fleet (see the module docs'
/// failure-semantics section) — surfaced by driver reports and asserted
/// by the self-healing acceptance tests.
#[derive(Clone, Debug, Default)]
pub struct FailureStats {
    /// successful worker respawns after a death notice or watchdog firing
    pub worker_restarts: usize,
    /// tracked job slots re-sent to a replacement or re-dispatched onto
    /// the survivors after a degradation
    pub jobs_requeued: usize,
    /// discrete fault firings from the plan (panics, upload/compile
    /// failures, stalls; continuous `slow` lanes are not counted)
    pub faults_injected: usize,
    /// one entry per lane retired after exhausting its restart budget
    pub degraded_events: Vec<String>,
    /// most recent worker death reasons (capped ring)
    pub last_deaths: Vec<String>,
}

impl FailureStats {
    /// Anything worth reporting?
    pub fn any(&self) -> bool {
        self.worker_restarts > 0
            || self.jobs_requeued > 0
            || self.faults_injected > 0
            || !self.degraded_events.is_empty()
            || !self.last_deaths.is_empty()
    }
}

type ResMsg = (u64, usize, Result<Partial, String>);

/// Sentinel job id a worker sends right before its thread exits on a
/// panic.  The supervisor turns it into a respawn of the worker's lane and
/// a requeue of every slot the dead incarnation still owed — see the
/// module docs' failure-semantics section.  Job ids count up from 0 and
/// can never reach this value in practice.
const DEATH_NOTICE: u64 = u64::MAX;

/// Memo key: `(model id, set, kind, config, override digest)` — overrides
/// are folded in as a content digest so AdaRound-stitched and plain
/// evaluations of the same bit-config never alias, and two models' probes
/// never collide.
type MemoKey = (u64, SetKey, ProbeKind, QuantConfig, u64);

/// One live fleet worker.
///
/// * `widx` — the **incarnation id**, unique across the fleet's lifetime
///   and stamped on every reply; a respawned replacement always gets a
///   fresh one, so late replies from a previous incarnation match no
///   pending slot.
/// * `lane` — the stable **supervision slot**: a replacement keeps its
///   predecessor's lane, which is what fault plans target and what the
///   restart budget is counted against.  Fresh spawns (including
///   `resize` growth) take lanes from a monotone counter, so a lane is
///   never accidentally reused after its worker was reaped.
struct Worker {
    widx: usize,
    lane: usize,
    /// restarts consumed by this lane so far (carried across incarnations)
    restarts: usize,
    tx: Option<mpsc::Sender<Job>>,
    join: Option<JoinHandle<()>>,
    /// present on process lanes: the subprocess + its bridge threads
    proc: Option<proc::ProcLane>,
}

impl Worker {
    /// Phase one of a deliberate close: mark a process lane's teardown
    /// intentional (so its reader doesn't report the EOF as a death) and
    /// drop the job sender, which ends the lane's serving loop.
    fn close_begin(&mut self) {
        if let Some(p) = &self.proc {
            p.begin_close();
        }
        self.tx.take();
    }

    /// Phase two: join the worker thread (thread lanes) or the bridge
    /// threads + child process (process lanes).  Callers run phase one on
    /// *every* worker being closed before running phase two on any, so
    /// lanes drain concurrently.
    fn close_finish(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        if let Some(p) = self.proc.take() {
            p.finish_close();
        }
    }
}

/// One worker's result slot in a tracked job.  The request is retained
/// until the slot resolves so the supervisor can requeue it if the owing
/// incarnation dies.
struct PendSlot {
    /// incarnation that currently owes this slot's result
    widx: usize,
    req: Option<Request>,
    res: Option<Result<Partial, String>>,
}

/// An in-flight tracked job: per-worker result slots (in dispatch = global
/// batch order) plus how many are still outstanding.
struct Pending {
    slots: Vec<PendSlot>,
    remaining: usize,
}

/// Host-side replayable state for one attached model — what `resize` and
/// the supervisor's respawn path re-shard onto a changed worker set.
struct ModelState {
    id: u64,
    attached: usize,
    calib: Option<(ActRanges, HashMap<u8, Vec<Vec<f32>>>)>,
    sets: HashMap<SetKey, DataSet>,
    /// full-set FP32 reference logits retained from `install_references`
    /// / `fetch_reference`, re-installed shard-wise on replay so restored
    /// references survive resize and respawn without a rebuild sweep
    refs: HashMap<SetKey, Vec<Tensor>>,
}

/// The process-wide elastic worker fleet.  See the module docs.
///
/// The fleet handle is intended to be driven from one thread (the
/// coordinator); the workers it owns are where the parallelism lives.
/// Supervision (death handling, respawn, requeue, degradation) runs
/// inline on the coordinator thread inside `collect` and the submit
/// paths, so it is race-free with job dispatch by construction.
pub struct EvalFleet {
    dir: PathBuf,
    manifest: Manifest,
    workers: Mutex<Vec<Worker>>,
    /// kept alive for elastic spawn — new workers clone it
    res_tx: mpsc::Sender<ResMsg>,
    res_rx: Mutex<mpsc::Receiver<ResMsg>>,
    pending: Mutex<HashMap<u64, Pending>>,
    next_id: AtomicU64,
    memo: Mutex<HashMap<MemoKey, f64>>,
    memo_hits: AtomicUsize,
    memo_misses: AtomicUsize,
    /// model handles opened (= lazy compiles) across all workers, ever
    opens: Arc<AtomicUsize>,
    state: Mutex<HashMap<String, ModelState>>,
    /// idle (refcount-zero) models kept warm, least-recently-idled first;
    /// bounded by `max_idle` (see [`EvalFleet::set_max_idle`])
    warm: Mutex<Vec<String>>,
    /// idle-model retention budget: 0 = evict eagerly on last detach
    max_idle: AtomicUsize,
    next_model_id: AtomicU64,
    /// monotone incarnation-id allocator (see [`Worker::widx`])
    next_widx: AtomicUsize,
    /// monotone lane allocator for fresh (non-replacement) spawns
    next_lane: AtomicUsize,
    /// spawn lanes as `mpq worker` subprocesses instead of threads
    proc: bool,
    /// fault schedule + fire accounting (empty plan in production)
    faults: Arc<FaultState>,
    /// wire-level chaos telemetry (heartbeats, injected frames, liveness
    /// deaths); always allocated so counters read zero without a plan
    wire_stats: Arc<WireStats>,
    /// materialized per-lane wire-fault schedule; `None` without wire
    /// clauses, so the hot path stays a single branch on a plain option
    wire_faults: Option<Arc<WireFaults>>,
    worker_restarts: AtomicUsize,
    jobs_requeued: AtomicUsize,
    degraded: Mutex<Vec<String>>,
    last_deaths: Mutex<Vec<String>>,
    /// old job id → new job id for jobs re-dispatched after a degradation;
    /// collectors follow (and consume) these
    redirects: Mutex<HashMap<u64, u64>>,
}

impl EvalFleet {
    /// Spawn a fleet of `workers` (≥ 1) threads over the artifacts at
    /// `dir`.  Workers build their private runtime at spawn; models
    /// compile lazily on first use.
    ///
    /// The fault plan (normally empty) is resolved from, in precedence
    /// order: the `MPQ_FAULT_PLAN` environment variable, then the
    /// manifest's optional `"fault_plan"` key.  Use
    /// [`EvalFleet::with_faults`] to pin one explicitly.
    pub fn new(dir: impl AsRef<Path>, workers: usize) -> Result<Rc<Self>> {
        Self::build(dir.as_ref().to_path_buf(), workers, None, false)
    }

    /// Spawn a fleet with an explicit [`FaultPlan`] — wins over the
    /// environment and the manifest, so dedicated fault tests stay
    /// deterministic even under the fault-injection CI job.
    pub fn with_faults(dir: impl AsRef<Path>, workers: usize, plan: FaultPlan) -> Result<Rc<Self>> {
        Self::build(dir.as_ref().to_path_buf(), workers, Some(plan), false)
    }

    /// Spawn a fleet of `workers` **subprocess** lanes (`mpq worker`, see
    /// the module docs' process-lanes section) instead of threads.  Same
    /// API, same exactness guarantee, same supervisor — but a lane death
    /// is a real process death (SIGKILL-grade), and lane state lives in a
    /// separate address space.
    pub fn new_proc(dir: impl AsRef<Path>, workers: usize) -> Result<Rc<Self>> {
        Self::build(dir.as_ref().to_path_buf(), workers, None, true)
    }

    /// Process lanes with an explicit [`FaultPlan`] (the dist-tier fault
    /// harness).  Fault decisions stay coordinator-side — see
    /// [`transport::FaultDirective`] — so plan semantics match thread
    /// lanes exactly.
    pub fn with_faults_proc(
        dir: impl AsRef<Path>,
        workers: usize,
        plan: FaultPlan,
    ) -> Result<Rc<Self>> {
        Self::build(dir.as_ref().to_path_buf(), workers, Some(plan), true)
    }

    fn build(dir: PathBuf, workers: usize, explicit: Option<FaultPlan>, proc: bool) -> Result<Rc<Self>> {
        let manifest = Manifest::load(&dir)?;
        let plan = match explicit {
            Some(p) => p,
            None => match std::env::var("MPQ_FAULT_PLAN") {
                Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s)?,
                _ => match manifest.fault_plan.as_deref() {
                    Some(s) => FaultPlan::parse(s)?,
                    None => FaultPlan::default(),
                },
            },
        };
        let (res_tx, res_rx) = mpsc::channel::<ResMsg>();
        // materialize the wire schedule before FaultState consumes the plan
        let wire_stats = Arc::new(WireStats::default());
        let wire_faults = WireFaults::new(&plan, workers.max(1), wire_stats.clone());
        let fleet = Rc::new(Self {
            dir,
            manifest,
            workers: Mutex::new(Vec::new()),
            res_tx,
            res_rx: Mutex::new(res_rx),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            memo: Mutex::new(HashMap::new()),
            memo_hits: AtomicUsize::new(0),
            memo_misses: AtomicUsize::new(0),
            opens: Arc::new(AtomicUsize::new(0)),
            state: Mutex::new(HashMap::new()),
            warm: Mutex::new(Vec::new()),
            max_idle: AtomicUsize::new(0),
            next_model_id: AtomicU64::new(0),
            next_widx: AtomicUsize::new(0),
            next_lane: AtomicUsize::new(0),
            proc,
            faults: Arc::new(FaultState::new(plan)),
            wire_stats,
            wire_faults,
            worker_restarts: AtomicUsize::new(0),
            jobs_requeued: AtomicUsize::new(0),
            degraded: Mutex::new(Vec::new()),
            last_deaths: Mutex::new(Vec::new()),
            redirects: Mutex::new(HashMap::new()),
        });
        fleet.spawn_workers(workers.max(1))?;
        Ok(fleet)
    }

    /// Artifacts directory the fleet serves.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Live worker count (dead lanes are reaped, so this is exact).
    pub fn workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Per-worker subprocess pids, in worker order (`None` for thread
    /// lanes).  The dist-tier supervision tests SIGKILL one of these and
    /// assert the fleet heals.
    pub fn proc_pids(&self) -> Vec<Option<u32>> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .map(|w| w.proc.as_ref().map(|p| p.pid()))
            .collect()
    }

    /// The fault plan this fleet was built with (empty in production).
    pub fn fault_plan(&self) -> &FaultPlan {
        self.faults.plan()
    }

    /// Probes actually dispatched to workers (memo misses), fleet-wide.
    pub fn probes_computed(&self) -> usize {
        self.memo_misses.load(Ordering::Relaxed)
    }

    /// Probes served from the fleet memo.
    pub fn memo_hits(&self) -> usize {
        self.memo_hits.load(Ordering::Relaxed)
    }

    /// Drop every memoized probe result (benchmarks use this to measure
    /// steady-state sweeps rather than pure cache hits).
    pub fn clear_memo(&self) {
        self.memo.lock().unwrap().clear();
    }

    /// Model handles opened (compiled) by workers over the fleet's life —
    /// the lazy-compile counter the fleet-reuse acceptance test asserts
    /// on: re-probing an attached model must not move it.
    pub fn model_opens(&self) -> usize {
        self.opens.load(Ordering::Relaxed)
    }

    /// Bound the number of **idle** (refcount-zero) models kept resident
    /// after their last client detaches.  `0` — the default — evicts
    /// eagerly on last detach, the historical behavior.  `n > 0` keeps up
    /// to `n` recently-idled models warm (host state, worker slots, memo
    /// entries and open handles all survive), so re-attaching one costs
    /// zero recompiles *and* zero re-opens; overflow evicts the
    /// least-recently-idled model.  A long-lived daemon under model churn
    /// uses this to bound resident compiled executables.  Shrinking the
    /// budget evicts the overflow immediately.
    pub fn set_max_idle(&self, n: usize) {
        self.max_idle.store(n, Ordering::Relaxed);
        self.trim_warm();
    }

    /// Current idle-model retention budget.
    pub fn max_idle(&self) -> usize {
        self.max_idle.load(Ordering::Relaxed)
    }

    /// Idle models currently kept warm (least-recently-idled first).
    pub fn warm_models(&self) -> Vec<String> {
        self.warm.lock().unwrap().clone()
    }

    /// Failure telemetry: restarts, requeues, injected faults, degradation
    /// events and the last stored death reasons.
    pub fn failure_stats(&self) -> FailureStats {
        FailureStats {
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            jobs_requeued: self.jobs_requeued.load(Ordering::Relaxed),
            faults_injected: self.faults.injected(),
            degraded_events: self.degraded.lock().unwrap().clone(),
            last_deaths: self.last_deaths.lock().unwrap().clone(),
        }
    }

    /// Wire-level chaos telemetry: heartbeats sent, liveness deaths,
    /// frames dropped/corrupted/split/reset by the injection seam.  All
    /// zeros in production (no wire plan, heartbeats healthy).
    pub fn wire_counters(&self) -> WireCounters {
        self.wire_stats.counters()
    }

    /// Per-worker compile-cache counters, in worker order.
    pub fn worker_stats(&self) -> Result<Vec<WorkerStats>> {
        let id = self.submit_broadcast(true, |_, _| Request::Stats)?;
        let mut out = Vec::new();
        for (_, p) in self.collect(id)? {
            match p {
                Partial::Stats(s) => out.push(s),
                _ => bail!("worker returned a non-stats partial"),
            }
        }
        Ok(out)
    }

    /// Grow or shrink the fleet to `n` workers (≥ 1) between phases.
    /// Host-side model state (calibration, datasets, retained FP32
    /// references) is re-sharded and replayed onto the new worker set; the
    /// probe memo survives (probe results are full-set values, independent
    /// of sharding).  Sets whose reference was installed or fetched are
    /// re-installed from the host copy — no rebuild sweep.
    pub fn resize(&self, n: usize) -> Result<()> {
        let n = n.max(1);
        if !self.pending.lock().unwrap().is_empty() {
            bail!("fleet resize with tracked jobs still in flight");
        }
        self.poll_notices()?;
        let cur = self.workers();
        if n == cur {
            return Ok(());
        }
        if n < cur {
            let mut removed: Vec<Worker> = self.workers.lock().unwrap().drain(n..).collect();
            for w in removed.iter_mut() {
                w.close_begin(); // closing the channel ends the worker's loop
            }
            for w in removed.iter_mut() {
                w.close_finish();
            }
        } else {
            self.spawn_workers(n - cur)?;
        }
        self.replay_state()
    }

    // -- internals -----------------------------------------------------------

    /// Spawn one worker (thread or, with `new_proc`, subprocess) on `lane`
    /// with a fresh incarnation id.  Does not wait for init and does not
    /// touch the worker vec.
    fn spawn_one(
        &self,
        lane: usize,
        init_tx: mpsc::Sender<(usize, Result<(), String>)>,
    ) -> Result<Worker> {
        let widx = self.next_widx.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<Job>();
        if self.proc {
            let pl = proc::spawn_proc_worker(
                widx,
                lane,
                &self.dir,
                rx,
                self.res_tx.clone(),
                init_tx,
                &self.faults,
                self.wire_faults.clone(),
                self.wire_stats.clone(),
            )
            .map_err(|e| anyhow!("spawning fleet worker process {widx}: {e:#}"))?;
            return Ok(Worker { widx, lane, restarts: 0, tx: Some(tx), join: None, proc: Some(pl) });
        }
        let (d, rtx) = (self.dir.clone(), self.res_tx.clone());
        let opens = self.opens.clone();
        let faults = self.faults.clone();
        let join = std::thread::Builder::new()
            .name(format!("mpq-fleet-{widx}"))
            .spawn(move || worker::worker_main(widx, lane, d, rx, rtx, init_tx, opens, faults))
            .map_err(|e| anyhow!("spawning fleet worker {widx}: {e}"))?;
        Ok(Worker { widx, lane, restarts: 0, tx: Some(tx), join: Some(join), proc: None })
    }

    /// Spawn `n` fresh workers at the tail (initial spawn and `resize`
    /// growth), waiting for every init and rolling back the batch on any
    /// failure.
    fn spawn_workers(&self, n: usize) -> Result<()> {
        let (init_tx, init_rx) = mpsc::channel::<(usize, Result<(), String>)>();
        {
            let mut ws = self.workers.lock().unwrap();
            for _ in 0..n {
                let lane = self.next_lane.fetch_add(1, Ordering::Relaxed);
                let w = self.spawn_one(lane, init_tx.clone())?;
                ws.push(w);
            }
        }
        drop(init_tx);
        let mut failures = Vec::new();
        for _ in 0..n {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((w, Err(e))) => failures.push(format!("worker {w}: {e}")),
                Err(_) => {
                    failures.push("a worker exited before reporting init".into());
                    break;
                }
            }
        }
        if !failures.is_empty() {
            // roll back the batch we just spawned (they sit at the tail)
            let mut tail: Vec<Worker> = {
                let mut ws = self.workers.lock().unwrap();
                let keep = ws.len().saturating_sub(n);
                ws.drain(keep..).collect()
            };
            for w in tail.iter_mut() {
                w.close_begin();
            }
            for w in tail.iter_mut() {
                w.close_finish();
            }
            bail!("fleet worker init failed: {}", failures.join("; "));
        }
        Ok(())
    }

    /// Spawn a replacement on a dead worker's lane and wait for its init.
    fn spawn_replacement(&self, lane: usize) -> Result<Worker> {
        let (init_tx, init_rx) = mpsc::channel::<(usize, Result<(), String>)>();
        let mut w = self.spawn_one(lane, init_tx)?;
        match init_rx.recv() {
            Ok((_, Ok(()))) => Ok(w),
            Ok((_, Err(e))) => {
                w.close_begin();
                w.close_finish();
                bail!("replacement init failed: {e}")
            }
            Err(_) => {
                w.close_begin();
                w.close_finish();
                bail!("replacement exited before reporting init")
            }
        }
    }

    /// The replay requests rebuilding worker position `pos` of `n` from
    /// host state: calibration, its shard of every set, and its slice of
    /// every retained FP32 reference.
    fn replay_requests_for(&self, pos: usize, n: usize) -> Result<Vec<Request>> {
        type Snap = (
            String,
            Option<(ActRanges, HashMap<u8, Vec<Vec<f32>>>)>,
            Vec<(SetKey, DataSet)>,
            HashMap<SetKey, Vec<Tensor>>,
        );
        let snapshot: Vec<Snap> = {
            let st = self.state.lock().unwrap();
            st.iter()
                .map(|(name, ms)| {
                    (
                        name.clone(),
                        ms.calib.clone(),
                        ms.sets.iter().map(|(&k, ds)| (k, ds.clone())).collect(),
                        ms.refs.clone(),
                    )
                })
                .collect()
        };
        let mut out = Vec::new();
        for (name, calib, sets, refs) in snapshot {
            let model: Arc<str> = Arc::from(name.as_str());
            if let Some((ranges, w_scales)) = calib {
                out.push(Request::Calibrate { model: model.clone(), ranges, w_scales });
            }
            let batch = self.manifest.model(&name)?.batch;
            for (key, ds) in sets {
                let batches = ds.batches(batch)?;
                let labels = ds.labels_prefix(batch)?;
                let r = &shard_ranges(batches.len(), n)[pos];
                out.push(Request::LoadSet {
                    model: model.clone(),
                    key,
                    batches: batches[r.clone()].to_vec(),
                    labels: labels
                        .slice_rows(r.start * batch, (r.end - r.start) * batch)
                        .expect("labels_prefix is batch-aligned"),
                    first_batch: r.start,
                });
                if let Some(full) = refs.get(&key) {
                    let rr = &shard_ranges(full.len(), n)[pos];
                    out.push(Request::InstallReference {
                        model: model.clone(),
                        set: key,
                        batches: full[rr.clone()].to_vec(),
                    });
                }
            }
        }
        Ok(out)
    }

    /// Re-shard and replay every attached model's host state onto the
    /// current worker set (after a resize or a degradation).  Replay jobs
    /// are fire-and-forget: errors are recorded worker-side and surfaced
    /// by the first tracked job that touches the broken state.
    fn replay_state(&self) -> Result<()> {
        let n = self.workers();
        if n == 0 {
            return Ok(());
        }
        for pos in 0..n {
            let reqs = self.replay_requests_for(pos, n)?;
            let tx = {
                let ws = self.workers.lock().unwrap();
                ws.get(pos).and_then(|w| w.tx.clone())
            };
            let Some(tx) = tx else { continue };
            for req in reqs {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                // a send failure means the worker just died; its death
                // notice is already queued and will be handled on the next
                // poll
                let _ = tx.send(Job { id, req });
            }
        }
        Ok(())
    }

    /// Replay host state onto one (just-respawned) worker.
    fn replay_worker(&self, widx: usize) -> Result<()> {
        let (pos, n, tx) = {
            let ws = self.workers.lock().unwrap();
            match ws.iter().position(|w| w.widx == widx) {
                Some(pos) => match ws[pos].tx.clone() {
                    Some(tx) => (pos, ws.len(), tx),
                    None => return Ok(()),
                },
                None => return Ok(()),
            }
        };
        for req in self.replay_requests_for(pos, n)? {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Job { id, req });
        }
        Ok(())
    }

    /// Drop one client's reference.  At refcount zero the model either
    /// evicts immediately (`max_idle == 0`) or parks on the warm list,
    /// evicting the least-recently-idled overflow.
    fn detach(&self, model: &str) {
        let evict_now = {
            let mut st = self.state.lock().unwrap();
            match st.get_mut(model) {
                Some(ms) => {
                    ms.attached = ms.attached.saturating_sub(1);
                    if ms.attached != 0 {
                        return;
                    }
                    if self.max_idle.load(Ordering::Relaxed) == 0 {
                        st.remove(model).map(|ms| ms.id)
                    } else {
                        let mut warm = self.warm.lock().unwrap();
                        warm.retain(|m| m != model);
                        warm.push(model.to_string());
                        None
                    }
                }
                None => return,
            }
        };
        match evict_now {
            Some(id) => self.evict(model, id),
            None => self.trim_warm(),
        }
    }

    /// Purge an evicted model's memo entries and broadcast the worker-side
    /// detach (fire-and-forget; the host-side `ModelState` is already
    /// removed by the caller).
    fn evict(&self, model: &str, model_id: u64) {
        self.memo.lock().unwrap().retain(|k, _| k.0 != model_id);
        let m: Arc<str> = Arc::from(model);
        let _ = self.fire(|_, _| Request::Detach { model: m.clone() });
    }

    /// Evict least-recently-idled warm models until the warm list fits
    /// the idle budget.
    fn trim_warm(&self) {
        let victims: Vec<(String, u64)> = {
            let mut st = self.state.lock().unwrap();
            let mut warm = self.warm.lock().unwrap();
            let max_idle = self.max_idle.load(Ordering::Relaxed);
            let mut out = Vec::new();
            while warm.len() > max_idle {
                let victim = warm.remove(0);
                if let Some(ms) = st.remove(&victim) {
                    out.push((victim, ms.id));
                }
            }
            out
        };
        for (name, id) in victims {
            self.evict(&name, id);
        }
    }

    /// "Everything is dead" error text, carrying the stored root causes
    /// instead of a bare channel-disconnect message.
    fn no_workers_msg(&self) -> String {
        let deaths = self.last_deaths.lock().unwrap();
        if deaths.is_empty() {
            "all fleet workers exited".to_string()
        } else {
            format!("all fleet workers exited; last deaths: {}", deaths.join("; "))
        }
    }

    fn record_death(&self, widx: usize, reason: &str) {
        let mut deaths = self.last_deaths.lock().unwrap();
        deaths.push(format!("worker {widx}: {reason}"));
        let overflow = deaths.len().saturating_sub(LAST_DEATHS_CAP);
        if overflow > 0 {
            deaths.drain(..overflow);
        }
    }

    /// Drain every result message already queued, routing replies into
    /// pending slots and deaths into the supervisor.  Submit paths call
    /// this before snapshotting the worker set so they never dispatch to a
    /// worker whose death notice is already waiting.
    fn poll_notices(&self) -> Result<()> {
        loop {
            let msg = { self.res_rx.lock().unwrap().try_recv() };
            match msg {
                Ok(m) => self.route(m)?,
                Err(_) => return Ok(()), // empty (the fleet's own sender keeps it connected)
            }
        }
    }

    /// Route one result message: fill the matching pending slot, or hand a
    /// death notice to the supervisor.  Replies whose `(job, widx)` pair
    /// matches no open slot — fire-and-forget acks, duplicates from a
    /// retried dispatch, stragglers from a retired incarnation — are
    /// dropped.
    fn route(&self, (jid, w, r): ResMsg) -> Result<()> {
        if jid == DEATH_NOTICE {
            let reason = match r {
                Err(e) => e,
                Ok(_) => "worker died".into(),
            };
            return self.handle_death(w, &reason, true);
        }
        let mut pending = self.pending.lock().unwrap();
        if let Some(p) = pending.get_mut(&jid) {
            if let Some(slot) = p.slots.iter_mut().find(|s| s.widx == w && s.res.is_none()) {
                slot.res = Some(r);
                slot.req = None; // resolved — no longer needed for requeue
                p.remaining -= 1;
            }
        }
        Ok(())
    }

    /// Supervise a worker death: respawn the lane within its restart
    /// budget (exponential backoff), replay host state onto the
    /// replacement and requeue everything the dead incarnation owed; or,
    /// budget exhausted, degrade to the survivors.  `true_death` means the
    /// thread actually exited (join it); the watchdog passes `false` for a
    /// stuck-but-alive thread, which is detached instead.
    fn handle_death(&self, dead: usize, reason: &str, true_death: bool) -> Result<()> {
        let (lane, restarts, join, proc) = {
            let mut ws = self.workers.lock().unwrap();
            let Some(pos) = ws.iter().position(|w| w.widx == dead) else {
                return Ok(()); // already handled (e.g. watchdog then notice)
            };
            let w = &mut ws[pos];
            w.tx.take();
            (w.lane, w.restarts, w.join.take(), w.proc.take())
        };
        self.record_death(dead, reason);
        if true_death {
            if let Some(j) = join {
                let _ = j.join();
            }
        }
        // else: drop the handle — the marooned thread's eventual replies
        // carry a retired widx and are dropped by `route`
        if let Some(p) = proc {
            // unlike a marooned thread, a stuck subprocess *can* be
            // reclaimed: reap kills it (raising `closing` first, so the
            // reader's post-kill EOF emits no second notice) and joins the
            // bridge threads.  For a true death the child already exited
            // and this just collects the corpse.
            p.reap();
        }

        let budget = self.faults.plan().budget.unwrap_or(DEFAULT_RESTART_BUDGET);
        let base = self.faults.plan().backoff_ms.unwrap_or(DEFAULT_BACKOFF_MS);
        let mut attempts = restarts;
        while attempts < budget {
            let wait = backoff_ms(base, attempts);
            if wait > 0 {
                std::thread::sleep(Duration::from_millis(wait));
            }
            attempts += 1;
            match self.spawn_replacement(lane) {
                Ok(mut neww) => {
                    neww.restarts = attempts;
                    let new_widx = neww.widx;
                    {
                        let mut ws = self.workers.lock().unwrap();
                        match ws.iter().position(|w| w.widx == dead) {
                            Some(pos) => ws[pos] = neww,
                            None => ws.push(neww), // unreachable: entries only leave via degrade
                        }
                    }
                    self.worker_restarts.fetch_add(1, Ordering::Relaxed);
                    self.replay_worker(new_widx)?;
                    self.requeue(dead, new_widx);
                    return Ok(());
                }
                Err(e) => {
                    self.record_death(dead, &format!("lane {lane} respawn attempt {attempts}: {e:#}"));
                }
            }
        }
        self.degrade(dead, lane, reason)
    }

    /// Move every unresolved slot of the dead incarnation onto its
    /// replacement, re-sending the retained requests under the same job
    /// ids.  Safe because the dead incarnation's replies all preceded its
    /// death notice (per-sender FIFO) — nothing stale can fill the moved
    /// slots, and the replacement serves its replayed state first (queue
    /// order).
    fn requeue(&self, dead: usize, new_widx: usize) {
        let new_tx = {
            let ws = self.workers.lock().unwrap();
            ws.iter().find(|w| w.widx == new_widx).and_then(|w| w.tx.clone())
        };
        let mut moved = 0usize;
        let mut pending = self.pending.lock().unwrap();
        for (id, p) in pending.iter_mut() {
            for slot in p.slots.iter_mut().filter(|s| s.widx == dead && s.res.is_none()) {
                slot.widx = new_widx;
                let sent = match (&new_tx, &slot.req) {
                    (Some(tx), Some(req)) => tx.send(Job { id: *id, req: req.clone() }).is_ok(),
                    _ => false,
                };
                if sent {
                    moved += 1;
                } else {
                    slot.res = Some(Err(
                        "job lost with its worker and could not be requeued".to_string(),
                    ));
                    slot.req = None;
                    p.remaining -= 1;
                }
            }
        }
        if moved > 0 {
            self.jobs_requeued.fetch_add(moved, Ordering::Relaxed);
        }
    }

    /// Restart budget exhausted: reap the dead lane, shrink to the
    /// survivors (re-sharding host state over them) and re-dispatch every
    /// orphaned job under the new sharding.  Only at zero live workers do
    /// the orphans fail — with the stored death reasons.
    fn degrade(&self, dead: usize, lane: usize, reason: &str) -> Result<()> {
        {
            let mut ws = self.workers.lock().unwrap();
            if let Some(pos) = ws.iter().position(|w| w.widx == dead) {
                ws.remove(pos);
            }
        }
        let survivors = self.workers();
        self.degraded.lock().unwrap().push(format!(
            "lane {lane} (worker {dead}) retired after exhausting its restart budget \
             ({reason}); continuing on {survivors} worker(s)"
        ));
        if survivors == 0 {
            let msg = self.no_workers_msg();
            let mut pending = self.pending.lock().unwrap();
            for p in pending.values_mut() {
                for slot in p.slots.iter_mut() {
                    if slot.res.is_none() {
                        slot.res = Some(Err(msg.clone()));
                        slot.req = None;
                        p.remaining -= 1;
                    }
                }
            }
            return Ok(());
        }
        self.replay_state()?;
        self.redispatch_orphans(dead)
    }

    /// Re-dispatch every tracked job the dead worker still owed as a fresh
    /// job over the surviving fleet (the survivors' in-flight copies of
    /// the old job are dropped — a shard under the old worker count is
    /// useless once the fleet re-shards).  Waiters find their way to the
    /// new id through `redirects`.
    fn redispatch_orphans(&self, dead: usize) -> Result<()> {
        let orphans: Vec<(u64, Pending)> = {
            let mut pending = self.pending.lock().unwrap();
            let ids: Vec<u64> = pending
                .iter()
                .filter(|(_, p)| p.slots.iter().any(|s| s.widx == dead && s.res.is_none()))
                .map(|(&id, _)| id)
                .collect();
            ids.into_iter().map(|id| (id, pending.remove(&id).unwrap())).collect()
        };
        for (old_id, p) in orphans {
            let req = p
                .slots
                .iter()
                .find(|s| s.widx == dead && s.res.is_none())
                .and_then(|s| s.req.clone());
            let redo = match req {
                // per-worker-different payload: rebuild the shards from the
                // retained host reference
                Some(Request::InstallReference { model, set, .. }) => {
                    self.submit_install_from_state(&model, set)
                }
                // single-worker job, deterministic on any worker
                Some(Request::AdaRound { model, job }) => {
                    self.submit_one(0, Request::AdaRound { model, job })
                }
                // broadcasts with per-worker-identical payloads (probes,
                // FIT passes, stats, reference fetches)
                Some(req) => self.submit_broadcast(true, move |_, _| req.clone()),
                None => Err(anyhow!(
                    "job {old_id} was lost with worker {dead} and left no retained request"
                )),
            };
            match redo {
                Ok(new_id) => {
                    self.jobs_requeued.fetch_add(1, Ordering::Relaxed);
                    self.redirects.lock().unwrap().insert(old_id, new_id);
                }
                Err(e) => {
                    // park a resolved-failed entry under the old id so the
                    // waiting collector surfaces the error instead of
                    // hitting an unknown job
                    self.pending.lock().unwrap().insert(
                        old_id,
                        Pending {
                            slots: vec![PendSlot {
                                widx: dead,
                                req: None,
                                res: Some(Err(format!("{e:#}"))),
                            }],
                            remaining: 0,
                        },
                    );
                }
            }
        }
        Ok(())
    }

    /// Fresh tracked `InstallReference` broadcast built from the retained
    /// host reference (used when re-dispatching an orphaned install).
    fn submit_install_from_state(&self, model: &Arc<str>, set: SetKey) -> Result<u64> {
        let full: Vec<Tensor> = {
            let st = self.state.lock().unwrap();
            st.get(&**model)
                .and_then(|ms| ms.refs.get(&set))
                .cloned()
                .ok_or_else(|| {
                    anyhow!("no retained host reference to re-dispatch the lost install job")
                })?
        };
        let model = model.clone();
        self.submit_broadcast(true, move |pos, n| Request::InstallReference {
            model: model.clone(),
            set,
            batches: full[shard_ranges(full.len(), n)[pos].clone()].to_vec(),
        })
    }

    /// Deadline watchdog: no worker replied within the plan's
    /// `deadline:MS` window, so every live worker still owing a result is
    /// presumed stuck and converted into a (non-joining) death — the
    /// supervisor respawns or degrades exactly as for a panic.
    fn watchdog_fire(&self) -> Result<()> {
        let owing: Vec<usize> = {
            let pending = self.pending.lock().unwrap();
            let ws = self.workers.lock().unwrap();
            ws.iter()
                .filter(|w| w.tx.is_some())
                .map(|w| w.widx)
                .filter(|&widx| {
                    pending
                        .values()
                        .any(|p| p.slots.iter().any(|s| s.widx == widx && s.res.is_none()))
                })
                .collect()
        };
        for widx in owing {
            self.handle_death(widx, "no reply within the watchdog deadline (presumed stuck)", false)?;
        }
        Ok(())
    }

    /// Send one job to every live worker.  With `track`, a [`Pending`]
    /// entry is created and [`Self::collect`] must be called; without, the
    /// job is fire-and-forget — workers still reply, and the unknown-id
    /// replies are dropped.  `mk(pos, n)` builds the request for worker
    /// position `pos` of `n`, so shard-dependent payloads stay correct if
    /// a death shrinks the fleet between attempts (each retry uses a fresh
    /// job id, so replies to an abandoned half-dispatch can never fill the
    /// retry's slots).
    fn submit_broadcast(&self, track: bool, mk: impl Fn(usize, usize) -> Request) -> Result<u64> {
        loop {
            self.poll_notices()?;
            let targets: Vec<(usize, mpsc::Sender<Job>)> = {
                let ws = self.workers.lock().unwrap();
                ws.iter()
                    .filter_map(|w| w.tx.as_ref().map(|tx| (w.widx, tx.clone())))
                    .collect()
            };
            if targets.is_empty() {
                bail!("{}", self.no_workers_msg());
            }
            let n = targets.len();
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let reqs: Vec<Request> = (0..n).map(|pos| mk(pos, n)).collect();
            if track {
                self.pending.lock().unwrap().insert(
                    id,
                    Pending {
                        slots: targets
                            .iter()
                            .zip(&reqs)
                            .map(|(&(widx, _), req)| PendSlot {
                                widx,
                                req: Some(req.clone()),
                                res: None,
                            })
                            .collect(),
                        remaining: n,
                    },
                );
            }
            let mut ok = true;
            for ((_, tx), req) in targets.iter().zip(reqs) {
                if tx.send(Job { id, req }).is_err() {
                    ok = false;
                    break;
                }
            }
            if ok {
                return Ok(id);
            }
            // a target died between the snapshot and the send — its death
            // notice is already queued (workers notify before dropping
            // their receiver).  Abandon this dispatch and redo the whole
            // broadcast after the supervisor has run.
            if track {
                self.pending.lock().unwrap().remove(&id);
            }
        }
    }

    fn fire(&self, mk: impl Fn(usize, usize) -> Request) -> Result<()> {
        self.submit_broadcast(false, mk).map(|_| ())
    }

    /// Send one tracked job to a single worker (`w` is taken modulo the
    /// live worker count, so round-robin callers stay valid across
    /// degradations).
    fn submit_one(&self, w: usize, req: Request) -> Result<u64> {
        loop {
            self.poll_notices()?;
            let target = {
                let ws = self.workers.lock().unwrap();
                let live: Vec<(usize, mpsc::Sender<Job>)> = ws
                    .iter()
                    .filter_map(|wk| wk.tx.as_ref().map(|tx| (wk.widx, tx.clone())))
                    .collect();
                if live.is_empty() {
                    None
                } else {
                    Some(live[w % live.len()].clone())
                }
            };
            let Some((widx, tx)) = target else {
                bail!("{}", self.no_workers_msg());
            };
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            self.pending.lock().unwrap().insert(
                id,
                Pending {
                    slots: vec![PendSlot { widx, req: Some(req.clone()), res: None }],
                    remaining: 1,
                },
            );
            if tx.send(Job { id, req: req.clone() }).is_ok() {
                return Ok(id);
            }
            self.pending.lock().unwrap().remove(&id);
        }
    }

    /// Block until every expected worker reported on `id`; error if any
    /// slot failed.  Returns the partials in dispatch (= global batch)
    /// order.  Runs the supervisor inline: death notices respawn/requeue,
    /// a degradation may redirect this job to a fresh id, and with a fault
    /// plan deadline the watchdog converts reply-starvation into deaths.
    fn collect(&self, id: u64) -> Result<Vec<(usize, Partial)>> {
        let mut id = id;
        let deadline = self.faults.plan().deadline_ms;
        loop {
            // a degradation may have re-dispatched this job under a new id
            while let Some(new_id) = self.redirects.lock().unwrap().remove(&id) {
                id = new_id;
            }
            {
                let mut pending = self.pending.lock().unwrap();
                let p = pending
                    .get(&id)
                    .ok_or_else(|| anyhow!("unknown or already-collected job {id}"))?;
                if p.remaining == 0 {
                    let p = pending.remove(&id).unwrap();
                    drop(pending);
                    let mut out = Vec::new();
                    let mut errs = Vec::new();
                    for s in p.slots {
                        match s.res {
                            None => {}
                            Some(Ok(part)) => out.push((s.widx, part)),
                            Some(Err(e)) => errs.push(format!("fleet worker {}: {e}", s.widx)),
                        }
                    }
                    if !errs.is_empty() {
                        bail!("{}", errs.join("; "));
                    }
                    return Ok(out);
                }
            }
            let msg = {
                let rx = self.res_rx.lock().unwrap();
                match deadline {
                    None => rx.recv().ok(),
                    Some(ms) => rx.recv_timeout(Duration::from_millis(ms)).ok(),
                }
            };
            match msg {
                Some(m) => self.route(m)?,
                // with a deadline, silence past it means stuck workers
                None if deadline.is_some() => self.watchdog_fire()?,
                // without one, recv can only fail if the channel fully
                // closed — which the fleet's own sender prevents
                None => bail!("{}", self.no_workers_msg()),
            }
        }
    }

    fn wait_unit(&self, id: u64) -> Result<()> {
        for (_, p) in self.collect(id)? {
            if !matches!(p, Partial::Unit) {
                bail!("worker returned a value for a control job");
            }
        }
        Ok(())
    }

    fn shutdown(&self) {
        let mut ws = self.workers.lock().unwrap();
        for w in ws.iter_mut() {
            w.close_begin(); // closing the channel ends the worker's recv loop
        }
        for w in ws.iter_mut() {
            w.close_finish();
        }
    }
}

impl Drop for EvalFleet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Exponential respawn backoff: `base << attempt`, capped.
fn backoff_ms(base: u64, attempt: usize) -> u64 {
    base.saturating_mul(1u64 << attempt.min(6)).min(MAX_BACKOFF_MS)
}

/// The `mpq worker` subprocess entrypoint (see the module docs' process-
/// lanes section): connect back to the coordinator's socket, handshake,
/// then serve framed jobs until the coordinator half-closes the lane.
/// Spawned by [`EvalFleet::new_proc`] fleets; never started by hand.
pub fn run_worker_child(
    socket: &Path,
    dir: &Path,
    lane: usize,
    compile_fault: Option<usize>,
) -> Result<()> {
    proc::run_worker(socket, dir, lane, compile_fault)
}

/// Per-model client of an [`EvalFleet`] — the handle pipelines and
/// searches drive.  [`EvalPool::new`] spawns a private single-model fleet
/// (the PR-2 shape); [`EvalPool::attach`] attaches to a shared one.
/// Dropping the last client of a model detaches it fleet-wide.
pub struct EvalPool {
    fleet: Rc<EvalFleet>,
    model: Arc<str>,
    model_id: u64,
    /// manifest task string — selects the accumulator used to merge
    /// task-metric partials
    task: String,
    batch: usize,
}

impl EvalPool {
    /// Spawn a private `workers`-thread fleet for one model at `dir` —
    /// the PR-2 compatible constructor.
    pub fn new(dir: impl AsRef<Path>, model: &str, workers: usize) -> Result<Self> {
        Self::attach(&EvalFleet::new(dir, workers)?, model)
    }

    /// Attach `model` (validated against the manifest) to a shared fleet
    /// and return the per-model client.  Attach counts are refcounted;
    /// the last client's drop detaches the model fleet-wide — eagerly
    /// (worker slots, shards and memo entries evicted) or onto the warm
    /// list when the fleet keeps idle models resident
    /// ([`EvalFleet::set_max_idle`]); attaching a warm model revives it
    /// with zero recompiles and zero re-opens.
    pub fn attach(fleet: &Rc<EvalFleet>, model: &str) -> Result<Self> {
        let entry = fleet.manifest.model(model)?;
        let (task, batch) = (entry.task.clone(), entry.batch);
        let model_id = {
            let mut st = fleet.state.lock().unwrap();
            let ms = st.entry(model.to_string()).or_insert_with(|| ModelState {
                id: fleet.next_model_id.fetch_add(1, Ordering::Relaxed),
                attached: 0,
                calib: None,
                sets: HashMap::new(),
                refs: HashMap::new(),
            });
            ms.attached += 1;
            // a warm model is idle no longer
            fleet.warm.lock().unwrap().retain(|m| m != model);
            ms.id
        };
        Ok(EvalPool {
            fleet: fleet.clone(),
            model: Arc::from(model),
            model_id,
            task,
            batch,
        })
    }

    /// The fleet this client drives (shared across models; `resize` and
    /// the compile counters live here).
    pub fn fleet(&self) -> &Rc<EvalFleet> {
        &self.fleet
    }

    /// Model this client is attached to.
    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn workers(&self) -> usize {
        self.fleet.workers()
    }

    /// Probes actually dispatched to workers (memo misses), fleet-wide.
    pub fn probes_computed(&self) -> usize {
        self.fleet.probes_computed()
    }

    /// Probes served from the fleet memo.
    pub fn memo_hits(&self) -> usize {
        self.fleet.memo_hits()
    }

    /// Drop every memoized probe result (fleet-wide; benchmarks).
    pub fn clear_memo(&self) {
        self.fleet.clear_memo();
    }

    /// Install calibrated quantizer state on every worker (pipelined, no
    /// ack).  Invalidates this model's memo entries: every probe result
    /// depends on the ranges.
    pub fn set_calibration(
        &self,
        ranges: &ActRanges,
        w_scales: &HashMap<u8, Vec<Vec<f32>>>,
    ) -> Result<()> {
        self.fleet
            .memo
            .lock()
            .unwrap()
            .retain(|k, _| k.0 != self.model_id);
        {
            let mut st = self.fleet.state.lock().unwrap();
            if let Some(ms) = st.get_mut(&*self.model) {
                ms.calib = Some((ranges.clone(), w_scales.clone()));
            }
        }
        self.fleet.fire(|_, _| Request::Calibrate {
            model: self.model.clone(),
            ranges: ranges.clone(),
            w_scales: w_scales.clone(),
        })
    }

    /// Register (or replace) an eval set under `key`, splitting its
    /// batches into contiguous per-worker shards (pipelined, no ack: the
    /// H→D upload overlaps the caller's subsequent probe construction, and
    /// probes enqueued behind it are correct by FIFO order).  Stale memo
    /// entries for `key` are dropped.  A trailing partial batch is
    /// truncated exactly like `ModelHandle::eval_set` does.
    pub fn load_set(&self, key: SetKey, ds: &DataSet) -> Result<()> {
        let batches = ds.batches(self.batch)?;
        if batches.is_empty() {
            bail!("dataset smaller than one batch ({})", self.batch);
        }
        let labels = ds.labels_prefix(self.batch)?;
        self.fleet
            .memo
            .lock()
            .unwrap()
            .retain(|k, _| !(k.0 == self.model_id && k.1 == key));
        {
            let mut st = self.fleet.state.lock().unwrap();
            if let Some(ms) = st.get_mut(&*self.model) {
                ms.sets.insert(key, ds.clone());
                // new data invalidates any retained FP32 reference
                ms.refs.remove(&key);
            }
        }
        let batch = self.batch;
        self.fleet.fire(|w, n| {
            let r = &shard_ranges(batches.len(), n)[w];
            Request::LoadSet {
                model: self.model.clone(),
                key,
                batches: batches[r.clone()].to_vec(),
                // labels rows [r.start·batch, r.end·batch) — may be empty
                labels: labels
                    .slice_rows(r.start * batch, (r.end - r.start) * batch)
                    .expect("labels_prefix is batch-aligned"),
                first_batch: r.start,
            }
        })
    }

    /// Build the FP32 reference for `set` eagerly — one full-set forward
    /// sweep, split across the workers' shards (pipelined, no ack).
    pub fn build_references(&self, set: SetKey) -> Result<()> {
        self.fleet.fire(|_, _| Request::BuildReference {
            model: self.model.clone(),
            set,
        })
    }

    /// Seed every worker's reference cache for `set` from host per-batch
    /// FP32 logits (the on-disk reference cache), skipping the forward
    /// sweep entirely.  Blocking: install errors indicate a stale or
    /// mis-keyed cache file and must surface at the call site.  The host
    /// copy is retained so resize and respawn replay re-install it.
    pub fn install_references(&self, set: SetKey, batches: &[Tensor]) -> Result<()> {
        {
            let mut st = self.fleet.state.lock().unwrap();
            if let Some(ms) = st.get_mut(&*self.model) {
                ms.refs.insert(set, batches.to_vec());
            }
        }
        let id = self.fleet.submit_broadcast(true, |w, n| Request::InstallReference {
            model: self.model.clone(),
            set,
            batches: batches[shard_ranges(batches.len(), n)[w].clone()].to_vec(),
        })?;
        self.fleet.wait_unit(id)
    }

    /// Collect the full-set FP32 reference (per-batch logits, global batch
    /// order) from the workers' shard caches — building shards that don't
    /// have one yet.  Feeds the on-disk reference cache; the collected
    /// copy is retained host-side for resize/respawn replay.
    pub fn fetch_reference(&self, set: SetKey) -> Result<Vec<Tensor>> {
        let id = self.fleet.submit_broadcast(true, |_, _| Request::FetchReference {
            model: self.model.clone(),
            set,
        })?;
        let mut shards: Vec<(usize, Vec<Tensor>)> = Vec::new();
        for (_, p) in self.fleet.collect(id)? {
            match p {
                Partial::Batches { first_batch, batches } => shards.push((first_batch, batches)),
                _ => bail!("worker returned a non-reference partial"),
            }
        }
        shards.sort_by_key(|&(fb, _)| fb);
        let full: Vec<Tensor> = shards.into_iter().flat_map(|(_, b)| b).collect();
        {
            let mut st = self.fleet.state.lock().unwrap();
            if let Some(ms) = st.get_mut(&*self.model) {
                ms.refs.insert(set, full.clone());
            }
        }
        Ok(full)
    }

    /// Submit one probe.  Served from the fleet memo when an identical
    /// probe (same model, set, kind, config and override content) already
    /// finished; otherwise fanned out to every worker's shard.  The
    /// returned handle must be waited on to collect (and memoize) the
    /// result.
    pub fn submit(
        &self,
        set: SetKey,
        kind: ProbeKind,
        cfg: &QuantConfig,
        overrides: &WeightOverrides,
    ) -> Result<JobHandle<'_>> {
        let key = (self.model_id, set, kind, cfg.clone(), overrides_digest(overrides));
        if let Some(&v) = self.fleet.memo.lock().unwrap().get(&key) {
            self.fleet.memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(JobHandle { pool: self, id: 0, kind, key: None, cached: Some(v) });
        }
        self.fleet.memo_misses.fetch_add(1, Ordering::Relaxed);
        let cfg = Arc::new(cfg.clone());
        let overrides = Arc::new(overrides.clone());
        let id = self.fleet.submit_broadcast(true, |_, _| Request::Probe {
            model: self.model.clone(),
            set,
            kind,
            cfg: cfg.clone(),
            overrides: overrides.clone(),
        })?;
        Ok(JobHandle { pool: self, id, kind, key: Some(key), cached: None })
    }

    /// Evaluate a list of probes, preserving input order in the results.
    /// All probes are enqueued before the first wait, so the whole list
    /// pipelines through the workers.  (Identical probes submitted in the
    /// same call are both dispatched — the memo fills at completion; probe
    /// lists don't repeat configurations in practice.)
    pub fn map_probes(
        &self,
        set: SetKey,
        kind: ProbeKind,
        probes: &[(QuantConfig, WeightOverrides)],
    ) -> Result<Vec<f64>> {
        let handles = probes
            .iter()
            .map(|(cfg, ov)| self.submit(set, kind, cfg, ov))
            .collect::<Result<Vec<_>>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Run one FIT accumulation pass per `qps` entry (one packed `act_qp`
    /// tensor per activation bit-width) over the workers' shards of `set`,
    /// returning the **raw per-batch** executable outputs in global batch
    /// order — the caller replays the serial accumulation over them, which
    /// is what makes pooled FIT bit-identical to the serial path.  All
    /// passes are enqueued before the first wait, so they pipeline.
    pub fn fit_accumulate(&self, set: SetKey, qps: &[Tensor]) -> Result<Vec<Vec<FitBatchRaw>>> {
        let ids = qps
            .iter()
            .map(|qp| {
                let qp = Arc::new(qp.clone());
                self.fleet.submit_broadcast(true, |_, _| Request::Fit {
                    model: self.model.clone(),
                    set,
                    qp: qp.clone(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        ids.into_iter()
            .map(|id| {
                let mut shards: Vec<(usize, Vec<FitBatchRaw>)> = Vec::new();
                for (_, p) in self.fleet.collect(id)? {
                    match p {
                        Partial::Fit(f) => shards.push((f.first_batch, f.raws)),
                        _ => bail!("worker returned a non-FIT partial"),
                    }
                }
                shards.sort_by_key(|&(fb, _)| fb);
                Ok(shards.into_iter().flat_map(|(_, r)| r).collect())
            })
            .collect()
    }

    /// Dispatch independent `(layer, wbits)` AdaRound optimizations across
    /// the fleet, one job per worker round-robin, and return the rounded
    /// weight tensors in job order.  All jobs are enqueued before the
    /// first wait, so layers anneal concurrently.
    pub fn adaround_jobs(&self, jobs: Vec<AdaRoundJob>) -> Result<Vec<Tensor>> {
        let n = self.workers();
        let ids = jobs
            .into_iter()
            .enumerate()
            .map(|(i, job)| {
                self.fleet.submit_one(
                    i % n.max(1),
                    Request::AdaRound { model: self.model.clone(), job: Arc::new(job) },
                )
            })
            .collect::<Result<Vec<_>>>()?;
        ids.into_iter()
            .map(|id| {
                let mut parts = self.fleet.collect(id)?;
                match (parts.len(), parts.pop()) {
                    (1, Some((_, Partial::Rounded(t)))) => Ok(t),
                    _ => bail!("adaround job returned an unexpected partial"),
                }
            })
            .collect()
    }

    // -- internals -----------------------------------------------------------

    /// Reduce shard partials to the full-set scalar, merging in worker
    /// (= batch) order.
    fn finalize(&self, kind: ProbeKind, parts: Vec<(usize, Partial)>) -> Result<f64> {
        match kind {
            ProbeKind::Sqnr => {
                let mut acc = StreamingSqnr::new();
                for (_, p) in parts {
                    match p {
                        Partial::Sqnr(s) => acc.merge(&s)?,
                        _ => bail!("worker returned a non-SQNR partial"),
                    }
                }
                Ok(acc.db())
            }
            ProbeKind::Metric => {
                let mut acc = StreamingTaskMetric::new(&self.task)?;
                for (_, p) in parts {
                    match p {
                        Partial::Task(t) => acc.merge(&t)?,
                        _ => bail!("worker returned a non-metric partial"),
                    }
                }
                Ok(acc.finalize())
            }
        }
    }
}

impl Drop for EvalPool {
    fn drop(&mut self) {
        self.fleet.detach(&self.model);
    }
}

/// An in-flight (or memo-served) probe.  [`Self::wait`] blocks for the
/// result and memoizes it for every later submitter.
pub struct JobHandle<'p> {
    pool: &'p EvalPool,
    id: u64,
    kind: ProbeKind,
    key: Option<MemoKey>,
    cached: Option<f64>,
}

impl JobHandle<'_> {
    pub fn wait(self) -> Result<f64> {
        if let Some(v) = self.cached {
            return Ok(v);
        }
        let parts = self.pool.fleet.collect(self.id)?;
        let v = self.pool.finalize(self.kind, parts)?;
        if let Some(key) = self.key {
            self.pool.fleet.memo.lock().unwrap().insert(key, v);
        }
        Ok(v)
    }
}

/// Contiguous near-even split of `n` batches over `workers` shards
/// (earlier shards take the remainder; empty shards are legal).
fn shard_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    let w = workers.max(1);
    let (base, rem) = (n / w, n % w);
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Content digest of a probe's weight overrides (0 when empty) — part of
/// the memo key so stitched-AdaRound and plain probes of the same bit
/// configuration never collide.
fn overrides_digest(ov: &WeightOverrides) -> u64 {
    if ov.is_empty() {
        return 0;
    }
    let mut keys: Vec<usize> = ov.keys().copied().collect();
    keys.sort_unstable();
    let mut h = crate::util::Fnv::new();
    for k in keys {
        h.write_usize(k);
        h.write_tensor(&ov[&k]);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_contiguously() {
        for (n, w) in [(0usize, 3usize), (1, 4), (7, 3), (8, 4), (16, 5), (5, 1)] {
            let rs = shard_ranges(n, w);
            assert_eq!(rs.len(), w);
            let mut next = 0usize;
            for r in &rs {
                assert_eq!(r.start, next, "shards must be contiguous (n={n} w={w})");
                next = r.end;
            }
            assert_eq!(next, n, "shards must cover all batches (n={n} w={w})");
            let max = rs.iter().map(|r| r.len()).max().unwrap();
            let min = rs.iter().map(|r| r.len()).min().unwrap();
            assert!(max - min <= 1, "shards must be near-even (n={n} w={w})");
        }
        assert_eq!(shard_ranges(4, 0).len(), 1, "0 workers clamps to 1");
    }

    #[test]
    fn overrides_digest_is_content_keyed() {
        let t1 = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let t2 = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 5.0]).unwrap();
        let empty = WeightOverrides::new();
        assert_eq!(overrides_digest(&empty), 0);
        let mut a = WeightOverrides::new();
        a.insert(0, t1.clone());
        let mut b = WeightOverrides::new();
        b.insert(0, t2);
        let mut c = WeightOverrides::new();
        c.insert(1, t1.clone());
        let da = overrides_digest(&a);
        assert_ne!(da, 0);
        assert_ne!(da, overrides_digest(&b), "content change must change digest");
        assert_ne!(da, overrides_digest(&c), "param index must change digest");
        // digest is stable across map iteration order: rebuild in reverse
        let mut a2 = WeightOverrides::new();
        a2.insert(2, t1.clone());
        a2.insert(0, t1.clone());
        let mut a3 = WeightOverrides::new();
        a3.insert(0, t1.clone());
        a3.insert(2, t1);
        assert_eq!(overrides_digest(&a2), overrides_digest(&a3));
    }

    #[test]
    fn idle_model_eviction_is_lru_and_bounds_residency() {
        let dir = std::env::temp_dir().join("mpq_pool_evict_test");
        let _ = std::fs::remove_dir_all(&dir);
        let spec = |name: &str| crate::sim::SimSpec {
            name: name.into(),
            batch: 4,
            dims: vec![8, 10, 6],
            calib_n: 8,
            val_n: 8,
            ood_n: 0,
            ..Default::default()
        };
        crate::sim::generate_zoo(&dir, &[spec("ev_a"), spec("ev_b")]).unwrap();
        let fleet = EvalFleet::new(&dir, 1).unwrap();
        fleet.set_max_idle(1);

        // A tracked request that lazily opens the model on the worker: the
        // fetch itself fails (no set loaded) but `ensure_model` has already
        // run, and the tracked round trip synchronizes the open counter.
        let open = |name: &str| {
            let pool = EvalPool::attach(&fleet, name).unwrap();
            assert!(pool.fetch_reference(CALIB_SET).is_err());
            pool
        };

        let a = open("ev_a");
        assert_eq!(fleet.model_opens(), 1);
        drop(a); // last detach parks it on the warm list
        assert_eq!(fleet.warm_models(), vec!["ev_a".to_string()]);
        let a = open("ev_a");
        assert_eq!(fleet.model_opens(), 1, "warm re-attach must not re-open");
        assert!(fleet.warm_models().is_empty(), "an attached model is not idle");
        drop(a);

        let b = open("ev_b");
        assert_eq!(fleet.model_opens(), 2);
        drop(b); // warm would be [ev_a, ev_b] — budget 1 evicts ev_a (LRU)
        assert_eq!(fleet.warm_models(), vec!["ev_b".to_string()]);

        let a = open("ev_a");
        assert_eq!(fleet.model_opens(), 3, "an evicted model re-opens on attach");
        let compiled = fleet.worker_stats().unwrap()[0].compiled;
        drop(a); // warm would be [ev_b, ev_a] — evicts ev_b
        assert_eq!(fleet.warm_models(), vec!["ev_a".to_string()]);
        let b = open("ev_b");
        assert_eq!(fleet.model_opens(), 4);
        assert_eq!(
            fleet.worker_stats().unwrap()[0].compiled,
            compiled,
            "re-opens hit the runtime executable cache — never a recompile"
        );
        drop(b);

        // shrinking the budget to zero evicts everything idle immediately
        fleet.set_max_idle(0);
        assert!(fleet.warm_models().is_empty());
        assert!(fleet.state.lock().unwrap().is_empty(), "no resident models at budget 0");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(backoff_ms(10, 0), 10);
        assert_eq!(backoff_ms(10, 1), 20);
        assert_eq!(backoff_ms(10, 3), 80);
        assert_eq!(backoff_ms(10, 20), MAX_BACKOFF_MS, "capped");
        assert_eq!(backoff_ms(0, 5), 0, "backoff:0 disables the sleep");
    }
}
