//! The evaluation fleet — one process-wide set of worker threads, each
//! owning a **private** backend client, shared by every model and pipeline
//! in the process.
//!
//! ## Why a fleet of whole clients
//!
//! The PJRT client (and everything hanging off it: compiled executables,
//! device buffers, `Rc`-shared runtime state) is not `Send`, so backend
//! state can never cross a thread boundary.  [`EvalFleet`] sidesteps the
//! `!Send` wall by *replication*: each worker thread builds its own
//! [`crate::runtime::Runtime`] and, **lazily on first use**, a per-model
//! [`crate::model::ModelHandle`] (compiled forward executable + resident
//! trained parameters) plus its shard of each registered eval set.  Only
//! host data crosses the channels: configs, override tensors, calibration
//! state in; streaming-accumulator partials out.
//!
//! ## Elasticity and sharing (vs the PR-2 per-pipeline pool)
//!
//! * **One fleet per process** — [`EvalFleet::new`] spawns the workers
//!   once; [`EvalPool::attach`] attaches a model and returns an
//!   [`EvalPool`], the per-model view every pipeline drives.  Worker
//!   runtimes (and their executable caches) outlive model attach/detach,
//!   so a multi-model experiment driver pays thread spawn and runtime
//!   construction once, and attaching a second model performs **zero
//!   recompilations** of the first model's executables (asserted via
//!   [`EvalFleet::worker_stats`] / [`EvalFleet::model_opens`]).  Detaching
//!   the last client of a model evicts its handles, shards and memo
//!   entries everywhere.
//! * **`resize(n)`** grows or shrinks the fleet between phases: the
//!   front-end keeps host copies of every model's calibration state and
//!   registered datasets, re-shards them over the new worker count, and
//!   replays them; probe results are full-set scalars, so the memo stays
//!   valid across any resize.
//! * **Pipelined (double-buffered) set upload** — `load_set`,
//!   `set_calibration` and `build_references` no longer block on worker
//!   acks.  Upload jobs ride the same FIFO queue as probes, so the
//!   coordinator enqueues an upload and immediately continues building and
//!   enqueueing probe work (and collecting results from other workers)
//!   while each worker's H→D copy is in flight; a probe enqueued behind
//!   its set's upload is correct by queue order.  Upload errors are
//!   recorded worker-side and surfaced by the first tracked job that
//!   touches the broken state.
//!
//! ## Execution model
//!
//! Shard-parallel work ([`EvalPool::submit`] / [`EvalPool::map_probes`] /
//! [`EvalPool::fit_accumulate`]) broadcasts to *all* workers — each
//! evaluates its contiguous shard and returns a partial, and the front-end
//! reduces in global batch order.  Job-parallel work
//! ([`EvalPool::adaround_jobs`]) dispatches each independent
//! `(layer, wbits)` optimization to a *single* worker round-robin, so
//! independent layers anneal concurrently.
//!
//! ## Exactness guarantee
//!
//! Fleet results are **bit-identical** to the serial path for SQNR, the
//! counting task metrics, FIT accumulation and AdaRound, for any worker
//! count:
//!
//! * shards are contiguous batch ranges, and each worker computes exactly
//!   the per-batch partials the serial path computes;
//! * [`StreamingSqnr`] keys partials by *global* batch index and reduces in
//!   index order; top-1 / F1 / mIoU partials are integer counts;
//! * FIT shards return **raw per-batch** gradient/error vectors and the
//!   front-end replays the serial `(abits, batch)` accumulation order
//!   term by term ([`crate::sensitivity`]);
//! * an AdaRound job is a self-contained deterministic optimization — the
//!   same inputs anneal to the same rounding on any worker.
//!
//! The one documented exception is the Pearson (STS-B) head, whose Welford
//! states combine to the serial value up to float rounding.
//!
//! ## Fleet-wide caches
//!
//! * **Memo** — finished probes are memoized by
//!   `(model, set, kind, config, override-digest)`, shared across every
//!   client and search on the fleet.  `set_calibration` and re-loading a
//!   set invalidate the affected entries; detach drops the model's.
//! * **Per-worker references** — each worker's engine caches the FP32
//!   reference for *its shard*; `build_references` triggers the build
//!   eagerly, `install_references` seeds it from a host copy (the on-disk
//!   reference cache), and `fetch_reference` collects the full-set
//!   reference back for persistence.

mod worker;

use crate::adaround::AdaRoundJob;
use crate::data::DataSet;
use crate::engine::StreamingSqnr;
use crate::manifest::Manifest;
use crate::metrics::StreamingTaskMetric;
use crate::model::{QuantConfig, WeightOverrides};
use crate::quant::ActRanges;
use crate::sensitivity::FitBatchRaw;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Identifies a registered eval set within the fleet (per model).
pub type SetKey = u64;

/// Conventional key for the calibration set (Phase 1).
pub const CALIB_SET: SetKey = 0;
/// Conventional key for the validation set (Phase 2).
pub const VAL_SET: SetKey = 1;

/// What a probe measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProbeKind {
    /// Network-output SQNR vs the cached FP32 reference (Eq. 3).
    Sqnr,
    /// The model's task metric (top-1 / F1 / Pearson / mIoU).
    Metric,
}

/// Host-only request shipped to a worker.  Everything here is `Send`; no
/// backend state ever crosses the channel.  Payloads sit behind `Arc` where
/// an N-worker broadcast would otherwise deep-copy them N times.
enum Request {
    /// Install calibrated quantizer state (host data) on the worker's
    /// handle for `model`.
    Calibrate {
        model: Arc<str>,
        ranges: ActRanges,
        w_scales: HashMap<u8, Vec<Vec<f32>>>,
    },
    /// Upload this worker's shard of an eval set.
    LoadSet {
        model: Arc<str>,
        key: SetKey,
        batches: Vec<Tensor>,
        labels: Tensor,
        first_batch: usize,
    },
    /// Eagerly build the FP32 reference for the worker's shard of `set`.
    BuildReference { model: Arc<str>, set: SetKey },
    /// Seed the worker's reference cache from host logits (the on-disk
    /// reference cache) instead of a forward sweep.
    InstallReference {
        model: Arc<str>,
        set: SetKey,
        batches: Vec<Tensor>,
    },
    /// Return the worker's shard of the FP32 reference (for persistence).
    FetchReference { model: Arc<str>, set: SetKey },
    /// Evaluate one probe on the worker's shard of `set`.
    Probe {
        model: Arc<str>,
        set: SetKey,
        kind: ProbeKind,
        cfg: Arc<QuantConfig>,
        overrides: Arc<WeightOverrides>,
    },
    /// FIT accumulation pass at one activation bit-width: run the FIT
    /// executable over the worker's shard and return the **raw per-batch**
    /// outputs, so the front-end can replay the serial accumulation order.
    Fit {
        model: Arc<str>,
        set: SetKey,
        qp: Arc<Tensor>,
    },
    /// One whole `(layer, wbits)` AdaRound optimization (single-worker
    /// dispatch, not a broadcast).
    AdaRound { model: Arc<str>, job: Arc<AdaRoundJob> },
    /// Drop the model's handle, shards and reference caches.
    Detach { model: Arc<str> },
    /// Report per-worker cache counters.
    Stats,
}

struct Job {
    id: u64,
    req: Request,
}

/// A worker's result for one job.
enum Partial {
    Sqnr(StreamingSqnr),
    Task(StreamingTaskMetric),
    Fit(FitShard),
    Batches { first_batch: usize, batches: Vec<Tensor> },
    Rounded(Tensor),
    Stats(WorkerStats),
    Unit,
}

/// Raw FIT outputs for one worker's shard (global batch order within).
struct FitShard {
    first_batch: usize,
    raws: Vec<FitBatchRaw>,
}

/// Per-worker cache counters (compile-cache assertions in tests/benches).
#[derive(Clone, Copy, Debug)]
pub struct WorkerStats {
    /// distinct executables compiled by this worker's runtime so far
    pub compiled: usize,
    /// model handles currently open on this worker
    pub models_open: usize,
}

type ResMsg = (u64, usize, Result<Partial, String>);

/// Sentinel job id a worker sends right before its thread exits on a
/// panic.  The collect loop turns it into errors on every pending slot of
/// that worker, so jobs already pipelined into the dead worker's queue
/// fail loudly instead of hanging the coordinator (the fleet keeps its
/// own `res_tx` alive for elastic spawn, so channel disconnect can no
/// longer signal total worker death).  Job ids count up from 0 and can
/// never reach this value in practice.
const DEATH_NOTICE: u64 = u64::MAX;

/// Memo key: `(model id, set, kind, config, override digest)` — overrides
/// are folded in as a content digest so AdaRound-stitched and plain
/// evaluations of the same bit-config never alias, and two models' probes
/// never collide.
type MemoKey = (u64, SetKey, ProbeKind, QuantConfig, u64);

struct Worker {
    tx: Option<mpsc::Sender<Job>>,
    join: Option<JoinHandle<()>>,
}

/// An in-flight tracked job: per-worker result slots plus how many are
/// still outstanding (broadcasts expect one per worker, single-worker
/// dispatch exactly one).
struct Pending {
    slots: Vec<Option<Result<Partial, String>>>,
    remaining: usize,
}

/// Host-side replayable state for one attached model — what `resize`
/// re-shards onto a changed worker set.
struct ModelState {
    id: u64,
    attached: usize,
    calib: Option<(ActRanges, HashMap<u8, Vec<Vec<f32>>>)>,
    sets: HashMap<SetKey, DataSet>,
}

/// The process-wide elastic worker fleet.  See the module docs.
///
/// The fleet handle is intended to be driven from one thread (the
/// coordinator); the workers it owns are where the parallelism lives.
pub struct EvalFleet {
    dir: PathBuf,
    manifest: Manifest,
    workers: Mutex<Vec<Worker>>,
    /// kept alive for elastic spawn — new workers clone it
    res_tx: mpsc::Sender<ResMsg>,
    res_rx: Mutex<mpsc::Receiver<ResMsg>>,
    pending: Mutex<HashMap<u64, Pending>>,
    next_id: AtomicU64,
    memo: Mutex<HashMap<MemoKey, f64>>,
    memo_hits: AtomicUsize,
    memo_misses: AtomicUsize,
    /// model handles opened (= lazy compiles) across all workers, ever
    opens: Arc<AtomicUsize>,
    state: Mutex<HashMap<String, ModelState>>,
    next_model_id: AtomicU64,
}

impl EvalFleet {
    /// Spawn a fleet of `workers` (≥ 1) threads over the artifacts at
    /// `dir`.  Workers build their private runtime at spawn; models
    /// compile lazily on first use.
    pub fn new(dir: impl AsRef<Path>, workers: usize) -> Result<Rc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let (res_tx, res_rx) = mpsc::channel::<ResMsg>();
        let fleet = Rc::new(Self {
            dir,
            manifest,
            workers: Mutex::new(Vec::new()),
            res_tx,
            res_rx: Mutex::new(res_rx),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            memo: Mutex::new(HashMap::new()),
            memo_hits: AtomicUsize::new(0),
            memo_misses: AtomicUsize::new(0),
            opens: Arc::new(AtomicUsize::new(0)),
            state: Mutex::new(HashMap::new()),
            next_model_id: AtomicU64::new(0),
        });
        fleet.spawn_workers(workers.max(1))?;
        Ok(fleet)
    }

    /// Artifacts directory the fleet serves.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Probes actually dispatched to workers (memo misses), fleet-wide.
    pub fn probes_computed(&self) -> usize {
        self.memo_misses.load(Ordering::Relaxed)
    }

    /// Probes served from the fleet memo.
    pub fn memo_hits(&self) -> usize {
        self.memo_hits.load(Ordering::Relaxed)
    }

    /// Drop every memoized probe result (benchmarks use this to measure
    /// steady-state sweeps rather than pure cache hits).
    pub fn clear_memo(&self) {
        self.memo.lock().unwrap().clear();
    }

    /// Model handles opened (compiled) by workers over the fleet's life —
    /// the lazy-compile counter the fleet-reuse acceptance test asserts
    /// on: re-probing an attached model must not move it.
    pub fn model_opens(&self) -> usize {
        self.opens.load(Ordering::Relaxed)
    }

    /// Per-worker compile-cache counters, in worker order.
    pub fn worker_stats(&self) -> Result<Vec<WorkerStats>> {
        let id = self.submit_broadcast(true, |_| Request::Stats)?;
        let mut out = Vec::new();
        for (_, p) in self.collect(id)? {
            match p {
                Partial::Stats(s) => out.push(s),
                _ => bail!("worker returned a non-stats partial"),
            }
        }
        Ok(out)
    }

    /// Grow or shrink the fleet to `n` workers (≥ 1) between phases.
    /// Host-side model state (calibration, datasets) is re-sharded and
    /// replayed onto the new worker set; the probe memo survives (probe
    /// results are full-set values, independent of sharding).  Per-worker
    /// reference caches are rebuilt lazily on the next SQNR probe.
    pub fn resize(&self, n: usize) -> Result<()> {
        let n = n.max(1);
        if !self.pending.lock().unwrap().is_empty() {
            bail!("fleet resize with tracked jobs still in flight");
        }
        let cur = self.workers();
        if n == cur {
            return Ok(());
        }
        if n < cur {
            let removed: Vec<Worker> = self.workers.lock().unwrap().drain(n..).collect();
            for mut w in removed {
                w.tx.take(); // closing the channel ends the worker's loop
                if let Some(j) = w.join.take() {
                    let _ = j.join();
                }
            }
        } else {
            self.spawn_workers(n - cur)?;
        }
        self.replay_state()
    }

    // -- internals -----------------------------------------------------------

    fn spawn_workers(&self, n: usize) -> Result<()> {
        let (init_tx, init_rx) = mpsc::channel::<(usize, Result<(), String>)>();
        {
            let mut ws = self.workers.lock().unwrap();
            let base = ws.len();
            for i in 0..n {
                let widx = base + i;
                let (tx, rx) = mpsc::channel::<Job>();
                let (d, rtx, itx) = (self.dir.clone(), self.res_tx.clone(), init_tx.clone());
                let opens = self.opens.clone();
                let join = std::thread::Builder::new()
                    .name(format!("mpq-fleet-{widx}"))
                    .spawn(move || worker::worker_main(widx, d, rx, rtx, itx, opens))
                    .map_err(|e| anyhow!("spawning fleet worker {widx}: {e}"))?;
                ws.push(Worker { tx: Some(tx), join: Some(join) });
            }
        }
        drop(init_tx);
        let mut failures = Vec::new();
        for _ in 0..n {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((w, Err(e))) => failures.push(format!("worker {w}: {e}")),
                Err(_) => {
                    failures.push("a worker exited before reporting init".into());
                    break;
                }
            }
        }
        if !failures.is_empty() {
            // roll back the batch we just spawned (they sit at the tail)
            let tail: Vec<Worker> = {
                let mut ws = self.workers.lock().unwrap();
                let keep = ws.len().saturating_sub(n);
                ws.drain(keep..).collect()
            };
            for mut w in tail {
                w.tx.take();
                if let Some(j) = w.join.take() {
                    let _ = j.join();
                }
            }
            bail!("fleet worker init failed: {}", failures.join("; "));
        }
        Ok(())
    }

    /// Re-shard and replay every attached model's host state onto the
    /// current worker set (after a resize).
    fn replay_state(&self) -> Result<()> {
        let snapshot: Vec<(String, Option<(ActRanges, HashMap<u8, Vec<Vec<f32>>>)>, Vec<(SetKey, DataSet)>)> = {
            let st = self.state.lock().unwrap();
            st.iter()
                .map(|(name, ms)| {
                    (
                        name.clone(),
                        ms.calib.clone(),
                        ms.sets.iter().map(|(&k, ds)| (k, ds.clone())).collect(),
                    )
                })
                .collect()
        };
        let n = self.workers();
        for (name, calib, sets) in snapshot {
            let model: Arc<str> = Arc::from(name.as_str());
            if let Some((ranges, w_scales)) = calib {
                self.fire(|_| Request::Calibrate {
                    model: model.clone(),
                    ranges: ranges.clone(),
                    w_scales: w_scales.clone(),
                })?;
            }
            let batch = self.manifest.model(&name)?.batch;
            for (key, ds) in sets {
                let batches = ds.batches(batch)?;
                let labels = ds.labels_prefix(batch)?;
                let ranges = shard_ranges(batches.len(), n);
                self.fire(|w| {
                    let r = &ranges[w];
                    Request::LoadSet {
                        model: model.clone(),
                        key,
                        batches: batches[r.clone()].to_vec(),
                        labels: labels
                            .slice_rows(r.start * batch, (r.end - r.start) * batch)
                            .expect("labels_prefix is batch-aligned"),
                        first_batch: r.start,
                    }
                })?;
            }
        }
        Ok(())
    }

    fn detach(&self, model: &str, model_id: u64) {
        let gone = {
            let mut st = self.state.lock().unwrap();
            match st.get_mut(model) {
                Some(ms) => {
                    ms.attached = ms.attached.saturating_sub(1);
                    if ms.attached == 0 {
                        st.remove(model);
                        true
                    } else {
                        false
                    }
                }
                None => false,
            }
        };
        if gone {
            self.memo.lock().unwrap().retain(|k, _| k.0 != model_id);
            let m: Arc<str> = Arc::from(model);
            let _ = self.fire(|_| Request::Detach { model: m.clone() });
        }
    }

    /// Send one job to every worker.  With `track`, a [`Pending`] entry is
    /// created and [`Self::collect`] must be called; without, the job is
    /// fire-and-forget — workers still reply, and the unknown-id replies
    /// are dropped by the collect loop.
    fn submit_broadcast(&self, track: bool, mk: impl Fn(usize) -> Request) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let ws = self.workers.lock().unwrap();
        if track {
            self.pending.lock().unwrap().insert(
                id,
                Pending {
                    slots: (0..ws.len()).map(|_| None).collect(),
                    remaining: ws.len(),
                },
            );
        }
        for (w, worker) in ws.iter().enumerate() {
            let sent = worker
                .tx
                .as_ref()
                .ok_or_else(|| anyhow!("fleet worker {w} is gone (dead or shut down)"))
                .and_then(|tx| {
                    tx.send(Job { id, req: mk(w) })
                        .map_err(|_| anyhow!("fleet worker {w} is gone"))
                });
            if let Err(e) = sent {
                if track {
                    self.pending.lock().unwrap().remove(&id);
                }
                return Err(e);
            }
        }
        Ok(id)
    }

    fn fire(&self, mk: impl Fn(usize) -> Request) -> Result<()> {
        self.submit_broadcast(false, mk).map(|_| ())
    }

    /// Send one tracked job to a single worker.
    fn submit_one(&self, w: usize, req: Request) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let ws = self.workers.lock().unwrap();
        if w >= ws.len() {
            bail!("no fleet worker {w}");
        }
        self.pending.lock().unwrap().insert(
            id,
            Pending {
                slots: (0..ws.len()).map(|_| None).collect(),
                remaining: 1,
            },
        );
        let sent = ws[w]
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("fleet worker {w} is gone (dead or shut down)"))
            .and_then(|tx| {
                tx.send(Job { id, req })
                    .map_err(|_| anyhow!("fleet worker {w} is gone"))
            });
        if let Err(e) = sent {
            self.pending.lock().unwrap().remove(&id);
            return Err(e);
        }
        Ok(id)
    }

    /// Block until every expected worker reported on `id`; error if any
    /// did.  Returns the partials in worker (= global batch) order.
    fn collect(&self, id: u64) -> Result<Vec<(usize, Partial)>> {
        loop {
            {
                let mut pending = self.pending.lock().unwrap();
                let p = pending
                    .get(&id)
                    .ok_or_else(|| anyhow!("unknown or already-collected job {id}"))?;
                if p.remaining == 0 {
                    let p = pending.remove(&id).unwrap();
                    drop(pending);
                    let mut out = Vec::new();
                    let mut errs = Vec::new();
                    for (w, s) in p.slots.into_iter().enumerate() {
                        match s {
                            None => {}
                            Some(Ok(part)) => out.push((w, part)),
                            Some(Err(e)) => errs.push(format!("fleet worker {w}: {e}")),
                        }
                    }
                    if !errs.is_empty() {
                        bail!("{}", errs.join("; "));
                    }
                    return Ok(out);
                }
            }
            let (jid, w, r) = {
                let rx = self.res_rx.lock().unwrap();
                rx.recv().map_err(|_| anyhow!("all fleet workers exited"))?
            };
            let mut pending = self.pending.lock().unwrap();
            if jid == DEATH_NOTICE {
                // the worker's thread is gone: nothing it still had queued
                // will ever be answered — fail its slot in every in-flight
                // job so no wait hangs, and close its sender so every
                // later submit errors immediately instead of racing the
                // thread teardown
                let msg = match r {
                    Err(e) => e,
                    Ok(_) => "worker died".into(),
                };
                for p in pending.values_mut() {
                    if w < p.slots.len() && p.slots[w].is_none() {
                        p.slots[w] = Some(Err(msg.clone()));
                        p.remaining -= 1;
                    }
                }
                drop(pending);
                if let Some(worker) = self.workers.lock().unwrap().get_mut(w) {
                    worker.tx.take();
                }
                continue;
            }
            if let Some(p) = pending.get_mut(&jid) {
                if w < p.slots.len() && p.slots[w].is_none() {
                    p.slots[w] = Some(r);
                    p.remaining -= 1;
                }
            }
            // replies to fire-and-forget (or already-failed) jobs fall
            // through here and are dropped
        }
    }

    fn wait_unit(&self, id: u64) -> Result<()> {
        for (_, p) in self.collect(id)? {
            if !matches!(p, Partial::Unit) {
                bail!("worker returned a value for a control job");
            }
        }
        Ok(())
    }

    fn shutdown(&self) {
        let mut ws = self.workers.lock().unwrap();
        for w in ws.iter_mut() {
            w.tx.take(); // closing the channel ends the worker's recv loop
        }
        for w in ws.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Drop for EvalFleet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-model client of an [`EvalFleet`] — the handle pipelines and
/// searches drive.  [`EvalPool::new`] spawns a private single-model fleet
/// (the PR-2 shape); [`EvalPool::attach`] attaches to a shared one.
/// Dropping the last client of a model detaches it fleet-wide.
pub struct EvalPool {
    fleet: Rc<EvalFleet>,
    model: Arc<str>,
    model_id: u64,
    /// manifest task string — selects the accumulator used to merge
    /// task-metric partials
    task: String,
    batch: usize,
}

impl EvalPool {
    /// Spawn a private `workers`-thread fleet for one model at `dir` —
    /// the PR-2 compatible constructor.
    pub fn new(dir: impl AsRef<Path>, model: &str, workers: usize) -> Result<Self> {
        Self::attach(&EvalFleet::new(dir, workers)?, model)
    }

    /// Attach `model` (validated against the manifest) to a shared fleet
    /// and return the per-model client.  Attach counts are refcounted;
    /// the last client's drop detaches the model fleet-wide (worker
    /// slots, shards and memo entries are evicted).
    pub fn attach(fleet: &Rc<EvalFleet>, model: &str) -> Result<Self> {
        let entry = fleet.manifest.model(model)?;
        let (task, batch) = (entry.task.clone(), entry.batch);
        let model_id = {
            let mut st = fleet.state.lock().unwrap();
            let ms = st.entry(model.to_string()).or_insert_with(|| ModelState {
                id: fleet.next_model_id.fetch_add(1, Ordering::Relaxed),
                attached: 0,
                calib: None,
                sets: HashMap::new(),
            });
            ms.attached += 1;
            ms.id
        };
        Ok(EvalPool {
            fleet: fleet.clone(),
            model: Arc::from(model),
            model_id,
            task,
            batch,
        })
    }

    /// The fleet this client drives (shared across models; `resize` and
    /// the compile counters live here).
    pub fn fleet(&self) -> &Rc<EvalFleet> {
        &self.fleet
    }

    /// Model this client is attached to.
    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn workers(&self) -> usize {
        self.fleet.workers()
    }

    /// Probes actually dispatched to workers (memo misses), fleet-wide.
    pub fn probes_computed(&self) -> usize {
        self.fleet.probes_computed()
    }

    /// Probes served from the fleet memo.
    pub fn memo_hits(&self) -> usize {
        self.fleet.memo_hits()
    }

    /// Drop every memoized probe result (fleet-wide; benchmarks).
    pub fn clear_memo(&self) {
        self.fleet.clear_memo();
    }

    /// Install calibrated quantizer state on every worker (pipelined, no
    /// ack).  Invalidates this model's memo entries: every probe result
    /// depends on the ranges.
    pub fn set_calibration(
        &self,
        ranges: &ActRanges,
        w_scales: &HashMap<u8, Vec<Vec<f32>>>,
    ) -> Result<()> {
        self.fleet
            .memo
            .lock()
            .unwrap()
            .retain(|k, _| k.0 != self.model_id);
        {
            let mut st = self.fleet.state.lock().unwrap();
            if let Some(ms) = st.get_mut(&*self.model) {
                ms.calib = Some((ranges.clone(), w_scales.clone()));
            }
        }
        self.fleet.fire(|_| Request::Calibrate {
            model: self.model.clone(),
            ranges: ranges.clone(),
            w_scales: w_scales.clone(),
        })
    }

    /// Register (or replace) an eval set under `key`, splitting its
    /// batches into contiguous per-worker shards (pipelined, no ack: the
    /// H→D upload overlaps the caller's subsequent probe construction, and
    /// probes enqueued behind it are correct by FIFO order).  Stale memo
    /// entries for `key` are dropped.  A trailing partial batch is
    /// truncated exactly like `ModelHandle::eval_set` does.
    pub fn load_set(&self, key: SetKey, ds: &DataSet) -> Result<()> {
        let batches = ds.batches(self.batch)?;
        if batches.is_empty() {
            bail!("dataset smaller than one batch ({})", self.batch);
        }
        let labels = ds.labels_prefix(self.batch)?;
        self.fleet
            .memo
            .lock()
            .unwrap()
            .retain(|k, _| !(k.0 == self.model_id && k.1 == key));
        {
            let mut st = self.fleet.state.lock().unwrap();
            if let Some(ms) = st.get_mut(&*self.model) {
                ms.sets.insert(key, ds.clone());
            }
        }
        let ranges = shard_ranges(batches.len(), self.workers());
        self.fleet.fire(|w| {
            let r = &ranges[w];
            Request::LoadSet {
                model: self.model.clone(),
                key,
                batches: batches[r.clone()].to_vec(),
                // labels rows [r.start·batch, r.end·batch) — may be empty
                labels: labels
                    .slice_rows(r.start * self.batch, (r.end - r.start) * self.batch)
                    .expect("labels_prefix is batch-aligned"),
                first_batch: r.start,
            }
        })
    }

    /// Build the FP32 reference for `set` eagerly — one full-set forward
    /// sweep, split across the workers' shards (pipelined, no ack).
    pub fn build_references(&self, set: SetKey) -> Result<()> {
        self.fleet.fire(|_| Request::BuildReference {
            model: self.model.clone(),
            set,
        })
    }

    /// Seed every worker's reference cache for `set` from host per-batch
    /// FP32 logits (the on-disk reference cache), skipping the forward
    /// sweep entirely.  Blocking: install errors indicate a stale or
    /// mis-keyed cache file and must surface at the call site.
    pub fn install_references(&self, set: SetKey, batches: &[Tensor]) -> Result<()> {
        let ranges = shard_ranges(batches.len(), self.workers());
        let id = self.fleet.submit_broadcast(true, |w| Request::InstallReference {
            model: self.model.clone(),
            set,
            batches: batches[ranges[w].clone()].to_vec(),
        })?;
        self.fleet.wait_unit(id)
    }

    /// Collect the full-set FP32 reference (per-batch logits, global batch
    /// order) from the workers' shard caches — building shards that don't
    /// have one yet.  Feeds the on-disk reference cache.
    pub fn fetch_reference(&self, set: SetKey) -> Result<Vec<Tensor>> {
        let id = self.fleet.submit_broadcast(true, |_| Request::FetchReference {
            model: self.model.clone(),
            set,
        })?;
        let mut shards: Vec<(usize, Vec<Tensor>)> = Vec::new();
        for (_, p) in self.fleet.collect(id)? {
            match p {
                Partial::Batches { first_batch, batches } => shards.push((first_batch, batches)),
                _ => bail!("worker returned a non-reference partial"),
            }
        }
        shards.sort_by_key(|&(fb, _)| fb);
        Ok(shards.into_iter().flat_map(|(_, b)| b).collect())
    }

    /// Submit one probe.  Served from the fleet memo when an identical
    /// probe (same model, set, kind, config and override content) already
    /// finished; otherwise fanned out to every worker's shard.  The
    /// returned handle must be waited on to collect (and memoize) the
    /// result.
    pub fn submit(
        &self,
        set: SetKey,
        kind: ProbeKind,
        cfg: &QuantConfig,
        overrides: &WeightOverrides,
    ) -> Result<JobHandle<'_>> {
        let key = (self.model_id, set, kind, cfg.clone(), overrides_digest(overrides));
        if let Some(&v) = self.fleet.memo.lock().unwrap().get(&key) {
            self.fleet.memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(JobHandle { pool: self, id: 0, kind, key: None, cached: Some(v) });
        }
        self.fleet.memo_misses.fetch_add(1, Ordering::Relaxed);
        let cfg = Arc::new(cfg.clone());
        let overrides = Arc::new(overrides.clone());
        let id = self.fleet.submit_broadcast(true, |_| Request::Probe {
            model: self.model.clone(),
            set,
            kind,
            cfg: cfg.clone(),
            overrides: overrides.clone(),
        })?;
        Ok(JobHandle { pool: self, id, kind, key: Some(key), cached: None })
    }

    /// Evaluate a list of probes, preserving input order in the results.
    /// All probes are enqueued before the first wait, so the whole list
    /// pipelines through the workers.  (Identical probes submitted in the
    /// same call are both dispatched — the memo fills at completion; probe
    /// lists don't repeat configurations in practice.)
    pub fn map_probes(
        &self,
        set: SetKey,
        kind: ProbeKind,
        probes: &[(QuantConfig, WeightOverrides)],
    ) -> Result<Vec<f64>> {
        let handles = probes
            .iter()
            .map(|(cfg, ov)| self.submit(set, kind, cfg, ov))
            .collect::<Result<Vec<_>>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Run one FIT accumulation pass per `qps` entry (one packed `act_qp`
    /// tensor per activation bit-width) over the workers' shards of `set`,
    /// returning the **raw per-batch** executable outputs in global batch
    /// order — the caller replays the serial accumulation over them, which
    /// is what makes pooled FIT bit-identical to the serial path.  All
    /// passes are enqueued before the first wait, so they pipeline.
    pub fn fit_accumulate(&self, set: SetKey, qps: &[Tensor]) -> Result<Vec<Vec<FitBatchRaw>>> {
        let ids = qps
            .iter()
            .map(|qp| {
                let qp = Arc::new(qp.clone());
                self.fleet.submit_broadcast(true, |_| Request::Fit {
                    model: self.model.clone(),
                    set,
                    qp: qp.clone(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        ids.into_iter()
            .map(|id| {
                let mut shards: Vec<(usize, Vec<FitBatchRaw>)> = Vec::new();
                for (_, p) in self.fleet.collect(id)? {
                    match p {
                        Partial::Fit(f) => shards.push((f.first_batch, f.raws)),
                        _ => bail!("worker returned a non-FIT partial"),
                    }
                }
                shards.sort_by_key(|&(fb, _)| fb);
                Ok(shards.into_iter().flat_map(|(_, r)| r).collect())
            })
            .collect()
    }

    /// Dispatch independent `(layer, wbits)` AdaRound optimizations across
    /// the fleet, one job per worker round-robin, and return the rounded
    /// weight tensors in job order.  All jobs are enqueued before the
    /// first wait, so layers anneal concurrently.
    pub fn adaround_jobs(&self, jobs: Vec<AdaRoundJob>) -> Result<Vec<Tensor>> {
        let n = self.workers();
        let ids = jobs
            .into_iter()
            .enumerate()
            .map(|(i, job)| {
                self.fleet.submit_one(
                    i % n,
                    Request::AdaRound { model: self.model.clone(), job: Arc::new(job) },
                )
            })
            .collect::<Result<Vec<_>>>()?;
        ids.into_iter()
            .map(|id| {
                let mut parts = self.fleet.collect(id)?;
                match (parts.len(), parts.pop()) {
                    (1, Some((_, Partial::Rounded(t)))) => Ok(t),
                    _ => bail!("adaround job returned an unexpected partial"),
                }
            })
            .collect()
    }

    // -- internals -----------------------------------------------------------

    /// Reduce shard partials to the full-set scalar, merging in worker
    /// (= batch) order.
    fn finalize(&self, kind: ProbeKind, parts: Vec<(usize, Partial)>) -> Result<f64> {
        match kind {
            ProbeKind::Sqnr => {
                let mut acc = StreamingSqnr::new();
                for (_, p) in parts {
                    match p {
                        Partial::Sqnr(s) => acc.merge(&s)?,
                        _ => bail!("worker returned a non-SQNR partial"),
                    }
                }
                Ok(acc.db())
            }
            ProbeKind::Metric => {
                let mut acc = StreamingTaskMetric::new(&self.task)?;
                for (_, p) in parts {
                    match p {
                        Partial::Task(t) => acc.merge(&t)?,
                        _ => bail!("worker returned a non-metric partial"),
                    }
                }
                Ok(acc.finalize())
            }
        }
    }
}

impl Drop for EvalPool {
    fn drop(&mut self) {
        self.fleet.detach(&self.model, self.model_id);
    }
}

/// An in-flight (or memo-served) probe.  [`Self::wait`] blocks for the
/// result and memoizes it for every later submitter.
pub struct JobHandle<'p> {
    pool: &'p EvalPool,
    id: u64,
    kind: ProbeKind,
    key: Option<MemoKey>,
    cached: Option<f64>,
}

impl JobHandle<'_> {
    pub fn wait(self) -> Result<f64> {
        if let Some(v) = self.cached {
            return Ok(v);
        }
        let parts = self.pool.fleet.collect(self.id)?;
        let v = self.pool.finalize(self.kind, parts)?;
        if let Some(key) = self.key {
            self.pool.fleet.memo.lock().unwrap().insert(key, v);
        }
        Ok(v)
    }
}

/// Contiguous near-even split of `n` batches over `workers` shards
/// (earlier shards take the remainder; empty shards are legal).
fn shard_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    let w = workers.max(1);
    let (base, rem) = (n / w, n % w);
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Content digest of a probe's weight overrides (0 when empty) — part of
/// the memo key so stitched-AdaRound and plain probes of the same bit
/// configuration never collide.
fn overrides_digest(ov: &WeightOverrides) -> u64 {
    if ov.is_empty() {
        return 0;
    }
    let mut keys: Vec<usize> = ov.keys().copied().collect();
    keys.sort_unstable();
    let mut h = crate::util::Fnv::new();
    for k in keys {
        h.write_usize(k);
        h.write_tensor(&ov[&k]);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_contiguously() {
        for (n, w) in [(0usize, 3usize), (1, 4), (7, 3), (8, 4), (16, 5), (5, 1)] {
            let rs = shard_ranges(n, w);
            assert_eq!(rs.len(), w);
            let mut next = 0usize;
            for r in &rs {
                assert_eq!(r.start, next, "shards must be contiguous (n={n} w={w})");
                next = r.end;
            }
            assert_eq!(next, n, "shards must cover all batches (n={n} w={w})");
            let max = rs.iter().map(|r| r.len()).max().unwrap();
            let min = rs.iter().map(|r| r.len()).min().unwrap();
            assert!(max - min <= 1, "shards must be near-even (n={n} w={w})");
        }
        assert_eq!(shard_ranges(4, 0).len(), 1, "0 workers clamps to 1");
    }

    #[test]
    fn overrides_digest_is_content_keyed() {
        let t1 = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let t2 = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 5.0]).unwrap();
        let empty = WeightOverrides::new();
        assert_eq!(overrides_digest(&empty), 0);
        let mut a = WeightOverrides::new();
        a.insert(0, t1.clone());
        let mut b = WeightOverrides::new();
        b.insert(0, t2);
        let mut c = WeightOverrides::new();
        c.insert(1, t1.clone());
        let da = overrides_digest(&a);
        assert_ne!(da, 0);
        assert_ne!(da, overrides_digest(&b), "content change must change digest");
        assert_ne!(da, overrides_digest(&c), "param index must change digest");
        // digest is stable across map iteration order: rebuild in reverse
        let mut a2 = WeightOverrides::new();
        a2.insert(2, t1.clone());
        a2.insert(0, t1.clone());
        let mut a3 = WeightOverrides::new();
        a3.insert(0, t1.clone());
        a3.insert(2, t1);
        assert_eq!(overrides_digest(&a2), overrides_digest(&a3));
    }
}
