//! The evaluation pool — N worker threads, each owning a **private** PJRT
//! client, turning the single-client evaluation service into a horizontally
//! scalable one.
//!
//! ## Why a pool of whole clients
//!
//! The PJRT client (and everything hanging off it: compiled executables,
//! device buffers, `Rc`-shared runtime state) is not `Send`, so PJRT state
//! can never cross a thread boundary.  `util::par_map` therefore only ever
//! covered pure host math, and after the engine (PR 1) removed the
//! redundant work, Phase-1 sweeps and Phase-2 searches were compute-bound
//! on one single-threaded client.  [`EvalPool`] sidesteps the `!Send` wall
//! by *replication*: each worker thread builds its own [`Runtime`] — the
//! backend the manifest names, PJRT or the pure-Rust sim interpreter — its
//! own [`ModelHandle`] (compiled forward executable + resident trained
//! parameters) and uploads its own **shard** of each eval set.  Only host
//! data crosses the channels: [`QuantConfig`]s, override [`Tensor`]s,
//! calibration state in, streaming-accumulator partials out.
//!
//! ## Execution model
//!
//! Work is submitted at **probe granularity** ([`EvalPool::submit`] /
//! [`EvalPool::map_probes`]): one probe = one `(config, overrides)`
//! evaluation over one registered eval set.  Internally every probe fans
//! out to *all* workers — each evaluates the config on its shard and
//! returns a partial accumulator — and the pool reduces the partials.
//! Sharding (rather than probe-per-worker placement) parallelizes both the
//! embarrassingly parallel Phase-1 sweep *and* the inherently sequential
//! Phase-2 searches, whose next prefix depends on the previous metric.
//! Probes pipeline: a whole sweep is enqueued at once and each worker
//! drains its queue at its own pace.
//!
//! ## Exactness guarantee
//!
//! Pool results are **bit-identical** to the serial path for SQNR and the
//! counting task metrics, for any worker count:
//!
//! * shards are contiguous batch ranges, and each worker computes exactly
//!   the per-batch partial sums the serial path computes;
//! * [`StreamingSqnr`] keys partials by *global* batch index and reduces in
//!   index order, so the final summation has the same operands in the same
//!   order regardless of sharding;
//! * top-1 / F1 / mIoU partials are integer counts — order-free.
//!
//! The one documented exception is the Pearson (STS-B) head, whose Welford
//! states combine to the serial value up to float rounding.
//!
//! ## Pool-aware caches
//!
//! * **Memo** — the pool memoizes finished probes by
//!   `(set, kind, config, override-digest)`, so a probe measured by any
//!   worker is served from cache for all subsequent submitters, across
//!   Phase-1 sweeps and Phase-2 runs alike.  [`EvalPool::set_calibration`]
//!   and re-loading a set invalidate the affected entries.
//! * **FP reference** — each worker's `HandleEngine` caches the FP32
//!   reference for *its shard*, so one full-set reference build costs a
//!   single sweep split across the workers ([`EvalPool::build_references`]
//!   triggers it eagerly; a first SQNR probe triggers it lazily).

use crate::data::DataSet;
use crate::engine::StreamingSqnr;
use crate::manifest::Manifest;
use crate::metrics::StreamingTaskMetric;
use crate::model::{EvalSet, ModelHandle, QuantConfig, WeightOverrides};
use crate::quant::ActRanges;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Identifies a registered eval set within the pool.
pub type SetKey = u64;

/// Conventional key for the calibration set (Phase 1).
pub const CALIB_SET: SetKey = 0;
/// Conventional key for the validation set (Phase 2).
pub const VAL_SET: SetKey = 1;

/// What a probe measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProbeKind {
    /// Network-output SQNR vs the cached FP32 reference (Eq. 3).
    Sqnr,
    /// The model's task metric (top-1 / F1 / Pearson / mIoU).
    Metric,
}

/// Host-only request shipped to a worker.  Everything here is `Send`; no
/// PJRT state ever crosses the channel.
enum Request {
    /// Install calibrated quantizer state (host data) on the worker's handle.
    Calibrate {
        ranges: ActRanges,
        w_scales: HashMap<u8, Vec<Vec<f32>>>,
    },
    /// Upload this worker's shard of an eval set.
    LoadSet {
        key: SetKey,
        batches: Vec<Tensor>,
        labels: Tensor,
        first_batch: usize,
    },
    /// Eagerly build the FP32 reference for the worker's shard of `set`.
    BuildReference { set: SetKey },
    /// Evaluate one probe on the worker's shard of `set`.  Payloads sit
    /// behind `Arc` so an N-worker broadcast is N pointer bumps, not N
    /// deep copies of the config and (potentially large) override tensors.
    Probe {
        set: SetKey,
        kind: ProbeKind,
        cfg: Arc<QuantConfig>,
        overrides: Arc<WeightOverrides>,
    },
}

struct Job {
    id: u64,
    req: Request,
}

/// A worker's shard-local result.
enum Partial {
    Sqnr(StreamingSqnr),
    Task(StreamingTaskMetric),
    Unit,
}

type ResMsg = (u64, usize, Result<Partial, String>);

/// Memo key: overrides are folded in as a content digest so AdaRound-
/// stitched and plain evaluations of the same bit-config never alias.
type MemoKey = (SetKey, ProbeKind, QuantConfig, u64);

struct Worker {
    tx: Option<mpsc::Sender<Job>>,
    join: Option<JoinHandle<()>>,
}

/// The multi-client evaluation pool.  See the module docs for the model.
///
/// The pool handle is intended to be driven from one thread (the
/// coordinator); the workers it owns are where the parallelism lives.
pub struct EvalPool {
    workers: Vec<Worker>,
    res_rx: Mutex<mpsc::Receiver<ResMsg>>,
    /// job id → per-worker result slots, filled as workers report
    pending: Mutex<HashMap<u64, Vec<Option<Result<Partial, String>>>>>,
    next_id: AtomicU64,
    memo: Mutex<HashMap<MemoKey, f64>>,
    memo_hits: AtomicUsize,
    memo_misses: AtomicUsize,
    /// manifest task string — selects the accumulator used to merge
    /// task-metric partials
    task: String,
    batch: usize,
}

impl EvalPool {
    /// Spawn `workers` (≥ 1) threads, each opening `model` from the
    /// artifacts at `dir` on a private PJRT client.  Fails if any worker
    /// fails to initialize (artifacts missing, compile error, …).
    pub fn new(dir: impl AsRef<Path>, model: &str, workers: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let entry = manifest.model(model)?;
        let (task, batch) = (entry.task.clone(), entry.batch);

        let n = workers.max(1);
        let (res_tx, res_rx) = mpsc::channel::<ResMsg>();
        let (init_tx, init_rx) = mpsc::channel::<(usize, Result<(), String>)>();
        let mut ws = Vec::with_capacity(n);
        for widx in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            let (d, m) = (dir.clone(), model.to_string());
            let (rtx, itx) = (res_tx.clone(), init_tx.clone());
            let join = std::thread::Builder::new()
                .name(format!("mpq-eval-{widx}"))
                .spawn(move || worker_main(widx, d, m, rx, rtx, itx))
                .map_err(|e| anyhow!("spawning eval worker {widx}: {e}"))?;
            ws.push(Worker { tx: Some(tx), join: Some(join) });
        }
        drop(res_tx);
        drop(init_tx);

        let mut pool = Self {
            workers: ws,
            res_rx: Mutex::new(res_rx),
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            memo: Mutex::new(HashMap::new()),
            memo_hits: AtomicUsize::new(0),
            memo_misses: AtomicUsize::new(0),
            task,
            batch,
        };
        let mut failures = Vec::new();
        for _ in 0..n {
            match init_rx.recv() {
                Ok((_, Ok(()))) => {}
                Ok((w, Err(e))) => failures.push(format!("worker {w}: {e}")),
                Err(_) => {
                    failures.push("a worker exited before reporting init".into());
                    break;
                }
            }
        }
        if !failures.is_empty() {
            pool.shutdown();
            bail!("eval pool init failed: {}", failures.join("; "));
        }
        Ok(pool)
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Probes actually dispatched to workers (memo misses).
    pub fn probes_computed(&self) -> usize {
        self.memo_misses.load(Ordering::Relaxed)
    }

    /// Probes served from the pool memo.
    pub fn memo_hits(&self) -> usize {
        self.memo_hits.load(Ordering::Relaxed)
    }

    /// Drop every memoized probe result (benchmarks use this to measure
    /// steady-state sweeps rather than pure cache hits).
    pub fn clear_memo(&self) {
        self.memo.lock().unwrap().clear();
    }

    /// Install calibrated quantizer state on every worker.  Invalidate the
    /// whole memo: every probe result depends on the ranges.
    pub fn set_calibration(
        &self,
        ranges: &ActRanges,
        w_scales: &HashMap<u8, Vec<Vec<f32>>>,
    ) -> Result<()> {
        self.memo.lock().unwrap().clear();
        let id = self.broadcast_with(|_| Request::Calibrate {
            ranges: ranges.clone(),
            w_scales: w_scales.clone(),
        })?;
        self.wait_unit(id)
    }

    /// Register (or replace) an eval set under `key`, splitting its batches
    /// into contiguous per-worker shards.  Stale memo entries for `key` are
    /// dropped.  A trailing partial batch is truncated exactly like
    /// `ModelHandle::eval_set` does.
    pub fn load_set(&self, key: SetKey, ds: &DataSet) -> Result<()> {
        let batches = ds.batches(self.batch)?;
        if batches.is_empty() {
            bail!("dataset smaller than one batch ({})", self.batch);
        }
        let labels = ds.labels_prefix(self.batch)?;
        self.memo.lock().unwrap().retain(|(s, ..), _| *s != key);
        let ranges = shard_ranges(batches.len(), self.workers.len());
        let id = self.broadcast_with(|w| {
            let r = &ranges[w];
            Request::LoadSet {
                key,
                batches: batches[r.clone()].to_vec(),
                // labels rows [r.start·batch, r.end·batch) — may be empty
                labels: labels
                    .slice_rows(r.start * self.batch, (r.end - r.start) * self.batch)
                    .expect("labels_prefix is batch-aligned"),
                first_batch: r.start,
            }
        })?;
        self.wait_unit(id)
    }

    /// Build the FP32 reference for `set` eagerly — one full-set forward
    /// sweep, split across the workers' shards.
    pub fn build_references(&self, set: SetKey) -> Result<()> {
        let id = self.broadcast_with(|_| Request::BuildReference { set })?;
        self.wait_unit(id)
    }

    /// Submit one probe.  Served from the pool memo when an identical probe
    /// (same set, kind, config and override content) already finished;
    /// otherwise fanned out to every worker's shard.  The returned handle
    /// must be waited on to collect (and memoize) the result.
    pub fn submit(
        &self,
        set: SetKey,
        kind: ProbeKind,
        cfg: &QuantConfig,
        overrides: &WeightOverrides,
    ) -> Result<JobHandle<'_>> {
        let key = (set, kind, cfg.clone(), overrides_digest(overrides));
        if let Some(&v) = self.memo.lock().unwrap().get(&key) {
            self.memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(JobHandle { pool: self, id: 0, kind, key: None, cached: Some(v) });
        }
        self.memo_misses.fetch_add(1, Ordering::Relaxed);
        let cfg = Arc::new(cfg.clone());
        let overrides = Arc::new(overrides.clone());
        let id = self.broadcast_with(|_| Request::Probe {
            set,
            kind,
            cfg: cfg.clone(),
            overrides: overrides.clone(),
        })?;
        Ok(JobHandle { pool: self, id, kind, key: Some(key), cached: None })
    }

    /// Evaluate a list of probes, preserving input order in the results.
    /// All probes are enqueued before the first wait, so the whole list
    /// pipelines through the workers.  (Identical probes submitted in the
    /// same call are both dispatched — the memo fills at completion; probe
    /// lists don't repeat configurations in practice.)
    pub fn map_probes(
        &self,
        set: SetKey,
        kind: ProbeKind,
        probes: &[(QuantConfig, WeightOverrides)],
    ) -> Result<Vec<f64>> {
        let handles = probes
            .iter()
            .map(|(cfg, ov)| self.submit(set, kind, cfg, ov))
            .collect::<Result<Vec<_>>>()?;
        handles.into_iter().map(|h| h.wait()).collect()
    }

    // -- internals -----------------------------------------------------------

    /// Send one job (id shared, per-worker request) to every worker.
    fn broadcast_with(&self, mk: impl Fn(usize) -> Request) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.pending
            .lock()
            .unwrap()
            .insert(id, (0..self.workers.len()).map(|_| None).collect());
        for (w, worker) in self.workers.iter().enumerate() {
            worker
                .tx
                .as_ref()
                .ok_or_else(|| anyhow!("pool is shut down"))?
                .send(Job { id, req: mk(w) })
                .map_err(|_| anyhow!("eval worker {w} is gone"))?;
        }
        Ok(id)
    }

    /// Block until every worker reported on `id`; error if any did.
    fn collect(&self, id: u64) -> Result<Vec<Partial>> {
        loop {
            {
                let mut pending = self.pending.lock().unwrap();
                let slots = pending
                    .get(&id)
                    .ok_or_else(|| anyhow!("unknown or already-collected job {id}"))?;
                if slots.iter().all(|s| s.is_some()) {
                    let slots = pending.remove(&id).unwrap();
                    drop(pending);
                    let mut out = Vec::with_capacity(slots.len());
                    for (w, s) in slots.into_iter().enumerate() {
                        match s.unwrap() {
                            Ok(p) => out.push(p),
                            Err(e) => bail!("eval worker {w}: {e}"),
                        }
                    }
                    return Ok(out);
                }
            }
            let (jid, w, r) = {
                let rx = self.res_rx.lock().unwrap();
                rx.recv().map_err(|_| anyhow!("all eval workers exited"))?
            };
            if let Some(slots) = self.pending.lock().unwrap().get_mut(&jid) {
                slots[w] = Some(r);
            }
        }
    }

    fn wait_unit(&self, id: u64) -> Result<()> {
        for p in self.collect(id)? {
            if !matches!(p, Partial::Unit) {
                bail!("worker returned a value for a control job");
            }
        }
        Ok(())
    }

    /// Reduce shard partials to the full-set scalar, merging in worker
    /// (= batch) order.
    fn finalize(&self, kind: ProbeKind, parts: Vec<Partial>) -> Result<f64> {
        match kind {
            ProbeKind::Sqnr => {
                let mut acc = StreamingSqnr::new();
                for p in parts {
                    match p {
                        Partial::Sqnr(s) => acc.merge(&s)?,
                        _ => bail!("worker returned a non-SQNR partial"),
                    }
                }
                Ok(acc.db())
            }
            ProbeKind::Metric => {
                let mut acc = StreamingTaskMetric::new(&self.task)?;
                for p in parts {
                    match p {
                        Partial::Task(t) => acc.merge(&t)?,
                        _ => bail!("worker returned a non-metric partial"),
                    }
                }
                Ok(acc.finalize())
            }
        }
    }

    fn shutdown(&mut self) {
        for w in &mut self.workers {
            w.tx.take(); // closing the channel ends the worker's recv loop
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Drop for EvalPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// An in-flight (or memo-served) probe.  [`Self::wait`] blocks for the
/// result and memoizes it for every later submitter.
pub struct JobHandle<'p> {
    pool: &'p EvalPool,
    id: u64,
    kind: ProbeKind,
    key: Option<MemoKey>,
    cached: Option<f64>,
}

impl JobHandle<'_> {
    pub fn wait(self) -> Result<f64> {
        if let Some(v) = self.cached {
            return Ok(v);
        }
        let parts = self.pool.collect(self.id)?;
        let v = self.pool.finalize(self.kind, parts)?;
        if let Some(key) = self.key {
            self.pool.memo.lock().unwrap().insert(key, v);
        }
        Ok(v)
    }
}

/// Contiguous near-even split of `n` batches over `workers` shards
/// (earlier shards take the remainder; empty shards are legal).
fn shard_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    let w = workers.max(1);
    let (base, rem) = (n / w, n % w);
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Content digest of a probe's weight overrides (0 when empty) — part of
/// the memo key so stitched-AdaRound and plain probes of the same bit
/// configuration never collide.
fn overrides_digest(ov: &WeightOverrides) -> u64 {
    if ov.is_empty() {
        return 0;
    }
    let mut keys: Vec<usize> = ov.keys().copied().collect();
    keys.sort_unstable();
    let mut h = crate::util::Fnv::new();
    for k in keys {
        h.write_usize(k);
        h.write_tensor(&ov[&k]);
    }
    h.finish()
}

// -- worker side -------------------------------------------------------------

/// A worker's view of one registered eval set: the device-resident shard
/// plus where it starts in the full set.
struct Shard {
    set: EvalSet,
    first_batch: usize,
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

fn worker_main(
    widx: usize,
    dir: PathBuf,
    model: String,
    rx: mpsc::Receiver<Job>,
    res: mpsc::Sender<ResMsg>,
    init: mpsc::Sender<(usize, Result<(), String>)>,
) {
    // All backend state (PJRT client or sim interpreter) is created here,
    // inside the thread, and never leaves.  Panics are caught and reported —
    // a silently dead worker would leave the coordinator blocked on a
    // result slot that can never fill.
    let built = std::panic::catch_unwind(move || -> Result<ModelHandle> {
        let manifest = Manifest::load(&dir)?;
        let rt = Rc::new(Runtime::for_manifest(&manifest)?);
        ModelHandle::open(rt, &manifest, &model)
    });
    let mut handle = match built {
        Ok(Ok(h)) => {
            let _ = init.send((widx, Ok(())));
            // release the init channel so EvalPool::new sees a disconnect
            // (not a hang) if any *other* worker dies before reporting
            drop(init);
            h
        }
        Ok(Err(e)) => {
            let _ = init.send((widx, Err(format!("{e:#}"))));
            return;
        }
        Err(p) => {
            let _ = init.send((widx, Err(format!("init panicked: {}", panic_text(&p)))));
            return;
        }
    };
    let mut shards: HashMap<SetKey, Shard> = HashMap::new();
    while let Ok(job) = rx.recv() {
        let Job { id, req } = job;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve(&mut handle, &mut shards, req)
        }));
        match outcome {
            Ok(out) => {
                if res.send((id, widx, out.map_err(|e| format!("{e:#}")))).is_err() {
                    return; // pool dropped
                }
            }
            Err(p) => {
                // report, then exit: the handle's caches may be mid-update,
                // so later jobs fail loudly at send() instead of computing
                // on inconsistent state
                let _ = res.send((id, widx, Err(format!("worker panicked: {}", panic_text(&p)))));
                return;
            }
        }
    }
}

fn serve(
    handle: &mut ModelHandle,
    shards: &mut HashMap<SetKey, Shard>,
    req: Request,
) -> Result<Partial> {
    match req {
        Request::Calibrate { ranges, w_scales } => {
            handle.act_ranges = Some(ranges);
            handle.w_scales = w_scales;
            // new ranges invalidate the cached activation qparam rows
            handle.engine.mat.invalidate();
            Ok(Partial::Unit)
        }
        Request::LoadSet { key, batches, labels, first_batch } => {
            let set = handle.eval_set_shard(&batches, labels)?;
            shards.insert(key, Shard { set, first_batch });
            Ok(Partial::Unit)
        }
        Request::BuildReference { set } => {
            let shard = get_shard(shards, set)?;
            if !shard.set.batches.is_empty() {
                handle.engine.reference(handle, &shard.set)?;
            }
            Ok(Partial::Unit)
        }
        Request::Probe { set, kind, cfg, overrides } => {
            let shard = get_shard(shards, set)?;
            let (cfg, overrides) = (&*cfg, &*overrides);
            match kind {
                ProbeKind::Metric => {
                    let mut acc = StreamingTaskMetric::new(&handle.entry.task)?;
                    if !shard.set.batches.is_empty() {
                        let cb = handle.config_buffers(cfg, overrides)?;
                        let b = shard.set.batch;
                        for (bi, xb) in shard.set.batches.iter().enumerate() {
                            let logits = handle.forward(xb, &cb)?;
                            acc.push(&logits, &shard.set.labels.slice_rows(bi * b, b)?)?;
                        }
                    }
                    Ok(Partial::Task(acc))
                }
                ProbeKind::Sqnr => {
                    let mut s = StreamingSqnr::new();
                    if !shard.set.batches.is_empty() {
                        let fp = handle.engine.reference(handle, &shard.set)?;
                        let cb = handle.config_buffers(cfg, overrides)?;
                        for (bi, xb) in shard.set.batches.iter().enumerate() {
                            let q = handle.forward(xb, &cb)?;
                            s.push_at(
                                (shard.first_batch + bi) as u64,
                                &fp.batches[bi],
                                &fp.sig_pow[bi],
                                &q,
                            )?;
                        }
                    }
                    Ok(Partial::Sqnr(s))
                }
            }
        }
    }
}

fn get_shard(shards: &HashMap<SetKey, Shard>, key: SetKey) -> Result<&Shard> {
    shards
        .get(&key)
        .ok_or_else(|| anyhow!("eval set {key} not loaded into the pool"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_contiguously() {
        for (n, w) in [(0usize, 3usize), (1, 4), (7, 3), (8, 4), (16, 5), (5, 1)] {
            let rs = shard_ranges(n, w);
            assert_eq!(rs.len(), w);
            let mut next = 0usize;
            for r in &rs {
                assert_eq!(r.start, next, "shards must be contiguous (n={n} w={w})");
                next = r.end;
            }
            assert_eq!(next, n, "shards must cover all batches (n={n} w={w})");
            let max = rs.iter().map(|r| r.len()).max().unwrap();
            let min = rs.iter().map(|r| r.len()).min().unwrap();
            assert!(max - min <= 1, "shards must be near-even (n={n} w={w})");
        }
        assert_eq!(shard_ranges(4, 0).len(), 1, "0 workers clamps to 1");
    }

    #[test]
    fn overrides_digest_is_content_keyed() {
        let t1 = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let t2 = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 5.0]).unwrap();
        let empty = WeightOverrides::new();
        assert_eq!(overrides_digest(&empty), 0);
        let mut a = WeightOverrides::new();
        a.insert(0, t1.clone());
        let mut b = WeightOverrides::new();
        b.insert(0, t2);
        let mut c = WeightOverrides::new();
        c.insert(1, t1.clone());
        let da = overrides_digest(&a);
        assert_ne!(da, 0);
        assert_ne!(da, overrides_digest(&b), "content change must change digest");
        assert_ne!(da, overrides_digest(&c), "param index must change digest");
        // digest is stable across map iteration order: rebuild in reverse
        let mut a2 = WeightOverrides::new();
        a2.insert(2, t1.clone());
        a2.insert(0, t1.clone());
        let mut a3 = WeightOverrides::new();
        a3.insert(0, t1.clone());
        a3.insert(2, t1);
        assert_eq!(overrides_digest(&a2), overrides_digest(&a3));
    }
}
