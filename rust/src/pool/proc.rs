//! Process-backed fleet lanes: each worker is an `mpq worker` subprocess
//! speaking the [`super::transport`] frame protocol over a private Unix
//! socket.
//!
//! The fleet's internal seam stays the mpsc job/result channels — a
//! process lane is a pair of **bridge threads** that adapt them to the
//! socket: the *feeder* drains the lane's job queue, computes each job's
//! [`FaultDirective`] coordinator-side (global fault-plan depletion lives
//! here, where the shared [`FaultState`] is), and writes JOB frames; the
//! *reader* forwards INIT and REPLY frames back onto the fleet's channels
//! and converts a broken or closed socket into the same `DEATH_NOTICE`
//! a panicking thread lane sends.  The supervisor above needs no new
//! cases: a SIGKILLed subprocess *is* a death notice, and respawn /
//! host-state replay / requeue proceed exactly as for threads.
//!
//! Clean shutdown is a two-phase close mirrored on the channel seam:
//! dropping the lane's job sender ends the feeder, which half-closes the
//! socket; the child drains, sees EOF, and exits; the reader sees EOF
//! with the `closing` flag up and exits silently.  `reap` (supervised
//! teardown of a lane that is *presumed stuck*) inverts the order — kill
//! the child first so both bridge threads unblock, then join them.

use super::fault::FaultState;
use super::transport::{self, FaultDirective};
use super::worker;
use super::{Job, Request, ResMsg, DEATH_NOTICE};
use crate::serve::proto;
use anyhow::{Context, Result};
use std::os::unix::fs::DirBuilderExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long the coordinator waits for a freshly spawned worker process to
/// connect back and complete the protocol handshake.
const CONNECT_DEADLINE: Duration = Duration::from_secs(10);

/// Process-wide spawn counter folded into every rendezvous path.  Worker
/// indices restart at 0 per fleet, so two fleets in one process (parallel
/// integration tests, embedders with several pools) would otherwise race
/// on the same socket name; this sequence makes each spawn's path unique
/// for the life of the process.
static SPAWN_SEQ: AtomicU64 = AtomicU64::new(0);

/// Owns the per-spawn private rendezvous directory; best-effort removal on
/// drop covers every early-return path, and the deliberate `drop` after
/// accept keeps the socket's lifetime to the rendezvous window.
struct RendezvousDir(PathBuf);

impl Drop for RendezvousDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// One process lane: the subprocess plus its two bridge threads.
pub(super) struct ProcLane {
    child: Child,
    /// raised before any deliberate teardown so the reader does not
    /// mistake the resulting EOF for a crash and emit a death notice
    closing: Arc<AtomicBool>,
    feeder: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

impl ProcLane {
    /// The worker process id (tests SIGKILL it to exercise supervision).
    pub(super) fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Phase one of a clean close: mark the teardown deliberate.  The
    /// caller drops the lane's job sender next, which unwinds feeder →
    /// child → reader without a death notice.
    pub(super) fn begin_close(&self) {
        self.closing.store(true, Ordering::SeqCst);
    }

    /// Phase two of a clean close: join the bridge threads, then reap the
    /// (already exited) child.  `Child::wait` caches the exit status, so
    /// a second wait on an already-reaped child is harmless.
    pub(super) fn finish_close(mut self) {
        if let Some(f) = self.feeder.take() {
            let _ = f.join();
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
        let _ = self.child.wait();
    }

    /// Supervised teardown of a lane presumed dead or stuck: kill the
    /// child *first* so a feeder blocked on a full socket buffer (or a
    /// reader blocked on a stalled child) unblocks, then join.  Unlike a
    /// marooned thread lane, a stuck subprocess can always be reclaimed.
    pub(super) fn reap(mut self) {
        self.closing.store(true, Ordering::SeqCst);
        let _ = self.child.kill();
        let _ = self.child.wait();
        if let Some(f) = self.feeder.take() {
            let _ = f.join();
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

/// Spawn one process lane: bind a private socket, launch `mpq worker`,
/// wait for it to connect and handshake, then stand up the bridge
/// threads.
///
/// Failure reporting follows the thread lanes' contract: infrastructure
/// failures the caller can do nothing about mid-loop (bind, spawn, thread
/// spawn) are hard `Err`s, while *worker-side* setup failures (it exited,
/// never connected, or flunked the handshake) are reported through the
/// init channel — exactly where a thread lane's failed `init_state`
/// lands — so `spawn_workers`' existing init-collection path handles
/// both lane kinds uniformly.
pub(super) fn spawn_proc_worker(
    widx: usize,
    lane: usize,
    dir: &Path,
    rx: mpsc::Receiver<Job>,
    res: mpsc::Sender<ResMsg>,
    init: mpsc::Sender<(usize, Result<(), String>)>,
    faults: &Arc<FaultState>,
) -> Result<ProcLane> {
    // Rendezvous in a freshly created mode-0700 directory whose name is
    // unique across every fleet in this process (pid + spawn sequence):
    // no other local user can connect before our child does, and no
    // pre-bind unlink is needed — if the path somehow exists, creation
    // fails loudly instead of clobbering a live fleet's listener.
    let seq = SPAWN_SEQ.fetch_add(1, Ordering::Relaxed);
    let rdv = RendezvousDir(
        std::env::temp_dir().join(format!("mpq-worker-{}-{seq}", std::process::id())),
    );
    let mut db = std::fs::DirBuilder::new();
    db.mode(0o700);
    db.create(&rdv.0)
        .with_context(|| format!("creating worker rendezvous dir {}", rdv.0.display()))?;
    let sock = rdv.0.join("worker.sock");
    let listener = UnixListener::bind(&sock)
        .with_context(|| format!("binding worker socket {}", sock.display()))?;

    // The coordinator re-executes itself by default; MPQ_WORKER_BIN
    // overrides for harnesses whose current_exe is not the mpq binary
    // (integration tests and benches point it at CARGO_BIN_EXE_mpq).
    let exe = match std::env::var_os("MPQ_WORKER_BIN") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::env::current_exe().context("resolving the mpq binary for worker spawn")?,
    };
    let mut cmd = Command::new(&exe);
    cmd.arg("worker")
        .arg("--socket")
        .arg(&sock)
        .arg("--artifacts")
        .arg(dir)
        .arg("--lane")
        .arg(lane.to_string())
        .stdin(Stdio::null());
    if let Some(nth) = faults.arm_compile(lane) {
        cmd.arg("--compile-fault").arg(nth.to_string());
    }
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawning worker process {}", exe.display()))?;
    let closing = Arc::new(AtomicBool::new(false));

    // Poll accept so a child that dies before connecting (bad binary,
    // immediate crash) is diagnosed by its exit status instead of a
    // 10-second timeout.
    listener
        .set_nonblocking(true)
        .context("setting worker listener non-blocking")?;
    let deadline = Instant::now() + CONNECT_DEADLINE;
    let accepted = loop {
        match listener.accept() {
            Ok((stream, _)) => break Ok(stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                match child.try_wait() {
                    Ok(Some(status)) => {
                        break Err(format!("worker process exited before connecting ({status})"))
                    }
                    Ok(None) => {}
                    Err(e) => break Err(format!("waiting on worker process: {e}")),
                }
                if Instant::now() >= deadline {
                    break Err(format!(
                        "worker process did not connect within {}s",
                        CONNECT_DEADLINE.as_secs()
                    ));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => break Err(format!("accepting worker connection: {e}")),
        }
    };
    // single-connection socket: remove the rendezvous dir (and the socket
    // inside it) as soon as the accept resolved
    drop(rdv);

    let setup = accepted.and_then(|mut stream| {
        let ready = (|| -> Result<()> {
            stream.set_nonblocking(false)?;
            stream.set_read_timeout(Some(CONNECT_DEADLINE))?;
            proto::handshake(&mut stream)?;
            stream.set_read_timeout(None)?;
            Ok(())
        })();
        match ready {
            Ok(()) => Ok(stream),
            Err(e) => Err(format!("worker handshake failed: {e:#}")),
        }
    });
    let stream = match setup {
        Ok(s) => s,
        Err(msg) => {
            let _ = child.kill();
            let _ = child.wait();
            let _ = init.send((widx, Err(msg)));
            return Ok(ProcLane { child, closing, feeder: None, reader: None });
        }
    };
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            let _ = child.kill();
            let _ = child.wait();
            let _ = init.send((widx, Err(format!("cloning worker socket: {e}"))));
            return Ok(ProcLane { child, closing, feeder: None, reader: None });
        }
    };

    let feeder = std::thread::Builder::new()
        .name(format!("mpq-proc-feed-{widx}"))
        .spawn({
            let faults = faults.clone();
            move || feed_loop(writer, rx, faults, lane)
        })
        .context("spawning process-lane feeder thread")?;
    let reader = match std::thread::Builder::new()
        .name(format!("mpq-proc-read-{widx}"))
        .spawn({
            let closing = closing.clone();
            move || read_loop(stream, widx, res, init, closing)
        }) {
        Ok(r) => r,
        Err(e) => {
            let _ = child.kill();
            let _ = child.wait();
            let _ = feeder.join();
            return Err(e).context("spawning process-lane reader thread");
        }
    };
    Ok(ProcLane { child, closing, feeder: Some(feeder), reader: Some(reader) })
}

/// Bridge the lane's job queue onto the socket.  Fault decisions are made
/// here, coordinator-side, per job: the shared [`FaultState`] keeps its
/// global one-shot depletion and per-incarnation recurrence semantics
/// (this thread's counters reset with each respawn, exactly like a thread
/// lane's), and the resulting [`FaultDirective`] rides the JOB frame.
fn feed_loop(mut w: UnixStream, rx: mpsc::Receiver<Job>, faults: Arc<FaultState>, lane: usize) {
    let slow = faults.slow_ms(lane).unwrap_or(0);
    let mut probes = 0usize;
    let mut uploads = 0usize;
    while let Ok(Job { id, req }) = rx.recv() {
        let mut d = FaultDirective { slow_ms: slow, ..Default::default() };
        if matches!(req, Request::Probe { .. }) {
            probes += 1;
            d.probes = probes as u64;
            d.stall = faults.fire_stall(lane, probes);
            d.panic = faults.fire_panic(lane, probes);
        }
        if matches!(
            req,
            Request::LoadSet { .. } | Request::BuildReference { .. } | Request::InstallReference { .. }
        ) {
            uploads += 1;
            d.uploads = uploads as u64;
            d.upload_fail = faults.fire_upload(lane, uploads);
        }
        if transport::write_job(&mut w, id, &req, &d).is_err() {
            // broken socket: the reader reports the death; nothing to do
            // here but stop feeding (the unsent job stays in its tracked
            // slot and is requeued by the supervisor)
            break;
        }
    }
    // half-close so the child's read_job sees a clean EOF and exits
    let _ = w.shutdown(std::net::Shutdown::Write);
}

/// Bridge the socket back onto the fleet's channels: first the one-time
/// INIT outcome, then replies until EOF or error — which, unless the
/// teardown was deliberate, becomes the lane's death notice.
fn read_loop(
    mut stream: UnixStream,
    widx: usize,
    res: mpsc::Sender<ResMsg>,
    init: mpsc::Sender<(usize, Result<(), String>)>,
    closing: Arc<AtomicBool>,
) {
    match transport::read_init(&mut stream) {
        Ok(Some(outcome)) => {
            let failed = outcome.is_err();
            let _ = init.send((widx, outcome));
            if failed {
                // the child exits after reporting a failed init; no death
                // notice — spawn_workers surfaces the init error itself
                return;
            }
        }
        Ok(None) => {
            let _ = init.send((widx, Err("worker process exited during init".into())));
            return;
        }
        Err(e) => {
            let _ = init.send((widx, Err(format!("worker process init failed: {e:#}"))));
            return;
        }
    }
    // release the init channel so the fleet sees a disconnect (not a
    // hang) if any *other* worker dies before reporting
    drop(init);
    loop {
        match transport::read_reply(&mut stream) {
            Ok(Some((id, out))) => {
                if res.send((id, widx, out)).is_err() {
                    return; // fleet dropped
                }
            }
            Ok(None) => {
                if !closing.load(Ordering::SeqCst) {
                    let _ = res.send((
                        DEATH_NOTICE,
                        widx,
                        Err("worker process exited unexpectedly (socket closed)".into()),
                    ));
                }
                return;
            }
            Err(e) => {
                if !closing.load(Ordering::SeqCst) {
                    let _ = res.send((
                        DEATH_NOTICE,
                        widx,
                        Err(format!("worker process connection failed: {e:#}")),
                    ));
                }
                return;
            }
        }
    }
}

/// The `mpq worker` subprocess entrypoint: connect back to the
/// coordinator, handshake, build the backend state, then serve framed
/// jobs until the coordinator half-closes the socket.
///
/// Injected `panic@` faults are deliberately **uncaught** here: a process
/// lane's panic is a process death (exit 101 → socket EOF → death notice
/// at the coordinator), which is precisely how supervision generalizes
/// from caught thread panics to SIGKILL-grade failures.
pub(super) fn run_worker(
    socket: &Path,
    dir: &Path,
    lane: usize,
    compile_fault: Option<usize>,
) -> Result<()> {
    let mut stream = UnixStream::connect(socket)
        .with_context(|| format!("connecting to coordinator socket {}", socket.display()))?;
    proto::handshake(&mut stream).context("coordinator handshake")?;
    let opens = Arc::new(AtomicUsize::new(0));
    let cf = compile_fault.map(|nth| (nth, Arc::new(AtomicUsize::new(0))));
    let mut state = match worker::init_state(dir, opens, cf) {
        Ok(state) => {
            transport::write_init(&mut stream, &Ok(()))?;
            state
        }
        Err(e) => {
            transport::write_init(&mut stream, &Err(format!("{e:#}")))?;
            return Ok(());
        }
    };
    while let Some((id, req, d)) = transport::read_job(&mut stream)? {
        if d.slow_ms > 0 {
            std::thread::sleep(Duration::from_millis(d.slow_ms));
        }
        if d.stall {
            // block far past any configured deadline; the collect watchdog
            // converts this lane into a death and reaps the process
            std::thread::sleep(Duration::from_secs(3600));
        }
        if d.panic {
            panic!("injected fault: worker panic on probe {} (lane {lane})", d.probes);
        }
        let out = if d.upload_fail {
            worker::inject_upload_failure(
                &mut state,
                &req,
                format!("injected fault: upload failure on request {} (lane {lane})", d.uploads),
            )
        } else {
            worker::serve(&mut state, req)
        };
        transport::write_reply(&mut stream, id, &out.map_err(|e| format!("{e:#}")))?;
    }
    Ok(())
}
