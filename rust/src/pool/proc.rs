//! Process-backed fleet lanes: each worker is an `mpq worker` subprocess
//! speaking the [`super::transport`] frame protocol over a private Unix
//! socket.
//!
//! The fleet's internal seam stays the mpsc job/result channels — a
//! process lane is a pair of **bridge threads** that adapt them to the
//! socket: the *feeder* drains the lane's job queue, computes each job's
//! [`FaultDirective`] coordinator-side (global fault-plan depletion lives
//! here, where the shared [`FaultState`] is), and writes JOB frames; the
//! *reader* forwards INIT and REPLY frames back onto the fleet's channels
//! and converts a broken or closed socket into the same `DEATH_NOTICE`
//! a panicking thread lane sends.  The supervisor above needs no new
//! cases: a SIGKILLed subprocess *is* a death notice, and respawn /
//! host-state replay / requeue proceed exactly as for threads.
//!
//! Clean shutdown is a two-phase close mirrored on the channel seam:
//! dropping the lane's job sender ends the feeder, which half-closes the
//! socket; the child drains, sees EOF, and exits; the reader sees EOF
//! with the `closing` flag up and exits silently.  `reap` (supervised
//! teardown of a lane that is *presumed stuck*) inverts the order — kill
//! the child first so both bridge threads unblock, then join them.

use super::fault::FaultState;
use super::transport::{self, FaultDirective};
use super::wire::{WireConn, WireFaults, WireStats};
use super::worker;
use super::{Job, Request, ResMsg, DEATH_NOTICE};
use crate::serve::proto;
use anyhow::{bail, Context, Result};
use std::os::unix::fs::DirBuilderExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long the coordinator waits for a freshly spawned worker process to
/// connect back and complete the protocol handshake.
const CONNECT_DEADLINE: Duration = Duration::from_secs(10);

/// Default coordinator→worker heartbeat interval (ms).  Overridable via
/// `MPQ_HEARTBEAT_MS`; `0` disables heartbeats (and with them the
/// liveness read timeout — production blocking semantics).
const DEFAULT_HEARTBEAT_MS: u64 = 250;

/// Heartbeat interval in ms (`MPQ_HEARTBEAT_MS`, default 250; 0 = off).
fn heartbeat_ms() -> u64 {
    match std::env::var("MPQ_HEARTBEAT_MS") {
        Ok(s) => s.trim().parse().unwrap_or(DEFAULT_HEARTBEAT_MS),
        Err(_) => DEFAULT_HEARTBEAT_MS,
    }
}

/// Liveness deadline: a lane that produces no frame (reply *or* pong) for
/// this long is declared dead.  Generous multiple of the ping interval so
/// scheduler jitter never kills a healthy lane; the worker's dedicated
/// socket-reader thread answers pings even mid-compute, so only a truly
/// wedged (or disconnected) peer goes silent this long.
fn liveness_ms(hb: u64) -> u64 {
    (hb * 8).max(1000)
}

/// Process-wide spawn counter folded into every rendezvous path.  Worker
/// indices restart at 0 per fleet, so two fleets in one process (parallel
/// integration tests, embedders with several pools) would otherwise race
/// on the same socket name; this sequence makes each spawn's path unique
/// for the life of the process.
static SPAWN_SEQ: AtomicU64 = AtomicU64::new(0);

/// Owns the per-spawn private rendezvous directory; best-effort removal on
/// drop covers every early-return path, and the deliberate `drop` after
/// accept keeps the socket's lifetime to the rendezvous window.
struct RendezvousDir(PathBuf);

impl Drop for RendezvousDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// One process lane: the subprocess plus its two bridge threads.
pub(super) struct ProcLane {
    child: Child,
    /// raised before any deliberate teardown so the reader does not
    /// mistake the resulting EOF for a crash and emit a death notice
    closing: Arc<AtomicBool>,
    feeder: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

impl ProcLane {
    /// The worker process id (tests SIGKILL it to exercise supervision).
    pub(super) fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Phase one of a clean close: mark the teardown deliberate.  The
    /// caller drops the lane's job sender next, which unwinds feeder →
    /// child → reader without a death notice.
    pub(super) fn begin_close(&self) {
        self.closing.store(true, Ordering::SeqCst);
    }

    /// Phase two of a clean close: join the bridge threads, then reap the
    /// (already exited) child.  `Child::wait` caches the exit status, so
    /// a second wait on an already-reaped child is harmless.
    pub(super) fn finish_close(mut self) {
        if let Some(f) = self.feeder.take() {
            let _ = f.join();
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
        let _ = self.child.wait();
    }

    /// Supervised teardown of a lane presumed dead or stuck: kill the
    /// child *first* so a feeder blocked on a full socket buffer (or a
    /// reader blocked on a stalled child) unblocks, then join.  Unlike a
    /// marooned thread lane, a stuck subprocess can always be reclaimed.
    pub(super) fn reap(mut self) {
        self.closing.store(true, Ordering::SeqCst);
        let _ = self.child.kill();
        let _ = self.child.wait();
        if let Some(f) = self.feeder.take() {
            let _ = f.join();
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

/// Spawn one process lane: bind a private socket, launch `mpq worker`,
/// wait for it to connect and handshake, then stand up the bridge
/// threads.
///
/// Failure reporting follows the thread lanes' contract: infrastructure
/// failures the caller can do nothing about mid-loop (bind, spawn, thread
/// spawn) are hard `Err`s, while *worker-side* setup failures (it exited,
/// never connected, or flunked the handshake) are reported through the
/// init channel — exactly where a thread lane's failed `init_state`
/// lands — so `spawn_workers`' existing init-collection path handles
/// both lane kinds uniformly.
pub(super) fn spawn_proc_worker(
    widx: usize,
    lane: usize,
    dir: &Path,
    rx: mpsc::Receiver<Job>,
    res: mpsc::Sender<ResMsg>,
    init: mpsc::Sender<(usize, Result<(), String>)>,
    faults: &Arc<FaultState>,
    wire: Option<Arc<WireFaults>>,
    wire_stats: Arc<WireStats>,
) -> Result<ProcLane> {
    // Rendezvous in a freshly created mode-0700 directory whose name is
    // unique across every fleet in this process (pid + spawn sequence):
    // no other local user can connect before our child does, and no
    // pre-bind unlink is needed — if the path somehow exists, creation
    // fails loudly instead of clobbering a live fleet's listener.
    let seq = SPAWN_SEQ.fetch_add(1, Ordering::Relaxed);
    let rdv = RendezvousDir(
        std::env::temp_dir().join(format!("mpq-worker-{}-{seq}", std::process::id())),
    );
    let mut db = std::fs::DirBuilder::new();
    db.mode(0o700);
    db.create(&rdv.0)
        .with_context(|| format!("creating worker rendezvous dir {}", rdv.0.display()))?;
    let sock = rdv.0.join("worker.sock");
    let listener = UnixListener::bind(&sock)
        .with_context(|| format!("binding worker socket {}", sock.display()))?;

    // The coordinator re-executes itself by default; MPQ_WORKER_BIN
    // overrides for harnesses whose current_exe is not the mpq binary
    // (integration tests and benches point it at CARGO_BIN_EXE_mpq).
    let exe = match std::env::var_os("MPQ_WORKER_BIN") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::env::current_exe().context("resolving the mpq binary for worker spawn")?,
    };
    let mut cmd = Command::new(&exe);
    cmd.arg("worker")
        .arg("--socket")
        .arg(&sock)
        .arg("--artifacts")
        .arg(dir)
        .arg("--lane")
        .arg(lane.to_string())
        .stdin(Stdio::null());
    if let Some(nth) = faults.arm_compile(lane) {
        cmd.arg("--compile-fault").arg(nth.to_string());
    }
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawning worker process {}", exe.display()))?;
    let closing = Arc::new(AtomicBool::new(false));

    // Poll accept so a child that dies before connecting (bad binary,
    // immediate crash) is diagnosed by its exit status instead of a
    // 10-second timeout.
    listener
        .set_nonblocking(true)
        .context("setting worker listener non-blocking")?;
    let deadline = Instant::now() + CONNECT_DEADLINE;
    let accepted = loop {
        match listener.accept() {
            Ok((stream, _)) => break Ok(stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                match child.try_wait() {
                    Ok(Some(status)) => {
                        break Err(format!("worker process exited before connecting ({status})"))
                    }
                    Ok(None) => {}
                    Err(e) => break Err(format!("waiting on worker process: {e}")),
                }
                if Instant::now() >= deadline {
                    break Err(format!(
                        "worker process did not connect within {}s",
                        CONNECT_DEADLINE.as_secs()
                    ));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => break Err(format!("accepting worker connection: {e}")),
        }
    };
    // single-connection socket: remove the rendezvous dir (and the socket
    // inside it) as soon as the accept resolved
    drop(rdv);

    let hb = heartbeat_ms();
    let setup = accepted.and_then(|mut stream| {
        let ready = (|| -> Result<()> {
            stream.set_nonblocking(false)?;
            stream.set_read_timeout(Some(CONNECT_DEADLINE))?;
            proto::handshake(&mut stream)?;
            // with heartbeats on, the read timeout becomes the liveness
            // deadline: the worker's reader thread pongs every ping even
            // mid-compute, so a window with no frame at all means the
            // peer is wedged or gone.  hb=0 restores blocking reads.
            stream.set_read_timeout(if hb > 0 {
                Some(Duration::from_millis(liveness_ms(hb)))
            } else {
                None
            })?;
            Ok(())
        })();
        match ready {
            Ok(()) => Ok(stream),
            Err(e) => Err(format!("worker handshake failed: {e:#}")),
        }
    });
    let stream = match setup {
        Ok(s) => s,
        Err(msg) => {
            let _ = child.kill();
            let _ = child.wait();
            let _ = init.send((widx, Err(msg)));
            return Ok(ProcLane { child, closing, feeder: None, reader: None });
        }
    };
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            let _ = child.kill();
            let _ = child.wait();
            let _ = init.send((widx, Err(format!("cloning worker socket: {e}"))));
            return Ok(ProcLane { child, closing, feeder: None, reader: None });
        }
    };

    let feeder = std::thread::Builder::new()
        .name(format!("mpq-proc-feed-{widx}"))
        .spawn({
            let faults = faults.clone();
            let conn = WireConn::new(wire.clone(), lane);
            let stats = wire_stats.clone();
            move || feed_loop(writer, rx, faults, lane, conn, stats, hb)
        })
        .context("spawning process-lane feeder thread")?;
    let reader = match std::thread::Builder::new()
        .name(format!("mpq-proc-read-{widx}"))
        .spawn({
            let closing = closing.clone();
            move || read_loop(stream, widx, lane, res, init, closing, wire, wire_stats, hb)
        }) {
        Ok(r) => r,
        Err(e) => {
            let _ = child.kill();
            let _ = child.wait();
            let _ = feeder.join();
            return Err(e).context("spawning process-lane reader thread");
        }
    };
    Ok(ProcLane { child, closing, feeder: Some(feeder), reader: Some(reader) })
}

/// Bridge the lane's job queue onto the socket.  Fault decisions are made
/// here, coordinator-side, per job: the shared [`FaultState`] keeps its
/// global one-shot depletion and per-incarnation recurrence semantics
/// (this thread's counters reset with each respawn, exactly like a thread
/// lane's), and the resulting [`FaultDirective`] rides the JOB frame.
///
/// With heartbeats on, an idle queue turns into a PING every `hb` ms — so
/// a lane waiting for work (or waiting on a long compute; the queue is
/// drained by the child's reader thread) keeps proving the path to the
/// worker is alive, and the worker keeps proving it can answer.  Every
/// frame — job or ping — goes through the lane's [`WireConn`], so wire
/// faults hit the heartbeat path too.
fn feed_loop(
    mut w: UnixStream,
    rx: mpsc::Receiver<Job>,
    faults: Arc<FaultState>,
    lane: usize,
    conn: WireConn,
    stats: Arc<WireStats>,
    hb: u64,
) {
    let slow = faults.slow_ms(lane).unwrap_or(0);
    let mut probes = 0usize;
    let mut uploads = 0usize;
    let mut ping_seq = 0u64;
    loop {
        let job = if hb == 0 {
            rx.recv().map_err(|_| ())
        } else {
            match rx.recv_timeout(Duration::from_millis(hb)) {
                Ok(j) => Ok(j),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    ping_seq += 1;
                    stats.heartbeats_sent.fetch_add(1, Ordering::Relaxed);
                    if transport::write_ping(&mut w, &conn, ping_seq).is_err() {
                        break;
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => Err(()),
            }
        };
        let Ok(Job { id, req }) = job else { break };
        let mut d = FaultDirective { slow_ms: slow, ..Default::default() };
        if matches!(req, Request::Probe { .. }) {
            probes += 1;
            d.probes = probes as u64;
            d.stall = faults.fire_stall(lane, probes);
            d.panic = faults.fire_panic(lane, probes);
        }
        if matches!(
            req,
            Request::LoadSet { .. } | Request::BuildReference { .. } | Request::InstallReference { .. }
        ) {
            uploads += 1;
            d.uploads = uploads as u64;
            d.upload_fail = faults.fire_upload(lane, uploads);
        }
        if transport::write_job(&mut w, &conn, id, &req, &d).is_err() {
            // broken socket (or an injected wsplit/wreset): the reader
            // reports the death; nothing to do here but stop feeding (the
            // unsent job stays in its tracked slot and is requeued by the
            // supervisor)
            break;
        }
    }
    // half-close so the child's read loop sees a clean EOF and exits
    let _ = w.shutdown(std::net::Shutdown::Write);
}

/// Does this error chain bottom out in a read-timeout (the liveness
/// deadline elapsing with no frame at all)?
fn is_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        })
    })
}

/// Bridge the socket back onto the fleet's channels: first the one-time
/// INIT outcome, then replies until EOF or error — which, unless the
/// teardown was deliberate, becomes the lane's death notice.  A liveness
/// timeout (no reply *or* pong within the deadline) is a distinct death
/// reason; when the lane's wire plan fired recently, the injected root
/// cause is appended so chaos errors always name the fault.
#[allow(clippy::too_many_arguments)]
fn read_loop(
    mut stream: UnixStream,
    widx: usize,
    lane: usize,
    res: mpsc::Sender<ResMsg>,
    init: mpsc::Sender<(usize, Result<(), String>)>,
    closing: Arc<AtomicBool>,
    wire: Option<Arc<WireFaults>>,
    stats: Arc<WireStats>,
    hb: u64,
) {
    let enrich = |msg: String| -> String {
        match wire.as_ref().and_then(|w| w.last_for(lane)) {
            Some(cause) => format!("{msg}; after {cause}"),
            None => msg,
        }
    };
    match transport::read_init(&mut stream) {
        Ok(Some(outcome)) => {
            let failed = outcome.is_err();
            let _ = init.send((widx, outcome));
            if failed {
                // the child exits after reporting a failed init; no death
                // notice — spawn_workers surfaces the init error itself
                return;
            }
        }
        Ok(None) => {
            let _ = init.send((widx, Err("worker process exited during init".into())));
            return;
        }
        Err(e) => {
            let _ = init.send((widx, Err(format!("worker process init failed: {e:#}"))));
            return;
        }
    }
    // release the init channel so the fleet sees a disconnect (not a
    // hang) if any *other* worker dies before reporting
    drop(init);
    loop {
        match transport::read_reply(&mut stream) {
            Ok(Some((id, out))) => {
                if res.send((id, widx, out)).is_err() {
                    return; // fleet dropped
                }
            }
            Ok(None) => {
                if !closing.load(Ordering::SeqCst) {
                    let _ = res.send((
                        DEATH_NOTICE,
                        widx,
                        Err(enrich("worker process exited unexpectedly (socket closed)".into())),
                    ));
                }
                return;
            }
            Err(e) => {
                if !closing.load(Ordering::SeqCst) {
                    let msg = if hb > 0 && is_timeout(&e) {
                        stats.heartbeat_deaths.fetch_add(1, Ordering::Relaxed);
                        format!(
                            "worker heartbeat missed (no frame within {}ms)",
                            liveness_ms(hb)
                        )
                    } else {
                        format!("worker process connection failed: {e:#}")
                    };
                    let _ = res.send((DEATH_NOTICE, widx, Err(enrich(msg))));
                }
                return;
            }
        }
    }
}

/// The `mpq worker` subprocess entrypoint: connect back to the
/// coordinator, handshake, build the backend state, then serve framed
/// jobs until the coordinator half-closes the socket.
///
/// Two threads: a dedicated **socket reader** answers PING frames with
/// PONGs the instant they arrive and forwards JOB frames over an internal
/// channel, while the main thread computes and writes replies.  Both
/// write through one mutex-guarded clone of the stream, and the lock is
/// held across whole frames, so a PONG can never interleave mid-reply.
/// This split is what makes the coordinator's liveness deadline sound:
/// a worker deep in a long compute (or an injected `slow@`/`stall@`)
/// still pongs, so only a truly wedged or dead process goes silent.
///
/// Injected `panic@` faults are deliberately **uncaught**, and run on the
/// main thread: a process lane's panic is a process death (exit 101 →
/// socket EOF → death notice at the coordinator), which is precisely how
/// supervision generalizes from caught thread panics to SIGKILL-grade
/// failures.
pub(super) fn run_worker(
    socket: &Path,
    dir: &Path,
    lane: usize,
    compile_fault: Option<usize>,
) -> Result<()> {
    let mut stream = UnixStream::connect(socket)
        .with_context(|| format!("connecting to coordinator socket {}", socket.display()))?;
    proto::handshake(&mut stream).context("coordinator handshake")?;
    let writer = Arc::new(Mutex::new(
        stream.try_clone().context("cloning worker socket for replies")?,
    ));

    // Stand the reader up before the (potentially slow) backend init so
    // pings sent during compilation are answered too.
    let (jtx, jrx) = mpsc::channel();
    let reader = std::thread::Builder::new()
        .name(format!("mpq-worker-read-{lane}"))
        .spawn({
            let writer = writer.clone();
            move || -> Result<()> {
                loop {
                    match transport::read_job_or_ping(&mut stream)? {
                        Some(transport::WorkerIn::Ping(seq)) => {
                            let mut w = writer.lock().unwrap();
                            transport::write_pong(&mut *w, seq)?;
                        }
                        Some(transport::WorkerIn::Job(id, req, d)) => {
                            if jtx.send((id, req, d)).is_err() {
                                return Ok(()); // main thread gone
                            }
                        }
                        // coordinator half-closed: clean end of the stream
                        None => return Ok(()),
                    }
                }
            }
        })
        .context("spawning worker socket-reader thread")?;

    let opens = Arc::new(AtomicUsize::new(0));
    let cf = compile_fault.map(|nth| (nth, Arc::new(AtomicUsize::new(0))));
    let mut state = match worker::init_state(dir, opens, cf) {
        Ok(state) => {
            transport::write_init(&mut *writer.lock().unwrap(), &Ok(()))?;
            state
        }
        Err(e) => {
            transport::write_init(&mut *writer.lock().unwrap(), &Err(format!("{e:#}")))?;
            return Ok(());
        }
    };
    while let Ok((id, req, d)) = jrx.recv() {
        if d.slow_ms > 0 {
            std::thread::sleep(Duration::from_millis(d.slow_ms));
        }
        if d.stall {
            // block far past any configured deadline; the reader thread
            // keeps answering pings, so it is the collect watchdog — not
            // the liveness timeout — that converts this lane into a death
            // and reaps the process, exactly as for thread lanes
            std::thread::sleep(Duration::from_secs(3600));
        }
        if d.panic {
            panic!("injected fault: worker panic on probe {} (lane {lane})", d.probes);
        }
        let out = if d.upload_fail {
            worker::inject_upload_failure(
                &mut state,
                &req,
                format!("injected fault: upload failure on request {} (lane {lane})", d.uploads),
            )
        } else {
            worker::serve(&mut state, req)
        };
        transport::write_reply(&mut *writer.lock().unwrap(), id, &out.map_err(|e| format!("{e:#}")))?;
    }
    // The reader ended the job stream: a clean half-close (Ok) or a wire
    // error (torn frame, checksum mismatch) that must surface as this
    // process's exit status so the coordinator's EOF death carries it.
    match reader.join() {
        Ok(r) => r,
        Err(_) => bail!("worker socket-reader thread panicked"),
    }
}
