//! `mpq` — the leader CLI.
//!
//! ```text
//! mpq list                      inventory of models in artifacts/
//! mpq run --model M [...]       two-phase MPQ on one model
//! mpq sensitivity --model M     Phase-1 list only
//! mpq sim-gen --out DIR         generate a pure-Rust sim model zoo
//! mpq table1..table5            reproduce a paper table
//! mpq fig2..fig5                reproduce a paper figure
//! mpq all                       every table + figure, saved to results/
//! ```
//!
//! `run`/`sensitivity` work on either backend: point `--artifacts` at a
//! PJRT artifacts dir or at a `sim-gen` output dir — the manifest's
//! `backend` key selects the runtime.
//!
//! Common flags: `--artifacts DIR`, `--calib N`, `--seed S`,
//! `--models a,b,c`, `--fast`, `--budget R`, `--lattice practical|expanded`,
//! `--workers N` (evaluation-fleet width, default = host parallelism).
//! `--workers` is a **fleet-level** setting: the experiment drivers spawn
//! one worker fleet per process and share it across every model they open
//! (worker threads and compiled executables persist across models), while
//! single-model commands spawn a private fleet of the same width.

use anyhow::{anyhow, bail, Result};
use mpq::cli::Args;
use mpq::coordinator::Pipeline;
use mpq::experiments::{self, Opts};
use mpq::groups::Lattice;
use mpq::manifest::Manifest;
use mpq::report::results_dir;

fn opts_from(args: &Args) -> Result<Opts> {
    let mut o = Opts::default();
    if let Some(d) = args.opt("artifacts") {
        o.dir = d.into();
    }
    o.calib_n = args.opt_usize("calib", o.calib_n)?;
    o.seed = args.opt_u64("seed", o.seed)?;
    o.fast = o.fast || args.flag("fast");
    o.workers = args.opt_workers()?;
    o.fault_plan = args.opt("fault-plan").map(String::from);
    o.resume = args.flag("resume");
    o.proc = args.flag("proc");
    if let Some(ms) = args.opt("models") {
        o.models = Some(ms.split(',').map(String::from).collect());
    }
    Ok(o)
}

/// Route probe evaluation through a worker fleet when `--workers` > 1,
/// honoring an explicit `--fault-plan` (the self-healing harness) and
/// `--proc` (subprocess lanes instead of threads).
fn enable_fleet(pipe: &mut Pipeline, opts: &Opts) -> Result<()> {
    let plan = opts
        .fault_plan
        .as_deref()
        .map(mpq::pool::FaultPlan::parse)
        .transpose()?;
    let fleet = match (plan, opts.proc) {
        (Some(plan), true) => mpq::pool::EvalFleet::with_faults_proc(&opts.dir, opts.workers, plan)?,
        (Some(plan), false) => mpq::pool::EvalFleet::with_faults(&opts.dir, opts.workers, plan)?,
        (None, true) => mpq::pool::EvalFleet::new_proc(&opts.dir, opts.workers)?,
        (None, false) => return pipe.enable_pool(opts.workers),
    };
    pipe.attach_fleet(&fleet)
}

/// Print the fleet's failure telemetry after a pooled command — only when
/// something actually happened (restart, requeue, injected fault, death).
fn report_fleet_failures(pipe: &Pipeline) {
    if let Some(pool) = &pipe.pool {
        let fs = pool.fleet().failure_stats();
        if fs.any() {
            mpq::report::fleet_failure_table(&fs).print();
        }
    }
}

/// Print the durability telemetry (journal replay/skips, quarantined
/// caches) — only when the journal or the caches actually did something.
fn report_store_stats(pipe: &Pipeline) {
    let ss = pipe.store_stats();
    if ss.any() {
        mpq::report::store_stats_table(ss).print();
    }
}

/// Attach the crash-safe run journal to a single-model command's pipeline
/// (`--resume` replays it; `MPQ_JOURNAL=0` disables).
fn attach_journal(pipe: &mut Pipeline, opts: &Opts) -> Result<()> {
    let journal = experiments::open_journal(opts, &pipe.manifest)?;
    pipe.set_journal(journal);
    Ok(())
}

fn lattice_from(args: &Args) -> Result<Lattice> {
    Ok(match args.opt_str("lattice", "practical") {
        "practical" => Lattice::practical(),
        "practical_no16" => Lattice::practical_no16(),
        "expanded" => Lattice::expanded(),
        l => bail!("unknown lattice '{l}'"),
    })
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let opts = opts_from(&args)?;
    let rdir = results_dir();

    match cmd {
        "list" => {
            let man = Manifest::load(&opts.dir)?;
            println!("{:<18} {:>4} {:>4} {:>7} {:>10} task", "model", "A", "W", "groups", "MACs");
            for m in &man.models {
                println!(
                    "{:<18} {:>4} {:>4} {:>7} {:>10} {}",
                    m.name,
                    m.n_act(),
                    m.n_w(),
                    m.groups.len(),
                    m.total_macs,
                    m.task
                );
            }
        }
        "run" => {
            let model = args.opt("model").unwrap_or("resnet_s");
            let lat = lattice_from(&args)?;
            let budget = args.opt_f64("budget", 0.5)?;
            let mut pipe = Pipeline::open(&opts.dir, model)?;
            if opts.workers > 1 {
                enable_fleet(&mut pipe, &opts)?;
            }
            pipe.set_sens_cache_dir(opts.sens_cache_dir());
            attach_journal(&mut pipe, &opts)?;
            pipe.calibrate(opts.calib_n, opts.seed)?;
            let fp = pipe.eval_fp32()?;
            let run = pipe.mixed_precision_for_budget(&lat, budget)?;
            println!(
                "{model}: fp32 {fp:.4} → MP r={:.3} metric={:.4} ({} flips, {:.1}s)",
                run.final_rel_bops,
                run.final_metric,
                run.applied.len(),
                run.wall_secs
            );
            for s in &run.applied {
                println!("  group {:>3} → {}  (r→{:.3}, Ω={:.1})", s.group, s.cand.label(), s.rel_bops, s.score);
            }
            report_fleet_failures(&pipe);
            report_store_stats(&pipe);
        }
        "sensitivity" => {
            let model = args.opt("model").unwrap_or("resnet_s");
            let lat = lattice_from(&args)?;
            let mut pipe = Pipeline::open(&opts.dir, model)?;
            if opts.workers > 1 {
                enable_fleet(&mut pipe, &opts)?;
            }
            pipe.set_sens_cache_dir(opts.sens_cache_dir());
            attach_journal(&mut pipe, &opts)?;
            pipe.calibrate(opts.calib_n, opts.seed)?;
            let sens = pipe.sensitivity_sqnr(&lat)?;
            println!("{:<8} {:<8} {:>10}", "group", "cand", "Ω (dB)");
            for e in &sens {
                println!("{:<8} {:<8} {:>10.2}", e.group, e.cand.label(), e.score);
            }
            report_fleet_failures(&pipe);
            report_store_stats(&pipe);
        }
        "sim-gen" => {
            let out = args.opt_str("out", "sim-artifacts");
            let base = mpq::sim::SimSpec::default();
            let dims = match args.opt("dims") {
                Some(d) => d
                    .split(',')
                    .map(|s| s.trim().parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| anyhow!("--dims {d}: {e}"))?,
                None => base.dims.clone(),
            };
            let spec = mpq::sim::SimSpec {
                name: base.name.clone(),
                batch: args.opt_usize("batch", base.batch)?,
                dims,
                calib_n: args.opt_usize("calib-n", base.calib_n)?,
                val_n: args.opt_usize("val-n", base.val_n)?,
                ood_n: args.opt_usize("ood-n", base.ood_n)?,
                seed: args.opt_u64("sim-seed", base.seed)?,
                fault_plan: args.opt("fault-plan").map(String::from),
            };
            mpq::sim::generate(out, &spec)?;
            println!(
                "wrote sim artifacts for '{}' ({:?}) to {out}",
                spec.name, spec.dims
            );
        }
        "serve" => {
            let cfg = mpq::serve::ServeCfg::from_args(&args)?;
            println!(
                "mpqd: serving {} on {} (workers {}, max-jobs {}, max-idle {})",
                cfg.dir.display(),
                cfg.socket.display(),
                cfg.workers,
                cfg.max_jobs,
                cfg.max_idle
            );
            mpq::serve::run(cfg)?;
        }
        "client" => mpq::serve::client::cli(&args)?,
        "worker" => {
            // internal: the process-lane entrypoint `EvalFleet::new_proc`
            // coordinators spawn (see the pool module docs) — not for
            // interactive use
            let socket = args
                .opt("socket")
                .ok_or_else(|| anyhow!("worker needs --socket PATH (spawned by a coordinator)"))?;
            let lane = args.opt_usize("lane", 0)?;
            let compile_fault = match args.opt("compile-fault") {
                Some(v) => Some(v.parse::<usize>().map_err(|e| anyhow!("--compile-fault {v}: {e}"))?),
                None => None,
            };
            mpq::pool::run_worker_child(std::path::Path::new(socket), &opts.dir, lane, compile_fault)?;
        }
        "table1" => { let t = experiments::table1(&opts)?; t.print(); t.save(&rdir, "table1")?; }
        "table2" => { let t = experiments::table2(&opts)?; t.print(); t.save(&rdir, "table2")?; }
        "table3" => { let t = experiments::table3(&opts)?; t.print(); t.save(&rdir, "table3")?; }
        "table4" => { let t = experiments::table4(&opts)?; t.print(); t.save(&rdir, "table4")?; }
        "table5" => { let t = experiments::table5(&opts)?; t.print(); t.save(&rdir, "table5")?; }
        "fig2" => {
            let (a, b) = experiments::fig2(&opts)?;
            a.print();
            b.print();
            a.save(&rdir, "fig2_curves")?;
            b.save(&rdir, "fig2_ktau")?;
        }
        "fig3" => { let t = experiments::fig3(&opts)?; t.print(); t.save(&rdir, "fig3")?; }
        "fig4" => { let t = experiments::fig4(&opts)?; t.print(); t.save(&rdir, "fig4")?; }
        "fig5" => { let t = experiments::fig5(&opts)?; t.print(); t.save(&rdir, "fig5")?; }
        "all" => {
            for (name, f) in [
                ("table1", experiments::table1 as fn(&Opts) -> Result<mpq::report::Table>),
                ("table2", experiments::table2),
                ("table3", experiments::table3),
                ("table4", experiments::table4),
                ("table5", experiments::table5),
                ("fig3", experiments::fig3),
                ("fig4", experiments::fig4),
                ("fig5", experiments::fig5),
            ] {
                let t = f(&opts)?;
                t.print();
                t.save(&rdir, name)?;
            }
            let (a, b) = experiments::fig2(&opts)?;
            a.print();
            b.print();
            a.save(&rdir, "fig2_curves")?;
            b.save(&rdir, "fig2_ktau")?;
        }
        "help" | _ => {
            println!("usage: mpq <list|run|sensitivity|sim-gen|serve|client|table1..table5|fig2..fig5|all> [flags]");
            println!("flags: --artifacts DIR --model M --models a,b --calib N --seed S");
            println!("       --budget R --lattice practical|practical_no16|expanded --fast");
            println!("       --workers N  evaluation-fleet width (default: host parallelism;");
            println!("                    one shared fleet per driver run, reused across all");
            println!("                    models; 1 = serial single-client path)");
            println!("       --proc       run fleet lanes as mpq worker subprocesses over");
            println!("                    Unix sockets (MPQJ frames; results stay byte-equal");
            println!("                    to serial); lane death heals via the supervisor");
            println!("       --fault-plan SPEC  deterministic fleet fault injection, e.g.");
            println!("                    'panic@1:3,budget:2,deadline:500' (also via the");
            println!("                    MPQ_FAULT_PLAN env var or the manifest fault_plan key;");
            println!("                    the supervisor respawns, requeues and degrades —");
            println!("                    results stay bit-identical to the fault-free run);");
            println!("                    'crash@PHASE:N' aborts the coordinator at its Nth");
            println!("                    run-journal barrier (crash-recovery testing);");
            println!("                    wire chaos for --proc lanes and mpqd replies:");
            println!("                    'wdrop@L:N' 'wcorrupt@L:N' 'wsplit@L:N' 'wreset@L:N'");
            println!("                    'wdelay@L:MS' hit lane L's Nth outbound frame, and");
            println!("                    'wseed:S' derives a randomized per-lane schedule");
            println!("                    (MPQ_HEARTBEAT_MS tunes lane liveness pings; 0 = off)");
            println!("       --resume     replay the run journal (<artifacts>/journal.mpqj,");
            println!("                    MPQ_JOURNAL overrides path, =0 disables): completed");
            println!("                    Phase-1 probes, search prefixes and AdaRound layers");
            println!("                    are served back bit-exactly instead of re-run");
            println!("sim-gen: --out DIR --dims d0,d1,..,dL --batch B --calib-n N --val-n N");
            println!("         --ood-n N --sim-seed S --fault-plan SPEC");
            println!("         (pure-Rust backend; no PJRT needed)");
            println!("serve:   --socket PATH --artifacts DIR [--state-dir DIR] [--workers N]");
            println!("         [--max-jobs N] [--max-idle N] [--hold] [--io-timeout-ms MS]");
            println!("         long-running daemon: one shared fleet, concurrent jobs, per-job");
            println!("         crash/resume journals; overload sheds with typed RETRY_AFTER;");
            println!("         io timeout bounds mid-frame stalls on every connection (0 = off)");
            println!("client:  <submit|status|watch|cancel|release|shutdown> --socket PATH");
            println!("         [--model M --calib N --seed S --priority P --eval-budget N");
            println!("          --deadline-ms MS --idem KEY --io-timeout-ms MS");
            println!("          --no-adaround --adaround-steps N --job J]");
            println!("         submits retry with backoff under an idempotency key: a retried");
            println!("         submit of a finished job returns its durable result, never re-runs");
            println!("worker:  --socket PATH --artifacts DIR [--lane N] [--compile-fault N]");
            println!("         (internal: process-lane entrypoint, spawned by --proc fleets)");
        }
    }
    Ok(())
}
