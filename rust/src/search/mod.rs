//! Phase 2 — mixed-precision configuration search (paper §3.3, §3.6,
//! Algorithm 1).
//!
//! The sensitivity list (Phase 1) defines a *flip sequence*: starting from
//! the all-baseline assignment, walk entries from least to most sensitive
//! and flip a group whenever the entry's candidate strictly reduces that
//! group's BOPs.  The resulting prefix family is the pareto curve.
//!
//! Three searches over that curve are implemented, matching Table 5:
//!
//! * [`sequential_accuracy`] — Algorithm 1 verbatim: evaluate after every
//!   flip, stop on budget violation. `O(L·M)` evaluations.
//! * [`binary_accuracy`] — binary search on the prefix length
//!   (`O(log₂ L·M)`), exploiting the curve's monotonicity.
//! * [`hybrid_accuracy`] — the paper's binary + interpolation scheme
//!   (Fig. 1): two binary steps split the curve into quarters, then
//!   interpolation search runs on the remaining piece-wise-linear segment.
//!
//! BOPs-budget search ([`bops_budget`]) needs no evaluations at all until
//! the final report — flipping is pure ledger arithmetic.

use crate::bops;
use crate::groups::{Assignment, Candidate, Lattice};
use crate::manifest::ModelEntry;
use crate::model::{EvalSet, ModelHandle, WeightOverrides};
use crate::sensitivity::{RoundedWeights, SensEntry};
use crate::util::Timer;
use anyhow::Result;

/// One applied flip.
#[derive(Clone, Debug)]
pub struct FlipStep {
    pub group: usize,
    pub cand: Candidate,
    /// relative BOPs after this flip
    pub rel_bops: f64,
    /// the Phase-1 score that ordered this flip
    pub score: f64,
}

/// Search outcome.
#[derive(Clone, Debug)]
pub struct SearchRun {
    pub assignment: Assignment,
    pub applied: Vec<FlipStep>,
    pub final_rel_bops: f64,
    pub final_metric: f64,
    /// number of full eval-set metric evaluations performed
    pub evals: usize,
    pub wall_secs: f64,
    /// (rel_bops, metric) after each evaluated step — the pareto curve
    pub curve: Vec<(f64, f64)>,
}

/// Materialize the flip sequence from a sorted sensitivity list.
///
/// Skips entries that do not strictly reduce the group's current BOPs
/// (Algorithm 1 only ever lowers precision).
pub fn flip_sequence(
    entry: &ModelEntry,
    lattice: &Lattice,
    sens: &[SensEntry],
) -> Vec<FlipStep> {
    let mut asg = Assignment::baseline(entry, lattice);
    let mut steps = Vec::new();
    for e in sens {
        if !Assignment::flippable(entry, e.group) {
            continue;
        }
        if bops::flip_gain(entry, &asg, e.group, e.cand) == 0 {
            continue;
        }
        asg.set(e.group, e.cand);
        steps.push(FlipStep {
            group: e.group,
            cand: e.cand,
            rel_bops: bops::rel_bops(entry, &asg),
            score: e.score,
        });
    }
    steps
}

/// Assignment after applying the first `k` flips.
pub fn assignment_at(
    entry: &ModelEntry,
    lattice: &Lattice,
    flips: &[FlipStep],
    k: usize,
) -> Assignment {
    let mut asg = Assignment::baseline(entry, lattice);
    for s in &flips[..k.min(flips.len())] {
        asg.set(s.group, s.cand);
    }
    asg
}

/// Shared context for the accuracy-target searches.
pub struct SearchCtx<'a> {
    pub handle: &'a ModelHandle,
    pub lattice: &'a Lattice,
    pub flips: &'a [FlipStep],
    pub set: &'a EvalSet,
    /// AdaRounded weights to stitch per configuration (§3.5)
    pub rounded: Option<&'a RoundedWeights>,
}

impl<'a> SearchCtx<'a> {
    /// Metric of the k-flip prefix configuration.
    pub fn eval_at(&self, k: usize) -> Result<f64> {
        let asg = assignment_at(&self.handle.entry, self.lattice, self.flips, k);
        let (act, w) = asg.per_quantizer(&self.handle.entry);
        let cfg = crate::model::QuantConfig { act, w };
        let ov = self.overrides_for(&asg);
        let cb = self.handle.config_buffers(&cfg, &ov)?;
        self.handle.eval_metric(self.set, &cb)
    }

    /// Stitch AdaRounded weights matching each parameter's current bits.
    fn overrides_for(&self, asg: &Assignment) -> WeightOverrides {
        let mut ov = WeightOverrides::new();
        if let Some(rounded) = self.rounded {
            let (_, wbits) = asg.per_quantizer(&self.handle.entry);
            for (i, wq) in self.handle.entry.w_quantizers.iter().enumerate() {
                if let Some(bits) = wbits[i] {
                    if let Some(t) = rounded.get(&(wq.param_idx, bits)) {
                        ov.insert(wq.param_idx, t.clone());
                    }
                }
            }
        }
        ov
    }

    fn finish(&self, k: usize, evals: usize, t: &Timer, curve: Vec<(f64, f64)>) -> Result<SearchRun> {
        let asg = assignment_at(&self.handle.entry, self.lattice, self.flips, k);
        let final_metric = self.eval_at(k)?;
        Ok(SearchRun {
            final_rel_bops: bops::rel_bops(&self.handle.entry, &asg),
            assignment: asg,
            applied: self.flips[..k].to_vec(),
            final_metric,
            evals: evals + 1,
            wall_secs: t.secs(),
            curve,
        })
    }
}

/// Efficiency-budget search (§3.3.1): flip until `r ≤ budget`.  Pure ledger
/// walk — a single final metric evaluation.
pub fn bops_budget(ctx: &SearchCtx, budget_r: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut k = 0;
    while k < ctx.flips.len() && ctx.flips[k].rel_bops - budget_r > 1e-12 {
        k += 1;
    }
    // ctx.flips[k-1].rel_bops > budget means even all flips didn't reach it;
    // use as many as available.
    if k < ctx.flips.len() {
        k += 1; // include the flip that crossed the budget
    }
    ctx.finish(k, 0, &t, vec![])
}

/// Full pareto sweep: evaluate after *every* flip (used to draw Fig. 2/4/5
/// curves).  Returns the complete curve.
pub fn full_curve(ctx: &SearchCtx) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::with_capacity(ctx.flips.len() + 1);
    let m0 = ctx.eval_at(0)?;
    curve.push((1.0, m0));
    for k in 1..=ctx.flips.len() {
        let m = ctx.eval_at(k)?;
        curve.push((ctx.flips[k - 1].rel_bops, m));
    }
    let k = ctx.flips.len();
    let evals = curve.len();
    ctx.finish(k, evals, &t, curve)
}

/// Task-performance budget, sequential scheme (Algorithm 1): stop at the
/// first flip whose metric violates `target`, return the previous model.
pub fn sequential_accuracy(ctx: &SearchCtx, target: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::new();
    let mut evals = 0usize;
    let mut best_k = 0usize;
    for k in 1..=ctx.flips.len() {
        let m = ctx.eval_at(k)?;
        evals += 1;
        curve.push((ctx.flips[k - 1].rel_bops, m));
        if m < target {
            break;
        }
        best_k = k;
    }
    ctx.finish(best_k, evals, &t, curve)
}

/// Binary search on the prefix length (§3.6): `O(log₂(LM))` evaluations.
/// Finds the largest `k` with `metric(k) ≥ target`, assuming monotonicity.
pub fn binary_accuracy(ctx: &SearchCtx, target: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::new();
    let mut evals = 0usize;
    let (mut lo, mut hi) = (0usize, ctx.flips.len()); // metric(lo) ≥ target invariant
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let m = ctx.eval_at(mid)?;
        evals += 1;
        let r = if mid == 0 { 1.0 } else { ctx.flips[mid - 1].rel_bops };
        curve.push((r, m));
        if m >= target {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    ctx.finish(lo, evals, &t, curve)
}

/// Binary + interpolation hybrid (§3.6, Fig. 1): two binary steps cut the
/// `L·M`-point curve into a `⌈LM/4⌉`-point segment, then interpolation
/// search (Peterson 1957) exploits the segment's near-linearity.
pub fn hybrid_accuracy(ctx: &SearchCtx, target: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::new();
    let mut evals = 0usize;

    let n = ctx.flips.len();
    let mut lo = 0usize; // metric(lo) ≥ target
    let mut hi = n; //  first index where metric may be < target
    let mut m_lo = ctx.eval_at(0)?;
    evals += 1;
    curve.push((1.0, m_lo));
    let mut m_hi = ctx.eval_at(n)?;
    evals += 1;
    curve.push((if n == 0 { 1.0 } else { ctx.flips[n - 1].rel_bops }, m_hi));
    if m_hi >= target {
        return ctx.finish(n, evals, &t, curve);
    }

    // two binary steps → quarter segment
    for _ in 0..2 {
        if hi - lo <= 1 {
            break;
        }
        let mid = (lo + hi) / 2;
        let m = ctx.eval_at(mid)?;
        evals += 1;
        curve.push((ctx.flips[mid.max(1) - 1].rel_bops, m));
        if m >= target {
            lo = mid;
            m_lo = m;
        } else {
            hi = mid;
            m_hi = m;
        }
    }

    // interpolation search on [lo, hi)
    while hi - lo > 1 {
        let span = hi - lo;
        let denom = (m_hi - m_lo).abs().max(1e-9);
        let frac = ((m_lo - target) / denom).clamp(0.0, 1.0);
        let mut probe = lo + ((span as f64) * frac) as usize;
        probe = probe.clamp(lo + 1, hi - 1);
        let m = ctx.eval_at(probe)?;
        evals += 1;
        curve.push((ctx.flips[probe - 1].rel_bops, m));
        if m >= target {
            lo = probe;
            m_lo = m;
        } else {
            hi = probe;
            m_hi = m;
        }
    }
    ctx.finish(lo, evals, &t, curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bops::tests_support::toy_entry;
    use crate::sensitivity::SensEntry;

    fn sens(entries: &[(usize, u8, u8, f64)]) -> Vec<SensEntry> {
        entries
            .iter()
            .map(|&(g, w, a, s)| SensEntry {
                group: g,
                cand: Candidate::new(w, a),
                score: s,
            })
            .collect()
    }

    #[test]
    fn flip_sequence_monotone_bops() {
        let e = toy_entry();
        let l = Lattice::practical();
        let s = sens(&[
            (1, 8, 8, 50.0),
            (0, 8, 8, 40.0),
            (1, 4, 8, 30.0),
            (0, 4, 8, 20.0),
        ]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 4);
        for w in f.windows(2) {
            assert!(w[1].rel_bops < w[0].rel_bops);
        }
        // final assignment: both groups at W4A8 → r = 0.25
        assert!((f.last().unwrap().rel_bops - 0.25).abs() < 1e-12);
    }

    #[test]
    fn flip_sequence_skips_non_improving() {
        let e = toy_entry();
        let l = Lattice::practical();
        // second entry tries to move group 1 back up — must be skipped
        let s = sens(&[(1, 4, 8, 50.0), (1, 8, 8, 45.0), (0, 8, 8, 40.0)]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].cand, Candidate::new(4, 8));
        assert_eq!(f[1].group, 0);
    }

    #[test]
    fn flip_sequence_ignores_weightless_groups() {
        let e = toy_entry();
        let l = Lattice::practical();
        let s = sens(&[(2, 4, 8, 99.0), (0, 8, 8, 1.0)]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].group, 0);
    }

    #[test]
    fn assignment_at_prefixes() {
        let e = toy_entry();
        let l = Lattice::practical();
        let s = sens(&[(1, 8, 8, 50.0), (0, 4, 8, 40.0)]);
        let f = flip_sequence(&e, &l, &s);
        let a0 = assignment_at(&e, &l, &f, 0);
        assert_eq!(a0, Assignment::baseline(&e, &l));
        let a2 = assignment_at(&e, &l, &f, 2);
        assert_eq!(a2.per_group[1], Candidate::new(8, 8));
        assert_eq!(a2.per_group[0], Candidate::new(4, 8));
    }
}
