//! Phase 2 — mixed-precision configuration search (paper §3.3, §3.6,
//! Algorithm 1).
//!
//! The sensitivity list (Phase 1) defines a *flip sequence*: starting from
//! the all-baseline assignment, walk entries from least to most sensitive
//! and flip a group whenever the entry's candidate strictly reduces that
//! group's BOPs.  The resulting prefix family is the pareto curve.
//!
//! Three searches over that curve are implemented, matching Table 5:
//!
//! * [`sequential_accuracy`] — Algorithm 1 verbatim: evaluate after every
//!   flip, stop on budget violation. `O(L·M)` evaluations.
//! * [`binary_accuracy`] — binary search on the prefix length
//!   (`O(log₂ L·M)`), exploiting the curve's monotonicity.
//! * [`hybrid_accuracy`] — the paper's binary + interpolation scheme
//!   (Fig. 1): two binary steps split the curve into quarters, then
//!   interpolation search runs on the remaining piece-wise-linear segment.
//!
//! BOPs-budget search ([`bops_budget`]) needs no evaluations at all until
//! the final report — flipping is pure ledger arithmetic.
//!
//! All prefix metrics run through the memoizing streaming
//! [`crate::engine::Evaluator`] owned by [`SearchCtx`], and prefix
//! assignments are maintained incrementally by a [`PrefixCursor`], so
//! re-visited prefixes (including the final report) cost zero additional
//! forward calls and `SearchRun::evals` counts *distinct* evaluations.
//!
//! Every search here is *sequential by nature* — the next prefix to probe
//! depends on the previous metric — so probe-level parallelism can't help.
//! [`SearchCtx::with_pool`] instead routes each prefix evaluation through
//! an [`crate::pool::EvalPool`], which splits the eval set across N PJRT
//! clients: the critical path stays one probe long but each probe costs
//! `1/N` of a sweep.  The pool's memo replaces the per-run [`Evaluator`]
//! memo (and persists across runs on the same pool), with identical
//! results for the counting metrics.
//!
//! With [`SearchCtx::with_journal`] attached, every evaluated prefix
//! metric is additionally appended to the crash-safe run journal (keyed
//! by the search-scope content digest + the prefix length `k`), and a
//! `--resume` run serves journaled prefixes back bit-exactly before
//! touching the engine or the pool — the search replays its own decision
//! sequence and continues from the first unevaluated prefix.

use crate::bops;
use crate::engine::Evaluator;
use crate::groups::{Assignment, Candidate, Lattice};
use crate::manifest::ModelEntry;
use crate::model::{EvalSet, ModelHandle, QuantConfig, WeightOverrides};
use crate::pool::{EvalPool, ProbeKind, SetKey};
use crate::sensitivity::{RoundedWeights, SensEntry};
use crate::store::{self, JournalScope};
use crate::util::Timer;
use anyhow::Result;
use std::cell::RefCell;

/// One applied flip.
#[derive(Clone, Debug)]
pub struct FlipStep {
    pub group: usize,
    pub cand: Candidate,
    /// candidate the group held *before* this flip — lets a
    /// [`PrefixCursor`] rewind without replaying the whole prefix
    pub prev: Candidate,
    /// relative BOPs after this flip
    pub rel_bops: f64,
    /// the Phase-1 score that ordered this flip
    pub score: f64,
}

/// Search outcome.
#[derive(Clone, Debug)]
pub struct SearchRun {
    pub assignment: Assignment,
    pub applied: Vec<FlipStep>,
    pub final_rel_bops: f64,
    pub final_metric: f64,
    /// number of *distinct* full eval-set metric evaluations performed —
    /// re-visits of an already-measured prefix are memo hits, not evals
    pub evals: usize,
    /// evaluations served from the engine memo (Table-5 accounting)
    pub memo_hits: usize,
    pub wall_secs: f64,
    /// (rel_bops, metric) after each evaluated step — the pareto curve
    pub curve: Vec<(f64, f64)>,
}

/// Materialize the flip sequence from a sorted sensitivity list.
///
/// Skips entries that do not strictly reduce the group's current BOPs
/// (Algorithm 1 only ever lowers precision).
pub fn flip_sequence(
    entry: &ModelEntry,
    lattice: &Lattice,
    sens: &[SensEntry],
) -> Vec<FlipStep> {
    let mut asg = Assignment::baseline(entry, lattice);
    let mut steps = Vec::new();
    for e in sens {
        if !Assignment::flippable(entry, e.group) {
            continue;
        }
        if bops::flip_gain(entry, &asg, e.group, e.cand) == 0 {
            continue;
        }
        let prev = asg.per_group[e.group];
        asg.set(e.group, e.cand);
        steps.push(FlipStep {
            group: e.group,
            cand: e.cand,
            prev,
            rel_bops: bops::rel_bops(entry, &asg),
            score: e.score,
        });
    }
    steps
}

/// Assignment after applying the first `k` flips — the from-scratch
/// reference; the searches themselves use a [`PrefixCursor`].
pub fn assignment_at(
    entry: &ModelEntry,
    lattice: &Lattice,
    flips: &[FlipStep],
    k: usize,
) -> Assignment {
    let mut asg = Assignment::baseline(entry, lattice);
    for s in &flips[..k.min(flips.len())] {
        asg.set(s.group, s.cand);
    }
    asg
}

/// Incrementally maintained prefix assignment: `seek(k)` applies or rewinds
/// only the `|k − k'|` flips between positions instead of replaying all `k`
/// from the baseline — the binary and interpolation searches jump around
/// the curve, and the from-scratch walk made every probe `O(k)`.
pub struct PrefixCursor {
    asg: Assignment,
    k: usize,
}

impl PrefixCursor {
    pub fn new(entry: &ModelEntry, lattice: &Lattice) -> Self {
        Self { asg: Assignment::baseline(entry, lattice), k: 0 }
    }

    /// The assignment after the first `k` flips (clamped to `flips.len()`).
    pub fn seek(&mut self, flips: &[FlipStep], k: usize) -> &Assignment {
        let k = k.min(flips.len());
        while self.k < k {
            let s = &flips[self.k];
            self.asg.set(s.group, s.cand);
            self.k += 1;
        }
        while self.k > k {
            self.k -= 1;
            let s = &flips[self.k];
            self.asg.set(s.group, s.prev);
        }
        &self.asg
    }
}

/// Shared context for the accuracy-target searches.
///
/// Every prefix evaluation routes through one [`Evaluator`]: metrics stream
/// batch-by-batch and are memoized by the canonical configuration, so a
/// prefix the search already measured — including the final report in
/// `finish` — never re-runs the eval set.  The evaluator is
/// per-context, keeping `evals`/`memo_hits` per-run (Table 5).
pub struct SearchCtx<'a> {
    pub handle: &'a ModelHandle,
    pub lattice: &'a Lattice,
    pub flips: &'a [FlipStep],
    pub set: &'a EvalSet,
    /// AdaRounded weights to stitch per configuration (§3.5)
    pub rounded: Option<&'a RoundedWeights>,
    /// the memoizing streaming evaluation engine (serial path)
    pub eval: Evaluator<'a>,
    /// shard-parallel dispatch: the pool plus the key the eval set is
    /// registered under (None = serial single-client path)
    pool: Option<(&'a EvalPool, SetKey)>,
    /// pool (misses, hits) at context creation — run counters are deltas
    pool_base: (usize, usize),
    cursor: RefCell<PrefixCursor>,
    /// run journal scoped to this search (model/data/lattice/flip-sequence
    /// digest): every evaluated prefix metric is appended as a barrier and
    /// `--resume` serves it back without touching the engine or the pool
    journal: Option<JournalScope>,
}

impl<'a> SearchCtx<'a> {
    pub fn new(
        handle: &'a ModelHandle,
        lattice: &'a Lattice,
        flips: &'a [FlipStep],
        set: &'a EvalSet,
        rounded: Option<&'a RoundedWeights>,
    ) -> Self {
        Self::with_pool(handle, lattice, flips, set, rounded, None)
    }

    /// Like [`Self::new`], but prefix metrics fan out over `pool`'s workers
    /// (`set` must already be loaded into the pool under the given key;
    /// `SearchRun` counters then come from the pool's memo instead of the
    /// per-run evaluator).
    pub fn with_pool(
        handle: &'a ModelHandle,
        lattice: &'a Lattice,
        flips: &'a [FlipStep],
        set: &'a EvalSet,
        rounded: Option<&'a RoundedWeights>,
        pool: Option<(&'a EvalPool, SetKey)>,
    ) -> Self {
        let pool_base = pool
            .map(|(p, _)| (p.probes_computed(), p.memo_hits()))
            .unwrap_or((0, 0));
        Self {
            cursor: RefCell::new(PrefixCursor::new(&handle.entry, lattice)),
            eval: Evaluator::new(handle, set),
            handle,
            lattice,
            flips,
            set,
            rounded,
            pool,
            pool_base,
            journal: None,
        }
    }

    /// Attach a run-journal scope: evaluated prefixes are journaled at
    /// `eval_key(scope.base, k)` and replayed on `--resume`.  Journal
    /// skips count as neither `evals` nor `memo_hits` — the counters keep
    /// describing what this process actually did.
    pub fn with_journal(mut self, scope: JournalScope) -> Self {
        self.journal = Some(scope);
        self
    }

    /// Canonical configuration of the k-flip prefix (incremental cursor).
    fn config_at(&self, k: usize) -> QuantConfig {
        let mut cur = self.cursor.borrow_mut();
        let (act, w) = cur.seek(self.flips, k).per_quantizer(&self.handle.entry);
        QuantConfig { act, w }
    }

    /// Metric of the k-flip prefix configuration (streamed + memoized),
    /// shard-parallel when a pool is attached, journal-replayed on resume.
    pub fn eval_at(&self, k: usize) -> Result<f64> {
        if let Some(j) = &self.journal {
            if let Some(m) = j
                .journal
                .lookup_f64(store::kind::SEARCH_EVAL, store::eval_key(j.base, k))
            {
                return Ok(m);
            }
        }
        let cfg = self.config_at(k);
        let ov = self.overrides_for(&cfg);
        let m = if let Some((pool, set)) = self.pool {
            pool.submit(set, ProbeKind::Metric, &cfg, &ov)?.wait()?
        } else {
            self.eval.metric(&cfg, &ov)?
        };
        if let Some(j) = &self.journal {
            j.journal
                .record_f64(store::kind::SEARCH_EVAL, store::eval_key(j.base, k), m)?;
        }
        Ok(m)
    }

    /// Distinct metric evaluations this run actually computed.
    fn run_evals(&self) -> usize {
        match self.pool {
            Some((p, _)) => p.probes_computed() - self.pool_base.0,
            None => self.eval.evals(),
        }
    }

    /// Evaluations this run served from a memo (the pool memo persists
    /// across runs, so earlier searches' prefixes also count as hits here).
    fn run_memo_hits(&self) -> usize {
        match self.pool {
            Some((p, _)) => p.memo_hits() - self.pool_base.1,
            None => self.eval.memo_hits(),
        }
    }

    /// Stitch AdaRounded weights matching each parameter's current bits.
    fn overrides_for(&self, cfg: &QuantConfig) -> WeightOverrides {
        let mut ov = WeightOverrides::new();
        if let Some(rounded) = self.rounded {
            for (i, wq) in self.handle.entry.w_quantizers.iter().enumerate() {
                if let Some(bits) = cfg.w[i] {
                    if let Some(t) = rounded.get(&(wq.param_idx, bits)) {
                        ov.insert(wq.param_idx, t.clone());
                    }
                }
            }
        }
        ov
    }

    fn finish(&self, k: usize, t: &Timer, curve: Vec<(f64, f64)>) -> Result<SearchRun> {
        // a winning prefix measured during the search is a memo hit here —
        // no extra eval-set pass, and `evals` stays the distinct count
        let final_metric = self.eval_at(k)?;
        let asg = assignment_at(&self.handle.entry, self.lattice, self.flips, k);
        Ok(SearchRun {
            final_rel_bops: bops::rel_bops(&self.handle.entry, &asg),
            assignment: asg,
            applied: self.flips[..k.min(self.flips.len())].to_vec(),
            final_metric,
            evals: self.run_evals(),
            memo_hits: self.run_memo_hits(),
            wall_secs: t.secs(),
            curve,
        })
    }
}

/// Efficiency-budget search (§3.3.1): flip until `r ≤ budget`.  Pure ledger
/// walk — a single final metric evaluation.
pub fn bops_budget(ctx: &SearchCtx, budget_r: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut k = 0;
    while k < ctx.flips.len() && ctx.flips[k].rel_bops - budget_r > 1e-12 {
        k += 1;
    }
    // ctx.flips[k-1].rel_bops > budget means even all flips didn't reach it;
    // use as many as available.
    if k < ctx.flips.len() {
        k += 1; // include the flip that crossed the budget
    }
    ctx.finish(k, &t, vec![])
}

/// Full pareto sweep: evaluate after *every* flip (used to draw Fig. 2/4/5
/// curves).  Returns the complete curve; the final report reuses the last
/// point's memoized metric, so `evals == flips.len() + 1`.
pub fn full_curve(ctx: &SearchCtx) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::with_capacity(ctx.flips.len() + 1);
    let m0 = ctx.eval_at(0)?;
    curve.push((1.0, m0));
    for k in 1..=ctx.flips.len() {
        let m = ctx.eval_at(k)?;
        curve.push((ctx.flips[k - 1].rel_bops, m));
    }
    ctx.finish(ctx.flips.len(), &t, curve)
}

/// Task-performance budget, sequential scheme (Algorithm 1): stop at the
/// first flip whose metric violates `target`, return the previous model.
pub fn sequential_accuracy(ctx: &SearchCtx, target: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::new();
    let mut best_k = 0usize;
    for k in 1..=ctx.flips.len() {
        let m = ctx.eval_at(k)?;
        curve.push((ctx.flips[k - 1].rel_bops, m));
        if m < target {
            break;
        }
        best_k = k;
    }
    ctx.finish(best_k, &t, curve)
}

/// Binary search on the prefix length (§3.6): `O(log₂(LM))` evaluations.
/// Finds the largest `k` with `metric(k) ≥ target`, assuming monotonicity.
/// With the memoized finish, a run costs at most `⌈log₂(L·M)⌉ + 1` distinct
/// prefix evaluations (the `+1` only when the winner is `k = 0`, which the
/// loop never probes).
pub fn binary_accuracy(ctx: &SearchCtx, target: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::new();
    let (mut lo, mut hi) = (0usize, ctx.flips.len()); // metric(lo) ≥ target invariant
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let m = ctx.eval_at(mid)?;
        let r = if mid == 0 { 1.0 } else { ctx.flips[mid - 1].rel_bops };
        curve.push((r, m));
        if m >= target {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    ctx.finish(lo, &t, curve)
}

/// Binary + interpolation hybrid (§3.6, Fig. 1): two binary steps cut the
/// `L·M`-point curve into a `⌈LM/4⌉`-point segment, then interpolation
/// search (Peterson 1957) exploits the segment's near-linearity.
pub fn hybrid_accuracy(ctx: &SearchCtx, target: f64) -> Result<SearchRun> {
    let t = Timer::start();
    let mut curve = Vec::new();

    let n = ctx.flips.len();
    let mut lo = 0usize; // metric(lo) ≥ target
    let mut hi = n; //  first index where metric may be < target
    let mut m_lo = ctx.eval_at(0)?;
    curve.push((1.0, m_lo));
    let mut m_hi = ctx.eval_at(n)?;
    curve.push((if n == 0 { 1.0 } else { ctx.flips[n - 1].rel_bops }, m_hi));
    if m_hi >= target {
        return ctx.finish(n, &t, curve);
    }

    // two binary steps → quarter segment
    for _ in 0..2 {
        if hi - lo <= 1 {
            break;
        }
        let mid = (lo + hi) / 2;
        let m = ctx.eval_at(mid)?;
        curve.push((ctx.flips[mid.max(1) - 1].rel_bops, m));
        if m >= target {
            lo = mid;
            m_lo = m;
        } else {
            hi = mid;
            m_hi = m;
        }
    }

    // interpolation search on [lo, hi)
    while hi - lo > 1 {
        let span = hi - lo;
        let denom = (m_hi - m_lo).abs().max(1e-9);
        let frac = ((m_lo - target) / denom).clamp(0.0, 1.0);
        let mut probe = lo + ((span as f64) * frac) as usize;
        probe = probe.clamp(lo + 1, hi - 1);
        let m = ctx.eval_at(probe)?;
        curve.push((ctx.flips[probe - 1].rel_bops, m));
        if m >= target {
            lo = probe;
            m_lo = m;
        } else {
            hi = probe;
            m_hi = m;
        }
    }
    ctx.finish(lo, &t, curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bops::tests_support::toy_entry;
    use crate::sensitivity::SensEntry;

    fn sens(entries: &[(usize, u8, u8, f64)]) -> Vec<SensEntry> {
        entries
            .iter()
            .map(|&(g, w, a, s)| SensEntry {
                group: g,
                cand: Candidate::new(w, a),
                score: s,
            })
            .collect()
    }

    #[test]
    fn flip_sequence_monotone_bops() {
        let e = toy_entry();
        let l = Lattice::practical();
        let s = sens(&[
            (1, 8, 8, 50.0),
            (0, 8, 8, 40.0),
            (1, 4, 8, 30.0),
            (0, 4, 8, 20.0),
        ]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 4);
        for w in f.windows(2) {
            assert!(w[1].rel_bops < w[0].rel_bops);
        }
        // final assignment: both groups at W4A8 → r = 0.25
        assert!((f.last().unwrap().rel_bops - 0.25).abs() < 1e-12);
    }

    #[test]
    fn flip_sequence_skips_non_improving() {
        let e = toy_entry();
        let l = Lattice::practical();
        // second entry tries to move group 1 back up — must be skipped
        let s = sens(&[(1, 4, 8, 50.0), (1, 8, 8, 45.0), (0, 8, 8, 40.0)]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].cand, Candidate::new(4, 8));
        assert_eq!(f[1].group, 0);
    }

    #[test]
    fn flip_sequence_ignores_weightless_groups() {
        let e = toy_entry();
        let l = Lattice::practical();
        let s = sens(&[(2, 4, 8, 99.0), (0, 8, 8, 1.0)]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].group, 0);
    }

    #[test]
    fn assignment_at_prefixes() {
        let e = toy_entry();
        let l = Lattice::practical();
        let s = sens(&[(1, 8, 8, 50.0), (0, 4, 8, 40.0)]);
        let f = flip_sequence(&e, &l, &s);
        let a0 = assignment_at(&e, &l, &f, 0);
        assert_eq!(a0, Assignment::baseline(&e, &l));
        let a2 = assignment_at(&e, &l, &f, 2);
        assert_eq!(a2.per_group[1], Candidate::new(8, 8));
        assert_eq!(a2.per_group[0], Candidate::new(4, 8));
    }

    #[test]
    fn flip_sequence_records_previous_candidate() {
        let e = toy_entry();
        let l = Lattice::practical();
        // group 1 flips twice: baseline → W8A8 → W4A8
        let s = sens(&[(1, 8, 8, 50.0), (1, 4, 8, 30.0)]);
        let f = flip_sequence(&e, &l, &s);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].prev, l.baseline);
        assert_eq!(f[1].prev, Candidate::new(8, 8));
    }

    #[test]
    fn prefix_cursor_matches_assignment_at_under_random_seeks() {
        let e = toy_entry();
        let l = Lattice::expanded();
        let s = sens(&[
            (0, 8, 8, 90.0),
            (1, 8, 8, 80.0),
            (0, 6, 8, 70.0),
            (1, 6, 6, 60.0),
            (0, 4, 6, 50.0),
            (1, 4, 4, 40.0),
        ]);
        let f = flip_sequence(&e, &l, &s);
        assert!(f.len() >= 4, "toy sequence too short for the seek pattern");
        let mut cur = PrefixCursor::new(&e, &l);
        let mut rng = crate::util::Rng::new(0x5EEC);
        // binary-search-style jumps: forward, backward, repeats, extremes
        let mut ks: Vec<usize> = (0..40).map(|_| rng.below(f.len() + 1)).collect();
        ks.extend([0, f.len(), 0, f.len() / 2, f.len() / 2, f.len() + 7]);
        for k in ks {
            let got = cur.seek(&f, k).clone();
            let want = assignment_at(&e, &l, &f, k);
            assert_eq!(got, want, "cursor diverged at k={k}");
        }
    }
}
