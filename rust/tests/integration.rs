//! Tier-2 integration tests over the real artifacts (run `make artifacts`
//! first; tests skip gracefully when artifacts are absent so `cargo test`
//! stays green on a fresh checkout).  The hermetic tier-1 counterpart —
//! the same pipeline end-to-end on the pure-Rust sim backend, never
//! skipped — lives in `sim_e2e.rs`; see `tests/README.md`.
//!
//! These exercise the full L3→PJRT→L2→L1 stack on `resnet_s`, including the
//! cross-layer numerical contract: the Rust FP32 evaluation must reproduce
//! the validation metric the python build path recorded in the manifest.

use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::engine::Evaluator;
use mpq::groups::{Assignment, Candidate, Lattice};
use mpq::manifest::Manifest;
use mpq::model::QuantConfig;
use mpq::search::SearchCtx;
use mpq::sensitivity;
use std::collections::HashMap;

fn artifacts() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the pjrt feature (PJRT artifacts unusable)");
        return None;
    }
    let dir = mpq::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {}", dir.display());
        None
    }
}

macro_rules! skip_unless_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => return,
        }
    };
}

fn pipe(dir: &std::path::Path) -> Pipeline {
    let mut p = Pipeline::open(dir, "resnet_s").expect("open resnet_s");
    p.calibrate(128, 0).expect("calibrate");
    p
}

#[test]
fn manifest_loads_and_groups_partition() {
    let dir = skip_unless_artifacts!();
    let man = Manifest::load(&dir).unwrap();
    assert!(!man.models.is_empty());
    for m in &man.models {
        Assignment::validate_partition(m)
            .unwrap_or_else(|e| panic!("{}: {e}", m.name));
        assert!(m.total_macs > 0, "{} has no MACs", m.name);
        assert_eq!(
            m.total_macs,
            m.groups.iter().map(|g| g.macs).sum::<u64>(),
            "{}: group MACs don't sum to total",
            m.name
        );
        // every layer's weight quantizer groups together with its inputs
        for l in &m.layers {
            let gw = m
                .groups
                .iter()
                .position(|g| g.w_q.contains(&l.w_q))
                .expect("layer w_q in some group");
            for a in &l.in_acts {
                assert!(
                    m.groups[gw].act_q.contains(a),
                    "{}: layer {} input act {} not grouped with its weight",
                    m.name,
                    l.name,
                    a
                );
            }
        }
    }
}

#[test]
fn fp32_matches_python_build_path() {
    let dir = skip_unless_artifacts!();
    let mut p = pipe(&dir);
    let fp = p.eval_fp32().unwrap();
    let want = p.model.entry.fp32_val_metric;
    assert!(
        (fp - want).abs() < 5e-3,
        "rust fp32 {fp} != manifest {want} — cross-layer drift"
    );
}

#[test]
fn a16_is_near_lossless() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let set = p.calib_set().unwrap();
    let fp = sensitivity::fp_logits(&p.model, set).unwrap();
    let cfg = QuantConfig {
        act: vec![Some(16); p.model.entry.n_act()],
        w: vec![None; p.model.entry.n_w()],
    };
    let cb = p.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    let q = p.model.logits_on(set, &cb).unwrap();
    let s = sensitivity::sqnr_db(&fp, &q).unwrap();
    assert!(s > 55.0, "A16 SQNR only {s} dB — activation path broken");
}

#[test]
fn lower_bits_lower_sqnr() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let set = p.calib_set().unwrap();
    let fp = sensitivity::fp_logits(&p.model, set).unwrap();
    let mut at = |bits: u8| {
        let cfg = QuantConfig {
            act: vec![Some(bits); p.model.entry.n_act()],
            w: vec![None; p.model.entry.n_w()],
        };
        let cb = p.model.config_buffers(&cfg, &HashMap::new()).unwrap();
        let q = p.model.logits_on(set, &cb).unwrap();
        sensitivity::sqnr_db(&fp, &q).unwrap()
    };
    let (s4, s8, s16) = (at(4), at(8), at(16));
    assert!(s4 < s8 && s8 < s16, "SQNR not monotone: {s4} {s8} {s16}");
}

#[test]
fn probe_config_only_touches_group() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let cfg = sensitivity::probe_config(&p.model.entry, 1, Candidate::new(4, 8));
    let grp = &p.model.entry.groups[1];
    for (i, b) in cfg.act.iter().enumerate() {
        assert_eq!(b.is_some(), grp.act_q.contains(&i));
    }
    for (i, b) in cfg.w.iter().enumerate() {
        assert_eq!(b.is_some(), grp.w_q.contains(&i));
    }
}

#[test]
fn sensitivity_list_sorted_and_complete() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flippable = (0..p.model.entry.groups.len())
        .filter(|&g| Assignment::flippable(&p.model.entry, g))
        .count();
    assert_eq!(sens.len(), flippable * (lat.candidates.len() - 1));
    for w in sens.windows(2) {
        assert!(w[0].score >= w[1].score, "list not sorted");
    }
}

#[test]
fn bops_budget_search_respects_budget() {
    let dir = skip_unless_artifacts!();
    let mut p = pipe(&dir);
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flips = p.flips(&lat, &sens);
    let min_r = mpq::bops::min_rel_bops(&p.model.entry, &lat);
    for budget in [0.75, 0.5, 0.375] {
        let run = p
            .search_bops_budget(&lat, &flips, budget)
            .unwrap();
        assert!(
            run.final_rel_bops <= budget + 1e-9 || (run.final_rel_bops - min_r).abs() < 1e-9,
            "budget {budget} not met: r={}",
            run.final_rel_bops
        );
    }
}

#[test]
fn binary_matches_sequential_on_monotone_prefix() {
    let dir = skip_unless_artifacts!();
    let mut p = pipe(&dir);
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flips = p.flips(&lat, &sens);
    let fp = p.eval_fp32().unwrap();
    let target = fp - 0.02;
    let seq = p
        .search_accuracy_target(&lat, &flips, target, SearchScheme::Sequential, None)
        .unwrap();
    let bin = p
        .search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
        .unwrap();
    let hyb = p
        .search_accuracy_target(&lat, &flips, target, SearchScheme::Hybrid, None)
        .unwrap();
    // all three must satisfy the target…
    for (name, run) in [("seq", &seq), ("bin", &bin), ("hyb", &hyb)] {
        assert!(
            run.final_metric >= target - 1e-9,
            "{name} violates target: {} < {target}",
            run.final_metric
        );
    }
    // …and the faster schemes must use strictly fewer evaluations when the
    // sequential walk went deep
    if seq.evals > 8 {
        assert!(bin.evals < seq.evals, "binary not faster: {} vs {}", bin.evals, seq.evals);
        assert!(hyb.evals <= seq.evals);
    }
}

#[test]
fn mixed_beats_or_matches_fixed_at_same_bops() {
    let dir = skip_unless_artifacts!();
    let mut p = pipe(&dir);
    let lat = Lattice::practical();
    let w8a8 = p.eval_fixed(Candidate::new(8, 8), None).unwrap();
    let run = p.mixed_precision_for_budget(&lat, 0.5).unwrap();
    assert!(run.final_rel_bops <= 0.5 + 1e-9);
    assert!(
        run.final_metric >= w8a8 - 0.02,
        "MP {} much worse than fixed W8A8 {}",
        run.final_metric,
        w8a8
    );
}

#[test]
fn weight_override_changes_logits() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let set = p.calib_set().unwrap();
    let cfg = QuantConfig::fp32(&p.model.entry);
    let cb = p.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    let base = p.model.logits_on(set, &cb).unwrap();

    // zero out the first conv's weights via override
    let pidx = p.model.entry.w_quantizers[0].param_idx;
    let zero = mpq::tensor::Tensor::zeros(&p.model.entry.params[pidx].shape);
    let mut ov = HashMap::new();
    ov.insert(pidx, zero);
    let cb2 = p.model.config_buffers(&cfg, &ov).unwrap();
    let changed = p.model.logits_on(set, &cb2).unwrap();
    assert_ne!(base.f32s().unwrap(), changed.f32s().unwrap());
}

/// Engine contract: a full Phase-1 sensitivity sweep performs exactly
/// `1 + probes` forward-sweep-equivalents — one cached FP reference pass
/// plus one streamed pass per probe.
#[test]
fn phase1_sweep_costs_one_plus_probes_forward_sweeps() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let nb = p.calib_set().unwrap().batches.len() as u64;
    let lat = Lattice::practical();
    let fwd0 = *p.model.fwd_calls.borrow();
    assert_eq!(fwd0, 0, "calibration must not run the forward executable");
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let fwd1 = *p.model.fwd_calls.borrow();
    assert_eq!(
        fwd1 - fwd0,
        (1 + sens.len() as u64) * nb,
        "sweep not 1 + probes forward-sweep-equivalents"
    );
    // a second sweep reuses the cached reference: exactly `probes` sweeps
    let sens2 = p.sensitivity_sqnr(&lat).unwrap();
    let fwd2 = *p.model.fwd_calls.borrow();
    assert_eq!(fwd2 - fwd1, sens2.len() as u64 * nb);
    assert!(p.model.engine.ref_hits.get() > 0);
}

/// Engine contract: repeating `eval_at(k)` for a measured prefix performs
/// zero additional forward calls (memoization).
#[test]
fn repeated_eval_at_costs_zero_forward_calls() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flips = p.flips(&lat, &sens);
    let set = p.calib_set().unwrap();
    let ctx = SearchCtx::new(&p.model, &lat, &flips, set, None);
    let k = flips.len().min(2);
    let m1 = ctx.eval_at(k).unwrap();
    let fwd = *p.model.fwd_calls.borrow();
    let m2 = ctx.eval_at(k).unwrap();
    assert_eq!(m1, m2);
    assert_eq!(*p.model.fwd_calls.borrow(), fwd, "memoized eval ran forwards");
    assert_eq!(ctx.eval.evals(), 1);
    assert_eq!(ctx.eval.memo_hits(), 1);
}

/// Regression: `finish` reuses an already-measured winning prefix, so the
/// eval counts are pinned — `bops_budget` = 1, `full_curve` = L+1, and
/// `binary_accuracy` + finish ≤ ⌈log₂(L·M)⌉ + 1 — and `fwd_calls` agrees.
#[test]
fn search_eval_counts_pinned() {
    let dir = skip_unless_artifacts!();
    let mut p = pipe(&dir);
    p.limit_val(512, 7).unwrap();
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flips = p.flips(&lat, &sens);
    let nb_val = p.val_set().unwrap().batches.len() as u64;

    let fwd0 = *p.model.fwd_calls.borrow();
    let run = p.search_bops_budget(&lat, &flips, 0.5).unwrap();
    assert_eq!(run.evals, 1, "bops_budget needs exactly one final eval");
    assert_eq!(*p.model.fwd_calls.borrow() - fwd0, nb_val);

    let fwd1 = *p.model.fwd_calls.borrow();
    let curve = p.pareto_curve_val(&lat, &flips, None).unwrap();
    assert_eq!(curve.evals, flips.len() + 1, "full_curve must not re-eval in finish");
    assert_eq!(curve.memo_hits, 1);
    assert_eq!(*p.model.fwd_calls.borrow() - fwd1, (flips.len() as u64 + 1) * nb_val);

    let fp = p.eval_fp32().unwrap();
    let fwd2 = *p.model.fwd_calls.borrow();
    let bin = p
        .search_accuracy_target(&lat, &flips, fp - 0.02, SearchScheme::Binary, None)
        .unwrap();
    let bound = ((flips.len() + 1) as f64).log2().ceil() as usize + 1;
    assert!(
        bin.evals <= bound,
        "binary + finish used {} distinct evals, bound {bound}",
        bin.evals
    );
    assert_eq!(*p.model.fwd_calls.borrow() - fwd2, bin.evals as u64 * nb_val);
}

/// Streaming SQNR through the engine equals `sqnr_db` on concatenated
/// logits on the real artifacts, to 1e-9.
#[test]
fn streaming_sqnr_matches_concatenated_on_artifacts() {
    let dir = skip_unless_artifacts!();
    let p = pipe(&dir);
    let set = p.calib_set().unwrap();
    let fp = sensitivity::fp_logits(&p.model, set).unwrap();
    let cfg = QuantConfig::fixed(&p.model.entry, 8, 8);
    let cb = p.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    let q = p.model.logits_on(set, &cb).unwrap();
    let want = sensitivity::sqnr_db(&fp, &q).unwrap();
    let ev = Evaluator::new(&p.model, set);
    let got = ev.sqnr(&cfg, &HashMap::new()).unwrap();
    assert!(
        (got - want).abs() < 1e-9,
        "streaming {got} != concatenated {want}"
    );
}

#[test]
fn ood_calibration_runs() {
    let dir = skip_unless_artifacts!();
    let mut p = Pipeline::open(&dir, "resnet_s").unwrap();
    let x = p.model.data.ood_calib.clone().expect("ood data");
    let sub = x.slice_rows(0, 128).unwrap();
    p.calibrate_unlabeled(&sub).unwrap();
    let lat = Lattice::practical_no16();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert!(!sens.is_empty());
}

/// Acceptance: the evaluation pool must be *bit-identical* to the serial
/// single-client path, for any worker count.  An `EvalPool` with 1 and with
/// 4 workers must produce the same sensitivity list (same order, same score
/// bits) and the same Phase-2 chosen prefix as the serial search.
#[test]
fn pool_matches_serial_bit_for_bit() {
    let dir = skip_unless_artifacts!();
    let lat = Lattice::practical();

    // serial reference
    let mut sp = Pipeline::open(&dir, "resnet_s").unwrap();
    sp.calibrate(128, 0).unwrap();
    sp.limit_val(256, 7).unwrap();
    let ssens = sp.sensitivity_sqnr(&lat).unwrap();
    let sflips = sp.flips(&lat, &ssens);
    let sfp = sp.eval_fp32().unwrap();
    let srun = sp
        .search_accuracy_target(&lat, &sflips, sfp - 0.02, SearchScheme::Binary, None)
        .unwrap();

    for workers in [1usize, 4] {
        let mut p = Pipeline::open(&dir, "resnet_s").unwrap();
        p.enable_pool(workers).unwrap();
        p.calibrate(128, 0).unwrap();
        p.limit_val(256, 7).unwrap();
        let sens = p.sensitivity_sqnr(&lat).unwrap();
        assert_eq!(sens.len(), ssens.len(), "w={workers}");
        for (a, b) in sens.iter().zip(&ssens) {
            assert_eq!(a.group, b.group, "w={workers}: probe order diverged");
            assert_eq!(a.cand, b.cand, "w={workers}: probe order diverged");
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "w={workers}: score for (g{}, {:?}) differs: {} vs {}",
                a.group,
                a.cand,
                a.score,
                b.score
            );
        }
        let flips = p.flips(&lat, &sens);
        assert_eq!(flips.len(), sflips.len(), "w={workers}");
        let fp = p.eval_fp32().unwrap();
        assert_eq!(fp.to_bits(), sfp.to_bits(), "w={workers}: fp32 metric differs");
        let run = p
            .search_accuracy_target(&lat, &flips, fp - 0.02, SearchScheme::Binary, None)
            .unwrap();
        assert_eq!(
            run.applied.len(),
            srun.applied.len(),
            "w={workers}: chosen prefix differs"
        );
        assert_eq!(
            run.final_rel_bops.to_bits(),
            srun.final_rel_bops.to_bits(),
            "w={workers}"
        );
        assert_eq!(
            run.final_metric.to_bits(),
            srun.final_metric.to_bits(),
            "w={workers}"
        );
    }
}

/// The pool memo must make re-visited prefixes free across runs: a second
/// identical search computes zero new probes.
#[test]
fn pool_memo_is_shared_across_runs() {
    let dir = skip_unless_artifacts!();
    let lat = Lattice::practical();
    let mut p = Pipeline::open(&dir, "resnet_s").unwrap();
    p.enable_pool(2).unwrap();
    p.calibrate(128, 0).unwrap();
    p.limit_val(256, 7).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flips = p.flips(&lat, &sens);
    let fp = p.eval_fp32().unwrap();
    let first = p
        .search_accuracy_target(&lat, &flips, fp - 0.02, SearchScheme::Binary, None)
        .unwrap();
    assert!(first.evals > 0);
    let again = p
        .search_accuracy_target(&lat, &flips, fp - 0.02, SearchScheme::Binary, None)
        .unwrap();
    assert_eq!(again.evals, 0, "identical re-search must be all memo hits");
    assert!(again.memo_hits > 0);
    assert_eq!(again.final_metric.to_bits(), first.final_metric.to_bits());
}

/// EvalSet truncation contract on the real artifacts: a dataset subset that
/// is not a batch multiple truncates `n` and `labels` consistently.
#[test]
fn eval_set_truncates_ragged_subset_consistently() {
    let dir = skip_unless_artifacts!();
    let p = Pipeline::open(&dir, "resnet_s").unwrap();
    let batch = p.model.entry.batch;
    let ragged = batch + batch / 2 + 1; // strictly between 1 and 2 batches
    let ds = p.model.data.val.take(ragged).unwrap();
    let set = p.model.eval_set(&ds).unwrap();
    assert_eq!(set.batches.len(), ragged / batch);
    assert_eq!(set.n, (ragged / batch) * batch, "n must report truncated count");
    assert_eq!(set.labels.shape[0], set.n, "labels must truncate with inputs");
}

/// On-disk sensitivity cache: second sweep is served from disk without any
/// forward calls, bit-identically.
#[test]
fn sens_cache_skips_repeat_sweeps() {
    let dir = skip_unless_artifacts!();
    let cache = std::env::temp_dir().join("mpq_sens_cache_it");
    std::fs::remove_dir_all(&cache).ok();
    let lat = Lattice::practical();
    let mut p = pipe(&dir);
    p.set_sens_cache_dir(Some(cache.clone()));
    let first = p.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(p.sens_cache_stats(), (0, 1), "first sweep is a miss");
    let fwd = *p.model.fwd_calls.borrow();
    let second = p.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(p.sens_cache_stats(), (1, 1), "second sweep must hit");
    assert_eq!(*p.model.fwd_calls.borrow(), fwd, "cache hit must cost zero forwards");
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert_eq!((a.group, a.cand), (b.group, b.cand));
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "scores must round-trip");
    }
    std::fs::remove_dir_all(&cache).ok();
}
