//! Hermetic end-to-end tests for the **process-backed** worker fleet
//! (`EvalFleet::new_proc` → `mpq worker` subprocesses over Unix-socket
//! MPQJ frames; see `src/pool/transport.rs` and `src/pool/proc.rs`).
//!
//! These are the distributed-tier counterpart of the `sim_e2e.rs` pool
//! tests: the *same* Phase-1 sweep and Phase-2 searches, on the same
//! generated sim zoo, but with every worker lane running in its own OS
//! process.  The contract is unchanged — **bit-identical** to the serial
//! path at every lane count — plus real process supervision: a SIGKILLed
//! worker heals through the same death-notice → respawn → replay →
//! requeue machinery the thread lanes use, with byte-equal results.
//!
//! The worker executable is this crate's own `mpq` binary, resolved via
//! `MPQ_WORKER_BIN` (cargo builds it for integration tests and exposes
//! the path as `CARGO_BIN_EXE_mpq`).
//!
//! Deliberately absent: assertions on `fleet.model_opens()` or on death
//! reasons carrying the injected panic message.  Both counters live in
//! the child process for `--proc` lanes (the parent observes only the
//! socket closing), which the pool module docs call out as the two
//! telemetry caveats of process lanes.

use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::groups::Lattice;
use mpq::pool::{EvalFleet, FaultPlan};
use mpq::sensitivity::SensEntry;
use mpq::sim::{self, SimSpec};

const MODEL: &str = "sim_mlp";

/// Point the fleet at this test build's own `mpq` binary (once per
/// process; every test needs it before constructing a `--proc` fleet).
fn worker_bin_env() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var("MPQ_WORKER_BIN", env!("CARGO_BIN_EXE_mpq")));
}

/// `MPQ_FAULT_PLAN` in the environment (the chaos CI variant) injects
/// wire faults into every env-plan fleet, so restart/degradation counts
/// become schedule-dependent: exact-zero and exactly-once assertions only
/// hold without it.  Results must stay byte-equal either way.
fn env_faults() -> bool {
    std::env::var("MPQ_FAULT_PLAN").map(|s| !s.trim().is_empty()).unwrap_or(false)
}

/// Fresh sim artifacts under a per-test temp dir.
fn sim_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_dist_e2e_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    sim::generate(&dir, &SimSpec::default()).expect("generate sim artifacts");
    dir
}

fn serial_pipe(dir: &std::path::Path) -> Pipeline {
    let mut p = Pipeline::open(dir, MODEL).expect("open sim_mlp");
    p.calibrate(128, 0).expect("calibrate");
    p
}

/// Two Phase-1 lists agree in order and **bit-for-bit** scores.
fn assert_sens_bits(got: &[SensEntry], want: &[SensEntry], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: list length");
    for (a, b) in got.iter().zip(want) {
        assert_eq!((a.group, a.cand), (b.group, b.cand), "{tag}: order diverged");
        assert_eq!(
            a.score.to_bits(),
            b.score.to_bits(),
            "{tag}: score for (g{}, {:?}): {} vs {}",
            a.group,
            a.cand,
            a.score,
            b.score
        );
    }
}

/// The tentpole contract: Phase-1 sweeps and Phase-2 searches on process
/// lanes are **bit-identical** to the serial path at every lane count.
/// Every request/reply crosses the socket codec here — probes, set
/// uploads, reference build/fetch, fit, stats — so this is also the
/// end-to-end exercise of `pool/transport.rs` on real traffic.
#[test]
fn dist_proc_lanes_match_serial_bit_for_bit() {
    worker_bin_env();
    let dir = sim_dir("bits");
    let lat = Lattice::practical();

    // serial reference
    let mut sp = serial_pipe(&dir);
    let ssens = sp.sensitivity_sqnr(&lat).unwrap();
    let sflips = sp.flips(&lat, &ssens);
    let sfp = sp.eval_fp32().unwrap();
    let scurve = sp.pareto_curve_val(&lat, &sflips, None).unwrap();
    let target = (sfp + scurve.curve.iter().map(|&(_, m)| m).fold(f64::INFINITY, f64::min)) / 2.0;
    let srun = sp
        .search_accuracy_target(&lat, &sflips, target, SearchScheme::Binary, None)
        .unwrap();

    for workers in [1usize, 2, 4] {
        let fleet = EvalFleet::new_proc(&dir, workers).unwrap();
        let mut p = Pipeline::open(&dir, MODEL).unwrap();
        p.attach_fleet(&fleet).unwrap();
        p.calibrate(128, 0).unwrap();

        let pids = fleet.proc_pids();
        assert_eq!(pids.len(), workers, "w={workers}: one lane per worker");
        assert!(
            pids.iter().all(|p| p.is_some()),
            "w={workers}: every lane must be process-backed, got {pids:?}"
        );

        let sens = p.sensitivity_sqnr(&lat).unwrap();
        assert_sens_bits(&sens, &ssens, &format!("w={workers} sweep"));

        let flips = p.flips(&lat, &sens);
        assert_eq!(flips.len(), sflips.len(), "w={workers}");
        let fp = p.eval_fp32().unwrap();
        assert_eq!(fp.to_bits(), sfp.to_bits(), "w={workers}: fp32 metric differs");

        let curve = p.pareto_curve_val(&lat, &flips, None).unwrap();
        assert_eq!(curve.curve.len(), scurve.curve.len(), "w={workers}");
        for ((r1, m1), (r2, m2)) in curve.curve.iter().zip(&scurve.curve) {
            assert_eq!(r1.to_bits(), r2.to_bits(), "w={workers}: curve r differs");
            assert_eq!(m1.to_bits(), m2.to_bits(), "w={workers}: curve metric differs");
        }

        let run = p
            .search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
            .unwrap();
        assert_eq!(run.applied.len(), srun.applied.len(), "w={workers}: chosen prefix");
        for (a, b) in run.applied.iter().zip(&srun.applied) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "w={workers}: applied flips");
        }
        assert_eq!(run.final_rel_bops.to_bits(), srun.final_rel_bops.to_bits(), "w={workers}");
        assert_eq!(run.final_metric.to_bits(), srun.final_metric.to_bits(), "w={workers}");

        // worker stats cross the wire too (Stats request / reply codec);
        // per-child model counts are accurate — each child opened the one
        // attached model
        let stats = fleet.worker_stats().unwrap();
        assert_eq!(stats.len(), workers, "w={workers}");
        assert!(
            stats.iter().all(|s| s.models_open == 1),
            "w={workers}: each child serves exactly one model"
        );

        let fs = fleet.failure_stats();
        if !env_faults() {
            assert_eq!(fs.worker_restarts, 0, "w={workers}: clean run must not respawn");
        }
        assert!(fs.degraded_events.is_empty(), "w={workers}");
    }
}

/// Resizing a process-lane fleet mid-run spawns/reaps real subprocesses
/// and replays host state into the newcomers; sweeps stay bit-identical
/// through a grow and a shrink.
#[test]
fn dist_proc_fleet_resize_mid_run() {
    worker_bin_env();
    let dir = sim_dir("resize");
    let lat = Lattice::practical();
    let serial = serial_pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    let fleet = EvalFleet::new_proc(&dir, 1).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let check = |p: &Pipeline, tag: &str| {
        p.clear_eval_memo();
        let sens = p.sensitivity_sqnr(&lat).unwrap();
        assert_sens_bits(&sens, &serial, tag);
    };
    check(&p, "w=1 before resize");
    fleet.resize(3).unwrap();
    assert_eq!(fleet.workers(), 3);
    assert!(fleet.proc_pids().iter().all(|p| p.is_some()), "grown lanes are processes");
    check(&p, "after grow to 3");
    fleet.resize(2).unwrap();
    assert_eq!(fleet.workers(), 2);
    check(&p, "after shrink to 2");
    // Phase 2 still works across a resize (val set re-sharded too)
    let flips = p.flips(&lat, &serial);
    let run = p.search_bops_budget(&lat, &flips, 0.5).unwrap();
    assert!(run.final_metric.is_finite());
}

/// The acceptance SIGKILL: a worker **process** is killed dead from the
/// outside (no cooperation, no unwinding — the hardest death a thread
/// lane can't even express).  The feeder/reader bridge turns the closed
/// socket into a death notice; the supervisor respawns the lane, replays
/// its host state (calibration shard, reference), requeues what the dead
/// incarnation owed, and the sweep finishes **byte-equal** to serial with
/// exactly one restart.
#[test]
fn dist_proc_fleet_survives_sigkill_mid_sweep() {
    worker_bin_env();
    let dir = sim_dir("sigkill");
    let lat = Lattice::practical();
    let serial = serial_pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    let fleet = EvalFleet::new_proc(&dir, 4).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();

    // murder lane 1 after calibration has pushed host state everywhere
    let victim = fleet.proc_pids()[1].expect("lane 1 is process-backed");
    let status = std::process::Command::new("kill")
        .args(["-9", &victim.to_string()])
        .status()
        .expect("spawn kill");
    assert!(status.success(), "kill -9 {victim} failed");

    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &serial, "post-SIGKILL sweep");

    let fs = fleet.failure_stats();
    if env_faults() {
        assert!(fs.worker_restarts >= 1, "the SIGKILL must respawn a lane: {fs:?}");
    } else {
        assert_eq!(fs.worker_restarts, 1, "one respawn heals the fleet: {fs:?}");
    }
    assert!(fs.degraded_events.is_empty(), "death within budget must not degrade");
    assert_eq!(fleet.workers(), 4, "fleet back at full strength");
    assert!(
        fs.last_deaths.iter().any(|d| d.contains("worker process")),
        "death reason must name the process exit: {:?}",
        fs.last_deaths
    );
    assert!(
        fleet.proc_pids().iter().all(|p| p.is_some()),
        "the replacement lane must be process-backed too"
    );

    // the healed fleet keeps serving fresh sweeps exactly
    p.clear_eval_memo();
    let again = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&again, &serial, "re-sweep on the healed fleet");
    if !env_faults() {
        assert_eq!(fleet.failure_stats().worker_restarts, 1, "no further respawns");
    }
}

/// `panic@LANE:N` fault clauses extend to process lanes: the directive is
/// computed coordinator-side and shipped with the job; the child's panic
/// is deliberately uncaught, so the injected fault becomes a real process
/// death (exit 101 → socket EOF → death notice) and the supervisor heals
/// it like any other — byte-equal results, exactly one restart.
#[test]
fn dist_proc_fleet_heals_injected_panic() {
    worker_bin_env();
    let dir = sim_dir("panic");
    let lat = Lattice::practical();
    let serial = serial_pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    let plan = FaultPlan::parse("panic@1:3,backoff:0").unwrap();
    let fleet = EvalFleet::with_faults_proc(&dir, 4, plan).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &serial, "panic@1:3 proc w=4");

    let fs = fleet.failure_stats();
    assert_eq!(fs.faults_injected, 1, "the panic must fire exactly once: {fs:?}");
    assert_eq!(fs.worker_restarts, 1, "one respawn heals the fleet");
    assert!(fs.jobs_requeued > 0, "the dead process's slots must be requeued");
    assert!(fs.degraded_events.is_empty());
    assert_eq!(fleet.workers(), 4);
}

/// Per-lane latency faults (`slow@LANE:MS`) ship as directives too — a
/// continuously slowed process lane changes timing only, never bits.
/// This is what the `rust-hermetic-dist` CI variant relies on.
#[test]
fn dist_proc_fleet_exact_under_slow_lanes() {
    worker_bin_env();
    let dir = sim_dir("slow");
    let lat = Lattice::practical();
    let serial = serial_pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    let plan = FaultPlan::parse("slow@0:2,slow@1:5").unwrap();
    let fleet = EvalFleet::with_faults_proc(&dir, 2, plan).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &serial, "slow proc lanes");
    assert_eq!(fleet.failure_stats().worker_restarts, 0, "slow is not a death");
}
