//! Property-based tests on coordinator invariants (routing of flips,
//! ledger arithmetic, serialization), using seeded random generation from
//! `mpq::util::Rng` — the offline crate set has no `proptest`, so the
//! generator loop is explicit: 200 random cases per property.

use mpq::engine::StreamingSqnr;
use mpq::groups::{Assignment, Candidate, Lattice};
use mpq::jsonio::{self, Json};
use mpq::manifest::{ActQ, DataFiles, Group, Layer, ModelEntry, ParamInfo, WQ};
use mpq::metrics::{kendall_tau, PearsonAccum, StreamingTaskMetric};
use mpq::search::{assignment_at, flip_sequence, PrefixCursor};
use mpq::sensitivity::SensEntry;
use mpq::store;
use mpq::tensor::{io, Tensor};
use mpq::util::Rng;

const CASES: usize = 200;

/// Random model entry: `n_groups` weighted groups + one weightless.
fn random_entry(rng: &mut Rng) -> ModelEntry {
    let n = 2 + rng.below(10);
    let mut groups = Vec::new();
    let mut layers = Vec::new();
    let mut act_quantizers = Vec::new();
    let mut w_quantizers = Vec::new();
    let mut params = Vec::new();
    let mut total = 0u64;
    for g in 0..n {
        let macs = 100 + rng.below(10_000) as u64;
        total += macs;
        act_quantizers.push(ActQ { name: format!("a{g}"), numel: 64 });
        w_quantizers.push(WQ {
            name: format!("w{g}"),
            param_idx: g,
            channels: 4,
            channel_axis: 0,
        });
        params.push(ParamInfo { name: format!("w{g}"), shape: vec![4, 4] });
        layers.push(Layer { name: format!("l{g}"), macs, w_q: g, in_acts: vec![g] });
        groups.push(Group { w_q: vec![g], act_q: vec![g], macs });
    }
    act_quantizers.push(ActQ { name: "out".into(), numel: 10 });
    groups.push(Group { w_q: vec![], act_q: vec![n], macs: 0 });
    ModelEntry {
        name: "rand".into(),
        task: "classify10".into(),
        batch: 1,
        input_shape: vec![1],
        input_is_i32: false,
        forward: String::new(),
        stats: String::new(),
        stats_bits: vec![4, 8],
        stats_ratios: vec![1.0],
        weights_file: String::new(),
        params,
        out_shape: vec![1, 10],
        act_quantizers,
        w_quantizers,
        layers,
        groups,
        total_macs: total,
        cmax: 4,
        fp32_val_metric: 1.0,
        data: DataFiles {
            calib: String::new(),
            calib_labels: String::new(),
            val: String::new(),
            val_labels: String::new(),
            ood_calib: None,
        },
        taps: None,
        adaround: vec![],
        fit: None,
        fit_act_shapes: None,
    }
}

fn random_sens(rng: &mut Rng, entry: &ModelEntry, lat: &Lattice) -> Vec<SensEntry> {
    let mut out = Vec::new();
    for g in 0..entry.groups.len() {
        for &c in &lat.candidates {
            if c != lat.baseline {
                out.push(SensEntry { group: g, cand: c, score: rng.f64() * 100.0 });
            }
        }
    }
    out.sort_by(|a, b| b.score.total_cmp(&a.score));
    out
}

#[test]
fn flip_sequence_invariants() {
    let mut rng = Rng::new(0xF11);
    for case in 0..CASES {
        let entry = random_entry(&mut rng);
        let lat = if case % 2 == 0 { Lattice::practical() } else { Lattice::expanded() };
        let sens = random_sens(&mut rng, &entry, &lat);
        let flips = flip_sequence(&entry, &lat, &sens);
        // 1. strictly decreasing relative BOPs
        let mut prev = 1.0 + 1e-12;
        for f in &flips {
            assert!(f.rel_bops < prev, "BOPs not strictly decreasing");
            prev = f.rel_bops;
        }
        // 2. never flips weightless groups
        for f in &flips {
            assert!(Assignment::flippable(&entry, f.group));
        }
        // 3. per-group candidate factors strictly decrease over its flips
        let mut last: std::collections::HashMap<usize, u64> = Default::default();
        for f in &flips {
            let cur = f.cand.bops_factor();
            if let Some(&p) = last.get(&f.group) {
                assert!(cur < p, "group reflipped to non-cheaper candidate");
            }
            last.insert(f.group, cur);
        }
        // 4. the full prefix reaches the lattice minimum iff every weighted
        //    group was offered the cheapest candidate (it is, by enumeration)
        let final_asg = assignment_at(&entry, &lat, &flips, flips.len());
        let min_r = mpq::bops::min_rel_bops(&entry, &lat);
        assert!((mpq::bops::rel_bops(&entry, &final_asg) - min_r).abs() < 1e-9);
    }
}

/// The incremental prefix cursor must agree with the from-scratch
/// `assignment_at` under arbitrary forward/backward seek patterns (the
/// binary and interpolation searches jump around the curve), and every
/// flip's recorded `prev` must be the candidate the group actually held.
#[test]
fn prefix_cursor_equals_from_scratch_replay() {
    let mut rng = Rng::new(0xCC5);
    for case in 0..CASES {
        let entry = random_entry(&mut rng);
        let lat = if case % 2 == 0 { Lattice::practical() } else { Lattice::expanded() };
        let sens = random_sens(&mut rng, &entry, &lat);
        let flips = flip_sequence(&entry, &lat, &sens);
        // prev chains: each flip's prev equals the assignment right before it
        for (k, f) in flips.iter().enumerate() {
            let before = assignment_at(&entry, &lat, &flips, k);
            assert_eq!(f.prev, before.per_group[f.group], "prev wrong at flip {k}");
        }
        let mut cur = PrefixCursor::new(&entry, &lat);
        for _ in 0..20 {
            let k = rng.below(flips.len() + 2); // may exceed len (clamped)
            let got = cur.seek(&flips, k).clone();
            let want = assignment_at(&entry, &lat, &flips, k);
            assert_eq!(got, want, "cursor diverged at k={k}");
        }
    }
}

#[test]
fn assignment_prefix_is_monotone_in_k() {
    let mut rng = Rng::new(0xA55);
    for _ in 0..CASES {
        let entry = random_entry(&mut rng);
        let lat = Lattice::expanded();
        let sens = random_sens(&mut rng, &entry, &lat);
        let flips = flip_sequence(&entry, &lat, &sens);
        let mut prev_r = 1.0 + 1e-12;
        for k in 0..=flips.len() {
            let asg = assignment_at(&entry, &lat, &flips, k);
            let r = mpq::bops::rel_bops(&entry, &asg);
            assert!(r < prev_r || k == 0, "prefix r not strictly decreasing at k={k}");
            prev_r = r;
        }
    }
}

#[test]
fn bops_ledger_additivity() {
    let mut rng = Rng::new(0xB0B);
    for _ in 0..CASES {
        let entry = random_entry(&mut rng);
        let lat = Lattice::expanded();
        let mut asg = Assignment::baseline(&entry, &lat);
        let mut expect = mpq::bops::bops(&entry, &asg);
        // apply random flips, tracking gains
        for _ in 0..10 {
            let g = rng.below(entry.groups.len());
            let c = lat.candidates[rng.below(lat.candidates.len())];
            let gain = mpq::bops::flip_gain(&entry, &asg, g, c);
            if gain > 0 {
                asg.set(g, c);
                expect -= gain;
            }
            assert_eq!(mpq::bops::bops(&entry, &asg), expect, "ledger drift");
        }
    }
}

#[test]
fn per_quantizer_expansion_covers_everything() {
    let mut rng = Rng::new(0xC0C);
    for _ in 0..CASES {
        let entry = random_entry(&mut rng);
        let lat = Lattice::practical();
        let asg = Assignment::baseline(&entry, &lat);
        let (act, w) = asg.per_quantizer(&entry);
        assert!(act.iter().all(|b| b.is_some()));
        assert!(w.iter().all(|b| b.is_some()));
    }
}

#[test]
fn tensor_io_roundtrip_random() {
    let mut rng = Rng::new(0xD0D);
    let dir = std::env::temp_dir().join("mpq_prop_io");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..50 {
        let ndim = 1 + rng.below(4);
        let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(6)).collect();
        let n: usize = shape.iter().product();
        let t = if case % 2 == 0 {
            Tensor::from_f32(&shape, (0..n).map(|_| rng.f64() as f32 - 0.5).collect()).unwrap()
        } else {
            Tensor::from_i32(&shape, (0..n).map(|_| rng.below(1000) as i32 - 500).collect())
                .unwrap()
        };
        let p = dir.join(format!("t{case}.bin"));
        io::write_tensors(&p, std::slice::from_ref(&t)).unwrap();
        assert_eq!(io::read_tensors(&p).unwrap(), vec![t]);
    }
}

#[test]
fn json_roundtrip_random() {
    let mut rng = Rng::new(0xE0E);

    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(100000) as f64) / 8.0 - 1000.0),
            3 => Json::Str(format!("s{}✓\"\\\n", rng.below(100))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    for _ in 0..CASES {
        let j = gen(&mut rng, 3);
        let back = jsonio::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }
}

#[test]
fn kendall_tau_bounds_and_symmetry() {
    let mut rng = Rng::new(0xFAF);
    for _ in 0..CASES {
        let n = 3 + rng.below(30);
        let a: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let t = kendall_tau(&a, &b);
        assert!((-1.0..=1.0).contains(&t));
        assert!((kendall_tau(&b, &a) - t).abs() < 1e-12, "not symmetric");
        assert!((kendall_tau(&a, &a) - 1.0).abs() < 1e-12);
    }
}

/// Random shard assignment of `n` items over `k` shards (shards may be
/// empty, hold a single item, or hold everything) plus a random merge
/// order — the space of splits an [`mpq::pool::EvalPool`] can produce.
fn random_split(rng: &mut Rng, n: usize) -> (Vec<usize>, Vec<usize>) {
    let k = 1 + rng.below(n + 2); // sometimes more shards than items
    let assign: Vec<usize> = (0..n).map(|_| rng.below(k)).collect();
    let mut order: Vec<usize> = (0..k).collect();
    rng.shuffle(&mut order);
    (assign, order)
}

/// The pool exactness guarantee as a property: `StreamingSqnr` partials
/// keyed by global batch index, merged across *any* shard split in *any*
/// merge order — including empty and single-batch shards — are
/// bit-identical to the serial accumulator.
#[test]
fn streaming_sqnr_merge_any_split_any_order_is_bit_identical() {
    let mut rng = Rng::new(0x5A17);
    for _ in 0..CASES {
        let nb = 1 + rng.below(9);
        let bsz = 1 + rng.below(5);
        let c = 1 + rng.below(7);
        let mut serial = StreamingSqnr::new();
        let mut batches = Vec::new();
        for _ in 0..nb {
            let fp: Vec<f32> = (0..bsz * c).map(|_| rng.f64() as f32 * 4.0 - 2.0).collect();
            let q: Vec<f32> = fp
                .iter()
                .map(|&x| x + (rng.f64() as f32 - 0.5) * 0.1)
                .collect();
            let fp = Tensor::from_f32(&[bsz, c], fp).unwrap();
            let q = Tensor::from_f32(&[bsz, c], q).unwrap();
            // per-sample signal power, same f64 summation as FpReference
            let fv = fp.f32s().unwrap();
            let sig: Vec<f64> = (0..bsz)
                .map(|i| {
                    let mut s = 0f64;
                    for &x in &fv[i * c..(i + 1) * c] {
                        s += x as f64 * x as f64;
                    }
                    s
                })
                .collect();
            serial.push(&fp, &sig, &q).unwrap();
            batches.push((fp, sig, q));
        }
        let (assign, order) = random_split(&mut rng, nb);
        let k = order.len();
        let mut shards: Vec<StreamingSqnr> = (0..k).map(|_| StreamingSqnr::new()).collect();
        for (bi, (fp, sig, q)) in batches.iter().enumerate() {
            shards[assign[bi]].push_at(bi as u64, fp, sig, q).unwrap();
        }
        let mut merged = StreamingSqnr::new();
        for &s in &order {
            merged.merge(&shards[s]).unwrap();
        }
        assert_eq!(
            merged.db().to_bits(),
            serial.db().to_bits(),
            "nb={nb} k={k}: merged shards diverged from serial"
        );
    }
}

/// Same property for every task accumulator: counting metrics (top-1, F1,
/// mIoU) merge bit-identically across arbitrary splits and orders; the
/// Pearson head merges to the serial value within float rounding.
#[test]
fn task_metric_merge_any_split_any_order_matches_serial() {
    let mut rng = Rng::new(0x7A5C);
    for case in 0..60 {
        for task in ["classify10", "glue:mrpc_s", "glue:stsb_s", "seg"] {
            let nb = 1 + rng.below(7);
            let bsz = 1 + rng.below(5);
            let mut serial = StreamingTaskMetric::new(task).unwrap();
            let mut batches = Vec::new();
            for _ in 0..nb {
                let (logits, labels) = match task {
                    "seg" => {
                        let (c, h, w) = (3usize, 2usize, 2usize);
                        let lv: Vec<f32> =
                            (0..bsz * c * h * w).map(|_| rng.f64() as f32).collect();
                        let yv: Vec<i32> =
                            (0..bsz * h * w).map(|_| rng.below(c) as i32).collect();
                        (
                            Tensor::from_f32(&[bsz, c, h, w], lv).unwrap(),
                            Tensor::from_i32(&[bsz, h, w], yv).unwrap(),
                        )
                    }
                    "glue:stsb_s" => {
                        let lv: Vec<f32> = (0..bsz).map(|_| rng.f64() as f32 * 5.0).collect();
                        let yv: Vec<f32> =
                            lv.iter().map(|&x| x + rng.f64() as f32).collect();
                        (
                            Tensor::from_f32(&[bsz, 1], lv).unwrap(),
                            Tensor::from_f32(&[bsz], yv).unwrap(),
                        )
                    }
                    _ => {
                        let c = if task == "classify10" { 10 } else { 2 };
                        let lv: Vec<f32> = (0..bsz * c).map(|_| rng.f64() as f32).collect();
                        let yv: Vec<f32> = (0..bsz).map(|_| rng.below(c) as f32).collect();
                        (
                            Tensor::from_f32(&[bsz, c], lv).unwrap(),
                            Tensor::from_f32(&[bsz], yv).unwrap(),
                        )
                    }
                };
                serial.push(&logits, &labels).unwrap();
                batches.push((logits, labels));
            }
            let (assign, order) = random_split(&mut rng, nb);
            let mut shards: Vec<StreamingTaskMetric> = (0..order.len())
                .map(|_| StreamingTaskMetric::new(task).unwrap())
                .collect();
            for (bi, (l, y)) in batches.iter().enumerate() {
                shards[assign[bi]].push(l, y).unwrap();
            }
            let mut merged = StreamingTaskMetric::new(task).unwrap();
            for &s in &order {
                merged.merge(&shards[s]).unwrap();
            }
            let (got, want) = (merged.finalize(), serial.finalize());
            if task == "glue:stsb_s" {
                assert!(
                    (got - want).abs() < 1e-12,
                    "case {case} {task}: {got} vs {want}"
                );
            } else {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "case {case} {task}: {got} vs {want}"
                );
            }
        }
    }
}

/// PearsonAccum (Chan et al. co-moment combine) under arbitrary sample
/// splits and merge orders, including empty parts and singleton parts.
#[test]
fn pearson_accum_merge_any_split_any_order_matches_serial() {
    let mut rng = Rng::new(0xC0FF);
    for _ in 0..CASES {
        let n = 2 + rng.below(60);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0 - 5.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 0.7 * x + (x * 2.0).sin()).collect();
        let mut serial = PearsonAccum::default();
        for (&x, &y) in xs.iter().zip(&ys) {
            serial.push(x, y);
        }
        let (assign, order) = random_split(&mut rng, n);
        let mut parts: Vec<PearsonAccum> =
            (0..order.len()).map(|_| PearsonAccum::default()).collect();
        for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
            parts[assign[i]].push(x, y);
        }
        let mut merged = PearsonAccum::default();
        for &s in &order {
            merged.merge(&parts[s]);
        }
        assert!(
            (merged.r() - serial.r()).abs() < 1e-12,
            "merged {} vs serial {}",
            merged.r(),
            serial.r()
        );
    }
}

/// The self-healing supervisor as a property: under arbitrary seeded
/// fault schedules ([`mpq::pool::FaultPlan::random`] — panics including
/// recurring ones that exhaust the restart budget, upload failures, slow
/// lanes; never stalls, so no deadline is needed), a supervised Phase-1
/// sweep either completes **byte-equal** to the serial oracle or fails
/// with the injected root cause in the error — and never hangs
/// (completing every seeded case *is* the liveness assertion).
#[test]
fn supervised_fleet_under_random_faults_matches_serial_or_reports_cause() {
    use mpq::coordinator::Pipeline;
    use mpq::pool::{EvalFleet, FaultPlan};

    let dir = std::env::temp_dir().join("mpq_prop_faults");
    std::fs::remove_dir_all(&dir).ok();
    mpq::sim::generate(&dir, &mpq::sim::SimSpec::default()).unwrap();
    let lat = Lattice::practical();
    let mut sp = Pipeline::open(&dir, "sim_mlp").unwrap();
    sp.calibrate(128, 0).unwrap();
    let serial = sp.sensitivity_sqnr(&lat).unwrap();

    for seed in 0..12u64 {
        let plan = FaultPlan::random(seed, 3);
        let fleet = EvalFleet::with_faults(&dir, 3, plan.clone()).unwrap();
        let mut p = Pipeline::open(&dir, "sim_mlp").unwrap();
        p.attach_fleet(&fleet).unwrap();
        p.calibrate(128, 0).unwrap();
        match p.sensitivity_sqnr(&lat) {
            Ok(sens) => {
                assert_eq!(sens.len(), serial.len(), "seed {seed} ({plan:?}): list length");
                for (a, b) in sens.iter().zip(&serial) {
                    assert_eq!(
                        (a.group, a.cand),
                        (b.group, b.cand),
                        "seed {seed} ({plan:?}): order diverged"
                    );
                    assert_eq!(
                        a.score.to_bits(),
                        b.score.to_bits(),
                        "seed {seed} ({plan:?}): supervised sweep diverged from serial"
                    );
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("injected fault"),
                    "seed {seed} ({plan:?}): failure must carry the injected \
                     root cause, got: {msg}"
                );
            }
        }
        let fs = fleet.failure_stats();
        if !fs.degraded_events.is_empty() {
            assert!(
                fs.faults_injected > 0 && !fs.last_deaths.is_empty(),
                "seed {seed}: degradation without recorded deaths: {fs:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Durable-store corruption properties: arbitrarily mutilated bytes must
// never panic, never decode into records/tensors that were not written —
// the worst allowed outcome is a clean error or a shorter valid prefix.
// ---------------------------------------------------------------------------

/// Random framed-journal image: header + `n` records with unique digests
/// and random payloads.  Returns the records and the encoded bytes.
fn random_journal_image(rng: &mut Rng, case: usize) -> (Vec<store::Record>, Vec<u8>) {
    let n = 1 + rng.below(6);
    let mut recs = Vec::new();
    let mut bytes = store::file_header().to_vec();
    for i in 0..n {
        let kind = [
            store::kind::PROBE,
            store::kind::SEARCH_EVAL,
            store::kind::ADAROUND,
            store::kind::BLOB,
        ][rng.below(4)];
        // unique per (case, i): a corrupted record must never be able to
        // masquerade as a different original one
        let digest = ((case as u64) << 32) | ((i as u64) << 16) | rng.below(1 << 16) as u64;
        let payload: Vec<u8> = (0..rng.below(40)).map(|_| rng.below(256) as u8).collect();
        bytes.extend_from_slice(&store::encode_record(kind, digest, &payload));
        recs.push(store::Record { kind, digest, payload });
    }
    (recs, bytes)
}

/// Truncation at EVERY byte offset: `decode_records` returns exactly the
/// records that fit whole — always a prefix of what was written.
#[test]
fn journal_decode_any_truncation_keeps_valid_prefix() {
    let mut rng = Rng::new(0x70);
    for case in 0..40 {
        let (recs, bytes) = random_journal_image(&mut rng, case);
        for cut in 0..=bytes.len() {
            let (got, end) = store::decode_records(&bytes[..cut]);
            assert!(end <= cut, "valid end past the truncation point");
            assert!(got.len() <= recs.len(), "truncation invented records");
            assert_eq!(got, recs[..got.len()], "cut={cut}: decoded a non-prefix");
        }
    }
}

/// A bit flip at EVERY post-header offset: the checksum ends the valid
/// prefix at (or before) the flipped frame — records are served verbatim
/// or not at all, never altered.
#[test]
fn journal_decode_any_bitflip_keeps_valid_prefix() {
    let mut rng = Rng::new(0x71);
    let hdr = store::file_header().len();
    for case in 0..25 {
        let (recs, bytes) = random_journal_image(&mut rng, case);
        for off in hdr..bytes.len() {
            let mut m = bytes.clone();
            m[off] ^= 1 << rng.below(8);
            let (got, _) = store::decode_records(&m);
            // frames wholly before the flip are untouched; the flipped one
            // fails its checksum (reserved bytes are the benign exception)
            for (i, r) in got.iter().enumerate() {
                assert_eq!(
                    (r.kind, r.digest, &r.payload),
                    (recs[i].kind, recs[i].digest, &recs[i].payload),
                    "off={off}: bit flip altered record {i} instead of dropping it"
                );
            }
        }
    }
}

/// `RunJournal::open(resume)` on arbitrarily mutilated files: never
/// panics, never fails the run — a bad header quarantines, a bad tail
/// truncates, and every replayed payload is byte-equal to what was
/// written.
#[test]
fn journal_open_survives_arbitrary_corruption() {
    use std::rc::Rc;
    let mut rng = Rng::new(0x72);
    let dir = std::env::temp_dir().join("mpq_prop_journal");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..60 {
        let (recs, bytes) = random_journal_image(&mut rng, case);
        let mut m = bytes.clone();
        match case % 3 {
            0 => m.truncate(rng.below(m.len() + 1)),
            1 => {
                let off = rng.below(m.len());
                m[off] ^= 1 << rng.below(8);
            }
            _ => {
                m.truncate(rng.below(m.len() + 1));
                if !m.is_empty() {
                    let off = rng.below(m.len());
                    m[off] ^= 1 << rng.below(8);
                }
            }
        }
        let p = dir.join(format!("j{case}.mpqj"));
        std::fs::write(&p, &m).unwrap();
        let stats = Rc::new(mpq::store::StoreStats::default());
        let j = mpq::store::RunJournal::open(&p, true, Rc::clone(&stats))
            .unwrap_or_else(|e| panic!("case {case}: corrupt journal failed the open: {e:#}"));
        assert!(
            stats.journal_replayed.get() as usize <= recs.len(),
            "case {case}: replayed more records than were written"
        );
        for r in &recs {
            if let Some(got) = j.lookup(r.kind, r.digest) {
                assert_eq!(got, r.payload, "case {case}: replayed payload altered");
            }
        }
        // the journal must be append-ready after recovery
        j.record(store::kind::PROBE, u64::MAX - case as u64, &[1, 2, 3]).unwrap();
    }
}

/// MPQT streams truncated at every offset: `decode_tensors` either errors
/// cleanly or returns an exact prefix of the encoded tensors — never a
/// panic, an unbounded allocation, or reshaped data.
#[test]
fn tensor_decode_any_truncation_errs_or_prefix() {
    let mut rng = Rng::new(0x73);
    for _ in 0..30 {
        let nt = 1 + rng.below(3);
        let ts: Vec<Tensor> = (0..nt)
            .map(|_| {
                let shape: Vec<usize> = (0..1 + rng.below(3)).map(|_| 1 + rng.below(5)).collect();
                let n: usize = shape.iter().product();
                Tensor::from_f32(&shape, (0..n).map(|_| rng.f64() as f32).collect()).unwrap()
            })
            .collect();
        let bytes = io::encode_tensors(&ts);
        for cut in 0..=bytes.len() {
            if let Ok(got) = io::decode_tensors(&bytes[..cut]) {
                assert_eq!(got, ts[..got.len()], "cut={cut}: decoded a non-prefix");
            }
        }
    }
}

/// MPQT bit flips at every offset never panic or over-allocate, and a
/// corrupted checksummed blob ([`mpq::store::read_blob`]) is always a
/// clean error or the original payload — never garbage.
#[test]
fn tensor_and_blob_decode_bitflips_never_panic() {
    let mut rng = Rng::new(0x74);
    let dir = std::env::temp_dir().join("mpq_prop_blob");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..30 {
        let shape = vec![2 + rng.below(4), 1 + rng.below(4)];
        let n: usize = shape.iter().product();
        let t = Tensor::from_f32(&shape, (0..n).map(|_| rng.f64() as f32).collect()).unwrap();
        let bytes = io::encode_tensors(std::slice::from_ref(&t));
        for off in 0..bytes.len() {
            let mut m = bytes.clone();
            m[off] ^= 1 << rng.below(8);
            // any outcome but a panic/OOM is in-contract for raw MPQT; the
            // journal/blob checksum layer is what detects payload flips
            let _ = io::decode_tensors(&m);
        }
        let payload = bytes;
        let p = dir.join(format!("b{case}.blob"));
        store::write_blob(&p, 0xD1CE + case as u64, &payload).unwrap();
        let stored = std::fs::read(&p).unwrap();
        let off = rng.below(stored.len());
        let mut m = stored;
        m[off] ^= 1 << rng.below(8);
        std::fs::write(&p, &m).unwrap();
        match store::read_blob(&p, 0xD1CE + case as u64) {
            Ok(Some(got)) => assert_eq!(got, payload, "case {case}: blob flip served garbage"),
            Ok(None) | Err(_) => {}
        }
    }
}

/// An adversarial byte stream for [`store::read_frame`]: serves at most
/// `frag` bytes per `read`, injects a spurious `ErrorKind::Interrupted`
/// every `interrupt_nth`-th call, and ends at `data.len()`.  A call
/// budget proportional to the stream length turns any retry spin into a
/// loud failure instead of a hung test.
struct FragReader<'a> {
    data: &'a [u8],
    pos: usize,
    frag: usize,
    interrupt_nth: usize,
    calls: usize,
    max_calls: usize,
}

impl<'a> FragReader<'a> {
    fn new(data: &'a [u8], frag: usize, interrupt_nth: usize) -> Self {
        // worst case: one byte per successful call, one interrupt each
        let max_calls = 4 * (data.len() + 8);
        Self { data, pos: 0, frag, interrupt_nth, calls: 0, max_calls }
    }
}

impl std::io::Read for FragReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.calls += 1;
        assert!(
            self.calls <= self.max_calls,
            "read_frame is spinning: {} calls on a {}-byte stream",
            self.calls,
            self.data.len()
        );
        if self.interrupt_nth > 0 && self.calls % self.interrupt_nth == 0 {
            return Err(std::io::Error::from(std::io::ErrorKind::Interrupted));
        }
        let n = buf.len().min(self.frag).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// [`store::read_frame`] over adversarially fragmented streams — 1-byte
/// reads, injected `Interrupted` errors, EOF cuts at every offset (mid
/// header and mid payload included): it yields exactly the records a
/// whole-buffer decode yields, never panics, and never spins.  This is
/// the socket-facing contract the serve protocol and the process-lane
/// transport both build on: a Unix stream hands back arbitrary fragments,
/// and a signal-interrupted `read(2)` surfaces as `Interrupted`.
#[test]
fn frame_reads_over_fragmented_streams_match_whole_buffer_decode() {
    let mut rng = Rng::new(0x75);
    let hdr = store::file_header().len();
    for case in 0..25 {
        let (recs, bytes) = random_journal_image(&mut rng, case);
        let body = &bytes[hdr..]; // read_frame consumes bare frames
        // cumulative frame boundaries: boundary[i] = end of record i
        let mut boundary = vec![0usize];
        for r in &recs {
            let len = store::encode_record(r.kind, r.digest, &r.payload).len();
            boundary.push(boundary.last().unwrap() + len);
        }

        // full stream, every fragmentation × interruption pattern: the
        // complete record sequence, terminated by a clean Ok(None)
        for frag in [1usize, 2, 7, usize::MAX] {
            for interrupt_nth in [0usize, 2, 5] {
                let mut r = FragReader::new(body, frag, interrupt_nth);
                let mut got = Vec::new();
                while let Some(rec) = store::read_frame(&mut r, 1 << 20)
                    .unwrap_or_else(|e| panic!("case {case} frag={frag}: {e:#}"))
                {
                    got.push(rec);
                }
                assert_eq!(got, recs, "case {case} frag={frag} int={interrupt_nth}");
            }
        }

        // EOF at EVERY offset, worst-case 1-byte fragments: whole frames
        // before the cut are served verbatim; a boundary cut ends with a
        // clean Ok(None); a mid-frame cut is an error — never a panic,
        // never an invented or altered record
        for cut in 0..=body.len() {
            let mut r = FragReader::new(&body[..cut], 1, 3);
            let mut got = Vec::new();
            let tail = loop {
                match store::read_frame(&mut r, 1 << 20) {
                    Ok(Some(rec)) => got.push(rec),
                    Ok(None) => break Ok(()),
                    Err(e) => break Err(e),
                }
            };
            let whole = boundary.iter().filter(|&&b| b <= cut).count() - 1;
            assert_eq!(got.len(), whole, "case {case} cut={cut}: wrong record count");
            assert_eq!(got, recs[..whole], "case {case} cut={cut}: non-prefix");
            if boundary.contains(&cut) {
                assert!(tail.is_ok(), "case {case} cut={cut}: boundary EOF must be clean");
            } else {
                assert!(tail.is_err(), "case {case} cut={cut}: mid-frame EOF must error");
            }
        }
    }
}

/// The write-side counterpart of [`FragReader`]: accepts at most `cap`
/// bytes (in small fragments, so `write_all` must loop), then fails with
/// `ConnectionReset` — a torn write.  Optionally injects one spurious
/// error on the first call: `Interrupted` must be retried transparently
/// by `write_all`; `WouldBlock` is a hard error on a blocking socket.
struct TornWriter {
    buf: Vec<u8>,
    cap: usize,
    inject: Option<std::io::ErrorKind>,
}

impl TornWriter {
    fn new(cap: usize, inject: Option<std::io::ErrorKind>) -> Self {
        Self { buf: Vec::new(), cap, inject }
    }
}

impl std::io::Write for TornWriter {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        if let Some(k) = self.inject.take() {
            return Err(std::io::Error::from(k));
        }
        let room = self.cap - self.buf.len();
        if room == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "torn write: peer vanished mid-frame",
            ));
        }
        let n = data.len().min(room).min(3);
        self.buf.extend_from_slice(&data[..n]);
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Torn `write_frame` at EVERY byte offset: whatever prefix of the frame
/// reaches the wire, a reader sees either the exact records that were
/// fully written or a clean typed rejection (mid-frame EOF / checksum) —
/// never a wrong or invented record.  This is the crash-consistency
/// contract both socket planes (`pool/transport.rs`, `serve/proto.rs`)
/// and the injected `wsplit@`/`wreset@` wire faults lean on.
#[test]
fn torn_frame_writes_leave_exact_prefix_or_clean_rejection() {
    let mut rng = Rng::new(0x76);
    for case in 0..25 {
        let kind = store::kind::PROBE;
        let digest = 0xBEEF_0000 + case as u64;
        let payload: Vec<u8> = (0..rng.below(50)).map(|_| rng.below(256) as u8).collect();
        let frame = store::encode_record(kind, digest, &payload);
        // a complete frame already on the stream: torn writes after it
        // must never disturb what was previously committed
        let prior = store::encode_record(store::kind::BLOB, 0xA11CE, &[9, 9, 9]);

        for cap in 0..=frame.len() {
            let mut w = TornWriter::new(cap, None);
            let wrote = store::write_frame(&mut w, kind, digest, &payload);
            if cap >= frame.len() {
                assert!(wrote.is_ok(), "case {case}: full-capacity write failed");
                assert_eq!(w.buf, frame, "case {case}: bytes on the wire differ");
            } else {
                assert!(wrote.is_err(), "case {case} cap={cap}: torn write not reported");
                assert_eq!(w.buf, frame[..w.buf.len()], "case {case}: non-prefix on wire");
            }

            let mut stream = prior.clone();
            stream.extend_from_slice(&w.buf);
            let mut r = stream.as_slice();
            let first = store::read_frame(&mut r, 1 << 20)
                .unwrap_or_else(|e| panic!("case {case} cap={cap}: prior frame lost: {e:#}"))
                .expect("prior frame vanished");
            assert_eq!(
                (first.kind, first.digest, first.payload.as_slice()),
                (store::kind::BLOB, 0xA11CE, &[9u8, 9, 9][..]),
                "case {case} cap={cap}: torn write altered a committed frame"
            );
            match store::read_frame(&mut r, 1 << 20) {
                Ok(Some(rec)) => {
                    assert_eq!(cap, frame.len(), "case {case}: partial frame decoded");
                    assert_eq!(
                        (rec.kind, rec.digest, rec.payload),
                        (kind, digest, payload.clone()),
                        "case {case}: decoded record differs from what was written"
                    );
                }
                Ok(None) => assert_eq!(
                    w.buf.len(),
                    0,
                    "case {case} cap={cap}: mid-frame bytes read as a clean boundary"
                ),
                Err(_) => assert!(
                    !w.buf.is_empty() && w.buf.len() < frame.len(),
                    "case {case} cap={cap}: clean stream rejected"
                ),
            }
        }

        // a spurious Interrupted is retried to a complete frame; a
        // WouldBlock is a hard error with nothing (or a prefix) on the
        // wire — both end in the same prefix-or-rejection contract
        let mut w = TornWriter::new(frame.len(), Some(std::io::ErrorKind::Interrupted));
        store::write_frame(&mut w, kind, digest, &payload)
            .unwrap_or_else(|e| panic!("case {case}: Interrupted not retried: {e:#}"));
        assert_eq!(w.buf, frame, "case {case}: post-Interrupted frame differs");

        let mut w = TornWriter::new(frame.len(), Some(std::io::ErrorKind::WouldBlock));
        assert!(
            store::write_frame(&mut w, kind, digest, &payload).is_err(),
            "case {case}: WouldBlock swallowed"
        );
        assert_eq!(w.buf, frame[..w.buf.len()], "case {case}: WouldBlock left non-prefix");
    }
}

/// The randomized wire-chaos schedule (`wseed:SEED`) is a pure function
/// of `(seed, lane)`: re-materializing any lane's schedule — from the
/// same plan, a re-parsed plan, or a plan "sized" for a different fleet —
/// always yields identical clauses, so a CI seed echoed into a log is
/// enough to reproduce a failure at any worker count.  Different seeds
/// must actually differ, every derived clause is a gentle one-shot wire
/// fault, and `wseed` implies a collect watchdog (dropped frames would
/// otherwise hang the sweep forever).
#[test]
fn wire_seed_schedule_is_deterministic_and_lane_count_independent() {
    use mpq::pool::FaultPlan;
    let mut rng = Rng::new(0x77);
    let mut schedules = std::collections::HashSet::new();
    for _ in 0..CASES {
        let seed = rng.below(1 << 30) as u64;
        let plan = FaultPlan::parse(&format!("wseed:{seed}")).unwrap();
        assert_eq!(plan.wire_seed, Some(seed));
        assert_eq!(
            plan.deadline_ms,
            Some(2000),
            "wseed must imply a collect watchdog or dropped frames hang"
        );
        let reparsed = FaultPlan::parse(&format!("wseed:{seed},deadline:750")).unwrap();
        assert_eq!(reparsed.deadline_ms, Some(750), "explicit deadline overridden");
        let mut key = format!("{seed}:");
        for lane in 0..6 {
            let a = plan.wire_faults_for_lane(lane);
            let b = reparsed.wire_faults_for_lane(lane);
            assert_eq!(a, b, "seed {seed} lane {lane}: schedule not reproducible");
            assert!(a.len() <= 1, "seed {seed} lane {lane}: more than one derived fault");
            for f in &a {
                assert!(f.kind.is_wire(), "seed {seed}: derived a non-wire fault");
                assert!(!f.recurring, "seed {seed}: derived fault must be one-shot");
                assert_eq!(f.lane, lane);
            }
            key.push_str(&format!("{a:?};"));
        }
        schedules.insert(key);
    }
    // seeds genuinely steer the schedule (collisions allowed, but 200
    // seeds collapsing to a handful of schedules means the seed is dead)
    assert!(
        schedules.len() > CASES / 2,
        "only {} distinct schedules from {CASES} seeds",
        schedules.len()
    );
}

#[test]
fn candidate_labels_parse_back() {
    for w in [4u8, 6, 8] {
        for a in [4u8, 6, 8, 16] {
            let c = Candidate::new(w, a);
            assert_eq!(c.label(), format!("W{w}A{a}"));
        }
    }
}
